"""Fused one-pass sweep kernel: interpret-mode Pallas and the fused-jnp
oracle against the UNFUSED composition (scatter-add CountSketch + z emission
+ dense-argmax directional extremes + moment sums) the engines ran before
fusion — ragged tails, argmax tie-breaking, zero-weight padding rows,
proj_size on/off — plus the streams-each-row-once counting guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scoring import sketch_plan
from repro.kernels.extremes.ref import directional_extremes_ref
from repro.kernels.sweep.ops import fused_sweep_update
from repro.kernels.sweep.ref import blocked_extremes_ref, fused_sweep_ref


def _unfused(SX, X, P, sw, rows, signs, dirs=None, omega=None, mask=None,
             moments=None, want_z=True):
    """The pre-fusion per-chunk math, one dispatch per accumulator."""
    Xw = X * sw[:, None]
    SX = SX.at[rows].add((signs[:, None] * Xw).astype(SX.dtype))
    out_moments = None
    if moments is not None:
        out_moments = (moments[0] + jnp.sum(P, axis=0), moments[1] + P.T @ P)
    z = (Xw if omega is None else Xw @ omega) if want_z else None
    ext = None
    if dirs is not None:
        pm = mask
        if pm is not None and pm.shape[0] != P.shape[0]:
            pm = jnp.repeat(pm, P.shape[0] // pm.shape[0])
        ext = directional_extremes_ref(P, dirs, None if pm is None else pm > 0)
    return SX, z, ext, out_moments


def _case(n, D, d, r, m, sk, seed=0, q=None):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)
    P = jnp.asarray(rng.standard_normal((n * r, d)), jnp.float32)
    dirs = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    sw = jnp.asarray(rng.random(n) + 0.5, jnp.float32)
    omega = (
        None if q is None
        else jnp.asarray(rng.standard_normal((D, q)), jnp.float32)
    )
    rows, signs = sketch_plan(jax.random.PRNGKey(seed), n, sk)
    SX = jnp.zeros((sk, D), jnp.float32)
    return SX, X, P, sw, rows, signs, dirs, omega


def _check(got, ref, rtol=1e-6, atol=1e-6):
    SXg, zg, extg, mog = got
    SXr, zr, extr, mor = ref
    np.testing.assert_allclose(np.asarray(SXg), np.asarray(SXr),
                               rtol=rtol, atol=atol)
    assert (zg is None) == (zr is None)
    if zg is not None:
        np.testing.assert_allclose(np.asarray(zg), np.asarray(zr),
                                   rtol=rtol, atol=atol)
    assert (extg is None) == (extr is None)
    if extg is not None:
        vmax, imax, vmin, imin = extg
        rvmax, rimax, rvmin, rimin = extr
        # indices are EXACT — first-occurrence tie-breaking must survive the
        # two-level / running-block reduction restructure
        np.testing.assert_array_equal(np.asarray(imax), np.asarray(rimax))
        np.testing.assert_array_equal(np.asarray(imin), np.asarray(rimin))
        np.testing.assert_allclose(np.asarray(vmax), np.asarray(rvmax),
                                   rtol=rtol, atol=atol)
        np.testing.assert_allclose(np.asarray(vmin), np.asarray(rvmin),
                                   rtol=rtol, atol=atol)
    assert (mog is None) == (mor is None)
    if mog is not None:
        np.testing.assert_allclose(np.asarray(mog[0]), np.asarray(mor[0]),
                                   rtol=rtol, atol=1e-4)
        np.testing.assert_allclose(np.asarray(mog[1]), np.asarray(mor[1]),
                                   rtol=rtol, atol=1e-4)


# ragged tails on purpose: n not a multiple of any block size, r ∈ {1, 2},
# proj_size (omega) on/off
@pytest.mark.parametrize(
    "n,D,d,r,m,sk,q",
    [
        (257, 12, 6, 2, 16, 64, None),
        (1030, 14, 7, 2, 24, 96, None),
        (7, 10, 5, 1, 8, 32, None),
        (513, 14, 7, 2, 16, 64, 4),
        (640, 16, 8, 1, 130, 128, 8),
    ],
)
def test_fused_oracle_matches_unfused(n, D, d, r, m, sk, q):
    SX, X, P, sw, rows, signs, dirs, omega = _case(n, D, d, r, m, sk, n, q)
    moments = (jnp.zeros((d,), jnp.float32), jnp.zeros((d, d), jnp.float32))
    got = fused_sweep_ref(SX, X, P, sw, rows, signs, dirs=dirs, omega=omega,
                          moments=moments, tile=128)
    ref = _unfused(SX, X, P, sw, rows, signs, dirs=dirs, omega=omega,
                   moments=moments)
    _check(got, ref)


@pytest.mark.parametrize(
    "n,D,d,r,m,sk,q",
    [
        (257, 12, 6, 2, 16, 64, None),
        (1030, 14, 7, 2, 24, 96, None),
        (513, 14, 7, 2, 16, 64, 4),
    ],
)
def test_fused_interpret_matches_unfused(n, D, d, r, m, sk, q):
    """The Pallas kernel itself (interpret=True on CPU) against the unfused
    composition — the acceptance bar is ≤1e-6."""
    SX, X, P, sw, rows, signs, dirs, omega = _case(n, D, d, r, m, sk, n, q)
    got = fused_sweep_update(SX, X, P, sw, rows, signs, dirs=dirs,
                             omega=omega, block_rows=128, interpret=True)
    ref = _unfused(SX, X, P, sw, rows, signs, dirs=dirs, omega=omega)
    _check(got, ref)


def test_fused_interpret_moments_want_z_off():
    """TwoPassSketched's pass-1 configuration: moments on, nothing retained."""
    SX, X, P, sw, rows, signs, dirs, _ = _case(300, 12, 6, 2, 16, 64, 3)
    moments = (jnp.zeros((6,), jnp.float32), jnp.zeros((6, 6), jnp.float32))
    got = fused_sweep_update(SX, X, P, sw, rows, signs, moments=moments,
                             want_z=False, block_rows=64, interpret=True)
    ref = _unfused(SX, X, P, sw, rows, signs, moments=moments, want_z=False)
    _check(got, ref)


def test_fused_extremes_tie_breaking():
    """Duplicate P blocks straddling tile and Pallas block boundaries: both
    the two-level oracle reduction and the kernel's running fold must break
    ties to the FIRST occurrence, exactly like the dense argmax."""
    rng = np.random.default_rng(0)
    n, D, d, r, sk = 384, 12, 6, 2, 64
    P_np = rng.standard_normal((n * r, d)).astype(np.float32)
    P_np[256:512] = P_np[:256]  # duplicates across the 128-row tiles
    X = jnp.asarray(rng.standard_normal((n, D)), jnp.float32)
    P = jnp.asarray(P_np)
    dirs = jnp.asarray(rng.standard_normal((24, d)), jnp.float32)
    sw = jnp.asarray(rng.random(n) + 0.5, jnp.float32)
    rows, signs = sketch_plan(jax.random.PRNGKey(0), n, sk)
    SX = jnp.zeros((sk, D), jnp.float32)

    dense = directional_extremes_ref(P, dirs)
    for ext in (
        blocked_extremes_ref(P, dirs, tile=128),
        fused_sweep_ref(SX, X, P, sw, rows, signs, dirs=dirs, tile=128)[2],
        fused_sweep_update(SX, X, P, sw, rows, signs, dirs=dirs,
                           block_rows=64, interpret=True)[2],
    ):
        np.testing.assert_array_equal(np.asarray(ext[1]), np.asarray(dense[1]))
        np.testing.assert_array_equal(np.asarray(ext[3]), np.asarray(dense[3]))
        # every winner resolved into the first copy of the duplicated block
        assert not np.any((np.asarray(ext[1]) >= 256) & (np.asarray(ext[1]) < 512))


def test_fused_zero_weight_padding_rows():
    """The engines' shard-padding pattern: trailing rows carry sw = 0 and a
    prefix-ones mask. Padding garbage (huge values!) must not leak into the
    sketch, z, or the extremes — the outputs equal the trimmed computation."""
    rng = np.random.default_rng(1)
    n, nv, D, d, r, m, sk = 320, 277, 12, 6, 2, 16, 64
    X_np = rng.standard_normal((n, D)).astype(np.float32)
    P_np = rng.standard_normal((n * r, d)).astype(np.float32)
    X_np[nv:] = 1e9  # garbage beyond the valid prefix
    P_np[nv * r:] = 1e9
    sw_np = (rng.random(n) + 0.5).astype(np.float32)
    sw_np[nv:] = 0.0
    mask = jnp.arange(n) < nv
    dirs = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    rows, signs = sketch_plan(jax.random.PRNGKey(1), n, sk)
    SX = jnp.zeros((sk, D), jnp.float32)

    trimmed = _unfused(
        SX, jnp.asarray(X_np[:nv]), jnp.asarray(P_np[: nv * r]),
        jnp.asarray(sw_np[:nv]), rows[:nv], signs[:nv], dirs=dirs,
    )
    for got in (
        fused_sweep_ref(SX, jnp.asarray(X_np), jnp.asarray(P_np),
                        jnp.asarray(sw_np), rows, signs, dirs=dirs,
                        mask=mask, tile=128),
        fused_sweep_update(SX, jnp.asarray(X_np), jnp.asarray(P_np),
                           jnp.asarray(sw_np), rows, signs, dirs=dirs,
                           mask=mask, block_rows=64, interpret=True),
    ):
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(trimmed[0]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(got[1][:nv]),
                                   np.asarray(trimmed[1]), rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(got[2][1]),
                                      np.asarray(trimmed[2][1]))
        np.testing.assert_array_equal(np.asarray(got[2][3]),
                                      np.asarray(trimmed[2][3]))
        assert int(np.max(got[2][1])) < nv * r
        assert int(np.max(got[2][3])) < nv * r


def test_fused_path_streams_each_row_exactly_once():
    """The fused one-pass sweep (hull directions + sketch in one dispatch)
    must still be ONE data pass: every row featurized exactly once."""
    from repro.core.scoring import ScoringEngine

    calls = []
    rng = np.random.default_rng(0)
    F = rng.standard_normal((700, 10)).astype(np.float32)

    def featurize(Yc):
        calls.append(int(Yc.shape[0]))
        Fc = jnp.asarray(Yc, jnp.float32)
        return Fc, Fc

    engine = ScoringEngine(featurize=featurize, chunk_size=128, rows_per_point=1)
    res = engine.score(
        F, method="l2-hull", hull_k=4, hull_key=jax.random.PRNGKey(1),
        sketch_size=256, key=jax.random.PRNGKey(0),
    )
    assert np.isfinite(np.asarray(res.scores)).all()
    assert sum(calls) == 700, "fused one-pass must stream each row exactly once"
    assert len(calls) == -(-700 // 128)


def test_sweep_backend_dispatch():
    SX, X, P, sw, rows, signs, dirs, _ = _case(64, 8, 4, 1, 8, 32)
    with pytest.raises(ValueError):
        fused_sweep_update(SX, X, P, sw, rows, signs, backend="nope")
    # the Pallas kernel is f32-only — a widened accumulator (f64 under x64;
    # bf16 stands in here, x64 is off in this process) is an oracle feature
    with pytest.raises(ValueError, match="f32-only"):
        fused_sweep_update(SX.astype(jnp.bfloat16), X, P, sw, rows, signs,
                           backend="pallas")
