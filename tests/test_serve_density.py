"""DensityServeEngine: coalescing correctness, executable-cache stability,
hot-swap atomicity (ISSUE 9 acceptance tests)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.serve.density import (
    DensityServeEngine,
    bucket_for,
    bucket_sizes,
)

CFG = M.MCTMConfig(J=2, degree=5)


@pytest.fixture(scope="module")
def fitted():
    key = jax.random.PRNGKey(0)
    Y = np.array(jax.random.normal(key, (400, CFG.J)), np.float32)
    Y[:, 1] = 0.5 * Y[:, 0] + 0.8 * Y[:, 1]  # correlated dims
    scaler = DataScaler.fit(Y)
    params = M.init_params(key, CFG)
    return params, scaler, Y


def test_bucket_policy():
    assert bucket_sizes(8, 256) == (8, 16, 32, 64, 128, 256)
    assert bucket_sizes(8, 100) == (8, 16, 32, 64, 100)
    assert bucket_sizes(1, 1) == (1,)
    sizes = bucket_sizes(8, 256)
    assert bucket_for(1, sizes) == 8
    assert bucket_for(8, sizes) == 8
    assert bucket_for(9, sizes) == 16
    assert bucket_for(256, sizes) == 256


def test_coalesced_log_density_matches_per_request(fitted):
    params, scaler, Y = fitted
    # ragged: 37 queries through max_batch=32 → one full bucket + a 5-row
    # tail padded up to the 8-bucket (zero-padded slots exercised)
    eng = DensityServeEngine(CFG, params, scaler, max_batch=32, min_bucket=8)
    reqs = eng.submit_log_density(Y[:37])
    eng.run_until_drained()
    got = np.array([r.result for r in reqs])

    ref = np.asarray(M.log_density(CFG, params, scaler, jnp.asarray(Y[:37])))
    np.testing.assert_allclose(got, ref, atol=1e-6, rtol=1e-6)

    # per-request serving (bucket 1) agrees with the coalesced answers
    one = DensityServeEngine(CFG, params, scaler, max_batch=1, min_bucket=1)
    r1 = one.submit_log_density(Y[:5])
    one.run_until_drained()
    np.testing.assert_allclose(
        np.array([r.result for r in r1]), got[:5], atol=1e-6, rtol=1e-6
    )


def test_coalesced_sample_matches_per_request_exactly(fitted):
    params, scaler, Y = fitted
    key = jax.random.PRNGKey(3)
    seeds = [11, 7, 23, 5, 42, 8, 19]  # ragged count → padded bucket
    big = DensityServeEngine(CFG, params, scaler, max_batch=64, min_bucket=8,
                             sample_key=key)
    rb = big.submit_sample(len(seeds), seeds=seeds, y_obs=Y[0], n_obs=1)
    big.run_until_drained()
    batched = np.stack([r.result for r in rb])

    one = DensityServeEngine(CFG, params, scaler, max_batch=1, min_bucket=1,
                             sample_key=key)
    for i, s in enumerate(seeds):
        r = one.submit_sample(1, seeds=[s], y_obs=Y[0], n_obs=1)
        one.run_until_drained()
        # per-request randomness is fold_in(base_key, seed): EXACT agreement
        # regardless of bucket composition
        np.testing.assert_array_equal(r[0].result, batched[i])


def test_conditional_sample_contract(fitted):
    params, scaler, Y = fitted
    eng = DensityServeEngine(CFG, params, scaler, max_batch=16, min_bucket=4)
    # fully observed → the row comes back unchanged (the padding convention)
    r = eng.submit_sample(3, y_obs=Y[:3], n_obs=CFG.J, seeds=[1, 2, 3])
    eng.run_until_drained()
    np.testing.assert_allclose(np.stack([q.result for q in r]), Y[:3], atol=1e-6)
    # observed prefix is pinned, sampled dims vary with the seed
    r = eng.submit_sample(4, y_obs=Y[0], n_obs=1, seeds=[1, 2, 3, 4])
    eng.run_until_drained()
    out = np.stack([q.result for q in r])
    np.testing.assert_allclose(out[:, 0], Y[0, 0], atol=1e-6)
    assert len(np.unique(out[:, 1])) == 4
    # unconditional draws land inside the scaler's support
    r = eng.submit_sample(16, seeds=list(range(16)))
    eng.run_until_drained()
    out = np.stack([q.result for q in r])
    assert np.all(out >= scaler.low - 1e-5) and np.all(out <= scaler.high + 1e-5)


def test_steady_state_zero_recompiles(fitted):
    params, scaler, Y = fitted
    eng = DensityServeEngine(CFG, params, scaler, max_batch=32, min_bucket=8)
    warmed = eng.warmup()
    assert warmed == eng.compile_count == 2 * len(eng.buckets)
    # mixed ragged traffic over every bucket size, plus a hot swap: the
    # executable cache must absorb all of it without a single retrace
    rng = np.random.default_rng(0)
    for burst in (1, 5, 8, 9, 17, 32, 3):
        eng.submit_log_density(Y[rng.integers(0, len(Y), burst)])
        eng.submit_sample(burst, seeds=rng.integers(0, 1 << 30, burst).tolist())
        eng.step()
    eng.publish(M.init_params(jax.random.PRNGKey(9), CFG))
    eng.submit_log_density(Y[:10])
    eng.run_until_drained()
    assert eng.compile_count == warmed
    assert eng.stats()["compile_count"] == warmed


def test_hot_swap_atomicity_in_flight(fitted):
    """Queries in flight across publishes see exactly old-or-new params —
    every answer matches its recorded version's reference, never a blend."""
    params0, scaler, Y = fitted
    # strongly separated models: each version shifts the marginal transform
    # and the copula coupling, so the served answers identify their version
    all_params = [params0] + [
        M.MCTMParams(
            theta_raw=params0.theta_raw + 0.5 * v,
            lam=params0.lam + 0.4 * v,
        )
        for v in range(1, 4)
    ]
    eng = DensityServeEngine(CFG, params0, scaler, max_batch=16, min_bucket=4)
    eng.warmup()
    refs = [
        np.asarray(M.log_density(CFG, p, scaler, jnp.asarray(Y[:200])))
        for p in all_params
    ]

    stop = threading.Event()

    def publisher():
        v = 1
        while not stop.is_set() and v < len(all_params):
            eng.publish(all_params[v])
            v += 1

    reqs = []
    th = threading.Thread(target=publisher)
    th.start()
    i = 0
    while i < 200:
        burst = min(7, 200 - i)
        reqs += eng.submit_log_density(Y[i:i + burst])
        i += burst
        eng.step()
    eng.run_until_drained()
    stop.set()
    th.join()

    assert all(r.done for r in reqs), "no dropped queries across publishes"
    versions = {r.version for r in reqs}
    assert versions <= set(range(len(all_params))) and len(versions) >= 2
    # versions must be distinguishable on average for the check to bite
    for v in range(1, len(all_params)):
        assert np.abs(refs[v] - refs[0]).mean() > 1e-2
    for j, r in enumerate(reqs):
        dists = [abs(r.result - refs[v][j]) for v in range(len(all_params))]
        assert dists[r.version] < 1e-5, (
            f"query {j} does not match its recorded version {r.version}"
        )
        assert int(np.argmin(dists)) == r.version, (
            f"query {j} answered by params of a different version than recorded"
        )


def test_tick_serves_single_version(fitted):
    """All queries coalesced into one tick share one model version even when
    a publish lands mid-queue."""
    params0, scaler, Y = fitted
    eng = DensityServeEngine(CFG, params0, scaler, max_batch=64, min_bucket=8)
    eng.warmup()
    reqs = eng.submit_log_density(Y[:30])
    eng.publish(M.init_params(jax.random.PRNGKey(5), CFG))
    reqs += eng.submit_log_density(Y[30:60])
    eng.step()  # ONE tick: the staged slot swaps in at tick start
    assert all(r.done for r in reqs)
    assert len({r.version for r in reqs}) == 1


def test_publish_from_background_thread_never_blocks_serving(fitted):
    params0, scaler, Y = fitted
    eng = DensityServeEngine(CFG, params0, scaler, max_batch=16, min_bucket=4)
    eng.warmup()
    done = threading.Event()

    def worker():
        for v in range(3):
            eng.publish(M.init_params(jax.random.PRNGKey(v), CFG))
        done.set()

    th = threading.Thread(target=worker)
    th.start()
    for i in range(50):
        eng.submit_log_density(Y[i % len(Y)][None])
        eng.step()
    th.join(timeout=10)
    assert done.is_set()
    eng.run_until_drained()
    assert eng.version == 3
    stalls = [e["visible_s"] - e["published_s"]
              for e in eng.swap_events if e["visible_s"] is not None]
    assert stalls and all(s < 5.0 for s in stalls)
