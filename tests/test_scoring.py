"""Chunked ScoringEngine ≡ dense oracles (leverage, hull, variants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.hull import epsilon_kernel_indices
from repro.core.leverage import (
    flatten_features,
    leverage_scores_gram,
    ridge_leverage_scores,
    root_leverage_scores,
    sketched_leverage,
)
from repro.core.scoring import ScoringEngine, score_chunks

# chunk sizes chosen so n=503 exercises: dense fast path, even chunks with a
# ragged tail, chunk == n, and tiny many-chunk streaming
CHUNKS = [0, 503, 128, 100, 7]


def _setup(n=503, J=2, degree=5, seed=0, uniform=True):
    rng = np.random.default_rng(seed)
    Y = rng.random((n, J)) if uniform else rng.standard_normal((n, J))
    cfg = M.MCTMConfig(J=J, degree=degree)
    scaler = DataScaler.fit(Y)
    return cfg, scaler, Y


@pytest.mark.parametrize("chunk", CHUNKS)
def test_leverage_matches_dense_oracle(chunk):
    cfg, scaler, Y = _setup()
    A, _ = M.basis_features(cfg, scaler, jnp.asarray(Y))
    u_ref = np.asarray(leverage_scores_gram(flatten_features(A)))
    res = ScoringEngine(cfg, scaler, chunk_size=chunk).score(
        jnp.asarray(Y), method="l2-only"
    )
    assert res.n_chunks == (1 if chunk in (0, 503) else -(-503 // chunk))
    # uniform data → well-conditioned Gram → tight f32 agreement
    np.testing.assert_allclose(res.leverage, u_ref, atol=1e-5)
    np.testing.assert_allclose(res.scores, u_ref + 1.0 / 503, atol=1e-5)


@pytest.mark.parametrize("chunk", CHUNKS[1:])
def test_chunked_matches_dense_engine_gaussian(chunk):
    """Gaussian data (ill-conditioned tails): chunking must still not move
    scores beyond f32 Gram-accumulation noise."""
    cfg, scaler, Y = _setup(uniform=False)
    dense = ScoringEngine(cfg, scaler, chunk_size=0).score(
        jnp.asarray(Y), method="l2-only"
    )
    res = ScoringEngine(cfg, scaler, chunk_size=chunk).score(
        jnp.asarray(Y), method="l2-only"
    )
    np.testing.assert_allclose(res.leverage, dense.leverage, atol=1e-3)


@pytest.mark.parametrize("chunk", CHUNKS)
def test_hull_candidates_cover_dense_epsilon_kernel(chunk):
    """Engine hull candidates ⊇ the dense ε-kernel (shared direction net)."""
    cfg, scaler, Y = _setup(seed=1)
    k = 20
    key = jax.random.PRNGKey(3)
    engine = ScoringEngine(cfg, scaler, chunk_size=chunk)
    # oversample the engine's candidate budget so the dense first-k prefix of
    # the same candidate stream must be contained in it
    res = engine.score(jnp.asarray(Y), method="l2-hull", hull_k=2 * k, hull_key=key)
    _, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y))
    P = np.asarray(Ap).reshape(-1, cfg.d)
    dirs = engine._directions(
        key,
        P.sum(axis=0),
        P.T.astype(np.float64) @ P.astype(np.float64),
        P.shape[0],
        2 * k,
    )
    dense = epsilon_kernel_indices(P, k, key, dirs=dirs)
    assert set(dense.tolist()) <= set(res.hull_rows.tolist())
    # and the derived unique point set covers the dense selection's points
    assert set((dense // cfg.J).tolist()) <= set(res.hull_points.tolist())


def test_hull_exact_match_with_engine_directions():
    """Same k, same net → identical candidate selection at small n, up to the
    consumed budget. (The untruncated candidate tails may differ: a 1-ulp
    score difference between block layouts can flip a near-tied argmax for a
    late direction, which is invisible to any consumer of the first k.)"""
    from repro.core.coreset import exact_hull_points

    cfg, scaler, Y = _setup(seed=2)
    key = jax.random.PRNGKey(5)
    dense = ScoringEngine(cfg, scaler, chunk_size=0).score(
        jnp.asarray(Y), method="l2-hull", hull_k=16, hull_key=key
    )
    chunked = ScoringEngine(cfg, scaler, chunk_size=64).score(
        jnp.asarray(Y), method="l2-hull", hull_k=16, hull_key=key
    )
    np.testing.assert_array_equal(dense.hull_rows[:16], chunked.hull_rows[:16])
    np.testing.assert_array_equal(
        exact_hull_points(dense, dense.scores, 16),
        exact_hull_points(chunked, chunked.scores, 16),
    )


@pytest.mark.parametrize("chunk", [0, 100])
def test_ridge_root_sketch_variants(chunk):
    cfg, scaler, Y = _setup(seed=3)
    A, _ = M.basis_features(cfg, scaler, jnp.asarray(Y))
    X = flatten_features(A)
    engine = ScoringEngine(cfg, scaler, chunk_size=chunk)

    ridge = engine.score(jnp.asarray(Y), method="ridge-lss", ridge_reg=1.0)
    np.testing.assert_allclose(
        ridge.leverage, np.asarray(ridge_leverage_scores(X, 1.0)), atol=1e-5
    )

    root = engine.score(jnp.asarray(Y), method="root-l2")
    np.testing.assert_allclose(
        root.leverage, np.asarray(root_leverage_scores(X)), atol=1e-4
    )

    key = jax.random.PRNGKey(11)
    sk = engine.score(jnp.asarray(Y), method="l2-only", key=key, sketch_size=256)
    np.testing.assert_allclose(
        sk.leverage, np.asarray(sketched_leverage(X, key, 256)), atol=1e-4
    )


@pytest.mark.parametrize("chunk", [0, 100])
def test_weighted_leverage_matches_manual(chunk):
    """√w-scaled leverage (the Merge & Reduce reduction) vs manual dense."""
    cfg, scaler, Y = _setup(seed=4)
    rng = np.random.default_rng(4)
    w = rng.random(503) * 3.0 + 0.1
    A, _ = M.basis_features(cfg, scaler, jnp.asarray(Y))
    Xw = flatten_features(A) * jnp.sqrt(jnp.asarray(w, jnp.float32))[:, None]
    u_ref = np.asarray(leverage_scores_gram(Xw))
    res = ScoringEngine(cfg, scaler, chunk_size=chunk).score(
        jnp.asarray(Y), method="l2-only", weights=w
    )
    np.testing.assert_allclose(res.leverage, u_ref, atol=1e-4)


@given(st.integers(0, 10_000))
@settings(max_examples=5, deadline=None)
def test_leverage_sums_to_rank(seed):
    """Σu_i = rank(X̃) — the defining property of leverage scores."""
    cfg, scaler, Y = _setup(n=192, seed=seed)
    res = ScoringEngine(cfg, scaler, chunk_size=50).score(
        jnp.asarray(Y), method="l2-only"
    )
    A, _ = M.basis_features(cfg, scaler, jnp.asarray(Y))
    rank = np.linalg.matrix_rank(np.asarray(flatten_features(A), np.float64))
    # f32 may drop near-null modes the f64 rank counts — allow slack below
    assert rank - 1.5 <= res.leverage.sum() <= rank + 0.1
    assert (res.leverage >= -1e-6).all() and (res.leverage <= 1 + 1e-5).all()


def test_featurize_called_once_dense_and_chunk_bounded():
    """The engine's memory contract: one featurize call on the dense path,
    never more than chunk_size rows at a time when chunking."""
    calls = []

    def featurize(Yc):
        calls.append(int(Yc.shape[0]))
        F = jnp.asarray(Yc, jnp.float32)
        return F, F

    engine = ScoringEngine(featurize=featurize, chunk_size=0, rows_per_point=1)
    rng = np.random.default_rng(0)
    Y = rng.standard_normal((200, 6)).astype(np.float32)
    engine.score(Y, method="l2-only")
    assert calls == [200]  # dense fast path: exactly one evaluation

    calls.clear()
    engine = ScoringEngine(featurize=featurize, chunk_size=64, rows_per_point=1)
    engine.score(Y, method="l2-only", hull_k=4, hull_key=jax.random.PRNGKey(0))
    assert max(calls) <= 64          # O(chunk) working set
    assert len(calls) == 2 * 4       # two passes over ⌈200/64⌉ chunks


def test_score_chunks_functional_entry():
    cfg, scaler, Y = _setup(seed=6)
    res = score_chunks(cfg, scaler, jnp.asarray(Y), method="l2-only", chunk_size=100)
    assert res.scores.shape == (503,)
    assert res.n_chunks == 6


def test_engine_validates_arguments():
    cfg, scaler, Y = _setup()
    engine = ScoringEngine(cfg, scaler)
    with pytest.raises(ValueError):
        engine.score(jnp.asarray(Y), method="uniform")
    with pytest.raises(ValueError):
        engine.score(jnp.asarray(Y), method="l2-hull", hull_k=4)  # no hull_key
    with pytest.raises(ValueError):
        engine.score(jnp.asarray(Y), method="l2-only", sketch_size=64)  # no key
    with pytest.raises(ValueError):
        ScoringEngine()  # neither (cfg, scaler) nor featurize


def test_kernel_bench_smoke(tmp_path):
    """CI hook for the bench path: --smoke sizes, artifact written, paths agree."""
    from benchmarks.kernel_bench import scoring_bench

    out = tmp_path / "BENCH_scoring.json"
    rec = scoring_bench(smoke=True, out_path=str(out))
    assert out.exists()
    assert rec["smoke"] is True
    assert rec["max_abs_score_diff"] < 1e-5
    assert rec["chunked_bytes"] < rec["dense_bytes"]
