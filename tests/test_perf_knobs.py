"""Correctness of the §Perf optimization knobs: every perf variant must be
numerically equivalent to the baseline path (they only change sharding or
padding, never math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.models.layers import init_moe, moe_apply


def test_moe_expert_padding_is_equivalent():
    """Padded (dummy) experts never receive tokens → identical outputs."""
    cfg = get_reduced_config("qwen2_moe_a2_7b")  # E=8
    cfg_pad = cfg.replace(moe_pad_experts=12)
    key = jax.random.PRNGKey(0)
    p_base, _ = init_moe(key, cfg)
    p_pad, _ = init_moe(key, cfg_pad)
    # copy the real experts' weights into the padded params
    for name in ("wi_gate", "wi_up", "wo"):
        p_pad[name] = p_pad[name].at[: cfg.n_experts].set(p_base[name])
    p_pad["router"] = p_pad["router"].at[:, : cfg.n_experts].set(p_base["router"])

    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    y_base, aux_base = moe_apply(p_base, x, cfg, cfg.mlp_act)
    y_pad, aux_pad = moe_apply(p_pad, x, cfg_pad, cfg.mlp_act)
    np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_base), atol=1e-5)
    np.testing.assert_allclose(float(aux_pad), float(aux_base), rtol=1e-5)


def test_decode_seq_shard_flag_is_numerically_neutral():
    """With activation constraints disabled (tests), decode_seq_shard changes
    nothing numerically — it only alters sharding hints."""
    cfg = get_reduced_config("tinyllama_1b")
    model_a = build_model(cfg)
    model_b = build_model(cfg.replace(decode_seq_shard=True))
    params, _ = model_a.init(jax.random.PRNGKey(0))
    tokens = np.asarray([[1, 2, 3, 4, 5, 6]], np.int32)
    outs = []
    for model in (model_a, model_b):
        cache, _ = model.init_cache(1, 16)
        _, cache = model.prefill(params, {"tokens": tokens[:, :5]}, cache)
        logits, _ = model.decode_step(params, tokens[:, 5:6], cache)
        outs.append(np.asarray(logits, np.float32))
    np.testing.assert_allclose(outs[1], outs[0], atol=1e-6)


def test_scan_dtype_bf16_close_to_f32():
    from repro.models.rglru import init_rglru_block, rglru_block_apply

    cfg = get_reduced_config("recurrentgemma_2b")
    p, _ = init_rglru_block(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)
    y32, _ = rglru_block_apply(p, x, cfg)
    y16, _ = rglru_block_apply(p, x, cfg.replace(scan_dtype="bfloat16"))
    scale = float(jnp.abs(y32).max())
    np.testing.assert_allclose(
        np.asarray(y16, np.float32), np.asarray(y32, np.float32), atol=0.03 * max(scale, 1e-3)
    )


def test_ring_cache_matches_linear_cache():
    """Windowed ring cache decode ≡ linear cache with window mask."""
    from repro.models.layers import attention_apply, init_attention

    cfg = get_reduced_config("recurrentgemma_2b").replace(attn_window=8)
    params, _ = init_attention(jax.random.PRNGKey(0), cfg)
    B, T = 2, 14
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model), jnp.bfloat16) * 0.3

    # linear cache of the full length (window enforced via mask)
    lin_cache = {
        "k": jnp.zeros((B, T, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
        "v": jnp.zeros((B, T, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
        "pos": jnp.zeros((), jnp.int32),
    }
    # ring cache of exactly window size
    ring_cache = {
        "k": jnp.zeros((B, 8, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
        "v": jnp.zeros((B, 8, cfg.n_kv_heads, cfg.head_dim), jnp.bfloat16),
        "pos": jnp.zeros((), jnp.int32),
    }
    outs_lin, outs_ring = [], []
    for t in range(T):
        xt = x[:, t : t + 1]
        pos = jnp.arange(t, t + 1)
        o_lin, lin_cache = attention_apply(
            params, xt, cfg, positions=pos, cache=lin_cache, window=8
        )
        o_ring, ring_cache = attention_apply(
            params, xt, cfg, positions=pos, cache=ring_cache, window=8
        )
        outs_lin.append(np.asarray(o_lin, np.float32))
        outs_ring.append(np.asarray(o_ring, np.float32))
    np.testing.assert_allclose(
        np.concatenate(outs_ring, 1), np.concatenate(outs_lin, 1), atol=2e-2
    )


def test_ring_prefill_then_decode_matches_full_window_attention():
    """Prefill S > window into a ring cache, then one decode step — must equal
    the windowed attention computed over the whole sequence at once."""
    from repro.models.layers import attention_apply, init_attention, local_attention_chunked

    cfg = get_reduced_config("recurrentgemma_2b").replace(attn_window=8)
    params, _ = init_attention(jax.random.PRNGKey(2), cfg)
    B, S = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S + 1, cfg.d_model), jnp.float32) * 0.3

    ring = {
        "k": jnp.zeros((B, 8, cfg.n_kv_heads, cfg.head_dim), jnp.float32),
        "v": jnp.zeros((B, 8, cfg.n_kv_heads, cfg.head_dim), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }
    _, ring = attention_apply(
        params, x[:, :S], cfg, positions=jnp.arange(S), cache=ring, window=8
    )
    o_dec, _ = attention_apply(
        params, x[:, S:], cfg, positions=jnp.arange(S, S + 1), cache=ring, window=8
    )
    # reference: full-sequence windowed attention, take the last position
    o_full, _ = attention_apply(
        params, x, cfg, positions=jnp.arange(S + 1), cache=None, window=8
    )
    np.testing.assert_allclose(
        np.asarray(o_dec[:, 0], np.float32),
        np.asarray(o_full[:, -1], np.float32),
        atol=2e-3,
    )
