import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.distributed.sharding import ShardingRules, default_rules, resolve_spec
from repro.utils.compat import make_mesh


class FakeMesh:
    """Minimal mesh stub (axis_names + shape dict) for rule resolution."""

    def __init__(self, shape: dict):
        self._shape = shape

    @property
    def shape(self):
        return self._shape

    @property
    def axis_names(self):
        return tuple(self._shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _rules(mesh):
    multi_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules(
        {
            "batch": batch_axes,
            "embed": ("data",),
            "heads": ("model",),
            "kv": ("model",),
            "mlp": ("model",),
            "vocab": ("model",),
            "expert": ("model",),
            "lru": ("model",),
            "state": None,
            "layer": None,
            None: None,
        }
    )


def test_divisible_dims_shard():
    spec = resolve_spec(("embed", "heads"), (2048, 4096), MESH, _rules(MESH))
    assert spec == PartitionSpec("data", "model")


def test_non_divisible_falls_back_to_replicated():
    # MQA: kv=1 can't shard over model=16
    spec = resolve_spec(("layer", "batch", None, "kv", None), (18, 128, 32768, 1, 256), MESH, _rules(MESH))
    assert spec == PartitionSpec(None, "data", None, None, None)


def test_multi_pod_batch_axes():
    spec = resolve_spec(("batch", None), (512, 4096), MESH_MP, _rules(MESH_MP))
    assert spec == PartitionSpec(("pod", "data"), None)


def test_batch_not_divisible_by_pod_product():
    spec = resolve_spec(("batch", None), (100, 4), MESH_MP, _rules(MESH_MP))
    assert spec == PartitionSpec(None, None)


def test_axis_used_once():
    # both dims map to 'model' → second occurrence dropped
    rules = ShardingRules({"a": ("model",), "b": ("model",), None: None})
    spec = resolve_spec(("a", "b"), (64, 64), MESH, rules)
    assert spec == PartitionSpec("model", None)


def test_default_rules_real_mesh():
    # exercise the real default_rules against a real (tiny) mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    rules = default_rules(mesh)
    assert rules.get("batch") == ("data",)
    assert rules.get("heads") == ("model",)
