"""Unit suite for repro.utils.hlo — the parser under the analysis gate.

Fixtures are adversarial on purpose: tiled layouts with internal commas,
tuple result types, async -start/-done pairs, bounded dynamic dims, and
metadata noise that *names* a collective without being one.
"""
from repro.utils.hlo import (
    collective_stats,
    input_output_aliases,
    shape_bytes,
    while_trip_counts,
)

SAMPLE = """
HloModule test
%all-reduce.10 = f32[128,1,128]{2,1,0} all-reduce(%fusion.6), channel_id=6, replica_groups=[16,16]<=[256]
%all-gather.32 = bf16[1,2048]{0,1} all-gather(%slice.1), channel_id=1, dimensions={1}
%all-gather-start.2 = (f32[4,4]{1,0}, f32[8,4]{1,0}) all-gather-start(%p), channel_id=2
%all-gather-done.2 = f32[8,4]{1,0} all-gather-done(%all-gather-start.2)
%rs = f32[16]{0} reduce-scatter(%x), channel_id=3
%cp = s32[8,1,1]{2,1,0} collective-permute(%y), source_target_pairs={{0,1}}
%a2a = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-to-all(%z, %w), channel_id=9
%add.1 = f32[100]{0} add(%a, %b)
"""


def test_shape_bytes():
    assert shape_bytes("f32[128,1,128]{2,1,0}") == 128 * 128 * 4
    assert shape_bytes("bf16[1,2048]") == 2 * 2048
    assert shape_bytes("(f32[4,4], f32[8,4])") == (16 + 32) * 4
    assert shape_bytes("pred[]") == 1


def test_shape_bytes_tiled_layout_and_memory_space():
    # TPU tiling annotations carry commas and parens inside the layout braces
    assert shape_bytes("f32[256,128]{1,0:T(8,128)}") == 256 * 128 * 4
    assert shape_bytes("bf16[1024]{0:T(1024)S(1)}") == 1024 * 2


def test_shape_bytes_bounded_dynamic_dims():
    # bounded dynamic dims count at their bound
    assert shape_bytes("f32[<=512,4]") == 512 * 4 * 4
    assert shape_bytes("s32[<=8]") == 8 * 4


def test_shape_bytes_unknown_dtype_ignored():
    assert shape_bytes("opaque[16]") == 0
    assert shape_bytes("token[]") == 0


def test_collective_stats_counts_and_bytes():
    stats = collective_stats(SAMPLE)
    ops = stats["by_op"]
    assert ops["all-reduce"]["count"] == 1
    assert ops["all-reduce"]["bytes"] == 128 * 128 * 4
    # -start counted once, -done ignored
    assert ops["all-gather"]["count"] == 2
    assert ops["reduce-scatter"]["count"] == 1
    assert ops["collective-permute"]["count"] == 1
    assert ops["all-to-all"]["count"] == 1
    assert stats["total_bytes"] > 0
    assert stats["async_unmatched"] == {}


def test_non_collective_lines_ignored():
    stats = collective_stats("%add = f32[4]{0} add(%a, %b)")
    assert stats["total_bytes"] == 0
    assert stats["by_op"] == {}


def test_variadic_tuple_all_reduce_sums_elements():
    # a fused (variadic) psum of a tuple carry: ONE op, bytes = sum of elems
    text = ("%ar = (f32[8,8]{1,0}, f32[8]{0}, f32[24,2]{1,0}) "
            "all-reduce(%a, %b, %c), channel_id=1, to_apply=%add")
    stats = collective_stats(text)
    assert stats["by_op"]["all-reduce"]["count"] == 1
    assert stats["by_op"]["all-reduce"]["bytes"] == (64 + 8 + 48) * 4


def test_async_start_counts_largest_element_once():
    # -start result is (operand_alias, result): payload = max, not sum
    text = """
%ags = (f32[4,4]{1,0}, f32[32,4]{1,0}) all-gather-start(%p), channel_id=2
%agd = f32[32,4]{1,0} all-gather-done(%ags)
%ars = (f32[16]{0}, f32[16]{0}) all-reduce-start(%q), channel_id=3
%ard = f32[16]{0} all-reduce-done(%ars)
"""
    stats = collective_stats(text)
    assert stats["by_op"]["all-gather"]["count"] == 1
    assert stats["by_op"]["all-gather"]["bytes"] == 32 * 4 * 4
    assert stats["by_op"]["all-reduce"]["count"] == 1
    assert stats["by_op"]["all-reduce"]["bytes"] == 16 * 4
    assert stats["async_unmatched"] == {}


def test_unbalanced_async_pair_reported():
    text = "%ags = (f32[4]{0}, f32[8]{0}) all-gather-start(%p), channel_id=2"
    stats = collective_stats(text)
    assert stats["by_op"]["all-gather"]["count"] == 1
    assert stats["async_unmatched"] == {"all-gather": 1}


def test_tiled_layout_inside_tuple_does_not_split_elements():
    # layout braces carry commas AND parens; the tuple splitter must not
    # break f32[256,128]{1,0:T(8,128)} into two bogus elements
    text = ("%ar = (f32[256,128]{1,0:T(8,128)}, f32[8]{0}) "
            "all-reduce(%a, %b), channel_id=4, to_apply=%add")
    stats = collective_stats(text)
    assert stats["by_op"]["all-reduce"]["count"] == 1
    assert stats["by_op"]["all-reduce"]["bytes"] == (256 * 128 + 8) * 4


def test_metadata_naming_a_collective_is_not_counted():
    # fusion/custom-call lines can *mention* collectives in metadata or
    # backend_config — operand refs (%) / quoted strings reject the match
    text = """
%fusion.1 = f32[64]{0} fusion(%p0), kind=kLoop, calls=%comp, metadata={op_name="jit(f)/all-reduce"}
%cc = f32[4]{0} custom-call(%x), custom_call_target="foo", backend_config="all-gather"
"""
    stats = collective_stats(text)
    assert stats["by_op"] == {}
    assert stats["total_bytes"] == 0


def test_collective_named_result_var_still_counted():
    # the result variable NAME contains the op token before '=' — only the
    # post-'=' occurrence may count
    text = "%all-reduce.7 = f32[12]{0} all-reduce(%x), channel_id=1"
    stats = collective_stats(text)
    assert stats["by_op"]["all-reduce"]["count"] == 1
    assert stats["by_op"]["all-reduce"]["bytes"] == 12 * 4


def test_input_output_aliases_parsing():
    text = ("HloModule jit_step, input_output_alias={ {0}: (0, {}, may-alias), "
            "{1}: (2, {}, must-alias) }, entry_computation_layout={(f32[4])->f32[4]}")
    aliases = input_output_aliases(text)
    assert len(aliases) == 2
    assert aliases[0] == ("0", 0)
    assert aliases[1] == ("1", 2)


def test_input_output_aliases_nested_output_index():
    text = "HloModule m, input_output_alias={ {0, 1}: (3, {}, may-alias) }"
    aliases = input_output_aliases(text)
    assert aliases == [("0, 1", 3)]


def test_input_output_aliases_absent():
    assert input_output_aliases("HloModule m\n%r = f32[4]{0} add(%a, %b)") == []


def test_while_trip_counts():
    text = ('%w = while(%init), condition=%c, body=%b, '
            'backend_config={"known_trip_count":{"n":"7"}} trip_count=7\n'
            "%w2 = while(%i2), trip_count=3")
    assert sorted(while_trip_counts(text)) == [3, 7]
