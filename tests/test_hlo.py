from repro.utils.hlo import collective_stats, shape_bytes

SAMPLE = """
HloModule test
%all-reduce.10 = f32[128,1,128]{2,1,0} all-reduce(%fusion.6), channel_id=6, replica_groups=[16,16]<=[256]
%all-gather.32 = bf16[1,2048]{0,1} all-gather(%slice.1), channel_id=1, dimensions={1}
%all-gather-start.2 = (f32[4,4]{1,0}, f32[8,4]{1,0}) all-gather-start(%p), channel_id=2
%all-gather-done.2 = f32[8,4]{1,0} all-gather-done(%all-gather-start.2)
%rs = f32[16]{0} reduce-scatter(%x), channel_id=3
%cp = s32[8,1,1]{2,1,0} collective-permute(%y), source_target_pairs={{0,1}}
%a2a = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-to-all(%z, %w), channel_id=9
%add.1 = f32[100]{0} add(%a, %b)
"""


def test_shape_bytes():
    assert shape_bytes("f32[128,1,128]{2,1,0}") == 128 * 128 * 4
    assert shape_bytes("bf16[1,2048]") == 2 * 2048
    assert shape_bytes("(f32[4,4], f32[8,4])") == (16 + 32) * 4
    assert shape_bytes("pred[]") == 1


def test_collective_stats_counts_and_bytes():
    stats = collective_stats(SAMPLE)
    ops = stats["by_op"]
    assert ops["all-reduce"]["count"] == 1
    assert ops["all-reduce"]["bytes"] == 128 * 128 * 4
    # -start counted once, -done ignored
    assert ops["all-gather"]["count"] == 2
    assert ops["reduce-scatter"]["count"] == 1
    assert ops["collective-permute"]["count"] == 1
    assert ops["all-to-all"]["count"] == 1
    assert stats["total_bytes"] > 0


def test_non_collective_lines_ignored():
    stats = collective_stats("%add = f32[4]{0} add(%a, %b)")
    assert stats["total_bytes"] == 0
