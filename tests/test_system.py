"""End-to-end behaviour tests for the paper's system.

The paper's pipeline: large dataset → Algorithm-1 ℓ2-hull coreset → weighted
MCTM fit ≈ full-data fit. Plus the framework-level integration: coreset data
selection feeding a weighted-loss LM training loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.coreset import build_coreset, evaluate_coreset
from repro.data import CoresetSelector, generate, subset_loader
from repro.data.synthetic_lm import TokenStreamConfig, sample_batch


def test_paper_pipeline_end_to_end():
    """Fit on a 30-point ℓ2-hull coreset of 10k points ≈ full-data fit
    (paper Table 1 setting, relaxed thresholds for CI robustness)."""
    Y = generate("bivariate_normal", 10_000, seed=0)
    cfg = M.MCTMConfig(J=2, degree=6)
    scaler = DataScaler.fit(Y)
    full = M.fit_mctm(cfg, scaler, Y, steps=700)
    ev = evaluate_coreset(
        cfg, scaler, Y, full, k=30, method="l2-hull", key=jax.random.PRNGKey(0), steps=700
    )
    assert ev.k >= 25
    assert ev.likelihood_ratio < 1.6  # paper reports 1.54±0.29 at k=30
    assert np.isfinite(ev.param_l2)


def test_coreset_fit_likelihood_converges_with_k():
    Y = generate("hourglass", 8_000, seed=1)
    cfg = M.MCTMConfig(J=2, degree=5)
    scaler = DataScaler.fit(Y)
    full = M.fit_mctm(cfg, scaler, Y, steps=600)
    lrs = []
    for k in (30, 300):
        evs = [
            evaluate_coreset(
                cfg, scaler, Y, full, k=k, method="l2-hull",
                key=jax.random.PRNGKey(100 * k + s), steps=600,
            ).likelihood_ratio
            for s in range(2)
        ]
        lrs.append(np.mean([abs(lr - 1) for lr in evs]))
    assert lrs[1] <= lrs[0] + 0.02  # larger coresets are no worse


def test_lm_coreset_training_end_to_end():
    """Framework integration: select a coreset of a token corpus by embedding
    leverage, train with per-example weights, loss decreases."""
    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.optim import adamw
    from repro.train import init_train_state, make_train_step

    cfg = get_reduced_config("olmo_1b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    # corpus of 256 examples
    stream = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=16)
    corpus = [sample_batch(stream, batch=32, step=i) for i in range(8)]
    data = {k: np.concatenate([c[k] for c in corpus]) for k in ("tokens", "labels")}

    # featurize by embedding-pooling with the proxy (init) model
    emb = np.asarray(params["emb"]["embed"], np.float32)

    def featurize(tokens):
        return emb[tokens].mean(axis=1)

    sel = CoresetSelector(featurize=lambda ex: featurize(ex), method="l2-hull")
    subset = sel.select(data["tokens"], k=64, key=jax.random.PRNGKey(1))
    assert subset.size == 64

    fn = subset_loader(data, subset, batch=16)
    opt = adamw(3e-3)
    state = init_train_state(params, opt)
    step = jax.jit(make_train_step(model, opt))
    losses = []
    for i in range(25):
        state, metrics = step(state, fn(i))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
