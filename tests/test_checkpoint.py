import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)), "b": jnp.zeros((8,))},
        "opt": {"m": jnp.ones((8, 8)), "step": jnp.asarray(5, jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(10, state)
    restored = mgr.restore(jax.tree.map(np.zeros_like, state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 2):
        mgr.save(s, {"x": jnp.full((3,), float(s))})
    out = mgr.restore({"x": np.zeros(3)}, step=1)
    np.testing.assert_array_equal(out["x"], np.ones(3))


def test_crash_mid_save_leaves_previous_intact(tmp_path):
    """A stray .tmp dir (simulated crash) must not corrupt restore."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(1))
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert mgr.latest_step() == 1
    restored = mgr.restore(jax.tree.map(np.zeros_like, _state(1)))
    assert restored is not None


def test_torn_write_fully_populated_tmp_ignored_and_reclaimed(tmp_path):
    """Worst-case torn write: the crash lands AFTER every leaf and the
    manifest are fsynced but BEFORE the atomic rename — the injection hook
    fires at exactly that point. The torn ``step_N.tmp`` (which even carries
    a valid manifest.json) must stay invisible to latest_step/restore, the
    retried save must succeed, and GC must reclaim the debris."""
    from repro.ft.config import get_ft_config
    from repro.ft.failure import FailureSimulator, InjectedFailure

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(1))
    ft = get_ft_config()
    ft.simulator = FailureSimulator().inject("checkpoint", 2)
    try:
        with pytest.raises(InjectedFailure):
            mgr.save(2, _state(2))
    finally:
        ft.simulator = None
    torn = os.path.join(str(tmp_path), "step_00000002.tmp")
    assert os.path.exists(os.path.join(torn, "manifest.json"))  # genuinely torn
    assert mgr.latest_step() == 1
    restored = mgr.restore(jax.tree.map(np.zeros_like, _state(1)))
    for a, b in zip(jax.tree.leaves(_state(1)), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # supervisor-style retry of the same step: commit succeeds, tmp reclaimed
    mgr.save(2, _state(2))
    assert mgr.latest_step() == 2
    assert not any(d.endswith(".tmp") for d in os.listdir(str(tmp_path)))


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"x": np.zeros((5,))})


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(7, _state(7), block=False)
    mgr.wait()
    assert mgr.latest_step() == 7
