"""Supervised recovery: RunSupervisor retry/backoff/abort contract, LR
backoff on non-finite signals, injected-failure fit recovery, straggler
backup draws, and re-sharded checkpoint restore onto a shrunk mesh."""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.mctm_fit import MCTMDensityModel, fit_density_model
from repro.data.pipeline import with_backup_draws
from repro.ft import ElasticPlanner, FailureSimulator, RunSupervisor, StragglerPolicy
from repro.ft.config import ft_overrides, get_ft_config
from repro.ft.failure import InjectedFailure, NonFiniteError
from repro.ft.supervisor import MeshPlan
from repro.optim import adamw, scale_updates
from repro.train.loop import train_loop

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str):
    """Fresh interpreter with 8 fake CPU devices (device count is fixed at
    first jax init, so mesh-shrink scenarios can't run in-process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------------------------------------------------------- simulator


def test_simulator_once_fires_single_time_across_retries():
    sim = FailureSimulator().inject("scoring", 3)
    with pytest.raises(InjectedFailure):
        sim.maybe_fail(3, phase="scoring")
    sim.maybe_fail(3, phase="scoring")  # replay after retry: no re-fire
    sim.maybe_fail(3, phase="fit")      # other phases never match
    assert sim.log == [{"phase": "scoring", "step": 3, "mode": "once", "count": 1}]


def test_simulator_every_refires_and_log_persists():
    sim = FailureSimulator().inject("fit", 2, mode="every")
    for expect_count in (1, 2, 3):
        with pytest.raises(InjectedFailure):
            sim.maybe_fail(2, phase="fit")
        assert sim.log[-1]["count"] == expect_count
    assert len(sim.log) == 3  # never cleared — the abort diagnostic needs it


# --------------------------------------------------------------- supervisor


def test_supervisor_retries_then_succeeds_with_backoff():
    slept = []
    sup = RunSupervisor(label="t", sleep=slept.append)
    calls = []

    def attempt(ctx):
        calls.append((ctx.attempt, ctx.resume))
        if ctx.attempt < 2:
            raise RuntimeError("transient")
        return "done"

    with ft_overrides(max_retries=3, backoff_base_s=0.05, backoff_factor=2.0):
        assert sup.run(attempt) == "done"
    assert calls == [(0, False), (1, True), (2, True)]
    assert slept == [0.05, 0.1]  # exponential
    assert [e["kind"] for e in sup.events] == ["failure", "failure"]


def test_supervisor_budget_exhausted_diagnostic_includes_injection_log():
    ft = get_ft_config()
    sim = FailureSimulator().inject("fit", 0, mode="every")
    with ft_overrides(max_retries=1, backoff_base_s=0.0):
        ft.simulator = sim
        try:
            sup = RunSupervisor(label="crash")
            with pytest.raises(RuntimeError) as ei:
                sup.run(lambda ctx: sim.maybe_fail(0, phase="fit"))
        finally:
            ft.simulator = None
    msg = str(ei.value)
    assert "retry budget exhausted after 2 attempts" in msg
    assert "injection log" in msg and "'fit'" in msg
    assert isinstance(ei.value.__cause__, InjectedFailure)


@pytest.mark.parametrize("exc", [ValueError("bad"), TypeError("bad"),
                                 NotImplementedError("bad")])
def test_supervisor_non_retryable_propagates_immediately(exc):
    sup = RunSupervisor()
    calls = []

    def attempt(ctx):
        calls.append(ctx.attempt)
        raise exc

    with pytest.raises(type(exc)):
        sup.run(attempt)
    assert calls == [0]  # no retry burned on a programming error


def test_supervisor_nonfinite_backs_off_lr_without_replanning():
    planner = ElasticPlanner(model_parallel=1, base_data_parallel=8)
    sup = RunSupervisor(planner=planner, devices_fn=lambda: 8,
                        remesh=lambda plan: "mesh", sleep=lambda s: None)
    seen = []

    def attempt(ctx):
        seen.append((ctx.lr_scale, ctx.plan))
        if ctx.attempt < 2:
            raise NonFiniteError(ctx.attempt, loss=float("nan"))
        return "ok"

    with ft_overrides(max_retries=3, lr_backoff_factor=0.5, backoff_base_s=0.0):
        sup.run(attempt)
    assert [s[0] for s in seen] == [1.0, 0.5, 0.25]
    assert all(p is None for _, p in seen)  # divergence ≠ dead hardware


def test_supervisor_replans_on_failure_with_shrunk_pool():
    planner = ElasticPlanner(model_parallel=2, base_data_parallel=4,
                             base_global_batch=64)
    alive = [8, 6]  # two devices die before the first retry
    sup = RunSupervisor(planner=planner, devices_fn=lambda: alive[-1],
                        remesh=lambda plan: ("mesh", plan.shape),
                        sleep=lambda s: None)
    seen = []

    def attempt(ctx):
        seen.append(ctx)
        if ctx.attempt == 0:
            raise RuntimeError("node lost")
        return ctx

    with ft_overrides(max_retries=2, backoff_base_s=0.0, rescale_lr=True):
        ctx = sup.run(attempt)
    assert isinstance(ctx.plan, MeshPlan)
    assert ctx.plan.shape == (3, 2) and ctx.mesh == ("mesh", (3, 2))
    assert ctx.plan.global_batch == 48 and ctx.batch_scale == 48 / 64
    assert ctx.lr_scale == pytest.approx(ctx.plan.lr_scale)
    assert sup.events[0]["plan"]["shape"] == (3, 2)


# ----------------------------------------------------- lr backoff machinery


def test_scale_updates_halves_updates_same_state_structure():
    opt = adamw(1e-2)
    assert scale_updates(opt, 1.0) is opt  # identity: no wrapper in the way
    params = {"w": jnp.ones((3,))}
    grads = {"w": jnp.full((3,), 2.0)}
    s0 = opt.init(params)
    u_full, s1 = opt.update(grads, s0, params, jnp.asarray(0))
    u_half, s1h = scale_updates(opt, 0.5).update(grads, s0, params, jnp.asarray(0))
    np.testing.assert_allclose(np.asarray(u_half["w"]), 0.5 * np.asarray(u_full["w"]))
    # state structure + values untouched → pre-backoff checkpoints restore
    assert jax.tree.structure(s1) == jax.tree.structure(s1h)
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s1h)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_loop_raises_nonfinite_before_checkpointing(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"step": jnp.asarray(0, jnp.int32), "x": jnp.zeros(())}

    def step_fn(state, batch):
        i = int(state["step"])
        loss = np.nan if i == 2 else 1.0
        new = {"step": state["step"] + 1, "x": state["x"]}
        return new, {"loss": jnp.asarray(loss), "grad_norm": jnp.asarray(0.0)}

    with ft_overrides(nonfinite_rollback=True, nonfinite_check_every=1):
        with pytest.raises(NonFiniteError) as ei:
            train_loop(step_fn, state, lambda i: {}, 8, mgr=mgr, ckpt_every=1)
    assert ei.value.step == 2
    assert mgr.latest_step() == 2  # poisoned step-3 state never saved


# ----------------------------------------------------- straggler mitigation


def test_with_backup_draws_fake_clock():
    clock = {"t": 0.0, "cost": 0.0}

    def tick():
        clock["t"] += clock["cost"]
        return clock["t"]

    primary = lambda step: {"src": "primary", "step": step}
    backup = lambda step: {"src": "backup", "step": step}
    fn = with_backup_draws(primary, backup, StragglerPolicy(deadline_ms=100),
                           clock=tick)
    clock["cost"] = 0.01  # 10ms per tick → primary well under deadline
    assert fn(3) == {"src": "primary", "step": 3}
    clock["cost"] = 0.2   # 200ms → deadline missed, deterministic backup
    assert fn(4) == {"src": "backup", "step": 4}


# ------------------------------------------------------- fit-layer recovery


def _density_fixture(n=512, seed=0):
    rng = np.random.default_rng(seed)
    Y = rng.normal(size=(n, 2)).astype(np.float32)
    cfg = M.MCTMConfig(J=2, degree=4)
    model = MCTMDensityModel(cfg, DataScaler.fit(Y))
    p0 = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"Y": Y, "weights": np.ones(n, np.float32)}
    return model, p0, batch


def test_adam_injected_failure_recovers_bit_identical():
    """Crash at step 12 of 24 → supervisor resumes from the step-6/12 ckpt
    and the deterministic full-batch replay lands on identical params."""
    model, p0, batch = _density_fixture()
    ft = get_ft_config()

    def run(inject):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2)
            if inject:
                ft.simulator = FailureSimulator().inject("fit", 12)
            try:
                params, losses, _ = fit_density_model(
                    model, p0, batch, optimizer=adamw(5e-2), steps=24,
                    checkpoint=mgr, ckpt_every=6)
            finally:
                ft.simulator = None
            return params, losses

    p_clean, l_clean = run(False)
    p_rec, l_rec = run(True)
    for a, b in zip(jax.tree.leaves(p_clean), jax.tree.leaves(p_rec)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert l_rec[-1] == l_clean[-1]


def test_lbfgs_deterministic_nonfinite_crash_loops_to_clean_abort():
    """NaN data → non-finite objective on every attempt → the retry budget
    drains and the supervisor aborts with the full diagnostic (this is the
    intended behavior for a deterministically-poisoned objective)."""
    _, p0, _ = _density_fixture(n=64)
    cfg = M.MCTMConfig(J=2, degree=4)
    bad_Y = np.full((64, 2), np.nan, np.float32)
    good = np.random.default_rng(1).normal(size=(64, 2)).astype(np.float32)
    model = MCTMDensityModel(cfg, DataScaler.fit(good))
    bad = {"Y": bad_Y, "weights": np.ones(64, np.float32)}
    with ft_overrides(max_retries=2, backoff_base_s=0.0):
        with pytest.raises(RuntimeError) as ei:
            fit_density_model(model, p0, bad, steps=4, method="lbfgs")
    msg = str(ei.value)
    assert "retry budget exhausted after 3 attempts" in msg
    assert "non-finite" in msg


def test_minibatch_straggler_policy_swaps_in_backup_draws():
    """With a straggler deadline of ~0ms every primary draw misses, so the
    fit must run entirely on backup draws — and still converge/replay."""
    model, p0, batch = _density_fixture(n=256)
    common = dict(optimizer=adamw(5e-2), steps=8, method="minibatch",
                  batch_size=64)
    _, l_plain, _ = fit_density_model(model, p0, batch, **common)
    with ft_overrides(straggler_deadline_ms=1e-9):
        _, l_backup, _ = fit_density_model(model, p0, batch, **common)
    l_plain = [float(x) for x in l_plain]
    l_backup = [float(x) for x in l_backup]
    assert len(l_backup) == 8 and np.all(np.isfinite(l_backup))
    # backup draws use an offset seed → a genuinely different batch sequence
    assert l_backup != l_plain


# --------------------------------------------- re-shard restore, shrunk mesh


def test_restore_train_state_reshards_onto_shrunk_ragged_mesh():
    """Checkpoint written on the full 8-device pool restores onto a 6-device
    (3×2) survivor mesh via ``restore_train_state(shardings=)`` — values
    bit-identical, leaves committed to the degraded mesh's shardings."""
    run_in_subprocess(
        """
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.optim import adamw
        from repro.train import init_train_state
        from repro.train.loop import restore_train_state

        opt = adamw(1e-3)
        params = {"w": jnp.arange(24.0).reshape(6, 4), "b": jnp.ones((5,))}
        state = init_train_state(params, opt).replace(step=jnp.asarray(7, jnp.int32))

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(7, state)

            # 6 of 8 devices survive: a (3, 2) degraded mesh
            mesh = Mesh(np.asarray(jax.devices()[:6]).reshape(3, 2), ("data", "model"))

            def spec(x):
                if x.ndim >= 1 and x.shape[0] % 3 == 0:
                    return NamedSharding(mesh, P("data", *([None] * (x.ndim - 1))))
                return NamedSharding(mesh, P())

            template = jax.tree.map(jnp.zeros_like, state)
            shardings = jax.tree.map(spec, template)
            restored, start = restore_train_state(mgr, template, shardings=shardings)

        assert start == 7, start
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        w = restored.params["w"]
        assert w.sharding.mesh.devices.size == 6
        assert w.sharding.spec == P("data", None), w.sharding.spec
        assert restored.params["b"].sharding.spec == P(), restored.params["b"].sharding
        print("OK")
        """
    )
