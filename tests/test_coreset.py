import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.coreset import CORESET_METHODS, build_coreset, evaluate_coreset
from repro.core.sensitivity import sensitivity_sample, sample_size_bound
from repro.data.dgp import generate


@pytest.fixture(scope="module")
def setup():
    Y = generate("bivariate_normal", 2000, seed=0)
    cfg = M.MCTMConfig(J=2, degree=5)
    scaler = DataScaler.fit(Y)
    return cfg, scaler, Y


@pytest.mark.parametrize("method", CORESET_METHODS)
def test_methods_produce_valid_coresets(setup, method):
    cfg, scaler, Y = setup
    cs = build_coreset(cfg, scaler, Y, k=50, method=method, key=jax.random.PRNGKey(0))
    assert cs.size >= 40
    assert (cs.weights > 0).all()
    assert (cs.indices >= 0).all() and (cs.indices < Y.shape[0]).all()


def test_build_coreset_exact_k_low_diversity_hull():
    """Adversarial hull: nearly all points identical → ε-kernel candidates
    dedup to a handful of points. build_coreset must still return exactly k
    (shortfall topped up by score rank), with no duplicate hull entries."""
    rng = np.random.default_rng(5)
    Y = np.tile(rng.standard_normal((1, 2)), (400, 1))
    Y[:5] = rng.standard_normal((5, 2)) * 3.0
    cfg = M.MCTMConfig(J=2, degree=5)
    scaler = DataScaler.fit(Y)
    k, alpha = 80, 0.2  # k2 = 64 hull slots ≫ distinct extremal points
    cs = build_coreset(
        cfg, scaler, Y, k=k, method="l2-hull", key=jax.random.PRNGKey(2), alpha=alpha
    )
    assert cs.size == k
    assert (cs.weights > 0).all()
    hull_part = cs.indices[int(np.floor(alpha * k)) :]
    assert len(set(hull_part.tolist())) == k - int(np.floor(alpha * k))


def test_uniform_weights_are_n_over_k(setup):
    cfg, scaler, Y = setup
    cs = build_coreset(cfg, scaler, Y, k=100, method="uniform", key=jax.random.PRNGKey(1))
    np.testing.assert_allclose(cs.weights, Y.shape[0] / 100)


def test_sampled_nll_is_unbiased_estimator(setup):
    """E[weighted coreset NLL] = full NLL — average over repeated draws."""
    cfg, scaler, Y = setup
    A, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y))
    params = M.init_params(jax.random.PRNGKey(7), cfg)
    full = float(M.nll(cfg, params, A, Ap))
    ests = []
    for i in range(30):
        cs = build_coreset(
            cfg, scaler, Y, k=200, method="l2-only", key=jax.random.PRNGKey(i)
        )
        As, Aps = M.basis_features(cfg, scaler, jnp.asarray(Y[cs.indices]))
        ests.append(float(M.nll(cfg, params, As, Aps, jnp.asarray(cs.weights, jnp.float32))))
    assert np.mean(ests) == pytest.approx(full, rel=0.05)


def test_coreset_epsilon_approximation(setup):
    """Empirical (1±ε): the hybrid coreset's weighted NLL is within a small
    multiplicative band of the full NLL across random feasible parameters."""
    cfg, scaler, Y = setup
    A, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y))
    cs = build_coreset(cfg, scaler, Y, k=600, method="l2-hull", key=jax.random.PRNGKey(3))
    As, Aps = M.basis_features(cfg, scaler, jnp.asarray(Y[cs.indices]))
    w = jnp.asarray(cs.weights, jnp.float32)
    rels = []
    for seed in range(20):
        params = M.init_params(jax.random.PRNGKey(seed), cfg)
        full = float(M.nll(cfg, params, A, Ap))
        approx = float(M.nll(cfg, params, As, Aps, w))
        rels.append(abs(approx - full) / abs(full))
    assert np.median(rels) < 0.15
    assert np.max(rels) < 0.6


def test_end_to_end_coreset_beats_tiny_uniform_on_complex_dgp():
    """Paper's qualitative claim on a complex DGP at small k (averaged)."""
    Y = generate("copula_complex", 4000, seed=1)
    cfg = M.MCTMConfig(J=2, degree=5)
    scaler = DataScaler.fit(Y)
    full = M.fit_mctm(cfg, scaler, Y, steps=600)
    lr_hull, lr_unif = [], []
    for s in range(3):
        ev_h = evaluate_coreset(
            cfg, scaler, Y, full, k=40, method="l2-hull", key=jax.random.PRNGKey(s), steps=600
        )
        ev_u = evaluate_coreset(
            cfg, scaler, Y, full, k=40, method="uniform", key=jax.random.PRNGKey(100 + s), steps=600
        )
        lr_hull.append(abs(ev_h.likelihood_ratio - 1))
        lr_unif.append(abs(ev_u.likelihood_ratio - 1))
    assert np.mean(lr_hull) <= np.mean(lr_unif) * 1.5  # robust, not flaky-tight


def test_sensitivity_sample_weights():
    scores = np.array([1.0, 1.0, 2.0, 4.0])
    s = sensitivity_sample(jax.random.PRNGKey(0), scores, k=100)
    assert s.indices.shape == (100,)
    # weight · prob · k == 1 per draw
    np.testing.assert_allclose(s.weights * s.probs[s.indices] * 100, 1.0, rtol=1e-6)


def test_sample_size_bound_monotone_in_eps():
    assert sample_size_bound(10, 5, 0.1) > sample_size_bound(10, 5, 0.5)
