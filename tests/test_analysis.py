"""Tests for the compile-time invariant auditor (repro.analysis) and its CI
entry point scripts/analysis_gate.py.

The sharded programs need 8 fake CPU devices, so everything jax-touching
runs in a subprocess with XLA_FLAGS set before import (same pattern as
tests/test_mctm_fit.py).
"""
import json
import os
import subprocess
import sys
import textwrap

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
REPO_SRC = os.path.join(REPO_ROOT, "src")
GATE = os.path.join(REPO_ROOT, "scripts", "analysis_gate.py")


def _run(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _run_gate(*args: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    return subprocess.run(
        [sys.executable, GATE, *args],
        capture_output=True, text=True, env=env, timeout=900,
    )


# ------------------------------------------------------------ registry


def test_registry_has_every_subsystem():
    """The auditor must cover ≥ 8 programs spanning fit, scoring, segmented
    resume and kernel layers — the acceptance floor of the analysis PR."""
    out = _run(
        """
        from repro.analysis import all_programs

        names = {s.name for s in all_programs()}
        assert len(names) >= 8, names
        for required in [
            "streamed_nll_sharded", "adam_train_step",
            "lbfgs_value_and_grad_sharded", "two_pass_pass1_sharded",
            "two_pass_pass2_hull_sharded", "one_pass_sharded",
            "segmented_pass1_sharded", "gram_kernel_interpret",
        ]:
            assert required in names, (required, names)
        print("OK", len(names))
        """
    )
    assert "OK" in out


def test_all_registered_programs_audit_clean():
    """Every registered hot path honors its declared budgets on main."""
    out = _run(
        """
        from repro.analysis import all_programs, audit_program

        bad = []
        for spec in all_programs():
            rep = audit_program(spec)
            if not rep["ok"]:
                bad.append((spec.name, rep["failures"]))
        assert not bad, bad
        print("OK")
        """
    )
    assert "OK" in out


# ------------------------------------------------------------ violations


def test_every_seeded_violation_is_detected():
    """The gate must FAIL on each deliberately broken program — an extra
    collective, an (n, J, d) materialization, an f64 promotion, a silently
    copied donation, and a host callback."""
    out = _run(
        """
        from repro.analysis import audit_program
        from repro.analysis.violations import VIOLATIONS

        missed = [
            name for name, spec in VIOLATIONS.items()
            if audit_program(spec)["ok"]
        ]
        assert not missed, f"violations audited clean: {missed}"
        assert len(VIOLATIONS) >= 5, list(VIOLATIONS)
        print("OK", len(VIOLATIONS))
        """
    )
    assert "OK" in out


def test_gate_exits_nonzero_on_seeded_violation():
    res = _run_gate("--seed-violation", "extra_psum")
    assert res.returncode == 1, (res.returncode, res.stdout, res.stderr)
    assert "detected" in res.stdout


def test_gate_rejects_unknown_violation():
    res = _run_gate("--seed-violation", "nonsense")
    assert res.returncode == 2, (res.returncode, res.stdout)


# ------------------------------------------------------------ gate drift


def test_gate_detects_baseline_drift(tmp_path):
    """Tamper with a committed collective count → the gate must fail with a
    drift message (the bench_gate-style regenerate-in-same-PR contract)."""
    with open(os.path.join(REPO_ROOT, "benchmarks", "baselines",
                           "ANALYSIS_budgets.json")) as f:
        baseline = json.load(f)
    prog = baseline["programs"]["streamed_nll_sharded"]
    prog["collectives"]["all-reduce"] = 5  # the tampered expectation
    tampered = tmp_path / "tampered.json"
    tampered.write_text(json.dumps(baseline))
    res = _run_gate("--baseline", str(tampered), "--no-lint")
    assert res.returncode == 1, (res.returncode, res.stdout[-2000:])
    assert "drifted" in res.stdout


def test_gate_passes_on_committed_baseline():
    """The full gate (audits + lints + baseline diff) is green on main."""
    res = _run_gate()
    assert res.returncode == 0, (res.stdout[-3000:], res.stderr[-2000:])
    assert "ANALYSIS GATE: OK" in res.stdout


# ------------------------------------------------------------ check units


def test_materialization_budget_separates_chunk_from_stack():
    """Unit-level: the ratio rule admits row-scaled and chunk-bounded avals
    and rejects an n-scaled basis, independent of shard count."""
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.analysis.registry import (
            MaterializationBudget, ProgramSpec)
        from repro.analysis.checks import ProgramArtifacts, check_materialization

        def build_ok():
            # (n, 2) rows in, row-scaled out — never wider than 2/row
            fn = jax.jit(lambda y: jnp.sum(y * 2.0, axis=1))
            return fn, (np.ones((4096, 2), np.float32),)

        def build_bad():
            # widens every row to 8 columns: a basis-block shape
            fn = jax.jit(lambda y: jnp.tile(y, (1, 4)) * 3.0)
            return fn, (np.ones((4096, 2), np.float32),)

        budget = MaterializationBudget(row_elems=2, fixed_elems=2048)
        ok_spec = ProgramSpec("ok", "", build_ok, materialization=budget)
        bad_spec = ProgramSpec("bad", "", build_bad, materialization=budget)
        _, fails = check_materialization(ok_spec, ProgramArtifacts(ok_spec).jaxpr)
        assert fails == [], fails
        _, fails = check_materialization(bad_spec, ProgramArtifacts(bad_spec).jaxpr)
        assert fails, "stacked basis not caught"
        print("OK")
        """
    )
    assert "OK" in out


def test_dtype_check_ignores_weak_scalar_but_catches_promotion():
    out = _run(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.analysis.registry import ProgramSpec
        from repro.analysis.checks import ProgramArtifacts, check_dtypes

        def build_weak():
            # python-float scalar: weak tensor<f64> const under x64, but the
            # array math stays f32 → metric only, no failure
            fn = jax.jit(lambda x: jnp.minimum(x, 1.0))
            return fn, (np.ones((16,), np.float32),)

        def build_promoted():
            scale = np.float64(2.0)   # promotes the whole array under x64
            fn = jax.jit(lambda x: x * scale)
            return fn, (np.ones((16,), np.float32),)

        for build, should_fail in [(build_weak, False), (build_promoted, True)]:
            spec = ProgramSpec("p", "", build)
            art = ProgramArtifacts(spec)
            metrics, fails = check_dtypes(
                spec, art.stablehlo(False), art.stablehlo(True))
            assert bool(fails) == should_fail, (build.__name__, metrics, fails)
        print("OK")
        """
    )
    assert "OK" in out
