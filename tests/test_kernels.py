"""Per-kernel allclose sweeps (interpret=True) against the jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bernstein.ops import bernstein_basis_deriv
from repro.kernels.bernstein.ref import bernstein_basis_deriv_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gram.ops import gram_matrix
from repro.kernels.gram.ref import gram_ref
from repro.kernels.ssd.ops import ssd_chunked
from repro.kernels.ssd.ref import ssd_ref


# ---------------------------------------------------------------- bernstein


@pytest.mark.parametrize("n", [1, 100, 1024, 2049])
@pytest.mark.parametrize("degree", [1, 4, 7])
def test_bernstein_kernel_sweep(n, degree):
    rng = np.random.default_rng(n * 10 + degree)
    t = jnp.asarray(rng.random(n), jnp.float32)
    basis, deriv = bernstein_basis_deriv(t, degree)
    bref, dref = bernstein_basis_deriv_ref(t, degree)
    np.testing.assert_allclose(np.asarray(basis), np.asarray(bref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(deriv), np.asarray(dref), atol=1e-4)


# --------------------------------------------------------------------- gram


@pytest.mark.parametrize("shape", [(64, 4), (777, 14), (1024, 128), (300, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel_sweep(shape, dtype):
    rng = np.random.default_rng(shape[0])
    x = jnp.asarray(rng.standard_normal(shape), dtype)
    # interpret=True: exercise the Pallas kernel itself on CPU (the default
    # backend off-TPU is the jnp oracle, which would compare ref to ref)
    got = np.asarray(gram_matrix(x, interpret=True))
    ref = np.asarray(gram_ref(x))
    tol = 1e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, ref, rtol=tol, atol=tol * np.abs(ref).max())


# ----------------------------------------------------------- extremes


@pytest.mark.parametrize("n,m,d", [(64, 8, 5), (777, 24, 7), (1024, 130, 14)])
def test_extremes_kernel_sweep(n, m, d):
    from repro.kernels.extremes.ops import directional_extremes
    from repro.kernels.extremes.ref import directional_extremes_ref

    rng = np.random.default_rng(n + m)
    P = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    dirs = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    got = directional_extremes(P, dirs, interpret=True)
    ref = directional_extremes_ref(P, dirs)
    for g, r in zip(got, ref):
        np.testing.assert_allclose(
            np.asarray(g, np.float64), np.asarray(r, np.float64), atol=1e-4
        )


def test_extremes_kernel_mask_and_ties():
    """Tail masks (the engines' shard-padding pattern) and exact duplicates:
    masked rows can never win, ties break to the lowest row id — matching the
    dense-argmax oracle bit for bit on the indices."""
    from repro.kernels.extremes.ops import directional_extremes
    from repro.kernels.extremes.ref import directional_extremes_ref

    rng = np.random.default_rng(0)
    P_np = rng.standard_normal((300, 6)).astype(np.float32)
    P_np[100:200] = P_np[:100]  # duplicate block → cross-block ties
    P = jnp.asarray(P_np)
    dirs = jnp.asarray(rng.standard_normal((16, 6)), jnp.float32)
    n_valid = 257  # ragged tail mask
    mask = jnp.arange(300) < n_valid
    vmax, imax, vmin, imin = directional_extremes(P, dirs, mask, interpret=True)
    rvmax, rimax, rvmin, rimin = directional_extremes_ref(P, dirs, mask)
    np.testing.assert_array_equal(np.asarray(imax), np.asarray(rimax))
    np.testing.assert_array_equal(np.asarray(imin), np.asarray(rimin))
    np.testing.assert_allclose(np.asarray(vmax), np.asarray(rvmax), atol=1e-4)
    np.testing.assert_allclose(np.asarray(vmin), np.asarray(rvmin), atol=1e-4)
    assert int(np.max(imax)) < n_valid and int(np.max(imin)) < n_valid
    # any direction whose max lives in the duplicated block must have resolved
    # the cross-block tie toward the first copy (rows < 100)
    assert not np.any((np.asarray(imax) >= 100) & (np.asarray(imax) < 200))


def test_extremes_backend_dispatch():
    from repro.kernels.extremes.ops import directional_extremes

    P = jnp.ones((4, 2), jnp.float32)
    dirs = jnp.ones((3, 2), jnp.float32)
    with pytest.raises(ValueError):
        directional_extremes(P, dirs, backend="nope")


# ----------------------------------------------------------- flash attention


@pytest.mark.parametrize(
    "B,S,H,KV,d", [(1, 128, 2, 2, 32), (2, 256, 4, 2, 64), (1, 512, 8, 1, 64)]
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, KV, d, causal):
    rng = np.random.default_rng(S + H)
    q = jnp.asarray(rng.standard_normal((B, S, H, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    g = H // KV
    kq, vq = jnp.repeat(k, g, 2), jnp.repeat(v, g, 2)

    def flat(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, S, d)

    ref = attention_ref(flat(q), flat(kq), flat(vq), causal=causal)
    ref = ref.reshape(B, H, S, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64)

    def flat(a):
        return a.transpose(0, 2, 1, 3).reshape(2, 128, 64)

    ref = attention_ref(flat(q), flat(k), flat(v))
    ref = ref.reshape(1, 2, 128, 64).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2
    )


# ---------------------------------------------------------------------- ssd


@pytest.mark.parametrize("T,chunk", [(64, 16), (100, 32), (256, 128), (31, 32)])
@pytest.mark.parametrize("P,N", [(16, 8), (64, 32)])
def test_ssd_kernel_sweep(T, chunk, P, N):
    rng = np.random.default_rng(T + P)
    BH = 3
    x = jnp.asarray(rng.standard_normal((BH, T, P)), jnp.float32)
    dt = jnp.asarray(rng.random((BH, T)) * 0.5 + 0.01, jnp.float32)
    A = jnp.asarray(-rng.random((BH,)) * 2 - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((BH, T, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((BH, T, N)), jnp.float32)
    y = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    yr = ssd_ref(x, dt[..., None], A[:, None], Bm, Cm)
    scale = float(jnp.abs(yr).max())
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4 * max(scale, 1))


def test_ssd_matches_model_chunked_path():
    """kernel vs the model's _ssd_chunked lax implementation (same math)."""
    from repro.models.ssm import _ssd_chunked

    rng = np.random.default_rng(7)
    B, T, H, P, N = 2, 64, 4, 16, 8
    x = jnp.asarray(rng.standard_normal((B, T, H, P)), jnp.float32)
    dt = jnp.asarray(rng.random((B, T, H)) * 0.5 + 0.01, jnp.float32)
    A = jnp.asarray(-rng.random((H,)) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, T, 1, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, T, 1, N)), jnp.float32)
    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    y_model, _ = _ssd_chunked(x, dt, A, Bm, Cm, state0, chunk=16)

    # kernel layout: fold (B,H) → BH, broadcast Bm/Cm per head
    xk = x.transpose(0, 2, 1, 3).reshape(B * H, T, P)
    dtk = dt.transpose(0, 2, 1).reshape(B * H, T)
    Ak = jnp.tile(A, (B,))
    Bk = jnp.repeat(Bm[:, :, 0, :][:, None], H, 1).reshape(B * H, T, N)
    Ck = jnp.repeat(Cm[:, :, 0, :][:, None], H, 1).reshape(B * H, T, N)
    y_kernel = ssd_chunked(xk, dtk, Ak, Bk, Ck, chunk=16)
    y_kernel = y_kernel.reshape(B, H, T, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model), atol=1e-4)
