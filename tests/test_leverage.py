import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bernstein import DataScaler
from repro.core.leverage import (
    block_B_matrix,
    flatten_features,
    leverage_from_gram,
    leverage_scores_gram,
    leverage_scores_qr,
    ridge_leverage_scores,
    root_leverage_scores,
    sketched_leverage,
)
from repro.core.mctm import MCTMConfig, basis_features


def _features(n=64, J=2, degree=4, seed=0):
    rng = np.random.default_rng(seed)
    Y = rng.standard_normal((n, J))
    cfg = MCTMConfig(J=J, degree=degree)
    scaler = DataScaler.fit(Y)
    A, _ = basis_features(cfg, scaler, jnp.asarray(Y))
    return np.asarray(A)


def test_block_B_equivalence():
    """Paper identity: leverage of B-row (i,j) == leverage of Ã-row i, ∀j.

    Uses the Gram/pinv form — Bernstein features are rank-deficient (each
    j-block is a partition of unity), where QR-based leverage is ill-defined.
    """
    A = _features(n=32, J=3, degree=3)
    X = A.reshape(32, -1)
    u_small = np.asarray(leverage_scores_gram(jnp.asarray(X)))
    B = block_B_matrix(A)
    u_B = np.asarray(leverage_scores_gram(jnp.asarray(B)))  # (n·J,)
    u_B = u_B.reshape(32, 3)
    for j in range(3):
        np.testing.assert_allclose(u_B[:, j], u_small, rtol=1e-3, atol=1e-4)


def test_leverage_range_and_sum():
    X = jnp.asarray(_features().reshape(64, -1))
    u = np.asarray(leverage_scores_gram(X))
    assert (u >= -1e-6).all() and (u <= 1 + 1e-6).all()
    # Σu = numerical rank; the Bernstein Gram has near-zero modes that f32
    # may count or drop — allow ±1.5 around the f64 rank.
    rank = np.linalg.matrix_rank(np.asarray(X, np.float64))
    assert rank - 1.5 <= u.sum() <= rank + 0.1


def test_gram_vs_qr_full_rank():
    """On full-rank inputs the QR and Gram/pinv forms agree."""
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(leverage_scores_gram(X)),
        np.asarray(leverage_scores_qr(X)),
        rtol=1e-3,
        atol=1e-4,
    )


def test_sketched_leverage_constant_factor():
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.standard_normal((512, 8)), jnp.float32)
    exact = np.asarray(leverage_scores_qr(X))
    approx = np.asarray(sketched_leverage(X, jax.random.PRNGKey(0), 256))
    ratio = approx / np.maximum(exact, 1e-9)
    # constant-factor approximation for the bulk of points
    assert np.median(ratio) == pytest.approx(1.0, abs=0.5)


def test_ridge_leverage_below_plain():
    X = jnp.asarray(_features().reshape(64, -1))
    plain = np.asarray(leverage_scores_gram(X))
    ridge = np.asarray(ridge_leverage_scores(X, reg=10.0))
    assert (ridge <= plain + 1e-5).all()


def test_root_leverage_is_sqrt():
    X = jnp.asarray(_features().reshape(64, -1))
    np.testing.assert_allclose(
        np.asarray(root_leverage_scores(X)) ** 2,
        np.clip(np.asarray(leverage_scores_gram(X)), 0, None),
        rtol=1e-3,
        atol=1e-5,
    )


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_leverage_invariant_under_row_scaling_of_others(seed):
    """Leverage of a row depends only on the spanned subspace geometry."""
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((32, 4)).astype(np.float32)
    u = np.asarray(leverage_scores_qr(jnp.asarray(X)))
    # rotating the feature space leaves leverage unchanged
    Q, _ = np.linalg.qr(rng.standard_normal((4, 4)))
    u_rot = np.asarray(leverage_scores_qr(jnp.asarray(X @ Q.astype(np.float32))))
    np.testing.assert_allclose(u, u_rot, rtol=1e-3, atol=1e-4)
