"""Unit tests for the CI bench-regression gate (scripts/bench_gate.py):
rule semantics on synthetic records (no timing dependence) and the gate's
behavior against the committed baselines' file layout."""
import json
import os
import sys

import pytest

SCRIPTS = os.path.join(os.path.dirname(__file__), "..", "scripts")
sys.path.insert(0, os.path.abspath(SCRIPTS))

import bench_gate  # noqa: E402
from bench_gate import Rule, check_rule, gate_pair, rules_for  # noqa: E402


def test_time_ratio_rule_gates_only_large_regressions():
    rule = Rule("speedup", "time_ratio")
    base = {"speedup": 3.0}
    # within 1.5x — runner noise, passes
    assert check_rule(rule, {"speedup": 2.1}, base, 1.5) == []
    # faster than baseline obviously passes
    assert check_rule(rule, {"speedup": 4.0}, base, 1.5) == []
    # > 1.5x regression fails
    fails = check_rule(rule, {"speedup": 1.9}, base, 1.5)
    assert len(fails) == 1 and "regressed" in fails[0]


def test_exact_rule_envelope():
    rule = Rule("eps", "exact", rel=1.5, abs=0.01)
    base = {"eps": 0.02}
    assert check_rule(rule, {"eps": 0.03}, base, 1.5) == []   # ≤ 0.02·1.5+0.01
    assert check_rule(rule, {"eps": 0.041}, base, 1.5)        # above ceiling


def test_floor_rule_is_baseline_independent():
    """The speedup >= 1.0 headline gate: an absolute floor, not a ratio — a
    generous baseline can never mask the claim flipping back below 1."""
    rule = Rule("one_pass_vs_two_pass.speedup", "floor", floor=1.0)
    base = {"one_pass_vs_two_pass": {"speedup": 2.0}}
    assert check_rule(rule, {"one_pass_vs_two_pass": {"speedup": 1.01}},
                      base, 1.5) == []
    # within the 1.5x time_ratio noise envelope of baseline, but below the
    # floor — still fails
    fails = check_rule(rule, {"one_pass_vs_two_pass": {"speedup": 0.95}},
                       base, 1.5)
    assert len(fails) == 1 and "floor" in fails[0]


def test_invariant_rule_and_list_fanout():
    rule = Rule("per_k.[].within_band", "invariant")
    base = {"per_k": [{"within_band": True}, {"within_band": True}]}
    good = {"per_k": [{"within_band": True}, {"within_band": True}]}
    bad = {"per_k": [{"within_band": True}, {"within_band": False}]}
    assert check_rule(rule, good, base, 1.5) == []
    fails = check_rule(rule, bad, base, 1.5)
    assert len(fails) == 1 and "per_k[1]" in fails[0]
    # length mismatch = not comparable = failure, not a silent pass
    short = {"per_k": [{"within_band": True}]}
    assert check_rule(rule, short, base, 1.5)


def test_missing_keys_fail_not_crash():
    rule = Rule("one_pass_vs_two_pass.speedup", "time_ratio")
    fails = check_rule(rule, {}, {"one_pass_vs_two_pass": {"speedup": 1.0}}, 1.5)
    assert len(fails) == 1 and "generated" in fails[0]


def test_rules_cover_every_default_pair():
    for gen, _ in bench_gate.DEFAULT_PAIRS:
        assert rules_for(gen) is not None, gen
    # the method-suffixed mctm records pick up the mctm_fit rule set
    assert rules_for("BENCH_mctm_fit_smoke_lbfgs.json") is bench_gate.RULES["BENCH_mctm_fit"]


def test_gate_pair_end_to_end(tmp_path):
    base = {
        "n": 100, "degree": 6, "chunk_size": 8, "smoke": True,
        "speedup": 2.0, "max_abs_score_diff": 1e-7,
        "one_pass_vs_two_pass": {
            "speedup": 1.2, "one_pass_rows_streamed": 100,
            "one_pass_featurize_calls": 2,
            "median_rel_score_err": 0.04, "max_rel_score_err": 0.1,
            "fused_vs_unfused": {"measured_speedup": 2.5},
        },
    }
    bp = tmp_path / "BENCH_scoring_smoke.json"
    bp.write_text(json.dumps(base))
    gp = tmp_path / "gen" / "BENCH_scoring_smoke.json"
    gp.parent.mkdir()

    gen = dict(base, speedup=1.9)  # mild wall-clock noise
    gp.write_text(json.dumps(gen))
    assert gate_pair(str(gp), str(bp), time_ratio=1.5) == []

    gen = dict(base, max_abs_score_diff=1e-3)  # quality regression
    gp.write_text(json.dumps(gen))
    fails = gate_pair(str(gp), str(bp), time_ratio=1.5)
    assert fails and "max_abs_score_diff" in fails[0]

    # missing baseline fails unless explicitly allowed
    missing = str(tmp_path / "nope.json")
    assert gate_pair(str(gp), missing, time_ratio=1.5)
    assert gate_pair(str(gp), missing, time_ratio=1.5,
                     allow_missing_baseline=True) == []


def test_committed_baselines_parse_and_match_rules():
    """Every committed bench baseline is valid JSON and its rule set resolves
    all non-list paths — so the CI gate can't fail on a malformed baseline.
    Non-BENCH files in the dir (ANALYSIS_budgets.json, owned by
    scripts/analysis_gate.py and validated in tests/test_analysis.py) are out
    of scope for bench_gate's rules."""
    bdir = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "baselines")
    if not os.path.isdir(bdir):
        pytest.skip("no committed baselines")
    names = [n for n in os.listdir(bdir)
             if n.endswith(".json") and n.startswith("BENCH_")]
    assert names, "baseline dir exists but has no BENCH_* records"
    for name in names:
        with open(os.path.join(bdir, name)) as f:
            rec = json.load(f)
        rules = rules_for(name)
        assert rules is not None, name
        for rule in rules:
            vals = bench_gate._lookup(rec, rule.path)
            assert not any(isinstance(v, KeyError) for _, v in vals), (
                name, rule.path, vals)
