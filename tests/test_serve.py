"""Serving engine: continuous batching correctness + per-slot positions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serve import GenerationConfig, Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("tinyllama_1b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, n_new, max_len):
    """Single-request greedy decode (the unbatched ground truth)."""
    cache, _ = model.init_cache(1, max_len)
    logits, cache = model.prefill(params, {"tokens": prompt[None, :]}, cache)
    toks = [int(np.argmax(np.asarray(logits[0, -1])))]
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, np.asarray([[toks[-1]]], np.int32), cache
        )
        toks.append(int(np.argmax(np.asarray(logits[0, -1]))))
    return toks


def test_engine_matches_unbatched_greedy(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32) for n in (5, 9, 7)]
    engine = ServeEngine(model, params, n_slots=2, max_len=48)
    # per-slot position vector
    engine.cache["pos"] = jnp.zeros((2,), jnp.int32)
    reqs = [
        Request(uid=i, prompt=p, gen=GenerationConfig(max_new_tokens=6))
        for i, p in enumerate(prompts)
    ]
    for r in reqs:
        engine.submit(r)
    done = engine.run_until_drained()
    assert len(done) == 3
    for r in done:
        ref = _greedy_reference(model, params, r.prompt, 6, 48)
        assert r.output == ref, f"req {r.uid}: {r.output} vs {ref}"


def test_engine_recycles_slots(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    engine = ServeEngine(model, params, n_slots=2, max_len=32)
    engine.cache["pos"] = jnp.zeros((2,), jnp.int32)
    # 5 requests through 2 slots, mixed lengths
    for i in range(5):
        engine.submit(
            Request(
                uid=i,
                prompt=rng.integers(0, cfg.vocab_size, 4 + i).astype(np.int32),
                gen=GenerationConfig(max_new_tokens=3 + (i % 3)),
            )
        )
    done = engine.run_until_drained()
    assert sorted(r.uid for r in done) == [0, 1, 2, 3, 4]
    for r in done:
        assert len(r.output) == r.gen.max_new_tokens


def test_engine_rejects_encdec(setup):
    cfg = get_reduced_config("whisper_medium")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        ServeEngine(model, params)
