"""StreamingCoresetMaintainer: windowing/decay policies, drift detection,
and crash/resume bit-identity (the streaming contract in docs/STREAMING.md)."""
import tempfile

import jax
import numpy as np
import pytest

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.streaming import (
    STREAM_POLICIES,
    DriftDetector,
    StreamingCoresetMaintainer,
)
from repro.data.dgp import generate
from repro.ft.config import get_ft_config
from repro.ft.failure import FailureSimulator, InjectedFailure


def _setup(n=3072, seed=0, degree=4):
    Y = np.asarray(generate("normal_mixture", n, seed=seed), np.float32)
    cfg = M.MCTMConfig(J=2, degree=degree)
    return cfg, DataScaler.fit(Y), Y


def _windows(Y, w):
    return [Y[i : i + w] for i in range(0, len(Y), w)]


def test_policy_validation():
    cfg, scaler, _ = _setup(n=64)
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError):
        StreamingCoresetMaintainer(cfg, scaler, 32, key, policy="nope")
    with pytest.raises(ValueError):
        StreamingCoresetMaintainer(cfg, scaler, 32, key, policy="sliding")
    with pytest.raises(ValueError):
        StreamingCoresetMaintainer(cfg, scaler, 32, key, policy="decayed",
                                   decay=1.0)


def test_sliding_evicts_expired_buckets_exactly():
    """After T windows with window=W, exactly the last W births are live."""
    cfg, scaler, Y = _setup()
    m = StreamingCoresetMaintainer(
        cfg, scaler, 64, jax.random.PRNGKey(1), policy="sliding", window=3
    )
    for i, w in enumerate(_windows(Y, 384)):
        m.push(w)
        lo = max(0, i + 1 - 3)
        assert m.live_births() == list(range(lo, i + 1))
    # the evicted mass is gone: total weight covers only the live window
    assert m.total_weight() == pytest.approx(3 * 384, rel=1e-4)


def test_decayed_weights_match_closed_form():
    """After T equal windows of n rows under decay γ, total live weight is
    the geometric sum n·(1−γᵀ)/(1−γ) — exact, because every reduce conserves
    mass and decay is a plain scalar multiply."""
    cfg, scaler, Y = _setup()
    gamma, w = 0.6, 512
    m = StreamingCoresetMaintainer(
        cfg, scaler, 64, jax.random.PRNGKey(2), policy="decayed", decay=gamma
    )
    for T, rows in enumerate(_windows(Y, w), start=1):
        m.push(rows)
        expect = w * (1 - gamma**T) / (1 - gamma)
        assert m.total_weight() == pytest.approx(expect, rel=1e-4)


@pytest.mark.parametrize("policy", sorted(STREAM_POLICIES))
def test_result_idempotent_under_all_policies(policy):
    cfg, scaler, Y = _setup()
    kw = {"sliding": dict(window=2), "decayed": dict(decay=0.8)}.get(policy, {})
    m = StreamingCoresetMaintainer(
        cfg, scaler, 96, jax.random.PRNGKey(3), policy=policy,
        sketch_size=64, **kw
    )
    for rows in _windows(Y, 512):
        m.push(rows)
    r1, r2 = m.result(), m.result()
    np.testing.assert_array_equal(r1.Y, r2.Y)
    np.testing.assert_array_equal(r1.weights, r2.weights)
    # result() is a pure read: pushing after peeking stays deterministic
    m.push(Y[:512])
    r3 = m.result()
    assert r3.size > 0


@pytest.mark.parametrize("policy", sorted(STREAM_POLICIES))
def test_interrupted_resume_bit_identical(policy):
    """A maintainer killed mid-stream (injected failure at window 3) and
    resumed from its checkpoint must reproduce the uninterrupted final
    coreset bit-for-bit — the streaming analogue of test_scoring_resume."""
    cfg, scaler, Y = _setup()
    kw = {"sliding": dict(window=2), "decayed": dict(decay=0.7)}.get(policy, {})
    kw.update(policy=policy, sketch_size=64)
    key = jax.random.PRNGKey(4)
    windows = _windows(Y, 512)

    ref = StreamingCoresetMaintainer(cfg, scaler, 96, key, **kw)
    for rows in windows:
        ref.push(rows)
    rr = ref.result()

    ft = get_ft_config()
    with tempfile.TemporaryDirectory() as d:
        ft.simulator = FailureSimulator().inject("streaming", 3)
        try:
            interrupts = 0
            m = StreamingCoresetMaintainer(cfg, scaler, 96, key, ckpt_dir=d, **kw)
            done = 0
            while done < len(windows):
                try:
                    m.push(windows[done])
                    done = m.windows_done
                except InjectedFailure:
                    interrupts += 1
                    m = StreamingCoresetMaintainer(
                        cfg, scaler, 96, key, ckpt_dir=d, **kw
                    )
                    done = m.resume()
        finally:
            ft.simulator = None
        ri = m.result()

    assert interrupts >= 1
    assert m.n_seen == ref.n_seen
    np.testing.assert_array_equal(np.asarray(rr.Y), np.asarray(ri.Y))
    np.testing.assert_array_equal(np.asarray(rr.weights), np.asarray(ri.weights))


def test_state_dict_roundtrip_preserves_moments_and_detector():
    cfg, scaler, Y = _setup()
    det = DriftDetector(eps=0.2, alpha=0.5, min_windows=2)
    det.observe(1.0)
    det.observe(1.05)
    m = StreamingCoresetMaintainer(
        cfg, scaler, 64, jax.random.PRNGKey(5), sketch_size=64, detector=det
    )
    for rows in _windows(Y[:1536], 512):
        m.push(rows)
    state = m.state_dict()
    m2 = StreamingCoresetMaintainer(
        cfg, scaler, 64, jax.random.PRNGKey(5), sketch_size=64,
        detector=DriftDetector(eps=0.2, alpha=0.5, min_windows=2),
    )
    m2.load_state(state)
    assert m2.windows_done == m.windows_done and m2.n_seen == m.n_seen
    np.testing.assert_array_equal(m2.detector.state(), m.detector.state())
    assert (m2._moments is None) == (m._moments is None)
    if m._moments is not None:
        np.testing.assert_array_equal(m2._moments[0], m._moments[0])
    a, b = m.result(), m2.result()
    np.testing.assert_array_equal(a.Y, b.Y)
    np.testing.assert_array_equal(a.weights, b.weights)


# --------------------------------------------------------------- detector


def test_detector_anchor_never_fires_and_band_holds():
    det = DriftDetector(eps=0.1, alpha=0.5, min_windows=2)
    assert not det.observe(2.0)          # anchor observation
    for _ in range(5):
        assert not det.observe(2.01)     # ratio ≈ 1.005, inside the band
    assert det.alerts == 0
    assert det.in_band


def test_detector_fires_on_sustained_shift():
    det = DriftDetector(eps=0.1, alpha=0.5, min_windows=2)
    det.observe(1.0)
    fired = [det.observe(1.6) for _ in range(4)]
    assert any(fired)
    assert det.alerts == sum(fired)
    assert not det.in_band


def test_detector_reanchors_on_version_change():
    det = DriftDetector(eps=0.1, alpha=0.5, min_windows=1)
    det.observe(1.0, version=0)
    assert det.observe(1.8, version=0)   # drifted vs v0
    # new model published: first observation under v1 re-anchors (to the
    # engine's recorded fit NLL when given) and must not fire
    assert not det.observe(1.8, version=1, ref_hint=1.75)
    assert det.ref_version == 1 and det.ref_nll_pp == pytest.approx(1.75)
    assert not det.observe(1.76, version=1)
    assert det.in_band


def test_detector_state_roundtrip():
    det = DriftDetector(eps=0.15, alpha=0.4, min_windows=2)
    det.observe(1.2, version=0)
    det.observe(1.9, version=0)
    det.observe(1.9, version=0)
    s = det.state()
    det2 = DriftDetector(eps=0.15, alpha=0.4, min_windows=2)
    det2.load(s)
    np.testing.assert_array_equal(det2.state(), s)
    assert det2.ewma == det.ewma and det2.alerts == det.alerts
    # both continue identically
    assert det.observe(1.9) == det2.observe(1.9)
    assert det.ewma == det2.ewma
