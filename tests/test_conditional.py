"""Conditional MCTM (paper §4 extension): recovery + conditional coreset."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.conditional import (
    CMCTMConfig,
    build_conditional_coreset,
    cnll,
    conditional_coreset_scores,
    fit_cmctm,
)


@pytest.fixture(scope="module")
def cond_data():
    rng = np.random.default_rng(0)
    n, F = 4000, 2
    X = rng.standard_normal((n, F))
    beta_true = np.array([[1.5, -0.5], [0.3, 0.8]])
    eps = rng.standard_normal((n, 2)) @ np.linalg.cholesky(
        np.array([[1, 0.6], [0.6, 1]])
    ).T
    Y = X @ beta_true.T + eps
    return X, Y, beta_true


def test_conditional_fit_recovers_shift(cond_data):
    X, Y, beta_true = cond_data
    cfg = CMCTMConfig(J=2, n_features=2, degree=5)
    scaler = DataScaler.fit(Y)
    fit = fit_cmctm(cfg, scaler, Y, X, steps=900)
    # conditional NLL should beat the unconditional fit by ≈ the explained var
    uncond = M.fit_mctm(cfg.base, scaler, Y, steps=900)
    assert fit.final_nll < uncond.final_nll - 0.2 * Y.shape[0]
    # β enters through the monotone transform scale; check the *direction*
    b = np.asarray(fit.params.beta)
    corr0 = np.corrcoef(b[0], beta_true[0])[0, 1]
    assert abs(corr0) > 0.9


def test_conditional_coreset_scores_dimension(cond_data):
    X, Y, _ = cond_data
    cfg = CMCTMConfig(J=2, n_features=2, degree=5)
    scaler = DataScaler.fit(Y)
    s = conditional_coreset_scores(cfg, scaler, Y, X)
    assert s.shape == (Y.shape[0],)
    assert (s > 0).all()
    # Σ leverage ≤ rank(dJ + F) + uniform part
    assert s.sum() <= 2 * 6 + 2 + 1 + 1e-3


def test_conditional_coreset_fit_close_to_full(cond_data):
    X, Y, _ = cond_data
    cfg = CMCTMConfig(J=2, n_features=2, degree=5)
    scaler = DataScaler.fit(Y)
    full = fit_cmctm(cfg, scaler, Y, X, steps=800)
    idx, w = build_conditional_coreset(
        cfg, scaler, Y, X, k=200, key=jax.random.PRNGKey(1)
    )
    cs = fit_cmctm(cfg, scaler, Y[idx], X[idx], weights=w, steps=800)
    A, Ap = M.basis_features(cfg.base, scaler, jnp.asarray(Y))
    Xj = jnp.asarray(X, jnp.float32)
    nll_full = float(cnll(cfg, full.params, A, Ap, Xj))
    nll_cs = float(cnll(cfg, cs.params, A, Ap, Xj))
    assert nll_cs <= nll_full + 0.1 * abs(nll_full)
