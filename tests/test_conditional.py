"""Conditional MCTM (paper §4 extension): recovery + conditional coreset."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.conditional import (
    CMCTMConfig,
    build_conditional_coreset,
    cnll,
    conditional_coreset_scores,
    fit_cmctm,
)


@pytest.fixture(scope="module")
def cond_data():
    rng = np.random.default_rng(0)
    n, F = 4000, 2
    X = rng.standard_normal((n, F))
    beta_true = np.array([[1.5, -0.5], [0.3, 0.8]])
    eps = rng.standard_normal((n, 2)) @ np.linalg.cholesky(
        np.array([[1, 0.6], [0.6, 1]])
    ).T
    Y = X @ beta_true.T + eps
    return X, Y, beta_true


def test_conditional_fit_recovers_shift(cond_data):
    X, Y, beta_true = cond_data
    cfg = CMCTMConfig(J=2, n_features=2, degree=5)
    scaler = DataScaler.fit(Y)
    fit = fit_cmctm(cfg, scaler, Y, X, steps=900)
    # conditional NLL should beat the unconditional fit by ≈ the explained var
    uncond = M.fit_mctm(cfg.base, scaler, Y, steps=900)
    assert fit.final_nll < uncond.final_nll - 0.2 * Y.shape[0]
    # β enters through the monotone transform scale; check the *direction*
    b = np.asarray(fit.params.beta)
    corr0 = np.corrcoef(b[0], beta_true[0])[0, 1]
    assert abs(corr0) > 0.9


def test_conditional_coreset_scores_dimension(cond_data):
    X, Y, _ = cond_data
    cfg = CMCTMConfig(J=2, n_features=2, degree=5)
    scaler = DataScaler.fit(Y)
    s = conditional_coreset_scores(cfg, scaler, Y, X)
    assert s.shape == (Y.shape[0],)
    assert (s > 0).all()
    # Σ leverage ≤ rank(dJ + F) + uniform part
    assert s.sum() <= 2 * 6 + 2 + 1 + 1e-3


def test_conditional_scores_match_dense_oracle(cond_data):
    """Engine-routed (b_i, x_i) leverage ≡ the explicit augmented-matrix
    computation, dense and chunked."""
    from repro.core.leverage import leverage_scores_gram
    import jax.numpy as jnp

    X, Y, _ = cond_data
    cfg = CMCTMConfig(J=2, n_features=2, degree=5)
    scaler = DataScaler.fit(Y)
    A, _ = M.basis_features(cfg.base, scaler, jnp.asarray(Y))
    n = A.shape[0]
    feats = jnp.concatenate(
        [A.reshape(n, -1), jnp.asarray(X, jnp.float32)], axis=1
    )
    want = np.asarray(leverage_scores_gram(feats)) + 1.0 / n
    got_dense = conditional_coreset_scores(cfg, scaler, Y, X)
    got_chunked = conditional_coreset_scores(cfg, scaler, Y, X, chunk_size=257)
    # the engine's f64 host eigh vs the oracle's f32 device eigh: modes near
    # the rcond cutoff carry ~1e-4 solver noise on this Gaussian-feature Gram
    np.testing.assert_allclose(got_dense, want, atol=5e-4)
    np.testing.assert_allclose(got_chunked, want, atol=5e-4)


def test_conditional_coreset_exact_k_low_diversity_hull():
    """Adversarial hull: nearly all points identical, so the ε-kernel rows
    dedup to a handful of distinct points. The build must still return
    exactly k indices (shortfall topped up from next-ranked candidates)."""
    rng = np.random.default_rng(5)
    n, F = 400, 2
    # 5 distinct support points, everything else a single repeated row →
    # directional argmaxes concentrate on ≤ ~6 points
    Y = np.tile(rng.standard_normal((1, 2)), (n, 1))
    Y[:5] = rng.standard_normal((5, 2)) * 3.0
    X = rng.standard_normal((n, F))
    cfg = CMCTMConfig(J=2, n_features=F, degree=5)
    scaler = DataScaler.fit(Y)
    k = 80
    idx, w = build_conditional_coreset(
        cfg, scaler, Y, X, k=k, key=jax.random.PRNGKey(2), alpha=0.2
    )
    # α=0.2 → k2 = 64 hull slots ≫ distinct extremal points available
    assert idx.shape == (k,)
    assert w.shape == (k,)
    assert (w > 0).all()
    k1 = int(np.floor(0.2 * k))
    hull_part = idx[k1:]
    assert len(set(hull_part.tolist())) == k - k1  # top-up never duplicates


def test_conditional_coreset_fit_close_to_full(cond_data):
    X, Y, _ = cond_data
    cfg = CMCTMConfig(J=2, n_features=2, degree=5)
    scaler = DataScaler.fit(Y)
    full = fit_cmctm(cfg, scaler, Y, X, steps=800)
    idx, w = build_conditional_coreset(
        cfg, scaler, Y, X, k=200, key=jax.random.PRNGKey(1)
    )
    cs = fit_cmctm(cfg, scaler, Y[idx], X[idx], weights=w, steps=800)
    A, Ap = M.basis_features(cfg.base, scaler, jnp.asarray(Y))
    Xj = jnp.asarray(X, jnp.float32)
    nll_full = float(cnll(cfg, full.params, A, Ap, Xj))
    nll_cs = float(cnll(cfg, cs.params, A, Ap, Xj))
    assert nll_cs <= nll_full + 0.1 * abs(nll_full)
