"""Fault-tolerance: elastic planning, injected failure + checkpoint-restore
resume equivalence, straggler policy."""
import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced_config
from repro.data.synthetic_lm import TokenStreamConfig, sample_batch
from repro.ft import ElasticPlanner, FailureSimulator, StragglerPolicy
from repro.models import build_model
from repro.optim import adamw
from repro.train import init_train_state, make_train_step


def test_elastic_planner_full_strength():
    p = ElasticPlanner(model_parallel=16, base_data_parallel=16, n_pods=2, base_global_batch=256)
    plan = p.plan(512)
    assert plan.shape == (2, 16, 16)
    assert plan.global_batch == 256
    assert plan.lr_scale == 1.0


def test_elastic_planner_degraded():
    p = ElasticPlanner(model_parallel=16, base_data_parallel=16, n_pods=2, base_global_batch=256)
    plan = p.plan(300)  # lost ~40% of chips
    assert plan.devices_used <= 300
    assert plan.shape[-1] == 16  # TP degree preserved (memory constraint)
    assert plan.global_batch < 256
    assert 0 < plan.lr_scale < 1


def test_elastic_planner_single_pod_survivors():
    p = ElasticPlanner(model_parallel=16, base_data_parallel=16, n_pods=2)
    plan = p.plan(17 * 16)
    assert plan.axes[-1] == "model"
    assert plan.n_devices <= 17 * 16


def test_elastic_planner_insufficient():
    p = ElasticPlanner(model_parallel=16, base_data_parallel=16)
    with pytest.raises(RuntimeError):
        p.plan(8)


def test_straggler_policy():
    pol = StragglerPolicy(deadline_ms=100)
    decisions = pol.decide(np.array([10.0, 250.0, 99.0, 101.0]))
    assert decisions.tolist() == [False, True, False, True]


def test_failure_restore_resumes_identically(tmp_path):
    """Crash at step 5 → restore from step 4 → states at step 8 match an
    uninterrupted run (deterministic data ⇒ exact resume)."""
    cfg = get_reduced_config("olmo_1b")
    model = build_model(cfg)
    opt = adamw(1e-3)
    stream = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=16)
    step_fn = jax.jit(make_train_step(model, opt))

    def run(n_steps, state):
        for i in range(int(state.step), n_steps):
            state, _ = step_fn(state, sample_batch(stream, batch=4, step=i))
        return state

    params, _ = model.init(jax.random.PRNGKey(0))
    golden = run(8, init_train_state(params, opt))

    # interrupted run with checkpointing every step
    mgr = CheckpointManager(str(tmp_path))
    state = init_train_state(params, opt)
    sim = FailureSimulator({5})
    try:
        for i in range(8):
            sim.maybe_fail(i)
            state, _ = step_fn(state, sample_batch(stream, batch=4, step=i))
            mgr.save(i + 1, state)
    except RuntimeError:
        pass
    assert sim.failures == [5]
    template = init_train_state(params, opt)
    restored = mgr.restore(jax.tree.map(np.zeros_like, template))
    restored = jax.tree.unflatten(jax.tree.structure(template), jax.tree.leaves(restored))
    state = jax.tree.map(lambda x: jax.numpy.asarray(x), restored)
    from repro.train.state import TrainState

    state = TrainState(step=state.step, params=state.params, opt_state=state.opt_state)
    state = run(8, state)
    for a, b in zip(jax.tree.leaves(golden.params), jax.tree.leaves(state.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
