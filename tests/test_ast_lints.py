"""Unit suite for repro.analysis.ast_lints — the Python-hazard layer of the
analysis gate. Fixtures are small source snippets linted in-process (no jax
import needed)."""
import textwrap

from repro.analysis.ast_lints import lint_paths, lint_source


def _lint(src: str):
    return lint_source(textwrap.dedent(src))


def _codes(src: str):
    return [f.code for f in _lint(src)]


# ------------------------------------------------------------- AL001 PRNG


def test_prng_reuse_after_split_flagged():
    findings = _lint(
        """
        import jax

        def f(key):
            sub = jax.random.split(key, 2)
            return jax.random.normal(key, (3,))
        """
    )
    assert [f.code for f in findings] == ["AL001"]
    assert "key" in findings[0].message


def test_prng_rebind_idiom_clean():
    # the canonical key, sub = split(key) rotation must NOT be flagged
    assert _codes(
        """
        import jax

        def f(key):
            key, sub = jax.random.split(key)
            a = jax.random.normal(sub, (3,))
            key, sub = jax.random.split(key)
            return a + jax.random.normal(sub, (3,))
        """
    ) == []


def test_prng_fold_in_consumes_key():
    assert _codes(
        """
        import jax

        def f(key):
            k1 = jax.random.fold_in(key, 1)
            return jax.random.uniform(key, (2,))
        """
    ) == ["AL001"]


def test_prng_exclusive_branches_not_flagged():
    # consuming in one if-arm must not poison the other arm
    assert _codes(
        """
        import jax

        def f(key, flag):
            if flag:
                a, b, c = jax.random.split(key, 3)
            else:
                a, b = jax.random.split(key)
                c = None
            return a
        """
    ) == []


def test_prng_use_after_both_branches_consume_flagged():
    assert _codes(
        """
        import jax

        def f(key, flag):
            if flag:
                ks = jax.random.split(key, 3)
            else:
                ks = jax.random.split(key, 2)
            return jax.random.normal(key, (2,))
        """
    ) == ["AL001"]


def test_split_count_argument_is_not_a_key():
    # jax.random.split(ks[1], E): E is a count, not a key — regression test
    # for the models/layers.py init_moe false positive
    assert _codes(
        """
        import jax

        def f(key, E):
            ks = jax.random.split(key, 5)
            a = jax.random.split(ks[1], E)
            b = jax.random.split(ks[2], E)
            return a, b
        """
    ) == []


def test_prng_lint_respects_import_alias():
    assert _codes(
        """
        from jax import random

        def f(key):
            sub = random.split(key)
            return random.normal(key, (2,))
        """
    ) == ["AL001"]


# ------------------------------------------------------------- AL002 np-in-jit


def test_np_math_in_jitted_function_flagged():
    assert _codes(
        """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.sum(x)
        """
    ) == ["AL002"]


def test_np_math_on_static_config_not_flagged():
    # np math NOT involving a traced parameter is static setup — fine
    assert _codes(
        """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            c = np.sqrt(2.0)
            return x * c
        """
    ) == []


def test_np_math_outside_jit_not_flagged():
    assert _codes(
        """
        import numpy as np

        def f(x):
            return np.sum(x)
        """
    ) == []


def test_function_passed_to_jit_is_traced():
    assert _codes(
        """
        import jax
        import numpy as np

        def body(x):
            return np.dot(x, x)

        g = jax.jit(body)
        """
    ) == ["AL002"]


def test_scan_body_closure_is_traced():
    # a nested def inside a jitted function traces with it
    assert _codes(
        """
        import jax
        import numpy as np

        @jax.jit
        def outer(xs):
            def body(carry, x):
                return carry + np.log(xs), None
            return jax.lax.scan(body, 0.0, xs)
        """
    ) == ["AL002"]


def test_partial_jit_decorator_detected():
    assert _codes(
        """
        from functools import partial
        import jax
        import numpy as np

        @partial(jax.jit, static_argnums=(1,))
        def f(x, n):
            return np.mean(x)
        """
    ) == ["AL002"]


# ------------------------------------------------------------- AL003 defaults


def test_mutable_default_flagged():
    assert _codes(
        """
        def f(x, cache={}):
            return x
        """
    ) == ["AL003"]


def test_none_default_clean():
    assert _codes(
        """
        def f(x, cache=None, k=3, name="a", t=()):
            return x
        """
    ) == []


# ------------------------------------------------------------- noqa + sweep


def test_noqa_suppresses_specific_code():
    assert _codes(
        """
        import jax

        def f(key):
            sub = jax.random.split(key)
            return jax.random.normal(key, (2,))  # noqa: AL001
        """
    ) == []


def test_noqa_other_code_does_not_suppress():
    assert _codes(
        """
        import jax

        def f(key):
            sub = jax.random.split(key)
            return jax.random.normal(key, (2,))  # noqa: AL002
        """
    ) == ["AL001"]


def test_repo_source_tree_is_lint_clean():
    """src/repro must stay clean — the gate fails CI otherwise. Intentional
    exceptions carry a per-line noqa with a justification comment."""
    findings = lint_paths("src/repro")
    assert findings == [], [str(f) for f in findings]
