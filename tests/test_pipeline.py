import itertools

import jax
import numpy as np
import pytest

from repro.data import (
    CoresetSelector,
    DGP_NAMES,
    ShardedLoader,
    TokenStreamConfig,
    generate,
    generate_covertype,
    generate_equity_returns,
    sample_batch,
    subset_loader,
)


def test_dgps_all_generate():
    for name in DGP_NAMES:
        Y = generate(name, 500, seed=3)
        assert Y.shape == (500, 2)
        assert np.isfinite(Y).all(), name
    assert len(DGP_NAMES) == 14  # the paper's 14 processes


def test_covertype_shape_and_bounds():
    X = generate_covertype(2000, seed=0)
    assert X.shape == (2000, 10)
    hillshade = X[:, 6:9]
    assert (hillshade >= 0).all() and (hillshade <= 254).all()


def test_equity_heavy_tails():
    R = generate_equity_returns(5000, 10, seed=0)
    assert R.shape == (5000, 10)
    kurt = ((R - R.mean(0)) ** 4).mean(0) / (R.var(0) ** 2)
    assert (kurt > 4).all()  # heavier than gaussian (3)


def test_token_stream_deterministic_and_resumable():
    cfg = TokenStreamConfig(vocab_size=512, seq_len=16)
    a = sample_batch(cfg, batch=4, step=7)
    b = sample_batch(cfg, batch=4, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = sample_batch(cfg, batch=4, step=8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_sharded_loader_prefetch_and_resume():
    cfg = TokenStreamConfig(vocab_size=128, seq_len=8)
    loader = ShardedLoader(lambda step: sample_batch(cfg, 2, step), start_step=5)
    it = iter(loader)
    batches = list(itertools.islice(it, 3))
    assert [int(b["_step"]) for b in batches] == [5, 6, 7]
    # resume from saved state
    state = loader.state_dict(int(batches[-1]["_step"]) + 1)
    loader2 = ShardedLoader(lambda step: sample_batch(cfg, 2, step), **state)
    nxt = next(iter(loader2))
    assert int(nxt["_step"]) == 8
    np.testing.assert_array_equal(
        nxt["tokens"], sample_batch(cfg, 2, 8)["tokens"]
    )


def test_coreset_selector_weights_unbiased():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((500, 6)).astype(np.float32)
    sel = CoresetSelector(featurize=lambda e: e, method="l2-hull")
    sub = sel.select(X, k=100, key=jax.random.PRNGKey(0))
    assert sub.size == 100
    assert (sub.weights > 0).all()
    # sum of weights ≈ n for the sampled part + hull points count
    assert sub.weights.sum() == pytest.approx(500, rel=0.5)


def test_coreset_selector_uniform():
    X = np.random.default_rng(1).standard_normal((100, 3)).astype(np.float32)
    sel = CoresetSelector(featurize=lambda e: e, method="uniform")
    sub = sel.select(X, k=10, key=jax.random.PRNGKey(1))
    assert len(set(sub.indices.tolist())) == 10  # without replacement
    np.testing.assert_allclose(sub.weights, 10.0)


def test_subset_loader_emits_weights():
    data = {"x": np.arange(50, dtype=np.float32)}
    sel = CoresetSelector(
        featurize=lambda e: np.stack([e, np.ones_like(e)], axis=1), method="l2-only"
    )
    sub = sel.select(data["x"], k=20, key=jax.random.PRNGKey(2))
    fn = subset_loader(data, sub, batch=8)
    b0, b0b = fn(0), fn(0)
    np.testing.assert_array_equal(b0["x"], b0b["x"])  # deterministic
    assert b0["weights"].shape == (8,)
    assert set(b0["x"].tolist()) <= set(data["x"][sub.indices].tolist())


def test_coreset_selector_sketched_one_pass():
    """sketch_size: selection runs through the one-pass strategy (each
    feature row featurized exactly once when chunked), stays deterministic
    under a fixed key, and still returns exact-k weighted subsets."""
    rng = np.random.default_rng(3)
    X = rng.standard_normal((500, 6)).astype(np.float32)
    calls = []

    def featurize(e):
        calls.append(e.shape[0])
        return e * 2.0

    sel = CoresetSelector(
        featurize=featurize, method="l2-hull", chunk_size=128, sketch_size=256
    )
    sub = sel.select(X, k=64, key=jax.random.PRNGKey(0))
    assert sum(calls) == 500 and len(calls) == 4  # one pass over 4 chunks
    assert sub.size == 64 and (sub.weights > 0).all()
    sub2 = CoresetSelector(
        featurize=lambda e: e * 2.0, method="l2-hull", chunk_size=128,
        sketch_size=256,
    ).select(X, k=64, key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(sub.indices, sub2.indices)
    np.testing.assert_allclose(sub.weights, sub2.weights)


def test_importance_sampling_unbiased_with_constant_batch_weight():
    """w-proportional draws with the 1/p correction: same expectation as
    uniform draws (so the minibatch normalizer is untouched), zero weight
    variance inside every batch."""
    from repro.data.pipeline import full_data_loader

    rng = np.random.default_rng(0)
    n, b = 4000, 256
    f = rng.normal(size=n).astype(np.float32)
    w = (rng.pareto(1.2, n) + 0.1).astype(np.float32)  # heavy-tailed weights
    target = float(w.astype(np.float64) @ f.astype(np.float64))
    data = {"f": f}

    fn = full_data_loader(data, w, b, seed=1, sampling="importance")
    b0, b0b = fn(0), fn(0)
    np.testing.assert_array_equal(b0["f"], b0b["f"])  # pure in (seed, step)
    # the 1/p-corrected weight is the CONSTANT Σw/n in every batch
    np.testing.assert_allclose(b0["weights"], w.sum() / n, rtol=1e-5)

    ests = np.array([
        fn(s)["weights"].astype(np.float64) @ fn(s)["f"] * (n / b)
        for s in range(800)
    ])
    se = ests.std() / np.sqrt(len(ests))
    assert abs(ests.mean() - target) < 5 * se  # unbiased

    # and it beats uniform draws on estimator spread for heavy-tailed w
    fn_u = full_data_loader(data, w, b, seed=1, sampling="uniform")
    ests_u = np.array([
        fn_u(s)["weights"].astype(np.float64) @ fn_u(s)["f"] * (n / b)
        for s in range(800)
    ])
    assert ests.std() < 0.5 * ests_u.std()


def test_importance_sampling_subset_loader_and_validation():
    from repro.data.pipeline import full_data_loader

    data = {"x": np.arange(50, dtype=np.float32)}
    sel = CoresetSelector(
        featurize=lambda e: np.stack([e, np.ones_like(e)], axis=1), method="l2-only"
    )
    sub = sel.select(data["x"], k=20, key=jax.random.PRNGKey(2))
    fn = subset_loader(data, sub, batch=8, sampling="importance")
    batch = fn(0)
    assert set(batch["x"].tolist()) <= set(data["x"][sub.indices].tolist())
    np.testing.assert_allclose(
        batch["weights"], sub.weights.sum() / sub.size, rtol=1e-5
    )
    with pytest.raises(ValueError):
        subset_loader(data, sub, batch=8, sampling="nope")
    with pytest.raises(ValueError):
        full_data_loader(data, np.zeros(50, np.float32), 8, sampling="importance")


def test_importance_sampling_minibatch_fit_runs():
    """End-to-end: the minibatch fit mode accepts sampling="importance" and
    converges on heavy-tailed weights (plumbing check)."""
    from repro.core import mctm as M
    from repro.core.bernstein import DataScaler
    from repro.core.mctm_fit import fit_mctm_streaming

    rng = np.random.default_rng(0)
    Y = rng.normal(size=(1200, 2)).astype(np.float32)
    w = (rng.pareto(1.3, 1200) + 0.1).astype(np.float32)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)
    fit = fit_mctm_streaming(
        cfg, scaler, Y, weights=w, key=jax.random.PRNGKey(0),
        steps=30, method="minibatch", batch_size=256, sampling="importance",
    )
    assert np.isfinite(fit.final_nll)
    assert np.isfinite(fit.losses).all()
