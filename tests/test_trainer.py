import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.data.synthetic_lm import TokenStreamConfig, sample_batch
from repro.models import build_model
from repro.optim import adamw, chain, clip_by_global_norm
from repro.train import TrainState, init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_reduced_config("tinyllama_1b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = adamw(3e-3)
    state = init_train_state(params, opt)
    stream = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=32)
    return cfg, model, opt, state, stream


def test_training_reduces_loss(tiny_setup):
    cfg, model, opt, state, stream = tiny_setup
    step = jax.jit(make_train_step(model, opt))
    losses = []
    for i in range(30):
        batch = sample_batch(stream, batch=8, step=i)
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert int(state.step) == 30
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_microbatch_equals_full_batch_grads(tiny_setup):
    """Gradient accumulation ≈ full-batch gradient (bf16 forward ⇒ compare
    by direction + loss value, not elementwise post-optimizer params)."""
    cfg, model, opt, _, stream = tiny_setup
    params, _ = model.init(jax.random.PRNGKey(1))
    batch = sample_batch(stream, batch=8, step=0)

    loss_full, _ = model.loss_fn(params, batch)
    g_full = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)

    def reshape(x):
        return x.reshape(4, 2, *x.shape[1:])

    mb = jax.tree.map(reshape, batch)
    losses, grads = [], []
    for i in range(4):
        one = jax.tree.map(lambda x: x[i], mb)
        losses.append(float(model.loss_fn(params, one)[0]))
        grads.append(jax.grad(lambda p: model.loss_fn(p, one)[0])(params))
    g_acc = jax.tree.map(lambda *x: sum(x) / 4, *grads)

    assert np.mean(losses) == pytest.approx(float(loss_full), abs=2e-2)
    va = np.concatenate([np.asarray(x, np.float32).ravel() for x in jax.tree.leaves(g_full)])
    vb = np.concatenate([np.asarray(x, np.float32).ravel() for x in jax.tree.leaves(g_acc)])
    cos = float(va @ vb / (np.linalg.norm(va) * np.linalg.norm(vb)))
    assert cos > 0.99


def test_weighted_examples_change_gradients(tiny_setup):
    cfg, model, opt, state, stream = tiny_setup
    batch = sample_batch(stream, batch=8, step=0)
    loss_w1, _ = model.loss_fn(state.params, batch)
    batch2 = dict(batch, weights=np.array([4.0] + [0.0] * 7, np.float32))
    loss_w2, _ = model.loss_fn(state.params, batch2)
    # weighting changes the objective (coreset weights flow through)
    assert abs(float(loss_w1) - float(loss_w2)) > 1e-4


def test_grad_clip_chain(tiny_setup):
    cfg, model, _, _, stream = tiny_setup
    params, _ = model.init(jax.random.PRNGKey(2))
    opt = chain(clip_by_global_norm(1e-9), adamw(1e-2))
    state = init_train_state(params, opt)
    step = jax.jit(make_train_step(model, opt))
    batch = sample_batch(stream, batch=4, step=0)
    new_state, m = step(state, batch)
    # with clip ~0 the params barely move beyond adam epsilon effects
    delta = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(new_state.params))
    )
    assert delta < 0.05
