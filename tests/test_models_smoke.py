"""Per-arch smoke tests: reduced config, one forward/train step, shapes + no NaNs,
plus prefill/decode ≡ re-prefill consistency (cache correctness) per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_reduced_config
from repro.models import build_model


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32),
        "weights": np.ones((B,), np.float32),
    }
    if cfg.modality == "vision":
        batch["patch_embeds"] = (
            rng.standard_normal((B, cfg.n_modality_positions, cfg.d_model)).astype(np.float32) * 0.02
        )
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32) * 0.02
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_train_step(name):
    cfg = get_reduced_config(name)
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda s: isinstance(s, tuple)
    )
    batch = _batch(cfg)
    loss, metrics = model.loss_fn(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) == pytest.approx(np.log(cfg.vocab_size), rel=0.35)

    # two small SGD steps decrease loss on the same batch
    for _ in range(2):
        grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
        params = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss2, _ = model.loss_fn(params, batch)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_consistency(name):
    """logits(prefill n) == logits(prefill n−1, then decode 1 token)."""
    cfg = get_reduced_config(name)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    batch = _batch(cfg, B=B, S=S, seed=1)
    maxlen = S + cfg.n_modality_positions + 4

    if cfg.family == "encdec":
        cache_a, _ = model.init_cache(B, S)
        pre_a = {"frames": batch["frames"], "tokens": batch["tokens"][:, :6]}
        logits_a, _ = model.prefill(params, pre_a, cache_a)
        cache_b, _ = model.init_cache(B, S)
        pre_b = {"frames": batch["frames"], "tokens": batch["tokens"][:, :5]}
        _, cache_b = model.prefill(params, pre_b, cache_b)
        logits_b, _ = model.decode_step(params, batch["tokens"][:, 5:6], cache_b)
    else:
        pre_keys = ("tokens", "patch_embeds")
        cache_a, _ = model.init_cache(B, maxlen)
        pre_a = {k: v for k, v in batch.items() if k in pre_keys}
        pre_a["tokens"] = batch["tokens"][:, :6]
        logits_a, _ = model.prefill(params, pre_a, cache_a)
        cache_b, _ = model.init_cache(B, maxlen)
        pre_b = dict(pre_a, tokens=batch["tokens"][:, :5])
        _, cache_b = model.prefill(params, pre_b, cache_b)
        logits_b, _ = model.decode_step(params, batch["tokens"][:, 5:6], cache_b)

    a = np.asarray(logits_a[:, -1], np.float32)
    b = np.asarray(logits_b[:, -1], np.float32)
    np.testing.assert_allclose(a, b, atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_configs_have_published_dims(name):
    cfg = get_config(name)
    # spot-check the assignment table numbers
    table = {
        "phi3_vision_4b": (32, 3072, 32, 32, 8192, 32064),
        "olmo_1b": (16, 2048, 16, 16, 8192, 50304),
        "minicpm3_4b": (62, 2560, 40, 40, 6400, 73448),
        "tinyllama_1b": (22, 2048, 32, 4, 5632, 32000),
        "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "whisper_medium": (48, 1024, 16, 16, 4096, 51865),
        "mamba2_370m": (48, 1024, 32, 32, 0, 50280),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == table


def test_moe_aux_loss_positive():
    cfg = get_reduced_config("qwen2_moe_a2_7b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    _, metrics = model.loss_fn(params, _batch(cfg))
    assert float(metrics["aux"]) > 0


def test_local_attention_matches_dense_banded():
    from repro.models.layers import _sdpa, causal_mask, local_attention_chunked

    rng = np.random.default_rng(3)
    B, S, H, hd, W = 2, 64, 2, 16, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    dense = _sdpa(q, k, v, causal_mask(S, S, 0, W))
    chunked = local_attention_chunked(q, k, v, W)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense), atol=1e-5)
