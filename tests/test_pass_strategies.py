"""Pass-strategy layer: one-pass sketched scoring, f64 Gram conditioning,
plan determinism, and the strategy↔strategy equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.leverage import flatten_features, leverage_scores_gram, sketched_leverage
from repro.core.scoring import (
    OnePassSketched,
    ScoringEngine,
    TwoPassExact,
    TwoPassSketched,
    resolve_strategy,
)


def _setup(n=503, J=2, degree=5, seed=0, uniform=True):
    rng = np.random.default_rng(seed)
    Y = rng.random((n, J)) if uniform else rng.standard_normal((n, J))
    cfg = M.MCTMConfig(J=J, degree=degree)
    scaler = DataScaler.fit(Y)
    return cfg, scaler, Y


def _counting_engine(chunk):
    """Identity-featurize engine that records every chunk streamed."""
    calls = []

    def featurize(Yc):
        calls.append(int(Yc.shape[0]))
        F = jnp.asarray(Yc, jnp.float32)
        return F, F

    return ScoringEngine(featurize=featurize, chunk_size=chunk, rows_per_point=1), calls


def test_one_pass_streams_each_row_exactly_once():
    """THE one-pass contract: every row featurized exactly once per score
    call, hull stage included (fused into the same sweep) — vs the two-pass
    strategy's two sweeps over the same chunks."""
    rng = np.random.default_rng(0)
    Y = rng.standard_normal((200, 6)).astype(np.float32)
    key = jax.random.PRNGKey(1)
    hkey = jax.random.PRNGKey(2)

    engine, calls = _counting_engine(chunk=64)
    engine.score(Y, method="l2-hull", hull_k=4, hull_key=hkey,
                 sketch_size=128, key=key)
    assert len(calls) == 4          # ⌈200/64⌉ chunks, ONE sweep
    assert sum(calls) == 200        # each row streamed exactly once
    assert max(calls) <= 64         # O(chunk) working set preserved

    calls.clear()
    engine.score(Y, method="l2-hull", hull_k=4, hull_key=hkey)  # two-pass
    assert len(calls) == 2 * 4 and sum(calls) == 2 * 200

    # dense fast path: both strategies featurize exactly once
    engine, calls = _counting_engine(chunk=0)
    engine.score(Y, method="l2-only", sketch_size=128, key=key)
    assert calls == [200]


def test_one_pass_matches_two_pass_sketched_exactly():
    """Same CountSketch plan → identical leverage estimates, whether the rows
    are re-streamed (two-pass-sketched) or retained (one-pass, Ω=identity).
    Both match the standalone ``sketched_leverage`` baseline."""
    cfg, scaler, Y = _setup(seed=3)
    key = jax.random.PRNGKey(11)
    for chunk in (0, 100):
        engine = ScoringEngine(cfg, scaler, chunk_size=chunk)
        one = engine.score(jnp.asarray(Y), method="l2-only",
                           sketch_size=256, key=key)
        two = engine.score(jnp.asarray(Y), method="l2-only", sketch_size=256,
                           key=key, strategy="two-pass-sketched")
        if jax.config.jax_enable_x64:
            # x64 changes which host-side finalize ops run in f64, so the two
            # strategies reassociate differently — equal to float noise only
            np.testing.assert_allclose(one.leverage, two.leverage, rtol=1e-6)
        else:
            np.testing.assert_array_equal(one.leverage, two.leverage)
        A, _ = M.basis_features(cfg, scaler, jnp.asarray(Y))
        ref = np.asarray(sketched_leverage(flatten_features(A), key, 256))
        np.testing.assert_allclose(one.leverage, ref, atol=1e-4)


def test_sketched_leverage_error_shrinks_with_sketch_size():
    """Property: the one-pass constant-factor estimates tighten as the
    CountSketch grows (the whole point of the sketch_size knob)."""
    rng = np.random.default_rng(0)
    errs = {64: [], 1024: []}
    for seed in range(5):
        F = rng.standard_normal((1500, 12)).astype(np.float32)
        exact = np.asarray(leverage_scores_gram(jnp.asarray(F)))
        engine = ScoringEngine(
            featurize=lambda Yc: (jnp.asarray(Yc), None),
            chunk_size=256,
            rows_per_point=1,
        )
        key = jax.random.PRNGKey(seed)
        for s in errs:
            got = engine.score(F, method="l2-only", sketch_size=s, key=key).leverage
            rel = np.abs(got - exact) / np.maximum(exact, 1e-6)
            errs[s].append(float(np.median(rel)))
    small, big = np.mean(errs[64]), np.mean(errs[1024])
    assert big < small, (errs, "larger sketch must be tighter on average")
    assert big < 0.05  # 1024 buckets for a rank-12 subspace: few-% regime


def test_sketch_plan_deterministic_under_fixed_key():
    """Same key → identical scores AND hull candidates across engine
    instances; a different key moves the estimates."""
    cfg, scaler, Y = _setup(seed=4)
    hkey = jax.random.PRNGKey(7)
    a = ScoringEngine(cfg, scaler, chunk_size=100).score(
        jnp.asarray(Y), method="l2-hull", hull_k=8, hull_key=hkey,
        sketch_size=128, key=jax.random.PRNGKey(5))
    b = ScoringEngine(cfg, scaler, chunk_size=100).score(
        jnp.asarray(Y), method="l2-hull", hull_k=8, hull_key=hkey,
        sketch_size=128, key=jax.random.PRNGKey(5))
    np.testing.assert_array_equal(a.scores, b.scores)
    np.testing.assert_array_equal(a.hull_rows, b.hull_rows)
    c = ScoringEngine(cfg, scaler, chunk_size=100).score(
        jnp.asarray(Y), method="l2-hull", hull_k=8, hull_key=hkey,
        sketch_size=128, key=jax.random.PRNGKey(6))
    assert np.abs(a.scores - c.scores).max() > 0


def test_one_pass_vs_two_pass_downstream_nll_agreement():
    """Coresets built by the two strategies fit statistically equivalent
    models: weighted-NLL of the full data under each coreset fit agrees."""
    from repro.core.coreset import build_coreset
    from repro.data.dgp import generate

    Y = generate("normal_mixture", 3000, seed=0)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)
    key = jax.random.PRNGKey(0)
    fit_key = jax.random.PRNGKey(1)

    nlls = {}
    for name, sketch in (("two-pass", 0), ("one-pass", 512)):
        cs = build_coreset(cfg, scaler, Y, 300, "l2-hull", key=key,
                           sketch_size=sketch)
        assert cs.size == 300
        fit = M.fit_mctm(
            cfg, scaler, jnp.asarray(Y[cs.indices]),
            weights=jnp.asarray(cs.weights, jnp.float32),
            key=fit_key, steps=300, lr=5e-2,
        )
        A, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y))
        nlls[name] = float(M.nll(cfg, fit.params, A, Ap))
    rel = abs(nlls["one-pass"] - nlls["two-pass"]) / max(abs(nlls["two-pass"]), 1e-6)
    assert rel < 0.1, nlls


def test_gram_dtype_float64_stabilizes_degree6():
    """f64 Gram accumulation makes degree-6 leverage chunk-layout-invariant
    (f32 puts genuine eigenvalues at the rcond cutoff, where accumulation
    order is visible)."""
    cfg, scaler, Y = _setup(n=1003, degree=6, seed=0, uniform=False)
    dense = ScoringEngine(cfg, scaler, chunk_size=0, gram_dtype="float64").score(
        jnp.asarray(Y), method="l2-only")
    chunked = ScoringEngine(cfg, scaler, chunk_size=64, gram_dtype="float64").score(
        jnp.asarray(Y), method="l2-only")
    assert np.abs(dense.scores - chunked.scores).max() <= 1e-6
    # and the f64 Gram really is carried in f64
    assert dense.gram.dtype == np.float64


def test_sketched_gram_dtype_refuses_without_x64():
    """An f64 CountSketch accumulator without x64 would silently downcast on
    device — ``_acc_dtype`` must refuse loudly (x64 is off in this process)."""
    assert not jax.config.jax_enable_x64
    for strat in (OnePassSketched(64, "float64"), TwoPassSketched(64, "float64")):
        with pytest.raises(ValueError, match="x64"):
            strat.init_state(12, None)


def test_sketched_gram_dtype_float64_parity():
    """Under x64 the sketched strategies carry SX in f64 (the sketched
    analogue of the two-pass f64 Gram carry) and reproduce the f32 leverage
    estimates — same plan, same streamed rows, only the accumulator widens.
    x64 must be set before jax initializes, so this runs in a subprocess."""
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = """
    import jax, jax.numpy as jnp, numpy as np
    assert jax.config.jax_enable_x64
    from repro.core.scoring import OnePassSketched, ScoringEngine, TwoPassSketched
    rng = np.random.default_rng(0)
    F = rng.standard_normal((700, 10)).astype(np.float32)
    engine = ScoringEngine(
        featurize=lambda Yc: (jnp.asarray(Yc, jnp.float32), None),
        chunk_size=128, rows_per_point=1,
    )
    key = jax.random.PRNGKey(0)
    for cls in (OnePassSketched, TwoPassSketched):
        # the accumulator really is carried in f64...
        st = cls(512, "float64").init_state(10, None)
        assert st[0].dtype == jnp.float64, cls.__name__
        # ...and the widened accumulation reproduces the f32 estimates
        s32 = engine.score(F, method="l2-only", key=key,
                           strategy=cls(512, "float32"))
        s64 = engine.score(F, method="l2-only", key=key,
                           strategy=cls(512, "float64"))
        rel = np.abs(s64.leverage - s32.leverage) / np.maximum(
            np.abs(s32.leverage), 1e-6)
        assert rel.max() < 1e-3, (cls.__name__, float(rel.max()))
        assert np.isfinite(np.asarray(s64.scores)).all()
    print("OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]


def test_proj_size_compression():
    """Ω-projected retention: proj_size ≥ rank reproduces the plain one-pass
    estimates (leverage is invariant under rank-preserving right
    multiplication); proj_size < rank degrades gracefully."""
    rng = np.random.default_rng(0)
    F = rng.standard_normal((800, 12)).astype(np.float32)
    engine = ScoringEngine(
        featurize=lambda Yc: (jnp.asarray(Yc), None),
        chunk_size=128,
        rows_per_point=1,
    )
    key = jax.random.PRNGKey(0)
    plain = engine.score(F, method="l2-only", sketch_size=1024, key=key)
    full = engine.score(F, method="l2-only", key=key,
                        strategy=OnePassSketched(1024, proj_size=12))
    # proj_size ≥ D → Ω is skipped entirely: identical retention
    np.testing.assert_array_equal(plain.leverage, full.leverage)
    low = engine.score(F, method="l2-only", key=key,
                       strategy=OnePassSketched(1024, proj_size=8))
    exact = np.asarray(leverage_scores_gram(jnp.asarray(F)))
    rel = np.abs(low.leverage - exact) / np.maximum(exact, 1e-6)
    assert np.isfinite(low.leverage).all()
    # rank-8 projection of a rank-12 row space: lossy but score-shaped
    assert np.median(rel) < 1.0
    assert np.corrcoef(low.leverage, exact)[0, 1] > 0.5


def test_resolve_strategy():
    assert isinstance(resolve_strategy(None), TwoPassExact)
    assert isinstance(resolve_strategy(None, sketch_size=64), OnePassSketched)
    assert isinstance(resolve_strategy("two-pass-sketched", sketch_size=64),
                      TwoPassSketched)
    assert resolve_strategy(None, gram_dtype="float64").gram_dtype == "float64"
    s = OnePassSketched(32)
    assert resolve_strategy(s) is s
    with pytest.raises(ValueError):
        resolve_strategy("nope")
    with pytest.raises(ValueError):
        resolve_strategy("one-pass", sketch_size=0)  # sketchless sketch
    with pytest.raises(ValueError):
        TwoPassExact("float16")
    engine = ScoringEngine(
        featurize=lambda Yc: (jnp.asarray(Yc), None), rows_per_point=1
    )
    with pytest.raises(ValueError):
        engine.score(np.ones((4, 2), np.float32), method="l2-only",
                     strategy="one-pass", sketch_size=64)  # key missing
