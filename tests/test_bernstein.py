import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bernstein import (
    DataScaler,
    bernstein_design,
    bernstein_deriv_design,
    binomial_coefficients,
    monotone_theta,
    monotone_theta_inverse,
)


@pytest.mark.parametrize("degree", [1, 3, 6, 10])
def test_partition_of_unity(degree):
    t = jnp.linspace(0, 1, 101)
    basis = bernstein_design(t, degree)
    assert basis.shape == (101, degree + 1)
    np.testing.assert_allclose(np.asarray(basis.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(basis) >= -1e-6).all()


@pytest.mark.parametrize("degree", [2, 6])
def test_derivative_matches_finite_difference(degree):
    t = jnp.linspace(0.05, 0.95, 37)
    eps = 1e-4
    d_analytic = bernstein_deriv_design(t, degree)
    d_numeric = (bernstein_design(t + eps, degree) - bernstein_design(t - eps, degree)) / (
        2 * eps
    )
    np.testing.assert_allclose(np.asarray(d_analytic), np.asarray(d_numeric), atol=1e-2)


def test_binomial_coefficients():
    np.testing.assert_allclose(binomial_coefficients(4), [1, 4, 6, 4, 1])


@given(st.lists(st.floats(-3, 3), min_size=2, max_size=9))
@settings(max_examples=30, deadline=None)
def test_monotone_reparam_strictly_increasing(raw):
    theta = monotone_theta(jnp.asarray(raw, jnp.float32))
    diffs = np.diff(np.asarray(theta))
    assert (diffs > 0).all()


def test_monotone_reparam_roundtrip():
    theta = jnp.asarray([-1.0, 0.0, 0.7, 2.0, 5.0])
    raw = monotone_theta_inverse(theta)
    back = monotone_theta(raw)
    np.testing.assert_allclose(np.asarray(back), np.asarray(theta), rtol=1e-4, atol=1e-4)


def test_monotone_transform_has_positive_derivative_everywhere():
    key = jax.random.PRNGKey(0)
    raw = jax.random.normal(key, (7,))
    theta = monotone_theta(raw)
    t = jnp.linspace(0, 1, 200)
    deriv = bernstein_deriv_design(t, 6) @ theta
    assert (np.asarray(deriv) > 0).all()


def test_scaler_maps_to_unit_interval():
    rng = np.random.default_rng(0)
    Y = rng.normal(3.0, 10.0, (500, 3))
    sc = DataScaler.fit(Y)
    T = np.asarray(sc.transform(jnp.asarray(Y)))
    assert (T > 0).all() and (T < 1).all()
