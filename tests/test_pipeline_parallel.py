"""GPipe pipeline-parallel forward ≡ sequential forward (subprocess, 8 devs)."""
import os
import subprocess
import sys
import textwrap

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_pipeline_forward_matches_sequential():
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.distributed.pipeline_parallel import pipeline_forward, split_stages

        mesh = make_mesh((4, 2), ("stage", "data"))
        L, D = 8, 16
        rng = np.random.default_rng(0)
        layer_w = jnp.asarray(rng.standard_normal((L, D, D)) * 0.1, jnp.float32)

        def layer_fn(w, x):
            return jnp.tanh(x @ w)

        n_micro, mb, S = 4, 2, 4
        x = jnp.asarray(rng.standard_normal((n_micro, mb, S, D)), jnp.float32)

        # sequential reference
        def seq(x):
            def body(h, w):
                return layer_fn(w, h), None
            h, _ = jax.lax.scan(body, x, layer_w)
            return h
        ref = jax.vmap(seq)(x)

        stages = split_stages(layer_w, 4)
        out = pipeline_forward(x, stages, layer_fn, mesh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
        print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
