"""2-process CPU validation of ``host_gather``'s multi-process path.

CI meshes are single-process fake-device meshes, so the non-fully-addressable
branch of ``host_gather`` (process_allgather, falling back to the distributed
runtime's KV store on backends that cannot run multi-process computations —
CPU is one) is never touched there. This harness spawns two real jax
processes wired through ``jax.distributed.initialize`` on localhost, builds
global arrays whose shards live in different processes, and asserts the
gather reproduces the full matrix in both of them.
"""
import os
import socket
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

WORKER = textwrap.dedent(
    """
    import os, sys
    pid, port = int(sys.argv[1]), sys.argv[2]
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    try:
        jax.distributed.initialize(
            coordinator_address=f"127.0.0.1:{port}", num_processes=2, process_id=pid
        )
    except Exception as e:  # environment cannot run multi-process jax at all
        print("SKIP:", type(e).__name__, e, flush=True)
        sys.exit(0)
    import numpy as np
    import jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core.distributed_coreset import host_gather

    assert jax.process_count() == 2 and jax.device_count() == 4

    mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("data",))
    sharding = NamedSharding(mesh, P("data", None))
    full = np.arange(16 * 3, dtype=np.float32).reshape(16, 3)

    # build the global row-sharded array from process-LOCAL shards only —
    # each process ever touches half the rows
    blocks = [
        jax.device_put(full[sharding.devices_indices_map((16, 3))[d][0]], d)
        for d in jax.local_devices()
    ]
    arr = jax.make_array_from_single_device_arrays((16, 3), sharding, blocks)
    assert not arr.is_fully_addressable

    got = host_gather(arr)  # exercises the cross-process branch
    np.testing.assert_array_equal(got, full)

    # a second gather in the same session: the per-call KV namespace/barrier
    # sequencing must hold up across repeated collective calls
    np.testing.assert_array_equal(host_gather(arr), full)

    # fully-replicated output path: read from a local shard, no collective
    rep_val = np.arange(5, dtype=np.float32)
    rep = jax.make_array_from_single_device_arrays(
        (5,),
        NamedSharding(mesh, P()),
        [jax.device_put(rep_val, d) for d in jax.local_devices()],
    )
    assert not rep.is_fully_addressable and rep.is_fully_replicated
    np.testing.assert_array_equal(host_gather(rep), rep_val)

    print("OK", pid, flush=True)
    """
)


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


# ---------------------------------------------------------------------------
# kill-one-worker recovery: worker 1 dies mid-training; worker 0's next
# kv_allreduce hits the barrier timeout (the dead-peer signal), the
# RunSupervisor catches it, re-plans onto the surviving local device pool,
# restores the last atomic checkpoint, and finishes the run degraded —
# landing within tolerance of an uninterrupted single-process reference.
# ---------------------------------------------------------------------------

KILL_WORKER = textwrap.dedent(
    """
    import os, sys
    pid, port, ckpt_dir = int(sys.argv[1]), sys.argv[2], sys.argv[3]
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    try:
        jax.distributed.initialize(
            coordinator_address=f"127.0.0.1:{port}", num_processes=2, process_id=pid
        )
    except Exception as e:  # environment cannot run multi-process jax at all
        print("SKIP:", type(e).__name__, e, flush=True)
        sys.exit(0)
    import numpy as np
    from repro.checkpoint import CheckpointManager
    from repro.core.distributed_coreset import kv_allreduce
    from repro.ft import RunSupervisor
    from repro.ft.config import ft_overrides

    STEPS, KILL_AT, LR = 12, 7, 0.05
    rng = np.random.default_rng(0)
    X = rng.standard_normal((64, 4)).astype(np.float32)
    y = (X @ np.array([1.0, -2.0, 0.5, 3.0], np.float32)).astype(np.float32)
    halves = np.array_split(np.arange(64), 2)
    mgr = CheckpointManager(ckpt_dir, keep=2)

    def grad_loss(w, rows):
        # partial contributions normalized by the GLOBAL row count, so the
        # cross-process sum IS the full-batch gradient/loss
        r = X[rows] @ w - y[rows]
        return (X[rows].T @ r) * (2.0 / len(X)), np.float32(r @ r / len(X))

    def reference():
        w = np.zeros(4, np.float32)
        for i in range(STEPS):
            g, l = grad_loss(w, np.arange(64))
            w = w - LR * g
        return w, float(l)

    def attempt(ctx):
        w, start = np.zeros(4, np.float32), 0
        if ctx.resume:
            got = mgr.restore({"step": np.zeros((), np.int64), "w": w})
            w, start = np.asarray(got["w"]), int(got["step"])
        degraded = ctx.attempt > 0  # survivors: local devices only, no peers
        for i in range(start, STEPS):
            if pid == 1 and i == KILL_AT:
                print("DYING at step", i, flush=True)
                os._exit(17)
            if degraded:
                g, l = grad_loss(w, np.arange(64))
            else:
                g, l = kv_allreduce(grad_loss(w, halves[pid]))
            w = w - LR * np.asarray(g)
            if (i + 1) % 2 == 0:
                mgr.save(i + 1, {"step": np.asarray(i + 1, np.int64), "w": w})
        return w, float(l)

    with ft_overrides(max_retries=2, backoff_base_s=0.0, kv_timeout_ms=8000):
        sup = RunSupervisor(label="killworker", devices_fn=lambda: 2)
        w, loss = sup.run(attempt)

    assert len(sup.events) == 1, sup.events  # exactly one dead-peer retry
    w_ref, loss_ref = reference()
    np.testing.assert_allclose(w, w_ref, rtol=5e-3, atol=1e-4)
    assert abs(loss - loss_ref) <= 5e-3 * max(abs(loss_ref), 1e-9), (loss, loss_ref)
    print("RECOVERED", sup.events[0]["error"][:60], flush=True)
    print("OK", pid, flush=True)
    # skip atexit jax.distributed.shutdown: its coordination shutdown barrier
    # can only fail against the dead peer (the service aborts the process
    # with SIGABRT) — the survivor's work is done and verified above
    os._exit(0)
    """
)


def test_two_process_host_gather(tmp_path):
    worker = tmp_path / "worker.py"
    worker.write_text(WORKER)
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    env.pop("XLA_FLAGS", None)
    port = _free_port()
    procs = [
        subprocess.Popen(
            [sys.executable, str(worker), str(pid), str(port)],
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
            text=True,
            env=env,
        )
        for pid in range(2)
    ]
    outs = []
    for p in procs:
        try:
            out, err = p.communicate(timeout=300)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        assert p.returncode == 0, err[-3000:]
        outs.append(out)
    if any("SKIP:" in o for o in outs):
        pytest.skip(f"multi-process jax unavailable here: {outs}")
    assert "OK 0" in outs[0] and "OK 1" in outs[1], outs


def test_kill_one_worker_survivor_recovers(tmp_path):
    worker = tmp_path / "kill_worker.py"
    worker.write_text(KILL_WORKER)
    ckpt_dir = tmp_path / "ckpt"
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    env.pop("XLA_FLAGS", None)
    port = _free_port()
    procs = [
        subprocess.Popen(
            [sys.executable, str(worker), str(pid), str(port), str(ckpt_dir)],
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
            text=True,
            env=env,
        )
        for pid in range(2)
    ]
    outs, errs, codes = [], [], []
    for p in procs:
        try:
            out, err = p.communicate(timeout=300)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        outs.append(out)
        errs.append(err)
        codes.append(p.returncode)
    if any("SKIP:" in o for o in outs):
        pytest.skip(f"multi-process jax unavailable here: {outs}")
    # worker 1 dies by design with its marker exit code; worker 0 (which also
    # hosts the coordinator — killing IT would take down the whole job, which
    # is a control-plane failure, not a worker failure) must recover
    assert codes[1] == 17 and "DYING" in outs[1], (codes, outs, errs[1][-2000:])
    assert codes[0] == 0, (codes, errs[0][-3000:])
    assert "RECOVERED" in outs[0] and "OK 0" in outs[0], outs
