"""2-process CPU validation of ``host_gather``'s multi-process path.

CI meshes are single-process fake-device meshes, so the non-fully-addressable
branch of ``host_gather`` (process_allgather, falling back to the distributed
runtime's KV store on backends that cannot run multi-process computations —
CPU is one) is never touched there. This harness spawns two real jax
processes wired through ``jax.distributed.initialize`` on localhost, builds
global arrays whose shards live in different processes, and asserts the
gather reproduces the full matrix in both of them.
"""
import os
import socket
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

WORKER = textwrap.dedent(
    """
    import os, sys
    pid, port = int(sys.argv[1]), sys.argv[2]
    os.environ["JAX_PLATFORMS"] = "cpu"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    try:
        jax.distributed.initialize(
            coordinator_address=f"127.0.0.1:{port}", num_processes=2, process_id=pid
        )
    except Exception as e:  # environment cannot run multi-process jax at all
        print("SKIP:", type(e).__name__, e, flush=True)
        sys.exit(0)
    import numpy as np
    import jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core.distributed_coreset import host_gather

    assert jax.process_count() == 2 and jax.device_count() == 4

    mesh = Mesh(np.asarray(jax.devices()).reshape(4), ("data",))
    sharding = NamedSharding(mesh, P("data", None))
    full = np.arange(16 * 3, dtype=np.float32).reshape(16, 3)

    # build the global row-sharded array from process-LOCAL shards only —
    # each process ever touches half the rows
    blocks = [
        jax.device_put(full[sharding.devices_indices_map((16, 3))[d][0]], d)
        for d in jax.local_devices()
    ]
    arr = jax.make_array_from_single_device_arrays((16, 3), sharding, blocks)
    assert not arr.is_fully_addressable

    got = host_gather(arr)  # exercises the cross-process branch
    np.testing.assert_array_equal(got, full)

    # a second gather in the same session: the per-call KV namespace/barrier
    # sequencing must hold up across repeated collective calls
    np.testing.assert_array_equal(host_gather(arr), full)

    # fully-replicated output path: read from a local shard, no collective
    rep_val = np.arange(5, dtype=np.float32)
    rep = jax.make_array_from_single_device_arrays(
        (5,),
        NamedSharding(mesh, P()),
        [jax.device_put(rep_val, d) for d in jax.local_devices()],
    )
    assert not rep.is_fully_addressable and rep.is_fully_replicated
    np.testing.assert_array_equal(host_gather(rep), rep_val)

    print("OK", pid, flush=True)
    """
)


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def test_two_process_host_gather(tmp_path):
    worker = tmp_path / "worker.py"
    worker.write_text(WORKER)
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    env.pop("XLA_FLAGS", None)
    port = _free_port()
    procs = [
        subprocess.Popen(
            [sys.executable, str(worker), str(pid), str(port)],
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
            text=True,
            env=env,
        )
        for pid in range(2)
    ]
    outs = []
    for p in procs:
        try:
            out, err = p.communicate(timeout=300)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        assert p.returncode == 0, err[-3000:]
        outs.append(out)
    if any("SKIP:" in o for o in outs):
        pytest.skip(f"multi-process jax unavailable here: {outs}")
    assert "OK 0" in outs[0] and "OK 1" in outs[1], outs
