import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hull import epsilon_kernel_indices, greedy_hull_projection, hull_distance


def test_interior_point_distance_zero():
    rng = np.random.default_rng(0)
    P = jnp.asarray(rng.standard_normal((200, 2)), jnp.float32)
    q = jnp.zeros((2,))  # mean region — interior w.h.p.
    assert hull_distance(P, q, eps=1e-3, max_iter=256) < 5e-2


def test_exterior_point_distance_positive():
    rng = np.random.default_rng(0)
    P = jnp.asarray(rng.random((200, 2)), jnp.float32)  # inside unit square
    q = jnp.asarray([3.0, 3.0])
    d = hull_distance(P, q, eps=1e-3, max_iter=128)
    true = np.linalg.norm([3 - 1, 3 - 1])
    assert d == pytest.approx(true, abs=0.2)


def test_projection_support_are_valid_indices():
    rng = np.random.default_rng(1)
    P = jnp.asarray(rng.standard_normal((64, 3)), jnp.float32)
    t, support, _ = greedy_hull_projection(P, jnp.asarray([5.0, 0.0, 0.0]))
    s = np.asarray(support)
    assert ((s >= -1) & (s < 64)).all()


def test_epsilon_kernel_recovers_square_corners():
    corners = np.array([[0, 0], [0, 1], [1, 0], [1, 1]], dtype=np.float32)
    rng = np.random.default_rng(2)
    interior = rng.random((500, 2)).astype(np.float32) * 0.6 + 0.2
    P = np.concatenate([interior, corners])
    idx = epsilon_kernel_indices(P, k=16, key=jax.random.PRNGKey(0))
    got = set(idx.tolist())
    # all four corners are extremal in some direction → must be selected
    assert {500, 501, 502, 503} <= got


def test_epsilon_kernel_small_n_returns_all():
    P = np.eye(3, dtype=np.float32)
    idx = epsilon_kernel_indices(P, k=10, key=jax.random.PRNGKey(0))
    assert sorted(idx.tolist()) == [0, 1, 2]
