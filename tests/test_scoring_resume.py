"""Resumable scoring sweeps: a sweep interrupted mid-scan and resumed from
its chunk-cursor checkpoint must be **bit-identical** to the uninterrupted
sweep — single-host for every pass strategy, and the segmented distributed
engine (which additionally must agree with its classic psum'd path)."""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.scoring import ScoringEngine
from repro.ft.config import ft_overrides, get_ft_config
from repro.ft.failure import FailureSimulator, InjectedFailure

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str):
    """Fresh interpreter with 8 fake CPU devices (see test_distributed.py)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _setup(n=503, seed=0):
    rng = np.random.default_rng(seed)
    Y = rng.random((n, 2)).astype(np.float32)
    cfg = M.MCTMConfig(J=2, degree=5)
    return cfg, DataScaler.fit(Y), Y


# per-method score kwargs covering all four pass strategies (l2-hull adds the
# fused extremes scan; the sketched pair runs the one-pass CountSketch path)
METHOD_KWARGS = {
    "l2-only": {},
    "l2-hull": dict(hull_k=8, hull_key=jax.random.PRNGKey(7)),
    "ridge-lss": dict(sketch_size=128, key=jax.random.PRNGKey(3), ridge_reg=0.5),
    "root-l2": dict(sketch_size=128, key=jax.random.PRNGKey(3)),
}


def _interrupt_until_done(engine, Y, d, kwargs):
    """Drive the sweep to completion across injected mid-scan crashes."""
    ft = get_ft_config()
    ft.simulator = FailureSimulator().inject("scoring", 2).inject("scoring", 5)
    try:
        interrupts = 0
        while True:
            try:
                return engine.score(Y, sweep_ckpt=d, resume=True, **kwargs), interrupts
            except InjectedFailure:
                interrupts += 1
    finally:
        ft.simulator = None


@pytest.mark.parametrize("method", sorted(METHOD_KWARGS))
def test_single_host_resume_bit_identical(method):
    cfg, scaler, Y = _setup()
    engine = ScoringEngine(cfg, scaler, chunk_size=64)
    kwargs = dict(METHOD_KWARGS[method], method=method,
                  weights=jnp.asarray(np.linspace(0.5, 1.5, len(Y)), jnp.float32))
    ref = engine.score(jnp.asarray(Y), **kwargs)
    with tempfile.TemporaryDirectory() as d:
        with ft_overrides(sweep_ckpt_every_chunks=2):
            got, interrupts = _interrupt_until_done(engine, jnp.asarray(Y), d, kwargs)
    assert interrupts >= 1  # the injections actually cut the sweep
    np.testing.assert_array_equal(np.asarray(ref.scores), np.asarray(got.scores))
    np.testing.assert_array_equal(np.asarray(ref.leverage), np.asarray(got.leverage))
    np.testing.assert_array_equal(np.asarray(ref.gram), np.asarray(got.gram))
    if ref.hull_rows is not None:
        np.testing.assert_array_equal(ref.hull_rows, got.hull_rows)


def test_sweep_checkpoint_unreadable_without_resume_flag():
    """A populated sweep_ckpt dir is only consulted when resume=True —
    otherwise the sweep restarts from chunk 0 (and still matches)."""
    cfg, scaler, Y = _setup(n=257)
    engine = ScoringEngine(cfg, scaler, chunk_size=64)
    ref = engine.score(jnp.asarray(Y), method="l2-only")
    with tempfile.TemporaryDirectory() as d:
        with ft_overrides(sweep_ckpt_every_chunks=1):
            ft = get_ft_config()
            ft.simulator = FailureSimulator().inject("scoring", 2)
            try:
                with pytest.raises(InjectedFailure):
                    engine.score(jnp.asarray(Y), method="l2-only", sweep_ckpt=d)
            finally:
                ft.simulator = None
            # fresh pass over the same dir, no resume: full re-scan
            got = engine.score(jnp.asarray(Y), method="l2-only", sweep_ckpt=d)
    np.testing.assert_array_equal(np.asarray(ref.scores), np.asarray(got.scores))


def test_distributed_segmented_resume_bit_identical():
    """Segmented sharded sweeps on a (4, 2) fake-device mesh: classic ≈
    segmented (host-side cross-shard reduction) and interrupted + resumed ==
    uninterrupted segmented, bit for bit — two-pass, hull, and one-pass."""
    run_in_subprocess(
        """
        import tempfile
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh

        from repro.core import mctm as M
        from repro.core.bernstein import DataScaler
        from repro.core.distributed_coreset import DistributedScoringEngine
        from repro.ft.config import get_ft_config, ft_overrides
        from repro.ft.failure import FailureSimulator, InjectedFailure

        rng = np.random.default_rng(0)
        n = 3001
        Y = rng.random((n, 2)).astype(np.float32)
        cfg = M.MCTMConfig(J=2, degree=5)
        scaler = DataScaler.fit(Y)
        hk, sk = jax.random.PRNGKey(7), jax.random.PRNGKey(3)
        w = (rng.random(n) + 0.5).astype(np.float32)
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        dist = DistributedScoringEngine(cfg, scaler, mesh=mesh, axis="data",
                                        chunk_size=128)

        for name, kwargs in [
            ("two-pass-hull", dict(method="l2-hull", hull_k=6, hull_key=hk, weights=w)),
            ("two-pass-plain", dict(method="l2-only", weights=w)),
            ("one-pass", dict(method="l2-hull", hull_k=6, hull_key=hk,
                              sketch_size=256, key=sk, weights=w)),
        ]:
            r_classic = dist.score(jnp.asarray(Y), **kwargs)
            with tempfile.TemporaryDirectory() as d:
                with ft_overrides(sweep_ckpt_every_chunks=2):
                    r_seg = dist.score(Y, sweep_ckpt=d, **kwargs)
            assert np.allclose(r_classic.scores, r_seg.scores, rtol=2e-4, atol=2e-6), name
            if r_classic.hull_rows is not None:
                assert np.array_equal(np.sort(r_classic.hull_rows),
                                      np.sort(r_seg.hull_rows)), name

            with tempfile.TemporaryDirectory() as d:
                with ft_overrides(sweep_ckpt_every_chunks=2):
                    ft = get_ft_config()
                    ft.simulator = (FailureSimulator()
                                    .inject("scoring", 2).inject("scoring", 8))
                    try:
                        interrupted = 0
                        while True:
                            try:
                                r_res = dist.score(Y, sweep_ckpt=d, resume=True, **kwargs)
                                break
                            except InjectedFailure:
                                interrupted += 1
                    finally:
                        ft.simulator = None
            assert interrupted >= 1, (name, interrupted)
            assert np.array_equal(np.asarray(r_seg.scores), np.asarray(r_res.scores)), name
            assert np.array_equal(np.asarray(r_seg.leverage), np.asarray(r_res.leverage)), name
            assert np.array_equal(np.asarray(r_seg.gram), np.asarray(r_res.gram)), name
            if r_seg.hull_rows is not None:
                assert np.array_equal(r_seg.hull_rows, r_res.hull_rows), name
            print(name, "OK", flush=True)
        print("SEGMENTED OK")
        """
    )
