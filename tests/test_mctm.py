import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mctm as M
from repro.core.bernstein import DataScaler


@pytest.fixture(scope="module")
def gaussian_fit():
    rng = np.random.default_rng(0)
    rho = 0.7
    L = np.linalg.cholesky(np.array([[1, rho], [rho, 1]]))
    Y = rng.standard_normal((3000, 2)) @ L.T
    cfg = M.MCTMConfig(J=2, degree=6)
    scaler = DataScaler.fit(Y)
    fit = M.fit_mctm(cfg, scaler, Y, steps=800)
    return cfg, scaler, Y, fit


def test_fit_reaches_gaussian_entropy(gaussian_fit):
    cfg, scaler, Y, fit = gaussian_fit
    per_point = fit.final_nll / Y.shape[0]
    rho = 0.7
    entropy = np.log(2 * np.pi * np.e) + 0.5 * np.log(1 - rho**2)
    # MLE should approach the differential entropy of the generator
    assert per_point == pytest.approx(entropy, abs=0.05)


def test_loss_decreases(gaussian_fit):
    _, _, _, fit = gaussian_fit
    assert fit.losses[-1] < fit.losses[0]


def test_nll_parts_consistency(gaussian_fit):
    cfg, scaler, Y, fit = gaussian_fit
    A, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y))
    parts = M.loss_parts(cfg, fit.params, A, Ap)
    total = M.nll(cfg, fit.params, A, Ap)
    n, J = Y.shape
    const = 0.5 * np.log(2 * np.pi) * n * J
    recomposed = parts["f1"] - parts["f2"] + parts["f3"] + const
    np.testing.assert_allclose(float(recomposed), float(total), rtol=1e-4)


def test_weighted_nll_equals_scaled(gaussian_fit):
    cfg, scaler, Y, fit = gaussian_fit
    A, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y))
    w = jnp.full((Y.shape[0],), 2.0)
    np.testing.assert_allclose(
        float(M.nll(cfg, fit.params, A, Ap, w)),
        2 * float(M.nll(cfg, fit.params, A, Ap)),
        rtol=1e-5,
    )


def test_sampling_roundtrip_moments(gaussian_fit):
    cfg, scaler, Y, fit = gaussian_fit
    samples = np.asarray(M.sample(cfg, fit.params, scaler, jax.random.PRNGKey(0), 4000))
    assert np.isfinite(samples).all()
    # first two moments of the fitted model match the training data loosely
    np.testing.assert_allclose(samples.mean(0), Y.mean(0), atol=0.15)
    np.testing.assert_allclose(samples.std(0), Y.std(0), rtol=0.15)
    corr_fit = np.corrcoef(samples.T)[0, 1]
    corr_true = np.corrcoef(np.asarray(Y).T)[0, 1]
    assert corr_fit == pytest.approx(corr_true, abs=0.1)


def test_log_density_integrates_to_one_2d(gaussian_fit):
    cfg, scaler, Y, fit = gaussian_fit
    g = np.linspace(-4, 4, 80)
    xx, yy = np.meshgrid(g, g)
    pts = jnp.asarray(np.stack([xx.ravel(), yy.ravel()], 1))
    dens = np.exp(np.asarray(M.log_density(cfg, fit.params, scaler, pts)))
    integral = dens.sum() * (g[1] - g[0]) ** 2
    assert integral == pytest.approx(1.0, abs=0.1)


def test_sample_log_density_roundtrip(gaussian_fit):
    """Samples from a fitted model must score near the fitted NLL: the mean
    of −log p̂ over model samples estimates the model's entropy, which for an
    MLE fit sits at the fitted per-point NLL (grid-inversion bias + Monte
    Carlo error allowed for)."""
    cfg, scaler, Y, fit = gaussian_fit
    samples = M.sample(cfg, fit.params, scaler, jax.random.PRNGKey(7), 6000)
    nll_samples = float(
        jnp.mean(-M.log_density(cfg, fit.params, scaler, samples))
    )
    per_point = fit.final_nll / Y.shape[0]
    assert nll_samples == pytest.approx(per_point, abs=0.1)


def test_sample_grid_inversion_monotone(gaussian_fit):
    """The marginal transforms the sampler inverts on a grid are strictly
    increasing — so inversion is well-posed — and larger latent targets must
    invert to larger observations in every dimension."""
    cfg, scaler, Y, fit = gaussian_fit
    # h̃_j strictly increasing along each dimension (inside that dimension's
    # scaler range — beyond it the basis clips t to [0, 1] and h̃ is constant)
    for j in range(cfg.J):
        g = np.linspace(float(scaler.low[j]), float(scaler.high[j]), 201)[1:-1]
        pts = np.tile(np.asarray(Y[:1]), (g.shape[0], 1))
        pts[:, j] = g
        A, Ap = M.basis_features(cfg, scaler, jnp.asarray(pts, jnp.float32))
        _, htilde, _ = M.transform_parts(cfg, fit.params, A, Ap)
        ht = np.asarray(htilde[:, j])
        assert np.all(np.diff(ht) > 0), f"h̃_{j} not strictly increasing"
    # monotone inversion: push sorted z through the triangular sampler by
    # sampling a diagonal model (λ = 0) where y_j must be monotone in z_j
    diag_params = fit.params._replace(lam=jnp.zeros_like(fit.params.lam))
    key = jax.random.PRNGKey(8)
    z = jax.random.normal(key, (500, cfg.J))
    samples = np.asarray(M.sample(cfg, diag_params, scaler, key, 500))
    for j in range(cfg.J):
        order = np.argsort(np.asarray(z[:, j]))
        assert np.all(np.diff(samples[order, j]) >= 0), (
            f"grid inversion not monotone in dim {j}"
        )
    assert np.isfinite(samples).all()


def test_lambda_recovers_dependence(gaussian_fit):
    cfg, scaler, Y, fit = gaussian_fit
    # for a gaussian copula with rho=0.7: Λ = [[1,0],[λ,1]], λ = −ρ/√(1−ρ²)
    lam = float(fit.params.lam[0])
    expected = -0.7 / np.sqrt(1 - 0.49)
    assert lam == pytest.approx(expected, abs=0.2)
