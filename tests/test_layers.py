"""Layer-level property tests: CE chunking, RoPE, GQA, MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced_config
from repro.models.layers import (
    chunked_xent_weighted,
    moe_apply,
    init_moe,
    rope,
    softmax_xent_weighted,
    _sdpa,
    causal_mask,
)


@pytest.mark.parametrize("S,chunk", [(32, 8), (30, 7), (16, 64)])
def test_chunked_xent_equals_full(S, chunk):
    rng = np.random.default_rng(0)
    B, D, V = 3, 8, 32
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    table = jnp.asarray(rng.standard_normal((V, D)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    w = jnp.asarray(rng.random(B) + 0.5, jnp.float32)
    full = softmax_xent_weighted(jnp.einsum("bsd,vd->bsv", x, table), labels, w)
    chunked = chunked_xent_weighted(x, table, labels, w, chunk=chunk)
    np.testing.assert_allclose(float(chunked), float(full), rtol=1e-5)


def test_rope_relative_position_property():
    """⟨rope(q,p), rope(k,p+Δ)⟩ depends only on Δ (per position pair)."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)

    def score(p_q, p_k):
        qr = rope(q, jnp.asarray([p_q]), 10_000.0)
        kr = rope(k, jnp.asarray([p_k]), 10_000.0)
        return float(jnp.sum(qr * kr))

    assert score(3, 7) == pytest.approx(score(103, 107), abs=1e-3)
    assert score(0, 5) == pytest.approx(score(50, 55), abs=1e-3)
    assert score(0, 5) != pytest.approx(score(0, 9), abs=1e-3)


def test_gqa_equals_expanded_mha():
    """GQA with kv broadcast == MHA with explicitly repeated kv heads."""
    rng = np.random.default_rng(2)
    B, S, H, KV, hd = 2, 16, 8, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    mask = causal_mask(S, S)
    out_gqa = _sdpa(q, k, v, mask)
    out_mha = _sdpa(q, jnp.repeat(k, H // KV, 2), jnp.repeat(v, H // KV, 2), mask)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha), atol=1e-5)


def test_moe_dispatch_equals_dense_reference():
    """Scatter-based top-k dispatch == dense per-expert einsum reference
    (capacity high enough that nothing drops)."""
    cfg = get_reduced_config("qwen2_moe_a2_7b").replace(capacity_factor=16.0)
    params, _ = init_moe(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    y, _ = moe_apply(params, x, cfg, cfg.mlp_act)

    # dense reference: every expert processes every token, combine by top-k
    T = 16
    xt = x.reshape(T, cfg.d_model)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, params["wi_gate"]))
    h = h * jnp.einsum("td,edf->tef", xt, params["wi_up"])
    y_all = jnp.einsum("tef,efd->ted", h, params["wo"])  # (T, E, D)
    combine = jnp.zeros((T, y_all.shape[1]))
    combine = combine.at[jnp.arange(T)[:, None], top_e].set(top_p)
    ref = jnp.einsum("te,ted->td", combine, y_all).reshape(2, 8, cfg.d_model)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)


@given(st.floats(0.1, 10.0), st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_xent_weight_scale_invariance(scale, seed):
    """Mean-normalized weighted CE is invariant to uniform weight scaling."""
    rng = np.random.default_rng(seed)
    B, S, D, V = 2, 8, 4, 16
    x = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    table = jnp.asarray(rng.standard_normal((V, D)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    w = jnp.asarray(rng.random(B) + 0.1, jnp.float32)
    a = chunked_xent_weighted(x, table, labels, w, chunk=4)
    b = chunked_xent_weighted(x, table, labels, w * scale, chunk=4)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-4)


@pytest.mark.parametrize("S,blk", [(64, 16), (100, 32), (48, 16)])
def test_blocked_causal_attention_equals_dense(S, blk):
    from repro.models.layers import blocked_causal_attention

    rng = np.random.default_rng(S)
    B, H, KV, hd = 2, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    a = blocked_causal_attention(q, k, v, block=blk)
    b = _sdpa(q, k, v, causal_mask(S, S))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_prefill_flash_block_is_numerically_neutral():
    """prefill_flash_block only changes the attention *algorithm*, not math:
    prefill logits and subsequent decode must match the baseline path."""
    from repro.models import build_model

    cfg = get_reduced_config("tinyllama_1b")
    cfg_f = cfg.replace(prefill_flash_block=8)
    rng = np.random.default_rng(9)
    tokens = rng.integers(0, cfg.vocab_size, (2, 24)).astype(np.int32)
    model_a, model_b = build_model(cfg), build_model(cfg_f)
    params, _ = model_a.init(jax.random.PRNGKey(0))
    outs = []
    for model in (model_a, model_b):
        cache, _ = model.init_cache(2, 32)
        logits, cache = model.prefill(params, {"tokens": tokens}, cache)
        logits2, _ = model.decode_step(params, tokens[:, :1], cache)
        outs.append((np.asarray(logits, np.float32), np.asarray(logits2, np.float32)))
    np.testing.assert_allclose(outs[1][0], outs[0][0], atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(outs[1][1], outs[0][1], atol=2e-2, rtol=2e-2)
