import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.streaming import MergeReduceCoreset, WeightedSet
from repro.data.dgp import generate


def test_merge_reduce_tracks_stream():
    Y = generate("normal_mixture", 4096, seed=0)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)
    mr = MergeReduceCoreset(cfg, scaler, k=128, key=jax.random.PRNGKey(0))
    for i in range(0, 4096, 512):
        mr.push(Y[i : i + 512])
    assert mr.n_seen == 4096
    res = mr.result()
    assert 0 < res.size <= 128
    # total weight ≈ n (unbiased representation of the stream)
    assert res.weights.sum() == pytest.approx(4096, rel=0.35)


def test_streaming_nll_close_to_full(monkeypatch):
    Y = generate("bivariate_normal", 2048, seed=1)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)
    mr = MergeReduceCoreset(cfg, scaler, k=256, key=jax.random.PRNGKey(1))
    for i in range(0, 2048, 256):
        mr.push(Y[i : i + 256])
    res = mr.result()
    params = M.init_params(jax.random.PRNGKey(5), cfg)
    A, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y))
    As, Aps = M.basis_features(cfg, scaler, jnp.asarray(res.Y))
    full = float(M.nll(cfg, params, A, Ap))
    approx = float(M.nll(cfg, params, As, Aps, jnp.asarray(res.weights, jnp.float32)))
    assert approx == pytest.approx(full, rel=0.3)


def test_alpha_one_disables_hull_stage():
    """α=1.0 → pure importance sampling, no hull points (regression: the
    engine returns hull_points=None when no hull stage is requested)."""
    Y = generate("bivariate_normal", 1024, seed=3)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)
    mr = MergeReduceCoreset(cfg, scaler, k=64, key=jax.random.PRNGKey(3), alpha=1.0)
    for i in range(0, 1024, 256):
        mr.push(Y[i : i + 256])
    res = mr.result()
    assert 0 < res.size <= 64
    assert res.weights.sum() == pytest.approx(1024, rel=0.35)


def test_result_is_idempotent():
    """result() must be a pure read: repeated calls return the same coreset
    (the reduction key derives from fold_in(key, n_seen), not the stream)."""
    Y = generate("normal_mixture", 2048, seed=4)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)
    mr = MergeReduceCoreset(cfg, scaler, k=96, key=jax.random.PRNGKey(4))
    for i in range(0, 2048, 256):
        mr.push(Y[i : i + 256])
    r1 = mr.result()
    r2 = mr.result()
    np.testing.assert_array_equal(r1.Y, r2.Y)
    np.testing.assert_array_equal(r1.weights, r2.weights)


def test_push_after_result_is_deterministic():
    """Peeking at the stream must not perturb it: two identical streams, one
    with interleaved result() calls, end in identical final coresets."""
    Y = generate("normal_mixture", 4096, seed=5)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)

    def run(peek: bool):
        mr = MergeReduceCoreset(cfg, scaler, k=96, key=jax.random.PRNGKey(5))
        for j, i in enumerate(range(0, 4096, 256)):
            mr.push(Y[i : i + 256])
            if peek and j % 3 == 0:
                mr.result()  # must be side-effect-free
        return mr.result()

    a = run(peek=False)
    b = run(peek=True)
    np.testing.assert_array_equal(a.Y, b.Y)
    np.testing.assert_array_equal(a.weights, b.weights)


def test_bucket_structure_is_logarithmic():
    Y = generate("bivariate_normal", 8192, seed=2)
    cfg = M.MCTMConfig(J=2, degree=3)
    scaler = DataScaler.fit(Y)
    mr = MergeReduceCoreset(cfg, scaler, k=64, key=jax.random.PRNGKey(2))
    for i in range(0, 8192, 256):
        mr.push(Y[i : i + 256])
    assert len(mr._buckets) <= int(np.log2(8192 / 256)) + 2


def _counted_stream(Y, cfg, scaler, sketch_size):
    """Push one 512-row block through a chunk_size=128 stream and return the
    featurize chunk sizes the triggering reduce streamed."""
    mr = MergeReduceCoreset(
        cfg,
        scaler,
        k=128,
        key=jax.random.PRNGKey(7),
        chunk_size=128,
        sketch_size=sketch_size,
    )
    calls = []
    base = mr._engine.featurize

    def counting(Yc):
        calls.append(int(Yc.shape[0]))
        return base(Yc)

    mr._engine.featurize = counting
    mr.push(Y[:512])
    return mr, calls


def test_one_pass_sketched_reduce_streams_blocks_once():
    """sketch_size routes every reduction through the one-pass strategy: the
    reduce of a 512-row block over 128-row chunks featurizes each row exactly
    once (4 chunk calls), where the exact two-pass reduce streams them twice
    (8) — the pass shape merge-reduce's consume-each-block-once contract
    assumes — and the stream still tracks total mass deterministically."""
    Y = generate("normal_mixture", 2048, seed=7)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)

    _, calls_one = _counted_stream(Y, cfg, scaler, sketch_size=256)
    assert calls_one == [128, 128, 128, 128]  # each row streamed ONCE
    _, calls_two = _counted_stream(Y, cfg, scaler, sketch_size=0)
    assert len(calls_two) == 8 and sum(calls_two) == 2 * 512

    def run():
        mr = MergeReduceCoreset(
            cfg, scaler, k=128, key=jax.random.PRNGKey(7), sketch_size=256
        )
        for i in range(0, 2048, 512):
            mr.push(Y[i : i + 512])
        return mr.result()

    res = run()
    assert 0 < res.size <= 128
    assert res.weights.sum() == pytest.approx(2048, rel=0.35)
    # determinism: an identical sketched stream reproduces the coreset
    res2 = run()
    np.testing.assert_array_equal(res.Y, res2.Y)
    np.testing.assert_array_equal(res.weights, res2.weights)


# ---------------------------------------------------- two-round direction net


def test_one_pass_moment_tracking_matches_direct_sums():
    """OnePassSketched(track_moments=True) surfaces (Σp, Σppᵀ, n) on the
    result, matching direct sums over the featurized P rows — the raw
    material of the two-round streaming direction net."""
    from repro.core.scoring import OnePassSketched, ScoringEngine

    Y = generate("normal_mixture", 777, seed=11)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)
    engine = ScoringEngine(cfg, scaler, chunk_size=128)
    strat = OnePassSketched(256, track_moments=True)
    res = engine.score(
        jnp.asarray(Y), method="l2-hull", hull_k=6,
        hull_key=jax.random.PRNGKey(7), sketch_size=256,
        key=jax.random.PRNGKey(3), strategy=strat,
    )
    assert res.moments is not None
    s1, s2, n_rows = res.moments
    assert n_rows >= len(Y)  # padded row count; padding rows are masked zero
    _, P = engine.featurize(jnp.asarray(Y))
    np.testing.assert_allclose(s1, np.asarray(P).sum(0), rtol=2e-4, atol=1e-3)
    np.testing.assert_allclose(
        s2, np.asarray(P).T @ np.asarray(P), rtol=2e-4, atol=1e-3
    )
    # plain one-pass keeps moments off the hot path
    res_plain = engine.score(
        jnp.asarray(Y), method="l2-hull", hull_k=6,
        hull_key=jax.random.PRNGKey(7), sketch_size=256,
        key=jax.random.PRNGKey(3),
    )
    assert res_plain.moments is None
    np.testing.assert_array_equal(
        np.asarray(res.scores), np.asarray(res_plain.scores)
    )


def test_hull_dirs_override_is_seedable_and_reproducible():
    """score(hull_dirs=...) with the default upfront net reproduces the
    unseeded one-pass sweep bit-for-bit; a moment-seeded net changes the
    hull candidates deterministically."""
    from repro.core.scoring import (
        ScoringEngine,
        OnePassSketched,
        directions_from_moments,
        upfront_directions,
    )

    Y = generate("hourglass", 1024, seed=12)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)
    engine = ScoringEngine(cfg, scaler, chunk_size=128)
    hk, sk = jax.random.PRNGKey(7), jax.random.PRNGKey(3)
    kwargs = dict(method="l2-hull", hull_k=6, hull_key=hk,
                  sketch_size=256, key=sk)
    base = engine.score(jnp.asarray(Y), **kwargs)
    p = engine.featurize(jnp.asarray(Y[:1]))[1].shape[1]
    explicit = engine.score(
        jnp.asarray(Y),
        hull_dirs=upfront_directions(hk, p, 6, engine.hull_oversample),
        **kwargs,
    )
    np.testing.assert_array_equal(np.asarray(base.scores),
                                  np.asarray(explicit.scores))
    np.testing.assert_array_equal(base.hull_rows, explicit.hull_rows)

    # seed round 2 from round 1's accumulated moments: deterministic, and
    # the net now reflects the data covariance instead of coordinate axes
    res1 = engine.score(
        jnp.asarray(Y), strategy=OnePassSketched(256, track_moments=True),
        **kwargs,
    )
    s1, s2, n_rows = res1.moments
    dirs = directions_from_moments(hk, s1, s2, n_rows, 6,
                                   engine.hull_oversample)
    seeded_a = engine.score(jnp.asarray(Y), hull_dirs=dirs, **kwargs)
    seeded_b = engine.score(jnp.asarray(Y), hull_dirs=dirs, **kwargs)
    np.testing.assert_array_equal(np.asarray(seeded_a.scores),
                                  np.asarray(seeded_b.scores))
    np.testing.assert_array_equal(seeded_a.hull_rows, seeded_b.hull_rows)


def test_hull_dirs_requires_hull_stage():
    from repro.core.scoring import ScoringEngine

    Y = generate("bivariate_normal", 256, seed=13)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)
    engine = ScoringEngine(cfg, scaler, chunk_size=128)
    with pytest.raises(ValueError, match="hull_dirs"):
        engine.score(jnp.asarray(Y), method="l2-only",
                     hull_dirs=np.eye(4, dtype=np.float32))


def test_maintainer_seeds_next_reduce_from_previous_moments():
    """A sketched maintainer accumulates moments across reduces (the
    two-round net): after the first reducing push `_moments` is populated
    and the stream stays deterministic."""
    from repro.core.streaming import StreamingCoresetMaintainer

    Y = np.asarray(generate("normal_mixture", 2048, seed=14), np.float32)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)

    def run():
        m = StreamingCoresetMaintainer(
            cfg, scaler, 96, jax.random.PRNGKey(14), sketch_size=128
        )
        for i in range(0, 2048, 512):
            m.push(Y[i : i + 512])
        return m

    m1 = run()
    assert m1._moments is not None
    s1, s2, n_rows = m1._moments
    assert s1.ndim == 1 and s2.shape == (s1.size, s1.size) and n_rows > 0
    m2 = run()
    a, b = m1.result(), m2.result()
    np.testing.assert_array_equal(a.Y, b.Y)
    np.testing.assert_array_equal(a.weights, b.weights)
