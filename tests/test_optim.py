import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adafactor,
    adamw,
    apply_updates,
    chain,
    clip_by_global_norm,
    cosine_warmup,
    lion,
    sgd,
)
from repro.utils.tree import tree_bytes


def _optimize(opt, steps=300):
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray([[1.0, 1.0], [1.0, 1.0]])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 0.5) ** 2)

    for i in range(steps):
        g = jax.grad(loss)(params)
        updates, state = opt.update(g, state, params, jnp.asarray(i))
        params = apply_updates(params, updates)
    return float(loss(params))


@pytest.mark.parametrize(
    "opt",
    [
        adamw(5e-2),
        # sign-scale optimizers need a decaying lr to settle on a quadratic
        adafactor(cosine_warmup(0.5, 5, 300, final_frac=0.001)),
        lion(cosine_warmup(6e-2, 5, 300, final_frac=0.001)),
        sgd(5e-2),
        sgd(5e-2, momentum=0.9),
    ],
    ids=["adamw", "adafactor", "lion", "sgd", "sgd-mom"],
)
def test_optimizers_minimize_quadratic(opt):
    assert _optimize(opt) < 0.05


def test_adafactor_state_is_factored():
    params = {"big": jnp.zeros((256, 512))}
    a_state = adafactor(1e-2).init(params)
    m_state = adamw(1e-2).init(params)
    assert tree_bytes(a_state) < tree_bytes(m_state) / 50


def test_adafactor_state_specs_match_structure():
    params = {"w": jnp.zeros((8, 16)), "s": jnp.zeros((8,))}
    specs = {"w": ("embed", "mlp"), "s": ("embed",)}
    opt = adafactor(1e-2)
    st = opt.init(params)
    sp = opt.state_specs(specs, params)
    assert jax.tree.structure(st) == jax.tree.structure(
        sp, is_leaf=lambda s: isinstance(s, tuple)
    )
    assert sp["w"]["vr"] == ("embed",)
    assert sp["w"]["vc"] == ("mlp",)


def test_clip_by_global_norm():
    opt = clip_by_global_norm(1.0)
    g = {"a": jnp.asarray([3.0, 4.0])}
    u, _ = opt.update(g, {}, g, jnp.asarray(0))
    np.testing.assert_allclose(float(jnp.linalg.norm(u["a"])), 1.0, rtol=1e-5)


def test_chain_composes():
    opt = chain(clip_by_global_norm(1.0), sgd(1.0))
    params = {"a": jnp.asarray([3.0, 4.0])}
    state = opt.init(params)
    u, _ = opt.update(params, state, params, jnp.asarray(0))
    # clipped to unit norm then scaled by −lr=−1
    np.testing.assert_allclose(float(jnp.linalg.norm(u["a"])), 1.0, rtol=1e-5)


def test_cosine_warmup_schedule():
    s = cosine_warmup(1.0, warmup=10, total=100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert float(s(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(s(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)
