import os
import sys

# Tests run on the single real CPU device. The 512-device dry-run sets
# XLA_FLAGS itself inside repro/launch/dryrun.py (and must NOT leak here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")


# ---------------------------------------------------------------------------
# Optional-dependency shim: the CI image may lack `hypothesis`, which the
# property tests import at module scope (collection would abort for the whole
# suite). When absent, install a minimal deterministic stand-in that runs each
# @given test over a fixed sample of the strategy space. With the real
# package installed this block is inert.
# ---------------------------------------------------------------------------
try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    import types

    import numpy as _np

    class _Strategy:
        def __init__(self, sampler):
            self.sampler = sampler  # rng -> value

        def sample(self, rng):
            return self.sampler(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value))
        )

    def _lists(elements, min_size=0, max_size=10):
        def sampler(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.sample(rng) for _ in range(size)]

        return _Strategy(sampler)

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    def _given(*strategies):
        def deco(fn):
            def wrapper():
                rng = _np.random.default_rng(0)
                # @settings may sit above or below @given — check both targets
                n = getattr(
                    wrapper, "_stub_max_examples", None
                ) or getattr(fn, "_stub_max_examples", 20)
                for _ in range(n):
                    vals = [s.sample(rng) for s in strategies]
                    fn(*vals)

            # zero-arg signature: pytest must not treat the strategy params
            # as fixture requests
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    stub = types.ModuleType("hypothesis")
    stub.given = _given
    stub.settings = _settings
    stub.strategies = types.ModuleType("hypothesis.strategies")
    stub.strategies.integers = _integers
    stub.strategies.floats = _floats
    stub.strategies.lists = _lists
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = stub.strategies
