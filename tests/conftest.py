import os

# Tests run on the single real CPU device. The 512-device dry-run sets
# XLA_FLAGS itself inside repro/launch/dryrun.py (and must NOT leak here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
