"""Distributed primitives on multi-device CPU meshes (subprocess-isolated:
device count is fixed at first jax init, so these spawn fresh interpreters
with XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str, extra_env: dict | None = None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    env["JAX_PLATFORMS"] = "cpu"
    if extra_env:
        env.update(extra_env)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_leverage_matches_local():
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core.distributed_coreset import distributed_leverage, distributed_gram
        from repro.core.leverage import leverage_scores_qr
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.standard_normal((640, 12)), jnp.float32)
        u_dist = np.asarray(distributed_leverage(X, mesh))
        u_loc = np.asarray(leverage_scores_qr(X))
        np.testing.assert_allclose(u_dist, u_loc, rtol=1e-3, atol=1e-4)
        G = np.asarray(distributed_gram(X, mesh))
        np.testing.assert_allclose(G, np.asarray(X.T @ X), rtol=1e-4, atol=1e-3)
        print("OK")
        """
    )


def test_distributed_direction_argmax():
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core.distributed_coreset import distributed_direction_argmax
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(1)
        P = jnp.asarray(rng.standard_normal((160, 5)), jnp.float32)
        dirs = jnp.asarray(rng.standard_normal((12, 5)), jnp.float32)
        got = np.asarray(distributed_direction_argmax(P, dirs, mesh))
        want = np.argmax(np.asarray(P) @ np.asarray(dirs).T, axis=0)
        np.testing.assert_array_equal(got, want)
        print("OK")
        """
    )


def test_distributed_direction_argmax_ragged():
    """n % shards != 0 (and even n < shards) must match the dense argmax
    oracle exactly — pad rows are masked to −inf. Empty inputs raise."""
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core.distributed_coreset import distributed_direction_argmax
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(11)
        dirs = jnp.asarray(rng.standard_normal((12, 5)), jnp.float32)
        for n in (163, 9, 5, 1):  # ragged, barely-ragged, n < shards, single
            P = jnp.asarray(rng.standard_normal((n, 5)), jnp.float32)
            got = np.asarray(distributed_direction_argmax(P, dirs, mesh))
            want = np.argmax(np.asarray(P) @ np.asarray(dirs).T, axis=0)
            np.testing.assert_array_equal(got, want, err_msg=f"n={n}")
            assert (got < n).all()  # never a padding index
        try:
            distributed_direction_argmax(jnp.zeros((0, 5)), dirs, mesh)
        except ValueError:
            pass
        else:
            raise AssertionError("empty input must raise")
        print("OK")
        """
    )


def test_sharded_engine_matches_single_host():
    """The tentpole acceptance: DistributedScoringEngine ≡ ScoringEngine to
    ≤1e-6 max-abs on an 8-fake-device mesh, n NOT divisible by the shard
    count, with identical hull candidate selection — plus the weighted
    (Merge & Reduce) path and the end-to-end distributed_build_coreset."""
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core import mctm as M
        from repro.core.bernstein import DataScaler
        from repro.core.scoring import ScoringEngine
        from repro.core.coreset import build_coreset
        from repro.core.distributed_coreset import (
            DistributedScoringEngine, distributed_build_coreset)

        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        n = 1003  # ragged: 1003 % 8 != 0, and per-shard chunking is ragged too
        Y = rng.random((n, 2)).astype(np.float32)
        # degree 5 for the f32 default path: Gram spectrum fully above the
        # f32 noise floor, so the two accumulation orders must agree to
        # ~1e-8. Degree 6 is covered by the gram_dtype="float64" test below
        # (test_sharded_engine_f64_gram_unpins_degree6).
        cfg = M.MCTMConfig(J=2, degree=5)
        scaler = DataScaler.fit(Y)
        key = jax.random.PRNGKey(3)

        single = ScoringEngine(cfg, scaler, chunk_size=128).score(
            jnp.asarray(Y), method="l2-hull", hull_k=20, hull_key=key)
        dist = DistributedScoringEngine(cfg, scaler, mesh=mesh, chunk_size=64).score(
            jnp.asarray(Y), method="l2-hull", hull_k=20, hull_key=key)
        assert np.abs(single.scores - dist.scores).max() <= 1e-6
        # candidate prefix + consumed hull-point set identical (the deep
        # candidate tail may flip on near-tied argmaxes — 1-ulp block-layout
        # differences — which no consumer of the first k ever sees)
        from repro.core.coreset import exact_hull_points
        np.testing.assert_array_equal(single.hull_rows[:20], dist.hull_rows[:20])
        np.testing.assert_array_equal(
            exact_hull_points(single, single.scores, 20),
            exact_hull_points(dist, dist.scores, 20))

        # weighted (√w-scaled) leverage — the Merge & Reduce reduction path
        w = rng.random(n) * 3.0 + 0.1
        su = ScoringEngine(cfg, scaler, chunk_size=128).score(
            jnp.asarray(Y), method="l2-only", weights=w)
        du = DistributedScoringEngine(cfg, scaler, mesh=mesh, chunk_size=64).score(
            jnp.asarray(Y), method="l2-only", weights=w)
        # √w scaling widens the Gram spectrum, amplifying f32 accumulation-
        # order noise a few-fold relative to the unweighted path
        assert np.abs(su.scores - du.scores).max() <= 5e-6

        # end-to-end Algorithm 1: same key → identical coreset
        cs = build_coreset(cfg, scaler, Y, 100, "l2-hull",
                           key=jax.random.PRNGKey(7), chunk_size=256)
        dcs = distributed_build_coreset(cfg, scaler, Y, 100, "l2-hull",
                                        mesh=mesh, key=jax.random.PRNGKey(7),
                                        chunk_size=64)
        np.testing.assert_array_equal(cs.indices, dcs.indices)
        np.testing.assert_allclose(cs.weights, dcs.weights, rtol=1e-4)
        print("OK")
        """
    )


def test_sharded_one_pass_sketched_matches_single_host():
    """The tentpole acceptance: DistributedScoringEngine accepts
    sketch_size > 0 through the fused one-pass sweep, whose estimates and
    hull candidates match the single-host one-pass strategy (same CountSketch
    plan + upfront net) to f32 psum noise on a ragged mesh — and the sweep
    invokes the sharded callable exactly ONCE (no second data pass)."""
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core import mctm as M
        from repro.core.bernstein import DataScaler
        from repro.core.scoring import OnePassSketched, ScoringEngine
        from repro.core import distributed_coreset as DC

        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        n = 1003  # ragged shards AND ragged per-shard chunking
        Y = rng.random((n, 2)).astype(np.float32)
        cfg = M.MCTMConfig(J=2, degree=5)
        scaler = DataScaler.fit(Y)
        hkey, skey = jax.random.PRNGKey(3), jax.random.PRNGKey(9)

        single = ScoringEngine(cfg, scaler, chunk_size=128).score(
            jnp.asarray(Y), method="l2-hull", hull_k=20, hull_key=hkey,
            sketch_size=256, key=skey)

        calls = []
        orig = DC.make_sharded_onepass_fn
        def counting(*a, **kw):
            fn = orig(*a, **kw)
            def wrapped(*args):
                calls.append(1)
                return fn(*args)
            return wrapped
        DC.make_sharded_onepass_fn = counting
        dist = DC.DistributedScoringEngine(cfg, scaler, mesh=mesh, chunk_size=64).score(
            jnp.asarray(Y), method="l2-hull", hull_k=20, hull_key=hkey,
            sketch_size=256, key=skey)
        assert calls == [1], "one-pass must launch exactly one sharded sweep"

        assert np.abs(single.scores - dist.scores).max() <= 1e-6
        from repro.core.coreset import exact_hull_points
        np.testing.assert_array_equal(single.hull_rows[:20], dist.hull_rows[:20])
        np.testing.assert_array_equal(
            exact_hull_points(single, single.scores, 20),
            exact_hull_points(dist, dist.scores, 20))

        # Ω-projected retention, weighted rows (Merge & Reduce shape)
        w = rng.random(n) * 3.0 + 0.1
        strat = OnePassSketched(256, proj_size=8)
        su = ScoringEngine(cfg, scaler, chunk_size=128).score(
            jnp.asarray(Y), method="l2-only", weights=w, key=skey, strategy=strat)
        du = DC.DistributedScoringEngine(cfg, scaler, mesh=mesh, chunk_size=64).score(
            jnp.asarray(Y), method="l2-only", weights=w, key=skey, strategy=strat)
        assert np.abs(su.scores - du.scores).max() <= 5e-6

        # end-to-end: same key + sketch → identical coreset on both engines
        from repro.core.coreset import build_coreset
        cs = build_coreset(cfg, scaler, Y, 100, "l2-hull",
                           key=jax.random.PRNGKey(7), sketch_size=256,
                           chunk_size=256)
        dcs = DC.distributed_build_coreset(cfg, scaler, Y, 100, "l2-hull",
                                           mesh=mesh, key=jax.random.PRNGKey(7),
                                           sketch_size=256, chunk_size=64)
        np.testing.assert_array_equal(cs.indices, dcs.indices)
        np.testing.assert_allclose(cs.weights, dcs.weights, rtol=1e-4)
        print("OK")
        """
    )


def test_sharded_engine_f64_gram_unpins_degree6():
    """gram_dtype="float64" (x64 subprocess): the degree-6 restriction of the
    1e-6 sharded-vs-single-host equivalence is lifted — the f64 Gram carry
    makes the two accumulation orders agree exactly where f32 legitimately
    drifts to ~1e-4 (genuine eigenvalues at the f32 rcond cutoff)."""
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core import mctm as M
        from repro.core.bernstein import DataScaler
        from repro.core.scoring import ScoringEngine
        from repro.core.distributed_coreset import DistributedScoringEngine

        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        n = 1003
        Y = rng.standard_normal((n, 2)).astype(np.float32)  # heavy tails
        cfg = M.MCTMConfig(J=2, degree=6)  # previously pinned to degree 5
        scaler = DataScaler.fit(Y)
        key = jax.random.PRNGKey(3)

        single = ScoringEngine(cfg, scaler, chunk_size=128,
                               gram_dtype="float64").score(
            jnp.asarray(Y), method="l2-hull", hull_k=20, hull_key=key)
        dist = DistributedScoringEngine(cfg, scaler, mesh=mesh, chunk_size=64,
                                        gram_dtype="float64").score(
            jnp.asarray(Y), method="l2-hull", hull_k=20, hull_key=key)
        assert np.abs(single.scores - dist.scores).max() <= 1e-6
        np.testing.assert_array_equal(single.hull_rows[:20], dist.hull_rows[:20])
        print("OK")
        """,
        extra_env={"JAX_ENABLE_X64": "1"},
    )


def test_sharded_engine_f64_requires_x64():
    """Without x64 the sharded engine must refuse f64 Grams loudly (a silent
    f32 downcast would claim precision it does not deliver)."""
    run_in_subprocess(
        """
        import jax, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core import mctm as M
        from repro.core.bernstein import DataScaler
        from repro.core.distributed_coreset import DistributedScoringEngine
        mesh = make_mesh((8,), ("data",))
        Y = np.random.default_rng(0).random((64, 2)).astype(np.float32)
        cfg = M.MCTMConfig(J=2, degree=5)
        eng = DistributedScoringEngine(cfg, DataScaler.fit(Y), mesh=mesh,
                                       gram_dtype="float64")
        try:
            eng.score(Y, method="l2-only")
        except ValueError as e:
            assert "x64" in str(e)
        else:
            raise AssertionError("f64 without x64 must raise")
        print("OK")
        """,
        # this test is ABOUT the no-x64 guard — pin it off even when the
        # parent suite runs under an JAX_ENABLE_X64=1 CI matrix leg
        extra_env={"JAX_ENABLE_X64": "0"},
    )


def test_sharded_engine_refuses_sketched_f64():
    """The sharded one-pass sweep carries (and psums) an f32 CountSketch —
    a sketched f64 request must be refused loudly, not silently downcast
    (the single-host engine's oracle path handles it instead)."""
    run_in_subprocess(
        """
        import jax, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core import mctm as M
        from repro.core.bernstein import DataScaler
        from repro.core.distributed_coreset import DistributedScoringEngine
        from repro.core.scoring import OnePassSketched
        mesh = make_mesh((8,), ("data",))
        Y = np.random.default_rng(0).random((64, 2)).astype(np.float32)
        cfg = M.MCTMConfig(J=2, degree=5)
        eng = DistributedScoringEngine(cfg, DataScaler.fit(Y), mesh=mesh)
        try:
            eng.score(Y, method="l2-only", key=jax.random.PRNGKey(0),
                      strategy=OnePassSketched(256, "float64"))
        except NotImplementedError as e:
            assert "single-host" in str(e)
        else:
            raise AssertionError("sharded sketched f64 must raise")
        print("OK")
        """,
        extra_env={"JAX_ENABLE_X64": "1"},
    )


def test_stage_rows_zero_copy_staging():
    """stage_rows assembles the engine-layout padded row-sharded array from
    O(chunk) host blocks; scoring the staged array (n_valid=) matches scoring
    the host matrix, including ragged n and hull selection."""
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core.distributed_coreset import DistributedScoringEngine
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        eng = DistributedScoringEngine(featurize=lambda F: (F, F), mesh=mesh,
                                       chunk_size=64, rows_per_point=1)
        for n in (1003, 64, 7):
            F = rng.standard_normal((n, 6)).astype(np.float32)
            blocks = (F[lo:lo + 100] for lo in range(0, n, 100))
            arr = eng.stage_rows(blocks, n, 6)
            assert arr.shape[0] >= n and len(arr.sharding.device_set) == 8
            np.testing.assert_array_equal(np.asarray(arr)[:n], F)
            hkey = jax.random.PRNGKey(1)
            ref = eng.score(jnp.asarray(F), method="l2-hull", hull_k=4,
                            hull_key=hkey)
            got = eng.score(arr, method="l2-hull", hull_k=4, hull_key=hkey,
                            n_valid=n)
            assert np.abs(ref.scores - got.scores).max() <= 1e-6
            np.testing.assert_array_equal(ref.hull_rows, got.hull_rows)
        # row-count mismatch is refused, not silently mis-scored
        try:
            eng.stage_rows(iter([np.zeros((3, 6), np.float32)]), 5, 6)
        except ValueError:
            pass
        else:
            raise AssertionError("short block stream must raise")
        print("OK")
        """
    )


def test_sharded_coreset_selector_matches_local():
    """CoresetSelector(mesh=...) routes through the sharded engine and picks
    the same subset as the single-host path."""
    run_in_subprocess(
        """
        import jax, numpy as np
        from repro.utils.compat import make_mesh
        from repro.data.pipeline import CoresetSelector
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        ex = rng.standard_normal((1003, 6)).astype(np.float32)
        feat = lambda E: E * 2.0
        key = jax.random.PRNGKey(0)
        a = CoresetSelector(feat, chunk_size=128).select(ex, 64, key)
        b = CoresetSelector(feat, chunk_size=64, mesh=mesh).select(ex, 64, key)
        assert a.size == b.size == 64
        np.testing.assert_array_equal(a.indices, b.indices)
        np.testing.assert_allclose(a.weights, b.weights, rtol=1e-4)
        print("OK")
        """
    )


def test_dryrun_engine_variant_compiles():
    """score_fn('engine') — the chunked shard-body pass structure — lowers
    and compiles on a small 2-axis mesh (miniature of the pod dry-run)."""
    run_in_subprocess(
        """
        import jax, numpy as np
        from repro.utils.compat import make_mesh
        from repro.launch.dryrun_coreset import score_fn
        mesh = make_mesh((4, 2), ("data", "model"))
        fn, shardings, args = score_fn("engine", mesh, 4096, 14, chunk=256)
        with mesh:
            compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
        assert compiled.cost_analysis() is not None
        text = compiled.as_text()
        assert "all-reduce" in text  # the fused pass-1 psum survived lowering
        print("OK")
        """
    )


def test_dist_scoring_bench_smoke(tmp_path):
    """CI hook for the dist_scoring bench: artifact written, engines agree."""
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.kernel_bench import dist_scoring_bench

    out = tmp_path / "BENCH_dist_scoring.json"
    rec = dist_scoring_bench(smoke=True, out_path=str(out))
    assert out.exists()
    assert rec["smoke"] is True
    assert rec["max_abs_score_diff"] <= 1e-6
    assert rec["hull_points_equal"]


def test_distributed_scoring_stats_match_local():
    """Sharded pass-1 statistics (Gram + hull moments) ≡ local computation."""
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core.distributed_coreset import distributed_scoring_stats
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(7)
        X = jnp.asarray(rng.standard_normal((320, 12)), jnp.float32)
        P = jnp.asarray(rng.standard_normal((320, 5)), jnp.float32)
        G, s1, s2 = distributed_scoring_stats(X, P, mesh)
        np.testing.assert_allclose(np.asarray(G), np.asarray(X.T @ X), rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(P).sum(0), rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(P).T @ np.asarray(P), rtol=1e-4, atol=1e-3)
        print("OK")
        """
    )


def test_quantized_psum_and_error_feedback():
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.utils.compat import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import psum_quantized
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
        fn = shard_map(lambda xs: psum_quantized(xs[0], "data", bits=8)[None],
                       mesh=mesh, in_specs=(P("data", None),), out_specs=P("data", None))
        got = np.asarray(fn(x))[0]
        want = np.asarray(x).sum(0)
        scale = np.abs(want).max()
        np.testing.assert_allclose(got, want, atol=0.1 * scale)
        print("OK")
        """
    )


def test_ring_allgather_matmul():
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.distributed.collectives import ring_allgather_matmul, reduce_scatter_matmul
        mesh = make_mesh((8,), ("model",))
        rng = np.random.default_rng(3)
        X = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)   # sharded K dim
        W = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
        got = np.asarray(ring_allgather_matmul(X, W, mesh, "model"))
        want = np.asarray(X) @ np.asarray(W)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
        got2 = np.asarray(reduce_scatter_matmul(X, W, mesh, "model"))
        np.testing.assert_allclose(got2, want, rtol=1e-4, atol=1e-3)
        print("OK")
        """
    )


def test_dryrun_single_cell_multipod():
    """End-to-end miniature of the 512-device dry-run (8 fake devices)."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.configs import get_reduced_config
        from repro.models import build_model
        from repro.models.transformer import shapes_and_specs
        from repro.distributed.sharding import default_rules, resolve_tree, batch_specs
        from repro.train.trainer import make_train_step
        from repro.train.state import TrainState
        from repro.optim import adamw
        from repro.distributed.sharding import replicated

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_reduced_config("tinyllama_1b")
        model = build_model(cfg, remat="full", xent_chunk=8)
        rules = default_rules(mesh)
        params_shapes, specs = shapes_and_specs(model)
        param_sh = resolve_tree(specs, params_shapes, mesh, rules)
        opt = adamw(1e-3)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        opt_sh = resolve_tree(opt.state_specs(specs, params_shapes), opt_shapes, mesh, rules)
        state_shapes = TrainState(step=jax.ShapeDtypeStruct((), jnp.int32),
                                  params=params_shapes, opt_state=opt_shapes)
        state_sh = TrainState(step=replicated(mesh), params=param_sh, opt_state=opt_sh)
        b = {
            "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32),
            "weights": jax.ShapeDtypeStruct((8,), jnp.float32),
        }
        b_sh = batch_specs(b, mesh, rules)
        step = make_train_step(model, opt, microbatches=2)
        with mesh:
            lowered = jax.jit(step, in_shardings=(state_sh, b_sh),
                              out_shardings=(state_sh, None)).lower(state_shapes, b)
            compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes >= 0
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
