"""Distributed primitives on multi-device CPU meshes (subprocess-isolated:
device count is fixed at first jax init, so these spawn fresh interpreters
with XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_leverage_matches_local():
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core.distributed_coreset import distributed_leverage, distributed_gram
        from repro.core.leverage import leverage_scores_qr
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        X = jnp.asarray(rng.standard_normal((640, 12)), jnp.float32)
        u_dist = np.asarray(distributed_leverage(X, mesh))
        u_loc = np.asarray(leverage_scores_qr(X))
        np.testing.assert_allclose(u_dist, u_loc, rtol=1e-3, atol=1e-4)
        G = np.asarray(distributed_gram(X, mesh))
        np.testing.assert_allclose(G, np.asarray(X.T @ X), rtol=1e-4, atol=1e-3)
        print("OK")
        """
    )


def test_distributed_direction_argmax():
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core.distributed_coreset import distributed_direction_argmax
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(1)
        P = jnp.asarray(rng.standard_normal((160, 5)), jnp.float32)
        dirs = jnp.asarray(rng.standard_normal((12, 5)), jnp.float32)
        got = np.asarray(distributed_direction_argmax(P, dirs, mesh))
        want = np.argmax(np.asarray(P) @ np.asarray(dirs).T, axis=0)
        np.testing.assert_array_equal(got, want)
        print("OK")
        """
    )


def test_distributed_scoring_stats_match_local():
    """Sharded pass-1 statistics (Gram + hull moments) ≡ local computation."""
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.core.distributed_coreset import distributed_scoring_stats
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(7)
        X = jnp.asarray(rng.standard_normal((320, 12)), jnp.float32)
        P = jnp.asarray(rng.standard_normal((320, 5)), jnp.float32)
        G, s1, s2 = distributed_scoring_stats(X, P, mesh)
        np.testing.assert_allclose(np.asarray(G), np.asarray(X.T @ X), rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(P).sum(0), rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(P).T @ np.asarray(P), rtol=1e-4, atol=1e-3)
        print("OK")
        """
    )


def test_quantized_psum_and_error_feedback():
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.utils.compat import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import psum_quantized
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
        fn = shard_map(lambda xs: psum_quantized(xs[0], "data", bits=8)[None],
                       mesh=mesh, in_specs=(P("data", None),), out_specs=P("data", None))
        got = np.asarray(fn(x))[0]
        want = np.asarray(x).sum(0)
        scale = np.abs(want).max()
        np.testing.assert_allclose(got, want, atol=0.1 * scale)
        print("OK")
        """
    )


def test_ring_allgather_matmul():
    run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.distributed.collectives import ring_allgather_matmul, reduce_scatter_matmul
        mesh = make_mesh((8,), ("model",))
        rng = np.random.default_rng(3)
        X = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)   # sharded K dim
        W = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
        got = np.asarray(ring_allgather_matmul(X, W, mesh, "model"))
        want = np.asarray(X) @ np.asarray(W)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
        got2 = np.asarray(reduce_scatter_matmul(X, W, mesh, "model"))
        np.testing.assert_allclose(got2, want, rtol=1e-4, atol=1e-3)
        print("OK")
        """
    )


def test_dryrun_single_cell_multipod():
    """End-to-end miniature of the 512-device dry-run (8 fake devices)."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.utils.compat import make_mesh
        from repro.configs import get_reduced_config
        from repro.models import build_model
        from repro.models.transformer import shapes_and_specs
        from repro.distributed.sharding import default_rules, resolve_tree, batch_specs
        from repro.train.trainer import make_train_step
        from repro.train.state import TrainState
        from repro.optim import adamw
        from repro.distributed.sharding import replicated

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_reduced_config("tinyllama_1b")
        model = build_model(cfg, remat="full", xent_chunk=8)
        rules = default_rules(mesh)
        params_shapes, specs = shapes_and_specs(model)
        param_sh = resolve_tree(specs, params_shapes, mesh, rules)
        opt = adamw(1e-3)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        opt_sh = resolve_tree(opt.state_specs(specs, params_shapes), opt_shapes, mesh, rules)
        state_shapes = TrainState(step=jax.ShapeDtypeStruct((), jnp.int32),
                                  params=params_shapes, opt_state=opt_shapes)
        state_sh = TrainState(step=replicated(mesh), params=param_sh, opt_state=opt_sh)
        b = {
            "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32),
            "weights": jax.ShapeDtypeStruct((8,), jnp.float32),
        }
        b_sh = batch_specs(b, mesh, rules)
        step = make_train_step(model, opt, microbatches=2)
        with mesh:
            lowered = jax.jit(step, in_shardings=(state_sh, b_sh),
                              out_shardings=(state_sh, None)).lower(state_shapes, b)
            compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes >= 0
        print("OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
