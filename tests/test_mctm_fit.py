"""Fit-layer tests (core.mctm_fit): streamed featurization, sharded parity,
checkpoint resume, the streamed evaluator, and the coreset (1±ε) check."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mctm as M
from repro.core import mctm_fit as F
from repro.core.bernstein import DataScaler

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _gaussian(n=2000, seed=0, rho=0.7):
    rng = np.random.default_rng(seed)
    L = np.linalg.cholesky(np.array([[1, rho], [rho, 1]]))
    Y = rng.standard_normal((n, 2)) @ L.T
    cfg = M.MCTMConfig(J=2, degree=5)
    return cfg, DataScaler.fit(Y), Y


def _counting_featurize(cfg, scaler, calls):
    from repro.core.scoring import _mctm_featurize

    base = _mctm_featurize(cfg, scaler)

    def feat(Yc):
        calls.append(int(Yc.shape[0]))
        return base(Yc)

    return feat


# ------------------------------------------------------------- streaming


def test_streamed_fit_never_materializes_full_basis():
    """THE streaming contract: with chunk_size < n, no featurize call — at
    trace time or run time — ever sees more than one chunk of rows, so an
    (n, J, d) basis tensor cannot exist (the counting-featurize assertion of
    tests/test_pass_strategies.py, applied to the fit layer)."""
    cfg, scaler, Y = _gaussian(n=1000)
    calls: list = []
    fit = F.fit_mctm_streaming(
        cfg, scaler, Y, steps=8, chunk_size=128,
        featurize=_counting_featurize(cfg, scaler, calls),
    )
    assert len(calls) >= 1
    assert max(calls) <= 128          # O(chunk·J·d) peak, never (n, J, d)
    assert np.isfinite(fit.final_nll)

    # the evaluator streams too (featurize traces once per distinct chunk
    # shape under jit — full-size 128 plus the 104-row ragged tail — so the
    # materialization bound is on the largest call, not the call count)
    calls.clear()
    F.streamed_nll(
        cfg, scaler, fit.params, Y, chunk=128,
        featurize=_counting_featurize(cfg, scaler, calls),
    )
    assert calls and max(calls) <= 128
    assert sorted(set(calls)) == [1000 % 128, 128]


def test_streamed_fit_matches_dense_fast_path():
    """Microbatched streaming optimizes the identical objective: the final
    NLL agrees with the dense single-chunk fast path to float noise."""
    cfg, scaler, Y = _gaussian(n=600)
    opt_args = dict(steps=150, lr=5e-2, key=jax.random.PRNGKey(1))
    dense = F.fit_mctm_streaming(cfg, scaler, Y, chunk_size=0, **opt_args)
    chunked = F.fit_mctm_streaming(cfg, scaler, Y, chunk_size=128, **opt_args)
    rel = abs(dense.final_nll - chunked.final_nll) / abs(dense.final_nll)
    assert rel < 1e-3, (dense.final_nll, chunked.final_nll)


def test_streamed_nll_matches_dense():
    cfg, scaler, Y = _gaussian(n=1003)  # ragged vs chunk on purpose
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    A, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y, jnp.float32))
    w = np.random.default_rng(0).random(1003).astype(np.float32) + 0.5
    dense = float(M.nll(cfg, params, A, Ap, jnp.asarray(w)))
    streamed = F.streamed_nll(cfg, scaler, params, Y, weights=w, chunk=97)
    assert abs(dense - streamed) / abs(dense) < 1e-5

    # eta override = evaluating under a strict-η config
    import dataclasses

    strict = dataclasses.replace(cfg, eta=1e-9)
    dense_strict = float(M.nll(strict, params, A, Ap, jnp.asarray(w)))
    streamed_strict = F.streamed_nll(
        cfg, scaler, params, Y, weights=w, chunk=97, eta=1e-9
    )
    assert abs(dense_strict - streamed_strict) / abs(dense_strict) < 1e-5


def test_weighted_fit_equals_mctm_nll_objective():
    """Coreset weights flow through the per-example-weight path: a weighted
    fit's final NLL is the weighted mctm.nll at the fitted parameters."""
    cfg, scaler, Y = _gaussian(n=400)
    w = np.random.default_rng(1).random(400).astype(np.float32) * 3 + 0.1
    fit = F.fit_mctm_streaming(cfg, scaler, Y, weights=w, steps=100)
    A, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y, jnp.float32))
    dense = float(M.nll(cfg, fit.params, A, Ap, jnp.asarray(w)))
    assert abs(dense - fit.final_nll) / abs(dense) < 1e-5


# ------------------------------------------------- streaming L-BFGS mode


def test_lbfgs_never_materializes_full_basis():
    """The lbfgs oracles — loss, grad, AND the curvature-pair HVP — all run
    the microbatched chunk driver: with chunk_size < n no featurize call ever
    sees more than one chunk of rows, so the (n, J, d) basis of the dense
    scipy path cannot exist on this one."""
    cfg, scaler, Y = _gaussian(n=1000)
    calls: list = []
    fit = F.fit_mctm_streaming(
        cfg, scaler, Y, steps=12, method="lbfgs", chunk_size=128,
        featurize=_counting_featurize(cfg, scaler, calls),
    )
    assert len(calls) >= 1
    assert max(calls) <= 128          # O(chunk·J·d) peak, never (n, J, d)
    assert np.isfinite(fit.final_nll)


def test_lbfgs_streaming_matches_scipy_dense_oracle():
    """Acceptance for the quasi-Newton rebuild: the streaming-HVP L-BFGS
    reaches the same optimum as the dense small-n scipy oracle
    (``mctm._scipy_lbfgs_fit``) it replaces."""
    pytest.importorskip("scipy")
    from repro.core.mctm import fit_mctm

    cfg, scaler, Y = _gaussian(n=500)
    dense = fit_mctm(cfg, scaler, Y, steps=500, method="scipy-lbfgs")
    stream = fit_mctm(cfg, scaler, Y, steps=150, method="lbfgs", chunk_size=128)
    rel = abs(dense.final_nll - stream.final_nll) / abs(dense.final_nll)
    assert rel < 1e-3, (dense.final_nll, stream.final_nll)


def test_lbfgs_weighted_objective_and_early_stop():
    """Weighted lbfgs optimizes the same Σ w·nll objective (final NLL is the
    weighted mctm.nll at the fitted params), and a converged run latches: a
    much longer run from the same start changes nothing after convergence."""
    cfg, scaler, Y = _gaussian(n=400)
    w = np.random.default_rng(2).random(400).astype(np.float32) * 3 + 0.1
    # coarse gtol so the latch genuinely engages well inside the budget
    kw = dict(weights=w, method="lbfgs", chunk_size=128, gtol=5e-2,
              key=jax.random.PRNGKey(4))
    fit = F.fit_mctm_streaming(cfg, scaler, Y, steps=120, **kw)
    A, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y, jnp.float32))
    dense = float(M.nll(cfg, fit.params, A, Ap, jnp.asarray(w)))
    assert abs(dense - fit.final_nll) / abs(dense) < 1e-5
    # latched: the loss trace goes exactly flat once converged ...
    assert len(fit.losses) == 120
    assert fit.losses[-1] == fit.losses[-20]
    # ... and a longer run past the latch point changes nothing at all
    longer = F.fit_mctm_streaming(cfg, scaler, Y, steps=200, **kw)
    np.testing.assert_array_equal(
        np.asarray(fit.params.theta_raw), np.asarray(longer.params.theta_raw)
    )


# ------------------------------------------------- sampled-minibatch mode


def test_minibatch_parity_with_full_batch_on_dgp():
    """Minibatch-vs-full-batch parity: on the DGP, the sampled-minibatch fit
    (unbiased weighted-NLL estimates through data.pipeline.subset_loader)
    lands within optimizer slack of the full-batch fit's final NLL."""
    from repro.data.dgp import generate

    Y = generate("normal_mixture", 4000, seed=3).astype(np.float32)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)
    w = np.random.default_rng(3).random(4000).astype(np.float32) + 0.5
    kw = dict(weights=w, key=jax.random.PRNGKey(5), lr=5e-2)
    full = F.fit_mctm_streaming(cfg, scaler, Y, steps=300, **kw)
    mini = F.fit_mctm_streaming(
        cfg, scaler, Y, steps=600, method="minibatch", batch_size=512, **kw
    )
    rel = abs(full.final_nll - mini.final_nll) / abs(full.final_nll)
    assert rel < 0.02, (full.final_nll, mini.final_nll)


def test_minibatch_step_touches_only_batch_size_rows():
    """Each minibatch step featurizes exactly batch_size sampled rows — the
    streaming guarantee for coresets beyond device memory."""
    cfg, scaler, Y = _gaussian(n=2000)
    calls: list = []
    F.fit_mctm_streaming(
        cfg, scaler, Y, steps=6, method="minibatch", batch_size=128,
        chunk_size=128,  # the final streamed_nll sweep must stream too
        featurize=_counting_featurize(cfg, scaler, calls),
    )
    assert calls and max(calls) <= 128


# ------------------------------------------------------------- checkpointing


def test_checkpoint_resume_reproduces_straight_run(tmp_path):
    from repro.checkpoint import CheckpointManager

    cfg, scaler, Y = _gaussian(n=500)
    # one shared optimizer so the lr schedule sees the same total horizon
    opt = F.default_fit_optimizer(5e-2, 60)
    common = dict(key=jax.random.PRNGKey(2), optimizer=opt, chunk_size=128)
    straight = F.fit_mctm_streaming(cfg, scaler, Y, steps=60, **common)

    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    F.fit_mctm_streaming(
        cfg, scaler, Y, steps=30, checkpoint=mgr, ckpt_every=10, **common
    )
    assert mgr.latest_step() == 30
    resumed = F.fit_mctm_streaming(
        cfg, scaler, Y, steps=60, checkpoint=mgr, resume=True, **common
    )
    # restore roundtrips f32 bits exactly; the remaining 30 steps replay the
    # identical jitted computation
    np.testing.assert_allclose(
        np.asarray(resumed.params.theta_raw),
        np.asarray(straight.params.theta_raw),
        atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(resumed.params.lam), np.asarray(straight.params.lam), atol=1e-6
    )
    assert len(resumed.losses) == 30  # only the replayed tail ran


@pytest.mark.parametrize("method", ["lbfgs", "minibatch"])
def test_checkpoint_resume_replays_new_methods(method, tmp_path):
    """Resume-replay for the two new fit modes: a run checkpointed halfway
    and resumed reproduces the straight run exactly (lbfgs iterations and
    minibatch sample draws are both pure functions of (state, step))."""
    from repro.checkpoint import CheckpointManager

    cfg, scaler, Y = _gaussian(n=500)
    common = dict(key=jax.random.PRNGKey(6), method=method, chunk_size=128)
    if method == "minibatch":
        common.update(batch_size=128, optimizer=F.default_fit_optimizer(5e-2, 40))
    straight = F.fit_mctm_streaming(cfg, scaler, Y, steps=40, **common)

    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    F.fit_mctm_streaming(
        cfg, scaler, Y, steps=20, checkpoint=mgr, ckpt_every=10, **common
    )
    assert mgr.latest_step() == 20
    resumed = F.fit_mctm_streaming(
        cfg, scaler, Y, steps=40, checkpoint=mgr, resume=True, **common
    )
    np.testing.assert_allclose(
        np.asarray(resumed.params.theta_raw),
        np.asarray(straight.params.theta_raw),
        atol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(resumed.params.lam), np.asarray(straight.params.lam), atol=1e-6
    )
    assert len(resumed.losses) == 20  # only the replayed tail ran


# ------------------------------------------------------------- sharded paths


def _run_in_subprocess(code: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_fit_matches_single_host_ragged():
    """Acceptance: the sharded fit on a ragged fake-device mesh matches the
    single-host fit's final NLL to ≤ 1e-4 (relative), weights included."""
    _run_in_subprocess(
        """
        import jax, numpy as np
        from repro.core import mctm as M
        from repro.core import mctm_fit as F
        from repro.core.bernstein import DataScaler
        from repro.utils.compat import make_mesh

        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        Y = rng.standard_normal((1501, 2)).astype(np.float32)  # ragged
        w = (rng.random(1501) * 3 + 0.1).astype(np.float32)
        cfg = M.MCTMConfig(J=2, degree=5)
        scaler = DataScaler.fit(Y)
        kw = dict(weights=w, steps=250, key=jax.random.PRNGKey(3), chunk_size=256)
        single = F.fit_mctm_streaming(cfg, scaler, Y, **kw)
        shard = F.fit_mctm_streaming(cfg, scaler, Y, mesh=mesh, **kw)
        rel = abs(single.final_nll - shard.final_nll) / abs(single.final_nll)
        assert rel <= 1e-4, (single.final_nll, shard.final_nll, rel)
        print("OK", rel)
        """
    )


def test_sharded_lbfgs_matches_single_host_ragged():
    """The streaming-HVP L-BFGS on a ragged fake-device mesh matches the
    single-host run (same oracles, GSPMD-reduced): final NLL ≤ 1e-4 rel."""
    _run_in_subprocess(
        """
        import jax, numpy as np
        from repro.core import mctm as M
        from repro.core import mctm_fit as F
        from repro.core.bernstein import DataScaler
        from repro.utils.compat import make_mesh

        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        Y = rng.standard_normal((1501, 2)).astype(np.float32)  # ragged
        w = (rng.random(1501) * 3 + 0.1).astype(np.float32)
        cfg = M.MCTMConfig(J=2, degree=5)
        scaler = DataScaler.fit(Y)
        kw = dict(weights=w, steps=40, method="lbfgs",
                  key=jax.random.PRNGKey(3), chunk_size=256)
        single = F.fit_mctm_streaming(cfg, scaler, Y, **kw)
        shard = F.fit_mctm_streaming(cfg, scaler, Y, mesh=mesh, **kw)
        rel = abs(single.final_nll - shard.final_nll) / abs(single.final_nll)
        assert rel <= 1e-4, (single.final_nll, shard.final_nll, rel)
        print("OK", rel)
        """
    )


def test_sharded_streamed_nll_one_psum():
    """The sharded evaluator matches the dense NLL on a ragged mesh AND
    honors its full invariant budget — the census now runs through the
    registry-based auditor (repro.analysis) instead of an ad-hoc
    collective_stats call, so this test and CI's analysis gate enforce the
    SAME contract (ONE all-reduce, chunk-bounded intermediates, no f64,
    no host callbacks)."""
    _run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import mctm as M
        from repro.core import mctm_fit as F
        from repro.core.bernstein import DataScaler
        from repro.utils.compat import make_mesh
        from repro.analysis import audit_program, get_program

        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        Y = rng.standard_normal((1203, 2)).astype(np.float32)
        w = (rng.random(1203) + 0.5).astype(np.float32)
        cfg = M.MCTMConfig(J=2, degree=5)
        scaler = DataScaler.fit(Y)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        A, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y))
        dense = float(M.nll(cfg, params, A, Ap, jnp.asarray(w)))
        got = F.streamed_nll(cfg, scaler, params, Y, weights=w, chunk=128, mesh=mesh)
        assert abs(dense - got) / abs(dense) < 1e-5, (dense, got)

        # full static audit of the registered evaluator program
        report = audit_program(get_program("streamed_nll_sharded"))
        assert report["ok"], report["failures"]
        assert report["metrics"]["collectives"]["all-reduce"] == 1, report
        print("OK", report["metrics"]["collectives"])
        """
    )


# ------------------------------------------------------- (1±ε) validation


def test_coreset_fit_nll_ratio_within_measured_epsilon():
    """The paper's headline loop, in miniature: build an l2-hull coreset,
    fit on it, measure the realized ε̂, and check the coreset-fit/full-fit
    NLL ratio lands in the (1±ε̂) band (with the finite-step slack the
    driver uses)."""
    from repro.core.coreset import build_coreset
    from repro.data.dgp import generate

    Y = generate("normal_mixture", 4000, seed=0).astype(np.float32)
    cfg = M.MCTMConfig(J=2, degree=4)
    scaler = DataScaler.fit(Y)
    full = F.fit_mctm_streaming(
        cfg, scaler, Y, steps=300, key=jax.random.PRNGKey(0)
    )
    cs = build_coreset(cfg, scaler, Y, 400, "l2-hull", key=jax.random.PRNGKey(1))
    fit = F.fit_mctm_streaming(
        cfg, scaler, Y[cs.indices],
        weights=np.asarray(cs.weights, np.float32),
        steps=300, key=jax.random.PRNGKey(2),
    )
    eps = F.coreset_epsilon(
        cfg, scaler, Y, Y[cs.indices], np.asarray(cs.weights, np.float32),
        [fit.params, full.params], eta=1e-9,
    )
    nll_cs = F.streamed_nll(cfg, scaler, fit.params, Y, eta=1e-9)
    nll_full = F.streamed_nll(cfg, scaler, full.params, Y, eta=1e-9)
    ratio = F.likelihood_ratio(nll_cs, nll_full)
    slack = 0.02
    lo, hi = 1.0 - eps - slack, (1.0 + eps) / (1.0 - eps) + slack
    assert lo <= ratio <= hi, (ratio, eps)
    assert eps < 0.5  # the measured ε must be a meaningful bound, not junk


def test_likelihood_ratio_shift_normalization():
    assert F.likelihood_ratio(110.0, 100.0) == pytest.approx(1.1)
    # negative reference NLL: one-plus-relative-excess reading
    assert F.likelihood_ratio(-90.0, -100.0) == pytest.approx(1.1)
    assert F.likelihood_ratio(-100.0, -100.0) == pytest.approx(1.0)


def test_lbfgs_fused_linesearch_two_sweeps_per_iter():
    """The fused value-and-grad Armijo oracle + gradient carry holds the
    streamed pass count at ~2 sweeps/iteration (1 fused line-search sweep +
    1 HVP), down from ~3.5 with a separate opening value+grad sweep and
    value-only trials."""
    from repro.core.mctm_fit import LAST_LBFGS_SWEEPS

    rng = np.random.default_rng(0)
    Y = rng.normal(size=(2000, 2)).astype(np.float32)
    scaler = DataScaler.fit(Y)
    cfg = M.MCTMConfig(J=2, degree=5)
    fit = F.fit_mctm_streaming(
        cfg, scaler, Y, key=jax.random.PRNGKey(1), steps=40,
        method="lbfgs", chunk_size=512,
    )
    assert np.isfinite(fit.final_nll)
    s = dict(LAST_LBFGS_SWEEPS)
    assert s["iters"] > 10
    # exactly one opening value+grad sweep for the whole run (first
    # iteration only — after that the accepted trial's gradient is carried)
    # plus one fused sweep per accepted/rejected trial; one HVP per accept
    assert s["hvp"] <= s["iters"]
    sweeps_per_iter = (s["vg"] + s["hvp"]) / s["iters"]
    assert sweeps_per_iter <= 2.5, (s, sweeps_per_iter)
    # the opening-sweep elimination is real: vg sweeps ≈ iters (+1 opener
    # + occasional extra backtracking trials), NOT 2·iters
    assert s["vg"] <= 1.5 * s["iters"] + 1, s
