from repro.data.dgp import DGPS, DGP_NAMES, generate
from repro.data.covertype import generate_covertype, COVERTYPE_COLUMNS
from repro.data.equity import generate_equity_returns
from repro.data.pipeline import CoresetSelector, ShardedLoader, WeightedSubset, subset_loader
from repro.data.synthetic_lm import TokenStreamConfig, sample_batch, sample_modality_stub

__all__ = [
    "DGPS",
    "DGP_NAMES",
    "generate",
    "generate_covertype",
    "COVERTYPE_COLUMNS",
    "generate_equity_returns",
    "CoresetSelector",
    "ShardedLoader",
    "WeightedSubset",
    "subset_loader",
    "TokenStreamConfig",
    "sample_batch",
    "sample_modality_stub",
]
