"""Synthetic equity-return generator (stand-in for the paper's stock panels).

Matches the stylized facts the paper's §E.2.2 experiment exercises: heavy
tails (t marginals, ν≈4), sector-block correlation with a market factor
(Gaussian copula over a factor covariance), and per-stock volatilities —
for J = 10 or 20 "stocks" over ~10k "days".
"""
from __future__ import annotations

import numpy as np

__all__ = ["generate_equity_returns"]


def generate_equity_returns(n: int = 10_000, n_stocks: int = 10, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n_sectors = max(n_stocks // 5, 1)
    sector = rng.integers(0, n_sectors, n_stocks)
    beta_mkt = rng.uniform(0.6, 1.4, n_stocks)
    beta_sec = rng.uniform(0.3, 0.8, n_stocks)
    vol = rng.uniform(0.008, 0.025, n_stocks)

    mkt = rng.standard_normal(n)
    sec = rng.standard_normal((n, n_sectors))
    idio = rng.standard_normal((n, n_stocks))
    z = (
        beta_mkt[None, :] * mkt[:, None]
        + beta_sec[None, :] * sec[:, sector]
        + idio
    )
    z /= z.std(axis=0, keepdims=True)
    # heavy tails: scale by inverse-chi (t-like, ν = 4)
    w = rng.chisquare(4, n) / 4.0
    returns = vol[None, :] * z / np.sqrt(w)[:, None]
    return returns
