"""Synthetic Covertype-like generator (offline stand-in for UCI Covertype).

The real dataset (n=581 012, 10 continuous terrain variables) is not available
offline; this generator reproduces its statistical challenges that motivate the
paper's experiment: multimodality (cover types → mixture), heavy skew
(distances), bounded indices (hillshade), and non-linear cross-dependence
(elevation ↔ hydrology ↔ hillshade).
"""
from __future__ import annotations

import numpy as np

__all__ = ["generate_covertype", "COVERTYPE_COLUMNS"]

COVERTYPE_COLUMNS = (
    "elevation",
    "aspect",
    "slope",
    "horiz_dist_hydrology",
    "vert_dist_hydrology",
    "horiz_dist_roadways",
    "hillshade_9am",
    "hillshade_noon",
    "hillshade_3pm",
    "horiz_dist_fire_points",
)


def generate_covertype(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    # 3 latent terrain regimes (cover types) with distinct elevations
    regime = rng.choice(3, n, p=[0.45, 0.35, 0.2])
    elev_mu = np.array([2400.0, 2900.0, 3300.0])[regime]
    elevation = rng.normal(elev_mu, 180.0)
    aspect = rng.uniform(0, 360, n)
    slope = np.clip(rng.gamma(2.5, 5.0, n), 0, 60)
    hd_hydro = rng.gamma(1.5, 180.0, n) * (1 + 0.0004 * (elevation - 2400))
    vd_hydro = rng.normal(0.12 * hd_hydro, 30.0)
    hd_road = rng.gamma(2.0, 900.0, n)
    # hillshade: bounded [0,254], nonlinear in aspect/slope
    az = np.deg2rad(aspect)
    sl = np.deg2rad(slope)
    def shade(sun_az_deg, sun_alt_deg):
        sa, sh = np.deg2rad(sun_az_deg), np.deg2rad(sun_alt_deg)
        v = np.cos(sh) * np.cos(sl) + np.sin(sh) * np.sin(sl) * np.cos(sa - az)
        return np.clip(254 * np.clip(v, 0, 1) + rng.normal(0, 6, n), 0, 254)
    hs9, hs12, hs15 = shade(90, 45), shade(180, 60), shade(270, 45)
    hd_fire = rng.gamma(1.8, 700.0, n) * (1 + 0.3 * (regime == 2))
    return np.stack(
        [elevation, aspect, slope, hd_hydro, vd_hydro, hd_road, hs9, hs12, hs15, hd_fire],
        axis=1,
    )
