"""Synthetic token streams for LM-architecture training and serving.

Deterministic, seekable (resume-from-step) generators producing structured
token sequences (Zipfian unigram + Markov bigram mixture) so the loss actually
decreases during the example training runs. Also provides the stub modality
frontends' inputs: precomputed patch/frame embeddings for [vlm]/[audio] archs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenStreamConfig", "sample_batch", "sample_modality_stub"]


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    zipf_a: float = 1.2
    markov_order: int = 1
    n_states: int = 64  # latent Markov states inducing learnable structure


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return p / p.sum()


def sample_batch(
    cfg: TokenStreamConfig, batch: int, step: int, seed: int = 0
) -> dict[str, np.ndarray]:
    """Deterministic batch for a given (seed, step): resumable by construction."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    probs = _zipf_probs(cfg.vocab_size, cfg.zipf_a)
    # latent-state Markov chain over vocab partitions: makes next-token
    # prediction learnable (state s emits tokens ≡ s mod n_states w.h.p.)
    states = rng.integers(0, cfg.n_states, (batch,))
    toks = np.empty((batch, cfg.seq_len + 1), dtype=np.int32)
    base = rng.choice(cfg.vocab_size, size=(batch, cfg.seq_len + 1), p=probs)
    for t in range(cfg.seq_len + 1):
        emit = (base[:, t] // cfg.n_states) * cfg.n_states + states
        use_struct = rng.random(batch) < 0.75
        toks[:, t] = np.where(use_struct, emit % cfg.vocab_size, base[:, t])
        states = (states * 31 + toks[:, t]) % cfg.n_states
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
        "weights": np.ones((batch,), dtype=np.float32),
    }


def sample_modality_stub(
    batch: int, n_positions: int, dim: int, step: int, seed: int = 1
) -> np.ndarray:
    """Precomputed patch/frame embeddings ([vlm]/[audio] frontend stubs)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 7]))
    return rng.standard_normal((batch, n_positions, dim)).astype(np.float32) * 0.02
