"""Data pipeline with the paper's coreset selection as a first-class stage.

Components:
  * ``ShardedLoader`` — deterministic, resumable, host-sharded batch iterator
    with background prefetch. Every batch is a pure function of
    (seed, step, shard), so restart-after-failure replays exactly.
  * ``CoresetSelector`` — the paper's Algorithm 1 lifted to generic training
    data: featurize examples (any callable, e.g. embedding pooling), compute
    ℓ2 leverage + uniform sensitivity scores, augment with directional hull
    extremes, and emit (indices, weights). The trainer consumes the weights
    in its per-example weighted loss.
  * ``WeightedSubset`` / ``subset_loader`` — iterate coreset-selected data;
    ``full_data_loader`` is the same sampler over ALL rows (the fit layer's
    minibatch mode — unbiased weighted draws when even the coreset exceeds
    device memory).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scoring import DEFAULT_CHUNK, ScoringEngine

__all__ = [
    "ShardedLoader",
    "CoresetSelector",
    "WeightedSubset",
    "SAMPLING_MODES",
    "subset_loader",
    "full_data_loader",
    "with_backup_draws",
    "BACKUP_SEED_OFFSET",
]

# seed offset for the deterministic backup draw of the same step (straggler
# mitigation): far from any user seed, stable across sessions
BACKUP_SEED_OFFSET = 0x5EED


@dataclasses.dataclass
class ShardedLoader:
    """Deterministic resumable loader. `sample_fn(step) -> dict[str, np.ndarray]`."""

    sample_fn: Callable[[int], dict[str, np.ndarray]]
    start_step: int = 0
    prefetch: int = 2

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            step = self.start_step
            while not stop.is_set():
                try:
                    q.put((step, self.sample_fn(step)), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        try:
            while True:
                step, batch = q.get()
                batch["_step"] = np.asarray(step)
                yield batch
        finally:
            stop.set()

    def state_dict(self, step: int) -> dict:
        return {"start_step": int(step)}


@dataclasses.dataclass
class WeightedSubset:
    indices: np.ndarray
    weights: np.ndarray

    @property
    def size(self) -> int:
        return int(self.indices.shape[0])


class CoresetSelector:
    """Generic ℓ2-hull data reduction (paper Algorithm 1 beyond MCTMs).

    featurize: (examples) -> (n, D) feature matrix. For LM data this is an
    embedding-pool of a proxy model; for MCTM it is the Bernstein basis.
    featurize must be ROW-WISE (each output row a function of its input row
    only): inputs beyond ``chunk_size`` are featurized chunk-by-chunk, so
    whole-batch statistics inside featurize would become chunk-local. Pass
    ``chunk_size=None`` to keep single-call semantics for batch-dependent
    featurizers.

    ``mesh``: route scoring through the sharded chunked
    ``DistributedScoringEngine`` — featurize still runs host-side (it may be
    arbitrary Python), but the leverage/hull passes over the (n, D) feature
    rows execute row-sharded on the mesh with one pass-1 psum. ``axis``
    selects the data axis (name or tuple of names). Featurize blocks are
    staged straight onto their target devices (``stage_rows`` →
    ``make_array_from_single_device_arrays``), so host RSS stays at
    O(chunk·D) — the full (n, D) matrix only ever exists row-sharded in
    device memory.

    ``sketch_size``: score through the engines' one-pass sketched strategy
    (constant-factor leverage, each feature row streamed exactly once).
    """

    def __init__(
        self,
        featurize: Callable[[np.ndarray], np.ndarray],
        *,
        alpha: float = 0.8,
        method: str = "l2-hull",
        chunk_size: int | None = DEFAULT_CHUNK,
        mesh=None,
        axis="data",
        sketch_size: int = 0,
    ):
        if method not in ("l2-hull", "l2-only", "uniform"):
            raise ValueError(method)
        self.featurize = featurize
        self.alpha = alpha
        self.method = method
        self.chunk_size = chunk_size
        self.mesh = mesh
        self.sketch_size = sketch_size

        def _feat(Yc):
            F = jnp.asarray(self.featurize(np.asarray(Yc)), jnp.float32)
            return F, F  # hull queries run on the feature rows themselves

        if mesh is not None:
            from repro.core.distributed_coreset import DistributedScoringEngine

            # feature rows arrive pre-computed (see select): the on-mesh
            # featurize is the identity, hull queries run on the rows
            self._engine = DistributedScoringEngine(
                featurize=lambda F: (F, F),
                mesh=mesh,
                axis=axis,
                chunk_size=chunk_size,
                rows_per_point=1,
            )
        else:
            # chunked two-pass scorer: examples beyond chunk_size stream
            # through featurize in O(chunk) memory instead of one giant
            # feature matrix
            self._engine = ScoringEngine(
                featurize=_feat, chunk_size=chunk_size, rows_per_point=1
            )

    def _stage_features(self, examples: np.ndarray):
        """Zero-copy sharded staging for the mesh path: featurize blocks of
        ≤ chunk rows go straight to their target devices (featurize may be
        arbitrary Python — it cannot run inside shard_map), so the host never
        holds more than O(chunk·D) of features at once."""
        n = examples.shape[0]
        chunk = self.chunk_size or n

        def blocks():
            for lo in range(0, n, chunk):
                yield np.asarray(
                    self.featurize(examples[lo : min(lo + chunk, n)]), np.float32
                )

        it = blocks()
        first = next(it)
        width = int(first.shape[1])
        import itertools

        return self._engine.stage_rows(itertools.chain([first], it), n, width)

    def select(self, examples: np.ndarray, k: int, key: jax.Array) -> WeightedSubset:
        n = examples.shape[0]
        k = min(k, n)
        if self.method == "uniform":
            idx = np.asarray(jax.random.choice(key, n, shape=(k,), replace=False))
            return WeightedSubset(idx, np.full(k, n / k, np.float32))

        k1 = int(np.floor(self.alpha * k)) if self.method == "l2-hull" else k
        k2 = k - k1 if self.method == "l2-hull" else 0
        if self.sketch_size > 0:
            # extra stream for the sketch plan; exact selection keeps the old
            # 2-way split so existing pipelines replay unchanged
            k_draw, k_hull, k_score = jax.random.split(key, 3)
        else:
            k_draw, k_hull = jax.random.split(key)
            k_score = None
        score_kw = dict(
            method="l2-only",
            hull_k=k2,
            hull_key=k_hull,
            sketch_size=self.sketch_size,
            key=k_score,
        )
        if self.mesh is not None:
            data = self._stage_features(examples)
            score_kw["n_valid"] = n
        else:
            data = examples
        res = self._engine.score(data, **score_kw)
        probs = res.scores / res.scores.sum()
        idx = np.asarray(
            jax.random.choice(k_draw, n, shape=(k1,), replace=True, p=jnp.asarray(probs))
        )
        w = (1.0 / (k1 * probs[idx])).astype(np.float32)
        if k2 > 0:
            # exactly k2 distinct example ids (rows == points here), topped
            # up by score rank when the hull candidates dedup short
            from repro.core.coreset import exact_hull_points

            hull = exact_hull_points(res, res.scores, k2)
            idx = np.concatenate([idx, hull])
            w = np.concatenate([w, np.ones(k2, np.float32)])
        return WeightedSubset(idx.astype(np.int64), w)


SAMPLING_MODES = ("uniform", "importance")


def subset_loader(
    data: dict[str, np.ndarray],
    subset: WeightedSubset,
    batch: int,
    seed: int = 0,
    sampling: str = "uniform",
) -> Callable[[int], dict[str, np.ndarray]]:
    """sample_fn over a coreset-selected subset, weights attached per example.

    ``sampling`` picks the draw distribution; both are unbiased for the same
    weighted objective, so they are interchangeable under the minibatch
    fit's ``n/batch`` normalizer:

    * ``"uniform"`` — uniform-with-replacement rows, weights passed through.
      Heavy-tailed coreset weights then ride into the gradient estimator:
      a batch's Σw varies with which rows it happened to draw.
    * ``"importance"`` — rows drawn w-proportionally (pᵢ = wᵢ/Σw) with the
      1/p correction wᵢ/(size·pᵢ) = Σw/size attached instead. The correction
      is CONSTANT across rows, so every batch carries exactly the same total
      weight — the weight contribution to gradient variance is zero, which
      is the whole point for heavy-tailed weight distributions.

    Each batch stays a pure function of (seed, step) in either mode.
    """
    if sampling not in SAMPLING_MODES:
        raise ValueError(f"sampling must be one of {SAMPLING_MODES}: {sampling!r}")
    probs = None
    if sampling == "importance":
        w = np.maximum(np.asarray(subset.weights, np.float64), 0.0)
        total = float(w.sum())
        if total <= 0.0:
            raise ValueError("importance sampling needs positive total weight")
        probs = w / total
        # the constant 1/p-corrected per-row weight Σw/size
        w_corr = np.full(batch, total / subset.size, np.float32)

    def sample_fn(step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
        if probs is None:
            pick = rng.integers(0, subset.size, batch)
            w_out = subset.weights[pick]
        else:
            pick = rng.choice(subset.size, size=batch, replace=True, p=probs)
            w_out = w_corr
        rows = subset.indices[pick]
        out = {k: v[rows] for k, v in data.items()}
        out["weights"] = w_out
        return out

    return sample_fn


def full_data_loader(
    data: dict[str, np.ndarray],
    weights: np.ndarray,
    batch: int,
    seed: int = 0,
    sampling: str = "uniform",
) -> Callable[[int], dict[str, np.ndarray]]:
    """``subset_loader`` over the all-rows subset: with-replacement weighted
    draws from the full dataset (``sampling`` as in ``subset_loader``). Each
    batch is a pure function of (seed, step) — the minibatch fit mode's
    resumable sampler, whose Σ w·nll·(n/batch) is an unbiased estimate of
    the full weighted NLL in both sampling modes."""
    n = int(next(iter(data.values())).shape[0])
    subset = WeightedSubset(
        np.arange(n, dtype=np.int64), np.asarray(weights, np.float32)
    )
    return subset_loader(data, subset, batch, seed, sampling)


def with_backup_draws(
    primary_fn: Callable[[int], dict],
    backup_fn: Callable[[int], dict],
    policy,
    clock: Callable[[], float] | None = None,
) -> Callable[[int], dict]:
    """Deadline the primary draw per ``StragglerPolicy``; on a miss, take the
    deterministic backup draw of the SAME step (pure in ``step``, so a
    resumed run replays the identical primary/backup decision inputs).
    ``clock`` is injectable for tests (defaults to ``time.monotonic``)."""
    import time as _time

    tick = clock if clock is not None else _time.monotonic

    def sample_fn(step: int) -> dict:
        t0 = tick()
        batch = primary_fn(step)
        elapsed_ms = (tick() - t0) * 1e3
        if bool(np.any(policy.decide(np.asarray([elapsed_ms], np.float64)))):
            return backup_fn(step)
        return batch

    return sample_fn
