"""The paper's 14 two-dimensional data generation processes (Section E.1.1).

Every generator takes (rng, n) and returns an (n, 2) float array. Registry
``DGPS`` maps the paper's names; ``generate(name, n, seed)`` is the entry
point used by benchmarks and tests.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

__all__ = ["DGPS", "generate", "DGP_NAMES"]


def _mvn(rng, n, mean, cov):
    return rng.multivariate_normal(np.asarray(mean, float), np.asarray(cov, float), size=n)


def bivariate_normal(rng: np.random.Generator, n: int, rho: float = 0.7) -> np.ndarray:
    return _mvn(rng, n, [0, 0], [[1, rho], [rho, 1]])


def nonlinear_correlation(rng, n):
    x = rng.uniform(-3, 3, n)
    y1 = x**2 + rng.normal(0, 0.5, n)
    # correlation ρ(x)=sin(x) to Y1 via conditional construction
    eps = rng.normal(0, 1, n)
    rho = np.sin(x)
    y2 = rho * (y1 - x**2) / 0.5 + np.sqrt(np.clip(1 - rho**2, 0, 1)) * eps
    return np.stack([y1, y2], axis=1)


def normal_mixture(rng, n):
    z = rng.random(n) < 0.5
    a = _mvn(rng, n, [0, 0], [[1, 0.8], [0.8, 1]])
    b = _mvn(rng, n, [3, -2], [[1.5, -0.5], [-0.5, 1.5]])
    return np.where(z[:, None], a, b)


def geometric_mixed(rng, n):
    z = rng.random(n) < 0.5
    # circular component
    r = rng.normal(2.0, 0.2, n)
    th = rng.uniform(0, 2 * np.pi, n)
    circ = np.stack([r * np.cos(th), r * np.sin(th)], axis=1)
    # cross component: two perpendicular lines
    line = rng.integers(0, 2, n)
    t = rng.uniform(-2.5, 2.5, n)
    noise = rng.normal(0, 0.1, (n, 2))
    cross = np.where(
        line[:, None].astype(bool),
        np.stack([t, np.zeros_like(t)], axis=1),
        np.stack([np.zeros_like(t), t], axis=1),
    ) + noise
    return np.where(z[:, None], circ, cross)


def skew_t(rng, n, nu: float = 4.0):
    """Azzalini-style bivariate skew-t: ξ=0, Ω=[[1,.5],[.5,1]], α=[5,−3], ν=4."""
    omega = np.array([[1, 0.5], [0.5, 1.0]])
    alpha = np.array([5.0, -3.0])
    L = np.linalg.cholesky(omega)
    # skew-normal via conditioning representation
    delta = (omega @ alpha) / np.sqrt(1 + alpha @ omega @ alpha)
    u0 = np.abs(rng.normal(0, 1, n))
    u = rng.standard_normal((n, 2)) @ L.T
    sn = delta[None, :] * u0[:, None] + np.sqrt(np.clip(1 - delta**2, 1e-9, None))[None, :] * u
    w = rng.chisquare(nu, n) / nu
    return sn / np.sqrt(w)[:, None]


def heteroscedastic(rng, n):
    x = rng.uniform(-3, 3, n)
    y1 = rng.normal(x**2, np.exp(0.5 * x))
    y2 = rng.normal(np.sin(x), np.sqrt(np.abs(x)) + 1e-3)
    return np.stack([y1, y2], axis=1)


def _clayton_copula(rng, n, theta=2.0):
    """Marshall–Olkin sampling of the Clayton copula."""
    v = rng.gamma(1.0 / theta, 1.0, n)
    e = rng.exponential(1.0, (n, 2))
    return (1.0 + e / v[:, None]) ** (-1.0 / theta)


def copula_complex(rng, n):
    from scipy import stats

    u = _clayton_copula(rng, n, theta=2.0)
    y1 = stats.gamma(2, scale=1.0).ppf(u[:, 0])
    y2 = stats.lognorm(s=1.0).ppf(u[:, 1])
    return np.stack([y1, y2], axis=1)


def spiral(rng, n):
    t = rng.uniform(0, 3 * np.pi, n)
    r = 0.5 * t
    y1 = r * np.cos(t) + rng.normal(0, 0.5, n)
    y2 = r * np.sin(t) + rng.normal(0, 0.5, n)
    return np.stack([y1, y2], axis=1)


def circular(rng, n):
    th = rng.uniform(0, 2 * np.pi, n)
    r = rng.normal(5, 1, n)
    return np.stack([r * np.cos(th), r * np.sin(th)], axis=1)


def t_copula(rng, n, rho=0.7, nu=3.0):
    from scipy import stats

    L = np.linalg.cholesky(np.array([[1, rho], [rho, 1]]))
    g = rng.standard_normal((n, 2)) @ L.T
    w = rng.chisquare(nu, n) / nu
    t_samples = g / np.sqrt(w)[:, None]
    u = stats.t(nu).cdf(t_samples)
    y1 = stats.t(5).ppf(u[:, 0])
    y2 = stats.expon(scale=1.0).ppf(np.clip(u[:, 1], 1e-12, 1 - 1e-12))
    return np.stack([y1, y2], axis=1)


def piecewise(rng, n):
    y1 = rng.normal(0, 2, n)
    e1 = rng.normal(0, 0.5, n)
    e2 = rng.normal(0, 0.8, n)
    e3 = rng.normal(0, 0.5, n)
    y2 = np.where(
        y1 < -1, 1.5 * y1 + e1, np.where(y1 < 1, -0.5 * y1 + e2, -2.0 * y1 + e3)
    )
    return np.stack([y1, y2], axis=1)


def hourglass(rng, n):
    y1 = rng.normal(0, 2, n)
    y2 = rng.normal(0, np.sqrt(0.2 + 0.3 * y1**2))
    return np.stack([y1, y2], axis=1)


def bimodal_clusters(rng, n):
    z = rng.random(n) < 0.5
    a = _mvn(rng, n, [-2, 2], [[1, 0.8], [0.8, 1]])
    b = _mvn(rng, n, [2, 2], [[1, -0.7], [-0.7, 1]])
    return np.where(z[:, None], a, b)


def sinusoidal(rng, n):
    y1 = rng.uniform(-3, 3, n)
    y2 = 2 * np.sin(np.pi * y1) + rng.normal(0, 0.5, n)
    return np.stack([y1, y2], axis=1)


DGPS: dict[str, Callable[[np.random.Generator, int], np.ndarray]] = {
    "bivariate_normal": bivariate_normal,
    "nonlinear_correlation": nonlinear_correlation,
    "normal_mixture": normal_mixture,
    "geometric_mixed": geometric_mixed,
    "skew_t": skew_t,
    "heteroscedastic": heteroscedastic,
    "copula_complex": copula_complex,
    "spiral": spiral,
    "circular": circular,
    "t_copula": t_copula,
    "piecewise": piecewise,
    "hourglass": hourglass,
    "bimodal_clusters": bimodal_clusters,
    "sinusoidal": sinusoidal,
}

DGP_NAMES = tuple(DGPS)


def generate(name: str, n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return DGPS[name](rng, n).astype(np.float64)
