"""Learning-rate schedules (step: int32 scalar → lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup: int):
    def fn(step):
        frac = jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.asarray(lr, jnp.float32) * frac

    return fn


def cosine_warmup(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.asarray(lr, jnp.float32) * warm * cos

    return fn
