"""Minimal dependency-free optimizer library (optax-like GradientTransformation).

Implemented: AdamW, Adafactor (factored second moments — the memory-feasible
choice for arctic-480b's 0.5T parameters), Lion, SGD(+momentum), global-norm
clipping, chaining. Optimizer states inherit the parameter sharding (moments
are elementwise → same logical axes), so ZeRO-style sharded optimizer state
falls out of FSDP parameter sharding for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import is_spec_leaf as _is_spec

PyTree = Any
Schedule = Callable[[jax.Array], jax.Array]


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], tuple[PyTree, PyTree]]
    # update(grads, opt_state, params, step) -> (updates, new_state)
    state_specs: Callable[[PyTree], PyTree] | None = None
    # state_specs(param_logical_specs) -> logical specs for the opt state
    # (moments inherit the param axes; factored moments drop reduced axes)


def _map_specs(fn, specs):
    return jax.tree.map(fn, specs, is_leaf=_is_spec)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def _as_schedule(lr) -> Schedule:
    return lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))


# ---------------------------------------------------------------------------


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, zeros)}

    def update(grads, state, params, step):
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], gf)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], gf)
        t = step.astype(jnp.float32) + 1.0
        bc1, bc2 = 1 - b1**t, 1 - b2**t
        lr_t = sched(step)

        def upd(m_, v_, p):
            u = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v}

    def state_specs(pspecs, pshapes):
        return {"m": pspecs, "v": pspecs}

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------


def adafactor(lr, decay=0.8, eps=1e-30, clip_threshold=1.0, weight_decay=0.0) -> Optimizer:
    """Adafactor (Shazeer & Stern 2018): O(n+m) second-moment state for (n,m)
    matrices — moments shrink from 2× param bytes to ~0, the enabler for
    trillion-parameter-class MoE configs on 16 GB/chip HBM."""
    sched = _as_schedule(lr)

    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        lr_t = sched(step)

        def one(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                precond = (
                    g
                    * jax.lax.rsqrt(vr[..., None] / denom[..., None])
                    * jax.lax.rsqrt(vc[..., None, :])
                )
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                precond = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            # update clipping (RMS ≤ clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-30)
            precond = precond / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr_t * precond
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u, new_s

        flat_u, flat_s = [], []
        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_s = treedef.flatten_up_to(state)
        leaves_p = jax.tree.leaves(params)
        for g, s, p in zip(leaves_g, leaves_s, leaves_p):
            u, ns = one(g, s, p)
            flat_u.append(u)
            flat_s.append(ns)
        return jax.tree.unflatten(treedef, flat_u), jax.tree.unflatten(treedef, flat_s)

    def state_specs(pspecs, pshapes):
        def one(s, p):
            s = tuple(s)
            if _factored(p.shape):
                return {"vr": s[:-1], "vc": s[:-2] + s[-1:]}
            return {"v": s}

        return jax.tree.map(one, pspecs, pshapes, is_leaf=_is_spec)

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------


def lion(lr, b1=0.9, b2=0.99, weight_decay=0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        lr_t = sched(step)

        def upd(m_, g, p):
            u = -lr_t * jnp.sign(b1 * m_ + (1 - b1) * g)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, state["m"], gf, params)
        m = jax.tree.map(lambda m_, g: b2 * m_ + (1 - b2) * g, state["m"], gf)
        return updates, {"m": m}

    def state_specs(pspecs, pshapes):
        return {"m": pspecs}

    return Optimizer(init, update, state_specs)


def sgd(lr, momentum=0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        lr_t = sched(step)
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr_t * g, gf), state
        m = jax.tree.map(lambda m_, g: momentum * m_ + g, state["m"], gf)
        return jax.tree.map(lambda m_: -lr_t * m_, m), {"m": m}

    def state_specs(pspecs, pshapes):
        return {} if momentum == 0.0 else {"m": pspecs}

    return Optimizer(init, update, state_specs)


# ---------------------------------------------------------------------------


def clip_by_global_norm(max_norm: float) -> Optimizer:
    """Gradient transformation — compose with `chain`."""

    def init(params):
        return {}

    def update(grads, state, params, step):
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        norm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(gf)))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        return jax.tree.map(lambda g: g * scale, gf), state

    return Optimizer(init, update)


def scale_updates(optimizer: Optimizer, scale: float) -> Optimizer:
    """Multiply emitted updates by ``scale`` — LR backoff that leaves the
    optimizer state *structure* untouched, so checkpoints written before the
    backoff still restore into the wrapped optimizer (the supervisor's
    non-finite-rollback path depends on this)."""
    if scale == 1.0:
        return optimizer
    s = float(scale)

    def update(grads, state, params, step):
        updates, new_state = optimizer.update(grads, state, params, step)
        return jax.tree.map(lambda u: u * s, updates), new_state

    return Optimizer(optimizer.init, update, optimizer.state_specs)


def chain(*transforms: Optimizer) -> Optimizer:
    """Compose transformations; each consumes the previous one's updates as
    'gradients'. The last element should be the actual optimizer."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params, step):
        new_states = []
        cur = grads
        for t, s in zip(transforms, state):
            cur, ns = t.update(cur, s, params, step)
            new_states.append(ns)
        return cur, tuple(new_states)

    def state_specs(pspecs, pshapes):
        return tuple(
            (t.state_specs(pspecs, pshapes) if t.state_specs is not None else {})
            for t in transforms
        )

    return Optimizer(init, update, state_specs)
