from repro.optim.optimizers import (
    Optimizer,
    adamw,
    adafactor,
    lion,
    sgd,
    chain,
    clip_by_global_norm,
    apply_updates,
    scale_updates,
)
from repro.optim.schedules import constant, cosine_warmup, linear_warmup

__all__ = [
    "Optimizer",
    "adamw",
    "adafactor",
    "lion",
    "sgd",
    "chain",
    "clip_by_global_norm",
    "apply_updates",
    "scale_updates",
    "constant",
    "cosine_warmup",
    "linear_warmup",
]
