"""Architecture registry: ``get_config(name)`` / ``get_reduced_config(name)``.

Every assigned arch lives in its own module with the exact published numbers;
``REDUCED_OVERRIDES`` shrink them to CPU-smoke-test size (same family/topology,
tiny widths).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_NAMES = (
    "phi3_vision_4b",
    "olmo_1b",
    "minicpm3_4b",
    "tinyllama_1b",
    "gemma_2b",
    "arctic_480b",
    "qwen2_moe_a2_7b",
    "whisper_medium",
    "mamba2_370m",
    "recurrentgemma_2b",
)

# public ids from the assignment → module names
ARCH_IDS = {
    "phi-3-vision-4.2b": "phi3_vision_4b",
    "olmo-1b": "olmo_1b",
    "minicpm3-4b": "minicpm3_4b",
    "tinyllama-1.1b": "tinyllama_1b",
    "gemma-2b": "gemma_2b",
    "arctic-480b": "arctic_480b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "whisper-medium": "whisper_medium",
    "mamba2-370m": "mamba2_370m",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


def _module(name: str):
    mod_name = ARCH_IDS.get(name, name.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_reduced_config(name: str) -> ModelConfig:
    return _module(name).reduced()


def all_configs() -> dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}
