"""whisper-medium [audio]: enc-dec, conv frontend (stub). 24L d_model=1024
16H (kv=16) d_ff=4096 vocab=51865 [arXiv:2212.04356; unverified]

24 encoder + 24 decoder layers; the audio frontend is a STUB — input_specs()
provides precomputed frame embeddings (B, T, d_model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=48,          # 24 enc + 24 dec
    n_enc_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    dec_max_len=448,
    norm_type="layernorm",
    mlp_act="gelu",
    tie_embeddings=True,
    modality="audio",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, n_enc_layers=2, n_dec_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, dec_max_len=32,
    )
