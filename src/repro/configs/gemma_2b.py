"""gemma-2b [dense]: GeGLU, head_dim=256, MQA. 18L d_model=2048 8H (kv=1)
d_ff=16384 vocab=256000 [arXiv:2403.08295; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,        # MQA
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    norm_type="rmsnorm",
    mlp_act="gelu",      # GeGLU
    tie_embeddings=True,
    scale_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=512,
    )
