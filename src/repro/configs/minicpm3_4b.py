"""minicpm3-4b [dense]: MLA attention. 62L d_model=2560 40H d_ff=6400
vocab=73448 [hf:openbmb/MiniCPM3-4B; hf]

MLA dims follow the published checkpoint: q_lora 768, kv_lora 256,
qk rope/nope 32/64, v_head 64.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,       # MLA: logical kv = heads; the cache stores latents
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_rope_dim=32,
    qk_nope_dim=64,
    v_head_dim=64,
    norm_type="rmsnorm",
    mlp_act="silu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, q_lora_rank=32, kv_lora_rank=16,
        qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16,
    )
