"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed top-4. 24L d_model=2048 16H
(kv=16) d_ff=1408 vocab=151936 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,   # shared-expert FFN of width 4·d_ff, always active
    norm_type="rmsnorm",
    mlp_act="silu",
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=64, vocab_size=256, n_experts=8, top_k=2, n_shared_experts=1,
        capacity_factor=8.0,
    )
