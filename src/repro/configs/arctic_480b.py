"""arctic-480b [moe]: 128 experts top-2 + dense residual. 35L d_model=7168
56H (GQA kv=8) d_ff=4864 vocab=32000 [hf:Snowflake/snowflake-arctic-base; hf]

Arctic is a dense-MoE hybrid: every layer runs a dense FFN residual in
parallel with the routed top-2 MoE FFN.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    top_k=2,
    moe_dense_residual=True,
    norm_type="rmsnorm",
    mlp_act="silu",
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=256, n_experts=8, top_k=2, capacity_factor=8.0,
    )
