"""mamba2-370m [ssm]: SSD (state-space duality). 48L d_model=1024 (attn-free)
vocab=50280, ssm_state=128 [arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,          # d_inner / headdim = 2048/64
    n_kv_heads=32,
    head_dim=64,
    d_ff=0,              # pure mamba blocks, no MLP
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    conv_kernel=4,
    ssm_chunk=256,
    norm_type="rmsnorm",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        vocab_size=256, ssm_state=16, ssm_headdim=32, ssm_chunk=16,
    )
