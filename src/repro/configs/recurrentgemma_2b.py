"""recurrentgemma-2b [hybrid]: RG-LRU + local attn, 1:2. 26L d_model=2560
10H (kv=1) d_ff=7680 vocab=256000 [arXiv:2402.19427; hf]

Pattern (rec, rec, attn) tiled over 26 layers (8 groups + 2 rec tail);
local attention window 2048, MQA, head_dim 256, GeGLU MLP.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    attn_window=2048,
    conv_kernel=4,
    norm_type="rmsnorm",
    mlp_act="gelu",
    tie_embeddings=True,
    scale_embeddings=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, lru_width=64, attn_window=16,
    )
