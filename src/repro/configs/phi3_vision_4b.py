"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP frontend (stub).

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    norm_type="rmsnorm",
    mlp_act="silu",
    tie_embeddings=False,
    modality="vision",
    n_modality_positions=256,  # stub patch embeddings prepended to text
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, n_modality_positions=8,
    )
