"""tinyllama-1.1b [dense]: llama2-arch small. 22L d_model=2048 32H (GQA kv=4)
d_ff=5632 vocab=32000 [arXiv:2401.02385; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    norm_type="rmsnorm",
    mlp_act="silu",
    tie_embeddings=False,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=128, vocab_size=256,
    )
