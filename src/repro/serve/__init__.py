from repro.serve.density import (
    DensityRequest,
    DensityServeEngine,
    ModelSlot,
    bucket_for,
    bucket_sizes,
    make_conditional_sample_fn,
    make_log_density_fn,
    refit_and_publish,
    start_background_refit,
)
from repro.serve.engine import GenerationConfig, Request, ServeEngine

__all__ = [
    "GenerationConfig",
    "Request",
    "ServeEngine",
    "DensityRequest",
    "DensityServeEngine",
    "ModelSlot",
    "bucket_sizes",
    "bucket_for",
    "make_log_density_fn",
    "make_conditional_sample_fn",
    "refit_and_publish",
    "start_background_refit",
]
