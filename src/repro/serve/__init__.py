from repro.serve.engine import GenerationConfig, Request, ServeEngine

__all__ = ["GenerationConfig", "Request", "ServeEngine"]
