"""Continuous-batching density-serving engine over a fitted MCTM.

The serving-side counterpart of the fit layer (ROADMAP item 1): the coreset
makes background *refits* cheap; this engine makes the fitted density
*usable* under traffic. It adapts the LM engine's slot/queue scheduler
(``serve.engine``) to density queries, where the two JAX-specific problems
are different from token decoding:

* **Static shapes under ragged traffic** — queries arrive one row at a
  time; XLA wants fixed shapes. The engine coalesces queued requests into
  padded **batch buckets** (powers of two from ``min_bucket`` up to
  ``max_batch``) and keeps a compiled-executable cache keyed by
  ``(query kind, bucket, dtype)``. After one warmup pass over the bucket
  ladder, mixed ``log_density`` / ``sample`` traffic never recompiles: every
  tick dispatches into an already-compiled executable (the jit dispatch
  cache is the executable store; the engine's own table is the warmed-key
  index and the recompile meter — a *trace-time* counter inside each jitted
  body, so ``compile_count`` moves iff XLA actually retraces).

* **Hot model refresh without draining** — a background refit (streaming
  L-BFGS on a fresh coreset, ``core.mctm_fit.fit_density_model``) must be
  published while queries are in flight. The engine double-buffers the model
  slot: ``publish()`` stages the new ``ModelSlot`` (params + scaler arrays +
  version) behind a lock; each tick swaps the staged slot in at its START
  and reads the slot exactly once, so every query in a tick — and therefore
  every query, since queries are served within exactly one tick — sees
  exactly one version, never a mix, and none are dropped. Parameters are
  *arguments* of the compiled executables, not closed-over constants, so a
  swap costs zero recompiles (shapes and dtypes are fixed by the config).

Query kinds
-----------
``log_density`` — batched ``log p(y)`` at the request's point (one jitted
featurize → ``nll_terms`` evaluation, exactly ``mctm.log_density``).

``sample`` — batched **conditional** sampling: each request carries an
observed prefix ``y_obs[:n_obs]`` (``n_obs = 0`` → unconditional draw) and a
per-request ``seed``. The MCTM is triangular (Z = Λ h̃(Y)), so dimension j
resolves as h̃_j = z_j − Σ_{l<j} λ_{jl} h̃_l with observed dimensions
substituting their realized h̃ — the same recursion as ``mctm.sample``, made
conditional. Randomness is ``fold_in(base_key, seed)`` per request, so a
request's sample is a pure function of (model version, seed) — independent
of which bucket it lands in, which is what makes coalesced and per-request
serving agree exactly.

Contract details (bucket policy, swap protocol, refit trigger) are in
``docs/SERVING.md``; the serving hot paths are registered in the
``repro.analysis`` auditor (host-free, bucket-bounded materialization,
f32-clean under x64).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mctm as M
from repro.core.bernstein import bernstein_design, bernstein_deriv_design, monotone_theta

__all__ = [
    "QUERY_KINDS",
    "DensityRequest",
    "ModelSlot",
    "DensityServeEngine",
    "bucket_sizes",
    "bucket_for",
    "make_log_density_fn",
    "make_conditional_sample_fn",
    "refit_and_publish",
    "start_background_refit",
]

QUERY_KINDS = ("log_density", "sample")


# ---------------------------------------------------------------------------
# batched query kernels (pure, params-as-arguments so hot swaps never retrace)
# ---------------------------------------------------------------------------


def make_log_density_fn(cfg: M.MCTMConfig) -> Callable:
    """Batched ``log p(y)``: ``fn(params, low, high, inv_span, Y)`` → (B,).

    ``low``/``high``/``inv_span`` are the ``DataScaler`` arrays passed as
    arguments (a refit may republish a new scaler without recompiling).
    Matches ``mctm.log_density`` exactly: ``inv_span`` arrives precomputed
    rather than re-derived so the Jacobian scale is bit-identical.
    """

    def log_density_fn(params, low, high, inv_span, Y):
        dt = Y.dtype
        T = (Y - jnp.asarray(low, dt)) / (jnp.asarray(high, dt) - jnp.asarray(low, dt))
        A = bernstein_design(T, cfg.degree)
        Ap = bernstein_deriv_design(T, cfg.degree) * jnp.asarray(inv_span, dt)[..., None]
        return -M.nll_terms(cfg, params, A, Ap)

    return log_density_fn


def make_conditional_sample_fn(cfg: M.MCTMConfig, n_grid: int = 512) -> Callable:
    """Batched conditional sampler:
    ``fn(params, low, high, base_key, y_obs, n_obs, seeds)`` → (B, J).

    Row i observes ``y_obs[i, :n_obs[i]]`` and samples the remaining
    dimensions (``n_obs[i] = 0`` → a full draw; ``n_obs[i] = J`` → returns
    the row unchanged, the padding convention). The triangular recursion
    h̃_j = z_j − Σ_{l<j} λ_{jl} h̃_l runs over realized h̃ values — observed
    dimensions contribute their Bernstein transform, sampled ones the value
    the recursion just produced — and sampled marginals invert on the same
    ``n_grid`` grid as ``mctm.sample``. Per-row randomness is
    ``fold_in(base_key, seeds[i])``: bucket-composition independent.
    """
    f32 = jnp.float32

    def sample_fn(params, low, high, base_key, y_obs, n_obs, seeds):
        theta = monotone_theta(params.theta_raw, cfg.min_slope)        # (J, d)
        Lam = M.lambda_matrix(cfg, params.lam)
        t_grid = jnp.linspace(f32(0.0), f32(1.0), n_grid, dtype=f32)
        grid_vals = bernstein_design(t_grid, cfg.degree) @ theta.T     # (G, J)
        z = jax.vmap(
            lambda s: jax.random.normal(jax.random.fold_in(base_key, s),
                                        (cfg.J,), f32)
        )(seeds)                                                       # (B, J)
        low = jnp.asarray(low, f32)
        high = jnp.asarray(high, f32)
        span = high - low
        t_obs = jnp.clip((y_obs - low) / span, f32(0.0), f32(1.0))
        h_obs = jnp.einsum("njd,jd->nj", bernstein_design(t_obs, cfg.degree), theta)
        observed = jnp.arange(cfg.J, dtype=n_obs.dtype)[None, :] < n_obs[:, None]
        h_cols: list = []
        y_cols: list = []
        for j in range(cfg.J):  # J is small and static — unrolled
            target = z[:, j]
            for l in range(j):
                target = target - Lam[j, l] * h_cols[l]
            idx = jnp.clip(jnp.searchsorted(grid_vals[:, j], target), 1, n_grid - 1)
            v0, v1 = grid_vals[idx - 1, j], grid_vals[idx, j]
            t0, t1 = t_grid[idx - 1], t_grid[idx]
            frac = jnp.clip(
                (target - v0) / jnp.maximum(v1 - v0, f32(1e-12)), f32(0.0), f32(1.0)
            )
            y_samp = low[j] + (t0 + frac * (t1 - t0)) * span[j]
            h_cols.append(jnp.where(observed[:, j], h_obs[:, j], target))
            y_cols.append(jnp.where(observed[:, j], y_obs[:, j], y_samp))
        return jnp.stack(y_cols, axis=1)

    return sample_fn


# ---------------------------------------------------------------------------
# requests, model slot, bucket policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DensityRequest:
    """One density query. ``kind`` is ``"log_density"`` (evaluate at ``y``)
    or ``"sample"`` (observe ``y[:n_obs]``, draw the rest with ``seed``)."""

    uid: int
    kind: str
    y: np.ndarray                      # (J,) float32
    n_obs: int = 0                     # sample: observed prefix length
    seed: int = 0                      # sample: per-request randomness
    # filled by the engine:
    result: np.ndarray | float | None = None
    version: int = -1                  # model version that served it
    submitted_s: float = 0.0
    finished_s: float = 0.0

    @property
    def done(self) -> bool:
        return self.finished_s > 0

    @property
    def latency_s(self) -> float:
        return self.finished_s - self.submitted_s


class ModelSlot(NamedTuple):
    """One published model: immutable, swapped whole (double buffering)."""

    version: int
    params: M.MCTMParams
    low: jax.Array        # (J,) f32 scaler bounds
    high: jax.Array
    inv_span: jax.Array   # (J,) f32, precomputed (bit-parity with DataScaler)


def bucket_sizes(min_bucket: int, max_batch: int) -> tuple[int, ...]:
    """The bucket ladder: powers of two from ``min_bucket``, capped at (and
    always including) ``max_batch``."""
    sizes = []
    b = max(1, int(min_bucket))
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(int(max_batch))
    return tuple(sizes)


def bucket_for(m: int, sizes: tuple[int, ...]) -> int:
    """Smallest bucket holding ``m`` rows (``m`` ≤ max(sizes) by admission)."""
    for b in sizes:
        if m <= b:
            return b
    return sizes[-1]


def _slot_from(version: int, params: M.MCTMParams, scaler) -> ModelSlot:
    return ModelSlot(
        version=version,
        params=jax.tree.map(jnp.asarray, params),
        low=jnp.asarray(np.asarray(scaler.low, np.float32)),
        high=jnp.asarray(np.asarray(scaler.high, np.float32)),
        inv_span=jnp.asarray(np.asarray(scaler.inv_span, np.float32)),
    )


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


class DensityServeEngine:
    """Continuous-batching server for ``log_density`` / conditional
    ``sample`` queries over a fitted MCTM (module doc for the contract).

    One ``step()`` = one tick: swap in any staged model, then for each query
    kind coalesce up to ``max_batch`` queued requests into their padded
    bucket and dispatch the cached executable. ``publish()`` may be called
    from any thread (the background refit worker); it never blocks serving.
    """

    def __init__(
        self,
        cfg: M.MCTMConfig,
        params: M.MCTMParams,
        scaler,
        *,
        max_batch: int = 256,
        min_bucket: int = 8,
        n_grid: int = 512,
        sample_key: jax.Array | None = None,
    ):
        if max_batch < 1 or min_bucket < 1:
            raise ValueError("max_batch and min_bucket must be ≥ 1")
        self.cfg = cfg
        self.max_batch = int(max_batch)
        self.buckets = bucket_sizes(min_bucket, max_batch)
        self.n_grid = int(n_grid)
        self._base_key = (
            sample_key if sample_key is not None else jax.random.PRNGKey(0)
        )
        self._slot = _slot_from(0, params, scaler)
        self._staged: ModelSlot | None = None
        self._lock = threading.Lock()
        self._version = 0
        self.queues: dict[str, deque[DensityRequest]] = {
            k: deque() for k in QUERY_KINDS
        }
        self._uid = 0
        # trace-time compile meter: the increments below run ONLY when jax
        # retraces (python side effects never execute from the dispatch
        # cache), so steady-state traffic keeps these counts frozen
        self.trace_counts = {k: 0 for k in QUERY_KINDS}
        ld = make_log_density_fn(cfg)
        sf = make_conditional_sample_fn(cfg, n_grid)

        def _ld(params, low, high, inv_span, Y):
            self.trace_counts["log_density"] += 1
            return ld(params, low, high, inv_span, Y)

        def _sf(params, low, high, base_key, y_obs, n_obs, seeds):
            self.trace_counts["sample"] += 1
            return sf(params, low, high, base_key, y_obs, n_obs, seeds)

        self._fns = {"log_density": jax.jit(_ld), "sample": jax.jit(_sf)}
        # warmed (kind, bucket, dtype) keys — the index over jit's executable
        # cache; a key present here will never trace again for any model slot
        self._execs: dict[tuple[str, int, str], Callable] = {}
        self.ticks = 0
        self.served = {k: 0 for k in QUERY_KINDS}
        self.bucket_counts: dict[tuple[str, int], int] = {}
        self.swap_events: list[dict] = []
        self.tick_times: list[float] = []
        # refit bookkeeping: one record per refit_and_publish cycle (version,
        # fit NLL per weighted coreset point — the drift detector's reference
        # anchor) and the single in-flight background refit thread
        self.refit_log: list[dict] = []
        self._refit_thread: threading.Thread | None = None

    # ------------------------------------------------------------ properties

    @property
    def compile_count(self) -> int:
        """Total XLA traces across both query kinds (the recompile meter)."""
        return sum(self.trace_counts.values())

    @property
    def version(self) -> int:
        return self._slot.version

    @property
    def refit_in_flight(self) -> bool:
        """True while a background refit started via
        :meth:`start_background_refit` is still running."""
        th = self._refit_thread
        return th is not None and th.is_alive()

    def current_slot(self) -> ModelSlot:
        """The live model slot (params + scaler bounds + version) — what a
        drift evaluator should score incoming windows against."""
        return self._slot

    def start_background_refit(self, *args, **kwargs):
        """Engine-owned trigger: run :func:`refit_and_publish` on a daemon
        thread with single-in-flight tracking — a second trigger while one
        refit is still running is a no-op returning ``None`` (drift alerts
        can fire on consecutive windows; one refit serves them all). Returns
        the started thread otherwise.
        """
        if self.refit_in_flight:
            return None
        th = threading.Thread(
            target=refit_and_publish, args=(self, *args), kwargs=kwargs,
            daemon=True,
        )
        self._refit_thread = th
        th.start()
        return th

    # -------------------------------------------------------------- admission

    def submit(self, req: DensityRequest) -> DensityRequest:
        if req.kind not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {req.kind!r}")
        req.submitted_s = time.perf_counter()
        self.queues[req.kind].append(req)
        return req

    def _next_uid(self) -> int:
        self._uid += 1
        return self._uid

    def submit_log_density(self, Y) -> list[DensityRequest]:
        """Queue one ``log_density`` request per row of ``Y`` (n, J)."""
        Y = np.atleast_2d(np.asarray(Y, np.float32))
        return [
            self.submit(DensityRequest(self._next_uid(), "log_density", y))
            for y in Y
        ]

    def submit_sample(
        self, n: int = 1, *, seeds=None, y_obs=None, n_obs: int = 0
    ) -> list[DensityRequest]:
        """Queue ``n`` conditional-sample requests. ``y_obs`` is one (J,)
        observed row shared by the batch (or (n, J) per-request rows);
        ``n_obs`` its observed prefix length; ``seeds`` per-request ints
        (default: sequential from the engine's running uid)."""
        J = self.cfg.J
        if y_obs is None:
            y_obs = np.zeros((n, J), np.float32)
        else:
            y_obs = np.asarray(y_obs, np.float32)
            y_obs = np.broadcast_to(
                np.atleast_2d(y_obs), (n, J)
            ).copy()
        if seeds is None:
            seeds = [self._uid + 1 + i for i in range(n)]
        return [
            self.submit(
                DensityRequest(
                    self._next_uid(), "sample", y_obs[i],
                    n_obs=int(n_obs), seed=int(seeds[i]),
                )
            )
            for i in range(n)
        ]

    # -------------------------------------------------------------- execution

    def _get_exec(self, kind: str, bucket: int, dtype: str) -> Callable:
        key = (kind, bucket, dtype)
        fn = self._execs.get(key)
        if fn is None:
            fn = self._fns[kind]
            self._execs[key] = fn
        return fn

    def _dispatch(self, slot: ModelSlot, kind: str, reqs: list[DensityRequest]):
        m = len(reqs)
        bucket = bucket_for(m, self.buckets)
        self.bucket_counts[(kind, bucket)] = (
            self.bucket_counts.get((kind, bucket), 0) + 1
        )
        Y = np.empty((bucket, self.cfg.J), np.float32)
        for i, r in enumerate(reqs):
            Y[i] = r.y
        # pad with valid row-0 copies (the fit layer's padding rule: real
        # data through the featurizer, results sliced away)
        Y[m:] = Y[0]
        if kind == "log_density":
            fn = self._get_exec(kind, bucket, "float32")
            out = fn(slot.params, slot.low, slot.high, slot.inv_span,
                     jnp.asarray(Y))
        else:
            n_obs = np.full(bucket, self.cfg.J, np.int32)  # pad: fully observed
            seeds = np.zeros(bucket, np.int32)
            for i, r in enumerate(reqs):
                n_obs[i] = r.n_obs
                seeds[i] = r.seed
            fn = self._get_exec(kind, bucket, "float32")
            out = fn(slot.params, slot.low, slot.high, self._base_key,
                     jnp.asarray(Y), jnp.asarray(n_obs), jnp.asarray(seeds))
        out = np.asarray(out)
        now = time.perf_counter()
        for i, r in enumerate(reqs):
            r.result = float(out[i]) if kind == "log_density" else out[i]
            r.version = slot.version
            r.finished_s = now
        self.served[kind] += m

    def step(self) -> int:
        """One tick: swap in a staged model, serve ≤ one bucket per kind.
        Returns the number of requests completed this tick."""
        t0 = time.perf_counter()
        with self._lock:
            if self._staged is not None:
                self._slot = self._staged
                self._staged = None
                self.swap_events[-1]["visible_s"] = time.perf_counter()
        slot = self._slot  # read ONCE per tick: all queries see one version
        done = 0
        for kind in QUERY_KINDS:
            q = self.queues[kind]
            if not q:
                continue
            reqs = [q.popleft() for _ in range(min(len(q), self.max_batch))]
            self._dispatch(slot, kind, reqs)
            done += len(reqs)
        self.ticks += 1
        self.tick_times.append(time.perf_counter() - t0)
        return done

    def run_until_drained(self, max_ticks: int = 1_000_000) -> int:
        """Tick until no work is pending. A staged-but-unswapped model counts
        as pending work: the swap only happens at tick START, so without the
        extra tick a model published after the last serving tick would stay
        invisible until the next request arrives."""
        done = 0
        while (
            any(self.queues.values()) or self._staged is not None
        ) and max_ticks > 0:
            done += self.step()
            max_ticks -= 1
        return done

    def warmup(self, kinds=QUERY_KINDS, buckets=None) -> int:
        """Compile the bucket ladder up front (dummy traffic through the real
        dispatch path) so steady-state serving never traces. Returns the
        number of executables compiled."""
        before = self.compile_count
        slot = self._slot
        for kind in kinds:
            for b in buckets or self.buckets:
                reqs = [
                    DensityRequest(0, kind, np.zeros(self.cfg.J, np.float32),
                                   n_obs=self.cfg.J)
                    for _ in range(b)
                ]
                self._dispatch(slot, kind, reqs)
        # warmup traffic is not served traffic
        for kind in kinds:
            self.served[kind] = 0
        self.bucket_counts.clear()
        return self.compile_count - before

    # -------------------------------------------------------------- hot swap

    def publish(self, params: M.MCTMParams, scaler=None) -> int:
        """Stage a new model for the next tick (thread-safe, non-blocking).

        Double-buffer protocol: the staged slot becomes visible at the START
        of the next tick; queries of the in-flight tick finish on the old
        slot. Re-publishing before the swap replaces the staged slot (last
        writer wins — both are complete models). Returns the new version.
        """
        with self._lock:
            self._version += 1
            scaler = scaler if scaler is not None else _ScalerView(
                np.asarray(self._slot.low), np.asarray(self._slot.high)
            )
            self._staged = _slot_from(self._version, params, scaler)
            self.swap_events.append({
                "version": self._version,
                "published_s": time.perf_counter(),
                "visible_s": None,
            })
            return self._version

    def stats(self) -> dict:
        ticks = np.asarray(self.tick_times, np.float64)
        return {
            "ticks": self.ticks,
            "served": dict(self.served),
            "compile_count": self.compile_count,
            "trace_counts": dict(self.trace_counts),
            "buckets": {f"{k}/{b}": c for (k, b), c in self.bucket_counts.items()},
            "version": self.version,
            "tick_p50_ms": float(np.percentile(ticks, 50) * 1e3) if ticks.size else 0.0,
            "tick_p99_ms": float(np.percentile(ticks, 99) * 1e3) if ticks.size else 0.0,
        }


@dataclasses.dataclass(frozen=True)
class _ScalerView:
    """DataScaler-shaped view over published bounds (publish() without a new
    scaler keeps the current one)."""

    low: np.ndarray
    high: np.ndarray

    @property
    def inv_span(self) -> np.ndarray:
        return 1.0 / (self.high - self.low)


# ---------------------------------------------------------------------------
# background refit → publish (the coreset economics loop)
# ---------------------------------------------------------------------------


def refit_and_publish(
    engine: DensityServeEngine,
    scaler,
    Y=None,
    k: int | None = None,
    *,
    key: jax.Array,
    method: str = "lbfgs",
    steps: int = 60,
    lr: float = 5e-2,
    sketch_size: int = 0,
    chunk_size: int | None = None,
    coreset=None,
) -> int:
    """One refresh cycle: fresh coreset on ``Y`` → streamed fit → publish.

    This is the paper's economics made operational: the coreset build + fit
    is the cheap background path (vs refitting on all of ``Y``), and the
    publish is atomic w.r.t. serving. Returns the published version.
    Runs synchronously — wrap with :func:`start_background_refit` to overlap
    with serving.

    ``coreset=(cs_Y, cs_weights)`` skips the build entirely and fits on an
    externally maintained coreset — the streaming maintainer's path, where
    merge-reduce already holds a fresh (k, J) weighted set and rebuilding
    from raw rows would defeat the point. Either ``coreset`` or ``(Y, k)``
    must be given.

    Every cycle appends ``{"version", "fit_nll_pp", "k"}`` to
    ``engine.refit_log``: the fitted model's NLL per weighted coreset point,
    the reference the drift detector re-anchors on after a publish.
    """
    from repro.core.mctm_fit import fit_mctm_streaming, streamed_nll
    from repro.core.scoring import DEFAULT_CHUNK

    k_build, k_fit = jax.random.split(key)
    if coreset is not None:
        cs_Y = np.asarray(coreset[0], np.float32)
        cs_w = np.asarray(coreset[1], np.float32)
    else:
        if Y is None or k is None:
            raise ValueError("refit_and_publish needs either coreset= or (Y, k)")
        from repro.core.coreset import build_coreset

        cs = build_coreset(
            engine.cfg, scaler, Y, k, "l2-hull", key=k_build,
            sketch_size=sketch_size,
            chunk_size=DEFAULT_CHUNK if chunk_size is None else chunk_size,
        )
        cs_Y = np.asarray(Y)[cs.indices]
        cs_w = np.asarray(cs.weights, np.float32)
    fit = fit_mctm_streaming(
        engine.cfg, scaler, cs_Y,
        weights=cs_w,
        key=k_fit, steps=steps, lr=lr, method=method,
        chunk_size=DEFAULT_CHUNK if chunk_size is None else chunk_size,
    )
    fit_nll_pp = streamed_nll(
        engine.cfg, scaler, fit.params, cs_Y, weights=cs_w,
        chunk=DEFAULT_CHUNK if chunk_size is None else chunk_size,
    ) / max(float(cs_w.sum()), 1e-9)
    version = engine.publish(fit.params, scaler)
    engine.refit_log.append(
        {"version": version, "fit_nll_pp": float(fit_nll_pp),
         "k": int(cs_Y.shape[0])}
    )
    return version


def start_background_refit(engine: DensityServeEngine, *args, **kwargs):
    """Run :func:`refit_and_publish` on a daemon thread (serving continues on
    the caller's thread; the publish lands between ticks). Returns the
    started thread; ``join()`` it to wait for the publish."""
    th = threading.Thread(
        target=refit_and_publish, args=(engine, *args), kwargs=kwargs,
        daemon=True,
    )
    th.start()
    return th
