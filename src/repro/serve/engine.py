"""Serving engine with continuous batching over fixed decode slots.

vLLM-style slot scheduler adapted to JAX's static shapes: the engine owns a
(B_slots, max_len) cache; requests are admitted into free slots, prefilled
one-at-a-time into their slot's cache lanes, and decoded *jointly* (one
batched decode_step per tick serves every active slot). Finished slots are
recycled immediately — new requests join mid-flight without recompiling
(shapes are static in B_slots and max_len).

Batched-cache slot surgery relies on the cache layout contract: every cache
leaf is either scalar 'pos' or has batch at a fixed axis (layer-stacked
leaves: axis 1; per-slot pos handled via per-slot offsets — see
``_PosPolicy``). Since family caches differ (KV / latent / SSM state /
RG-LRU + window), the engine prefills into a single-slot cache and scatters
its leaves into the batched cache at the slot index.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 → greedy
    eos_token: int = -1               # -1 → never stops early


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    gen: GenerationConfig = dataclasses.field(default_factory=GenerationConfig)
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    finished_s: float = 0.0

    @property
    def done(self) -> bool:
        return self.finished_s > 0


class ServeEngine:
    """Continuous-batching engine around a repro Model (decoder families)."""

    def __init__(self, model: Model, params, n_slots: int = 4, max_len: int = 128):
        if model.cfg.family == "encdec":
            raise ValueError("encdec serving needs per-request encoder state")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * n_slots
        self.remaining = np.zeros(n_slots, np.int64)
        self.cache, _ = model.init_cache(n_slots, max_len)
        # per-slot absolute positions (the shared scalar 'pos' is replaced by
        # the max; masking uses per-slot offsets via token-position plumbing)
        self.slot_pos = np.zeros(n_slots, np.int64)
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self.ticks = 0

    # ------------------------------------------------------------- lifecycle

    def submit(self, req: Request):
        req.submitted_s = time.perf_counter()
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def _admit(self):
        """Prefill queued requests into free slots (single-slot prefill,
        scatter into the batched cache)."""
        for slot in self._free_slots():
            if not self.queue:
                return
            req = self.queue.popleft()
            one_cache, _ = self.model.init_cache(1, self.max_len)
            logits, one_cache = self._prefill(
                self.params, {"tokens": req.prompt[None, :]}, one_cache
            )
            tok = int(np.argmax(np.asarray(logits[0, -1])))
            req.output.append(tok)
            self.cache = _scatter_slot(self.cache, one_cache, slot)
            self.active[slot] = req
            self.remaining[slot] = req.gen.max_new_tokens - 1
            self.slot_pos[slot] = len(req.prompt) + 0

    def _retire(self, slot: int):
        req = self.active[slot]
        req.finished_s = time.perf_counter()
        self.active[slot] = None
        self.remaining[slot] = 0

    # ------------------------------------------------------------------ tick

    def step(self, key=None) -> int:
        """One engine tick: admit, batched decode, sample, retire. Returns
        number of active requests served this tick."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        last_tokens = np.zeros((self.n_slots, 1), np.int32)
        for i in live:
            last_tokens[i, 0] = self.active[i].output[-1]
        logits, self.cache = self._decode(self.params, jnp.asarray(last_tokens), self.cache)
        logits = np.asarray(logits[:, -1], np.float32)
        for i in live:
            req = self.active[i]
            if req.gen.temperature > 0:
                key = key if key is not None else jax.random.PRNGKey(self.ticks)
                key, sub = jax.random.split(key)
                tok = int(jax.random.categorical(sub, jnp.asarray(logits[i]) / req.gen.temperature))
            else:
                tok = int(np.argmax(logits[i]))
            req.output.append(tok)
            self.remaining[i] -= 1
            if self.remaining[i] <= 0 or tok == req.gen.eos_token:
                self._retire(i)
        self.ticks += 1
        return len(live)

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        done: list[Request] = []
        while (self.queue or any(r is not None for r in self.active)) and self.ticks < max_ticks:
            before = [r for r in self.active]
            self.step()
            for r in before:
                if r is not None and r.done and r not in done:
                    done.append(r)
        return done


def _scatter_slot(batched_cache, one_cache, slot: int):
    """Write a 1-slot cache into slot `slot` of the batched cache.

    Layout contract: leaves with a leading layer axis carry batch at axis 1;
    unstacked leaves (hybrid tail blocks) carry batch at axis 0; scalar 'pos'
    merges by max (per-slot positions tracked host-side; correctness for
    mixed-length decode comes from each slot's own attention mask built from
    cache contents — valid because shorter slots' future lanes hold zeros and
    are masked by position ≥ written range only for ring caches; for linear
    caches the shared pos must be the per-slot max, so admission order should
    keep prompt lengths similar for exactness — documented engine limit).
    """

    def merge(b, o):
        if o.ndim == 0:  # 'pos' from the 1-slot cache
            if b.ndim == 0:
                return jnp.maximum(b, o)  # legacy shared-scalar pos
            return b.at[slot].set(o.astype(b.dtype))  # per-slot position vector
        if b.ndim >= 2 and o.ndim == b.ndim and o.shape[0] == b.shape[0] and o.shape[1] == 1:
            # layer-stacked (L, B, ...) leaf
            return jax.lax.dynamic_update_slice_in_dim(b, o.astype(b.dtype), slot, axis=1)
        # unstacked (B, ...) leaf
        return jax.lax.dynamic_update_slice_in_dim(b, o.astype(b.dtype), slot, axis=0)

    return jax.tree.map(merge, batched_cache, one_cache)
