"""HLO text analysis: collective wire bytes + op census for the roofline.

``cost_analysis()`` does not expose collective bytes, so we parse the
compiled module text and sum the *result* buffer sizes of every collective
op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, including their async -start forms). Result-bytes is a
consistent proxy for wire bytes per device (all-reduce rings move ~2× the
buffer, all-gather exactly the result minus the local shard); we keep one
convention across all measurements so §Perf deltas are meaningful.

Conventions:

* A collective is counted exactly once: at its plain form or its ``-start``
  form. ``-done`` lines only close the async pair (tracked in
  ``async_unmatched`` so a malformed module is visible, never double
  counted).
* A *plain* op with a tuple result (variadic all-reduce, all-to-all) sums
  the tuple elements — each element is a distinct payload on the wire.
* A ``-start`` op's tuple result is ``(operand_alias, result, ...)``; the
  payload is the *largest* element, so we take ``max`` instead of ``sum``
  to avoid counting the aliased input buffer as wire traffic.
* Bounded dynamic dims (``<=512``) count at their bound.
* Layout/tiling annotations (``{1,0:T(8,128)}``, ``S(1)`` memory spaces)
  are ignored wherever they appear inside a type.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# One shape literal: dtype[dims] where each dim may be bounded-dynamic
# (``<=512``). The dims group deliberately rejects layout braces — those are
# matched (and discarded) by the callers that care.
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[((?:<=)?\d+(?:,(?:<=)?\d+)*|)\]")

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_OP_TOKEN_RE = re.compile(
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\("
)


def _shape_literal_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            d = d.strip()
            if d.startswith("<="):
                d = d[2:]
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def shape_bytes(type_str: str) -> int:
    """Sum bytes over every shape literal in an HLO type string.

    Works on single shapes (``f32[4,4]{1,0:T(8,128)}``), tuples of shapes,
    and bounded dynamic dims (``f32[<=512]`` counts at its bound).
    """
    return sum(_shape_literal_bytes(d, dims) for d, dims in _SHAPE_RE.findall(type_str))


def _tuple_element_bytes(type_str: str) -> list[int]:
    """Byte size of each top-level tuple element; [shape_bytes] if no tuple.

    The splitter is balanced-delimiter aware so layout annotations with
    internal commas/parens (``{1,0:T(2,128)}``) don't break elements apart.
    """
    t = type_str.strip()
    if not (t.startswith("(") and t.endswith(")")):
        return [shape_bytes(t)]
    body = t[1:-1]
    elems, depth, start = [], 0, 0
    for i, c in enumerate(body):
        if c in "({[":
            depth += 1
        elif c in ")}]":
            depth -= 1
        elif c == "," and depth == 0:
            elems.append(body[start:i])
            start = i + 1
    elems.append(body[start:])
    return [shape_bytes(e) for e in elems if e.strip()]


# A well-formed result type contains only type syntax; operand references
# (``%fusion.6``) and string attrs (``metadata={op_name="..."}``) never do,
# which is what rejects false-positive matches of a collective name inside
# fusion/custom-call/metadata text.
_TYPE_CHARS_RE = re.compile(r'^[^%"]*$')
_TYPE_START_RE = re.compile(r"^\s*(\(|[a-z][a-z0-9]*\[)")


def collective_stats(hlo_text: str) -> dict:
    """{'total_bytes', 'by_op': {op: {'count','bytes'}}, 'async_unmatched'}.

    Bytes are the *result* buffer size of each collective in the per-device
    program; async ops are counted once at their -start form (largest tuple
    element — the aliased operand buffer is not wire traffic), plain tuple
    results (variadic all-reduce) sum their elements. ``async_unmatched``
    maps op → (#starts − #dones) for any op whose async pair is unbalanced;
    empty for a well-formed module.
    """
    by_op: dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0})
    starts: dict[str, int] = defaultdict(int)
    dones: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        eq = line.find("=")
        if eq < 0:
            continue
        m = _OP_TOKEN_RE.search(line, eq + 1)
        if not m:
            continue
        type_str = line[eq + 1 : m.start()]
        if not (_TYPE_CHARS_RE.match(type_str) and _TYPE_START_RE.match(type_str)):
            continue
        op, suffix = m.group("op"), m.group("suffix")
        if suffix == "-done":
            dones[op] += 1
            continue
        elems = _tuple_element_bytes(type_str)
        if suffix == "-start":
            starts[op] += 1
            b = max(elems) if elems else 0
        else:
            b = sum(elems)
        by_op[op]["count"] += 1
        by_op[op]["bytes"] += b
    unmatched = {
        op: starts[op] - dones[op]
        for op in set(starts) | set(dones)
        if starts[op] != dones[op]
    }
    total = sum(v["bytes"] for v in by_op.values())
    return {"total_bytes": total, "by_op": dict(by_op), "async_unmatched": unmatched}


def input_output_aliases(hlo_text: str) -> list[tuple[str, int]]:
    """Parse the module's ``input_output_alias`` header into
    ``[(output_index, param_number), ...]`` — one entry per donated/aliased
    output buffer. Empty list when the executable aliases nothing (i.e. a
    declared donation was NOT honored)."""
    key = "input_output_alias={"
    i = hlo_text.find(key)
    if i < 0:
        return []
    j = i + len(key) - 1
    depth, k = 0, j
    for k in range(j, len(hlo_text)):
        c = hlo_text[k]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth == 0:
                break
    body = hlo_text[j + 1 : k]
    entries = re.findall(r"\{([\d,\s]*)\}\s*:\s*\(\s*(\d+)", body)
    return [(out.strip(), int(param)) for out, param in entries]


def while_trip_counts(hlo_text: str) -> list[int]:
    """Best-effort trip counts of while loops (for scan-aware flop scaling)."""
    return [int(m) for m in re.findall(r"trip_count=(\d+)", hlo_text)]
