"""HLO text analysis: collective wire bytes + op census for the roofline.

``cost_analysis()`` does not expose collective bytes, so we parse the
compiled module text and sum the *result* buffer sizes of every collective
op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, including their async -start forms). Result-bytes is a
consistent proxy for wire bytes per device (all-reduce rings move ~2× the
buffer, all-gather exactly the result minus the local shard); we keep one
convention across all measurements so §Perf deltas are meaningful.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def shape_bytes(type_str: str) -> int:
    """Sum bytes over every shape literal in an HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COLLECTIVE_RE = re.compile(
    r"=\s*(?P<type>\(?[^=]*?\)?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\("
)


def collective_stats(hlo_text: str) -> dict:
    """{'total_bytes', 'by_op': {op: {'count', 'bytes'}}} from HLO text.

    Bytes are the *result* buffer size of each collective in the per-device
    program (async ops counted once at their -start/plain form).
    """
    by_op: dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        op = m.group("op")
        b = shape_bytes(m.group("type"))
        by_op[op]["count"] += 1
        by_op[op]["bytes"] += b
    total = sum(v["bytes"] for v in by_op.values())
    return {"total_bytes": total, "by_op": dict(by_op)}


def while_trip_counts(hlo_text: str) -> list[int]:
    """Best-effort trip counts of while loops (for scan-aware flop scaling)."""
    return [int(m) for m in re.findall(r"trip_count=(\d+)", hlo_text)]
