from repro.utils.tree import tree_size, tree_bytes, tree_allclose, tree_norm
from repro.utils.prng import key_iter, fold_in_str

__all__ = [
    "tree_size",
    "tree_bytes",
    "tree_allclose",
    "tree_norm",
    "key_iter",
    "fold_in_str",
]
