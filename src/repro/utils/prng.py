"""PRNG helpers: deterministic named key derivation for reproducible pipelines."""
from __future__ import annotations

import hashlib

import jax


def fold_in_str(key: jax.Array, name: str) -> jax.Array:
    """Deterministically fold a string tag into a PRNG key."""
    h = int.from_bytes(hashlib.sha256(name.encode()).digest()[:4], "little")
    return jax.random.fold_in(key, h)


def key_iter(key: jax.Array):
    """Infinite iterator of fresh subkeys."""
    while True:
        key, sub = jax.random.split(key)
        yield sub
