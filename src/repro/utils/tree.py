"""Pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def is_spec_leaf(x) -> bool:
    """Logical-sharding-spec leaves are plain tuples of axis names; param
    containers may themselves be NamedTuples (e.g. MCTMParams), which are
    tuples too — exclude them so spec trees can mirror any param pytree.
    Shared by the sharding resolver and the optimizer state_specs maps."""
    return isinstance(x, tuple) and not hasattr(x, "_fields")


def tree_size(tree) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree (uses dtype itemsize; ShapeDtypeStructs OK)."""
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(x, y, rtol=rtol, atol=atol) for x, y in zip(la, lb))


def tree_norm(tree) -> jax.Array:
    """Global l2 norm of a pytree."""
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)
