"""Version compatibility shims for the jax API surface this repo targets.

The code is written against current jax (`jax.shard_map`, `jax.make_mesh`
with ``axis_types``, ``check_vma``); CI images may carry an older 0.4.x where
those names live elsewhere or don't exist. Import the symbols from here so
every module (and the subprocess-isolated distributed tests) resolves them
uniformly.
"""
from __future__ import annotations

import inspect

import jax

__all__ = ["shard_map", "make_mesh", "MIN_JAX_VERSION"]

# The oldest jax this repo supports — the version every shim below exists
# for. CI's version matrix pins its minimum leg to exactly this (the
# workflow asserts the installed jax matches, so the pin cannot silently
# drift from the shims).
MIN_JAX_VERSION = "0.4.37"

try:  # jax >= 0.5
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# the replication-check kwarg was renamed check_rep → check_vma independently
# of where shard_map lives, so probe the signature rather than the import path
_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map).parameters
    else "check_rep"
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """`jax.shard_map` with the replication-check kwarg renamed per version."""
    kw = {}
    if check_vma is not None:
        kw[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_mesh(axis_shapes, axis_names, *, explicit: bool = False):
    """`jax.make_mesh` requesting Auto axis types where supported.

    Older jax has no ``axis_types`` kwarg (Auto is the only behavior); newer
    jax defaults to Auto unless ``explicit``.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
    kind = axis_type.Explicit if explicit else axis_type.Auto
    return jax.make_mesh(
        tuple(axis_shapes), tuple(axis_names), axis_types=(kind,) * len(axis_names)
    )
