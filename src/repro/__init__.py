"""repro — Scalable Learning of Multivariate Distributions via Coresets.

Production-grade JAX framework: MCTM coresets (the paper's contribution) as a
first-class data-reduction stage of a multi-pod training/serving stack.

Subpackages: core (paper), data, models, kernels, distributed, optim, train,
serve, checkpoint, ft, configs, launch. See README.md / DESIGN.md.
"""

__version__ = "1.0.0"
