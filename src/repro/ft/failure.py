"""Fault-tolerance control plane: failure handling + elastic re-meshing.

On a real cluster this layer sits in the coordinator: heartbeats detect dead
hosts, the job drains, and training restarts on the surviving slice from the
last atomic checkpoint. Here we implement the *decision logic* (pure,
testable) plus a single-process failure simulator used by the integration
tests:

  * ``ElasticPlanner.plan(n_alive)`` — pick the largest valid mesh that fits
    the survivors while (a) keeping the model axis intact if possible (TP
    degree is dictated by memory), (b) shrinking data/pod axes first, and
    (c) rescaling batch/LR consistently.
  * ``FailureSimulator`` — drives a train loop, injecting failures at chosen
    steps and verifying checkpoint-restore equivalence.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["MeshPlan", "ElasticPlanner", "FailureSimulator", "StragglerPolicy"]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    global_batch: int
    lr_scale: float
    devices_used: int

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


@dataclasses.dataclass
class ElasticPlanner:
    """Chooses a degraded mesh after failures (and upsizes when nodes return)."""

    model_parallel: int           # required TP degree (memory-bound, fixed)
    base_data_parallel: int       # DP at full strength (per pod)
    n_pods: int = 1
    base_global_batch: int = 256
    min_data_parallel: int = 1

    def plan(self, n_alive: int) -> MeshPlan:
        if n_alive < self.model_parallel * self.min_data_parallel:
            raise RuntimeError(
                f"{n_alive} devices cannot host model_parallel={self.model_parallel}"
            )
        # keep TP fixed; give the rest to (pod × data), preferring pod-sized blocks
        total_rows = n_alive // self.model_parallel
        pods = min(self.n_pods, total_rows)
        while pods > 1 and total_rows % pods != 0:
            pods -= 1
        data = total_rows // pods
        # batch scales with the surviving DP degree; LR follows linearly
        full_rows = self.base_data_parallel * self.n_pods
        frac = (data * pods) / full_rows
        gbatch = max(int(self.base_global_batch * frac), 1)
        if pods > 1:
            shape = (pods, data, self.model_parallel)
            axes = ("pod", "data", "model")
        else:
            shape = (data, self.model_parallel)
            axes = ("data", "model")
        return MeshPlan(
            shape=shape,
            axes=axes,
            global_batch=gbatch,
            lr_scale=frac,
            devices_used=data * pods * self.model_parallel,
        )


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-based straggler mitigation for the data-loading path.

    If a shard's batch is not ready within `deadline_ms`, the step proceeds
    with the backup batch (the deterministic re-sample of the same step with
    a fallback seed), and the slow fetch is cancelled. The decision function
    is pure so schedulers can unit-test it; at 1000+ nodes the same policy
    generalizes to backup *workers*: issue the step to `backup_factor`× hosts
    and take the first completion.
    """

    deadline_ms: float = 250.0
    backup_factor: int = 2

    def decide(self, elapsed_ms: np.ndarray) -> np.ndarray:
        """elapsed_ms: per-shard data-ready latency → bool mask 'use backup'."""
        return np.asarray(elapsed_ms) > self.deadline_ms


class FailureSimulator:
    """Drives step functions with injected failures; used by integration tests."""

    def __init__(self, fail_at_steps: set[int]):
        self.fail_at = set(fail_at_steps)
        self.failures: list[int] = []

    def maybe_fail(self, step: int):
        if step in self.fail_at:
            self.failures.append(step)
            self.fail_at.discard(step)
            raise RuntimeError(f"injected node failure at step {step}")
