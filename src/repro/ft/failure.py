"""Fault-tolerance control plane: failure handling + elastic re-meshing.

On a real cluster this layer sits in the coordinator: heartbeats detect dead
hosts, the job drains, and training restarts on the surviving slice from the
last atomic checkpoint. Here we implement the *decision logic* (pure,
testable) plus a single-process failure simulator used by the integration
tests:

  * ``ElasticPlanner.plan(n_alive)`` — pick the largest valid mesh that fits
    the survivors while (a) keeping the model axis intact if possible (TP
    degree is dictated by memory), (b) shrinking data/pod axes first, and
    (c) rescaling batch/LR consistently.
  * ``FailureSimulator`` — drives a train loop, injecting failures at chosen
    (phase, step) points. Every firing is appended to a persistent ``log``
    so a post-mortem (or the retry-budget-exhausted diagnostic) can show the
    full injection history; ``mode="every"`` rules re-fire on each retry,
    which is how crash-loop → clean-abort scenarios are tested.
  * ``StragglerPolicy`` — deadline-based backup-draw decision for the
    minibatch loading path.

The errors raised by the pipeline's failure paths also live here (so that
``train/loop.py`` and ``core/*`` can import them without cycles):
``InjectedFailure`` for simulated faults and ``NonFiniteError`` for a
detected non-finite loss/gradient. Both subclass ``RuntimeError``, the
retryable family that ``ft.supervisor.RunSupervisor`` catches.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "MeshPlan",
    "ElasticPlanner",
    "FailureSimulator",
    "StragglerPolicy",
    "InjectedFailure",
    "NonFiniteError",
]


class InjectedFailure(RuntimeError):
    """A simulated node/step failure raised by ``FailureSimulator``."""


class NonFiniteError(RuntimeError):
    """Non-finite loss or gradient detected during a fit step.

    Carries enough context (``step``, ``loss``, ``grad_norm``) for the
    supervisor to log a useful diagnostic and apply LR backoff before
    resuming from the last checkpoint.
    """

    def __init__(self, step: int, loss=None, grad_norm=None):
        super().__init__(
            f"non-finite training signal at step {step}: "
            f"loss={loss} grad_norm={grad_norm}"
        )
        self.step = int(step)
        self.loss = loss
        self.grad_norm = grad_norm


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    global_batch: int
    lr_scale: float
    devices_used: int

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


@dataclasses.dataclass
class ElasticPlanner:
    """Chooses a degraded mesh after failures (and upsizes when nodes return)."""

    model_parallel: int           # required TP degree (memory-bound, fixed)
    base_data_parallel: int       # DP at full strength (per pod)
    n_pods: int = 1
    base_global_batch: int = 256
    min_data_parallel: int = 1

    def plan(self, n_alive: int) -> MeshPlan:
        if n_alive < self.model_parallel * self.min_data_parallel:
            raise RuntimeError(
                f"{n_alive} devices cannot host model_parallel={self.model_parallel}"
            )
        # keep TP fixed; give the rest to (pod × data), preferring pod-sized blocks
        total_rows = n_alive // self.model_parallel
        pods = min(self.n_pods, total_rows)
        while pods > 1 and total_rows % pods != 0:
            pods -= 1
        data = total_rows // pods
        # batch scales with the surviving DP degree; LR follows linearly
        full_rows = self.base_data_parallel * self.n_pods
        frac = (data * pods) / full_rows
        gbatch = max(int(self.base_global_batch * frac), 1)
        if pods > 1:
            shape = (pods, data, self.model_parallel)
            axes = ("pod", "data", "model")
        else:
            shape = (data, self.model_parallel)
            axes = ("data", "model")
        return MeshPlan(
            shape=shape,
            axes=axes,
            global_batch=gbatch,
            lr_scale=frac,
            devices_used=data * pods * self.model_parallel,
        )


@dataclasses.dataclass
class StragglerPolicy:
    """Deadline-based straggler mitigation for the data-loading path.

    If a shard's batch is not ready within `deadline_ms`, the step proceeds
    with the backup batch (the deterministic re-sample of the same step with
    a fallback seed), and the slow fetch is cancelled. The decision function
    is pure so schedulers can unit-test it; at 1000+ nodes the same policy
    generalizes to backup *workers*: issue the step to `backup_factor`× hosts
    and take the first completion.
    """

    deadline_ms: float = 250.0
    backup_factor: int = 2

    def decide(self, elapsed_ms: np.ndarray) -> np.ndarray:
        """elapsed_ms: per-shard data-ready latency → bool mask 'use backup'."""
        return np.asarray(elapsed_ms) > self.deadline_ms


class FailureSimulator:
    """Drives step functions with injected failures; used by integration tests.

    Two entry styles:

      * legacy: ``FailureSimulator({5})`` — fail once at step 5, any phase.
      * rules:  ``FailureSimulator().inject("scoring", 2).inject("fit", 40,
        mode="every")`` — phase-scoped rules; ``mode="once"`` fires a single
        time across retries, ``mode="every"`` fires on every pass over the
        step (a crash loop that must exhaust the retry budget).

    ``failures`` keeps the legacy list of fired steps; ``log`` is the
    persistent injection log (one dict per firing, never cleared) that the
    supervisor embeds in its abort diagnostic.
    """

    def __init__(self, fail_at_steps=(), *, phase: str | None = None, mode: str = "once"):
        self.fail_at = set(int(s) for s in fail_at_steps)
        self.failures: list[int] = []
        self.log: list[dict] = []
        self._rules: list[dict] = [
            {"phase": phase, "step": s, "mode": mode, "fired": 0}
            for s in sorted(self.fail_at)
        ]

    def inject(self, phase: str | None, step: int, mode: str = "once") -> "FailureSimulator":
        """Add a rule: fail at ``step`` of ``phase`` (None = any phase)."""
        if mode not in ("once", "every"):
            raise ValueError(f"mode must be 'once' or 'every', got {mode!r}")
        self._rules.append({"phase": phase, "step": int(step), "mode": mode, "fired": 0})
        if phase is None:
            self.fail_at.add(int(step))
        return self

    def maybe_fail(self, step: int, phase: str | None = None):
        step = int(step)
        for rule in self._rules:
            if rule["step"] != step:
                continue
            if rule["phase"] is not None and rule["phase"] != phase:
                continue
            if rule["mode"] == "once" and rule["fired"]:
                continue
            rule["fired"] += 1
            self.failures.append(step)
            if rule["mode"] == "once":
                self.fail_at.discard(step)
            entry = {
                "phase": phase if phase is not None else rule["phase"],
                "step": step,
                "mode": rule["mode"],
                "count": rule["fired"],
            }
            self.log.append(entry)
            where = f" ({entry['phase']})" if entry["phase"] else ""
            raise InjectedFailure(f"injected node failure at step {step}{where}")
