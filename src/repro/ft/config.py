"""Global fault-tolerance configuration (Alpa-style module singleton).

The supervisor/retry/deadline knob surface grew past what threading kwargs
through every layer can carry, so — following Alpa's ``global_config``
idiom — all of it lives in one mutable dataclass singleton that the
pipeline layers read at use time:

  * ``train/loop.py``   — non-finite guard cadence, fit-phase injection
  * ``checkpoint/``     — checkpoint-phase injection
  * ``core/scoring.py`` and ``core/distributed_coreset.py`` — sweep
    checkpoint cadence, scoring-phase injection, KV-store timeouts
  * ``core/mctm_fit.py`` — straggler deadlines for the minibatch loader
  * ``ft/supervisor.py`` — retry budget, backoff schedule, LR backoff

Environment overrides: any scalar field can be set via
``REPRO_FT_<FIELDNAME>`` (upper-case), e.g. ``REPRO_FT_MAX_RETRIES=5``.

Tests mutate the singleton through the ``ft_overrides(...)`` context
manager, which restores the previous values on exit.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os

from repro.ft.failure import FailureSimulator

__all__ = ["FTConfig", "get_ft_config", "ft_overrides", "maybe_inject"]


@dataclasses.dataclass
class FTConfig:
    # -- supervisor retry/backoff
    max_retries: int = 3                 # retries after the first attempt
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    backoff_max_s: float = 2.0
    # -- graceful degradation in the fit
    nonfinite_rollback: bool = True      # raise NonFiniteError instead of corrupting the run
    nonfinite_check_every: int = 1       # steps between host-side finiteness checks
    lr_backoff_factor: float = 0.5       # LR scale applied per non-finite rollback
    rescale_lr: bool = True              # apply MeshPlan.lr_scale after a re-plan
    # -- resumable scoring sweeps
    sweep_ckpt_every_chunks: int = 4     # chunk-scan state saved every N chunks
    # -- straggler mitigation (minibatch loader); 0 disables
    straggler_deadline_ms: float = 0.0
    straggler_backup_factor: int = 2
    # -- multi-process coordination
    kv_timeout_ms: int = 120_000         # KV-store barrier/get deadline
    min_devices: int = 1
    # -- failure injection (None in production)
    simulator: FailureSimulator | None = None

    def backoff_s(self, attempt: int) -> float:
        """Exponential backoff delay before retry ``attempt`` (0-based)."""
        return min(self.backoff_base_s * self.backoff_factor**attempt, self.backoff_max_s)


def _env_overrides(cfg: FTConfig) -> FTConfig:
    for f in dataclasses.fields(cfg):
        raw = os.environ.get(f"REPRO_FT_{f.name.upper()}")
        if raw is None:
            continue
        if f.type in ("int", int):
            setattr(cfg, f.name, int(raw))
        elif f.type in ("float", float):
            setattr(cfg, f.name, float(raw))
        elif f.type in ("bool", bool):
            setattr(cfg, f.name, raw.lower() in ("1", "true", "yes", "on"))
    return cfg


ft_config = _env_overrides(FTConfig())


def get_ft_config() -> FTConfig:
    """The process-wide fault-tolerance configuration singleton."""
    return ft_config


def maybe_inject(phase: str, step: int) -> None:
    """Injection point: no-op unless a ``FailureSimulator`` is installed.

    Every failure-prone phase calls this with its own phase tag
    ("scoring" per chunk, "fit" per step, "checkpoint" per save) so
    ``--inject-failures`` runs can target each phase independently.
    """
    sim = ft_config.simulator
    if sim is not None:
        sim.maybe_fail(step, phase=phase)


_FIELDS = {f.name for f in dataclasses.fields(FTConfig)}


@contextlib.contextmanager
def ft_overrides(**kwargs):
    """Temporarily override singleton fields (tests / scoped injection)."""
    unknown = set(kwargs) - _FIELDS
    if unknown:
        raise TypeError(f"unknown FTConfig fields: {sorted(unknown)}")
    old = {k: getattr(ft_config, k) for k in kwargs}
    for k, v in kwargs.items():
        setattr(ft_config, k, v)
    try:
        yield ft_config
    finally:
        for k, v in old.items():
            setattr(ft_config, k, v)
