from repro.ft.failure import ElasticPlanner, FailureSimulator, MeshPlan, StragglerPolicy

__all__ = ["ElasticPlanner", "FailureSimulator", "MeshPlan", "StragglerPolicy"]
