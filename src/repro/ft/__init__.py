"""Fault tolerance for the coreset pipeline: supervision, elastic
re-meshing, failure injection.

Three cooperating pieces (each module carries its full contract):

* ``ft.config`` — the single Alpa-style knob surface (``FTConfig``
  singleton): retry budget/backoff, non-finite rollback + LR backoff,
  sweep-checkpoint cadence, straggler deadlines, KV timeouts, and the
  installed ``FailureSimulator``. Override via ``ft_overrides(...)`` or
  ``REPRO_FT_*`` env vars; ``maybe_inject(phase, step)`` is the injection
  hook the pipeline calls at its phase boundaries (scoring segment saved,
  fit step started, checkpoint tmp built).
* ``ft.failure`` — decision logic + errors: ``ElasticPlanner.plan(n_alive)``
  picks the degraded mesh with batch/LR rescaled, ``StragglerPolicy`` drives
  backup data draws, ``FailureSimulator`` injects ``InjectedFailure`` at
  (phase, step) points with a persistent log, ``NonFiniteError`` carries a
  detected divergence.
* ``ft.supervisor`` — ``RunSupervisor.run(attempt_fn)``: bounded retry with
  exponential backoff around an attempt closure that rebuilds its compute
  from a ``RunContext`` (``resume`` → restore last atomic checkpoint,
  ``mesh``/``plan`` → re-shard onto survivors, ``lr_scale`` → backed-off
  optimizer via ``optim.scale_updates``).

Wired in: ``train/loop.py`` (non-finite detection before checkpointing),
``core/mctm_fit.py`` (all three fit methods supervised),
``core/scoring.py`` + ``core/distributed_coreset.py`` (resumable sweeps via
``score(sweep_ckpt=, resume=)``), ``checkpoint/manager.py`` (torn-write
injection point), ``launch/train_mctm.py --inject-failures`` (end-to-end
drill).
"""
from repro.ft.config import FTConfig, ft_overrides, get_ft_config, maybe_inject
from repro.ft.failure import (
    ElasticPlanner,
    FailureSimulator,
    InjectedFailure,
    MeshPlan,
    NonFiniteError,
    StragglerPolicy,
)
from repro.ft.supervisor import RunContext, RunSupervisor, mesh_from_plan

__all__ = [
    "ElasticPlanner",
    "FailureSimulator",
    "InjectedFailure",
    "MeshPlan",
    "NonFiniteError",
    "StragglerPolicy",
    "FTConfig",
    "get_ft_config",
    "ft_overrides",
    "maybe_inject",
    "RunContext",
    "RunSupervisor",
    "mesh_from_plan",
]
