"""RunSupervisor: bounded-retry orchestration around the pipeline loops.

The supervisor wraps an *attempt closure* — a function that (re)builds its
compute (sharded step fns, engine chunk loops) from a ``RunContext`` and runs
it to completion. Contract:

  * **What is retried.** Any ``RuntimeError`` raised by the attempt — that
    family covers ``InjectedFailure``, ``NonFiniteError`` and jax's
    ``XlaRuntimeError`` (dead peer / barrier timeout / device loss).
    ``ValueError``/``TypeError``/``KeyboardInterrupt`` and friends are
    programming or user errors and propagate immediately, as do
    ``NotImplementedError``/``RecursionError`` (RuntimeError subclasses that
    are never transient).
  * **What triggers re-planning.** When a planner is attached, every retry
    consults ``ElasticPlanner.plan(n_alive)`` with the currently visible
    device count and rebuilds the mesh (``mesh_from_plan``, or a caller
    ``remesh`` hook) — so a shrunk device pool yields a degraded mesh with
    batch/LR rescaled per the plan. A ``NonFiniteError`` retry instead
    applies multiplicative LR backoff and does not re-plan (the hardware is
    fine; the optimization diverged).
  * **Recovery guarantees.** The attempt closure is responsible for resuming
    from the last atomic checkpoint when ``ctx.resume`` is set (the
    ``restore_train_state(shardings=)`` path re-shards params/opt-state onto
    the surviving mesh; scoring sweeps resume their chunk cursor
    bit-identically). After ``max_retries`` failed retries the supervisor
    aborts with a single diagnostic ``RuntimeError`` carrying the attempt
    history and, when a ``FailureSimulator`` is installed, its persistent
    injection log.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.ft.config import FTConfig, get_ft_config
from repro.ft.failure import ElasticPlanner, MeshPlan, NonFiniteError

__all__ = ["RunContext", "RunSupervisor", "mesh_from_plan"]

# RuntimeError subclasses that are never transient infrastructure faults
_NON_RETRYABLE = (NotImplementedError, RecursionError)


def mesh_from_plan(plan: MeshPlan, devices=None):
    """Materialize a ``MeshPlan`` on the first ``plan.n_devices`` devices."""
    import jax
    from jax.sharding import Mesh

    devs = list(devices) if devices is not None else list(jax.devices())
    if plan.n_devices > len(devs):
        raise RuntimeError(
            f"plan needs {plan.n_devices} devices, only {len(devs)} visible"
        )
    return Mesh(np.asarray(devs[: plan.n_devices]).reshape(plan.shape), plan.axes)


@dataclasses.dataclass
class RunContext:
    """What an attempt closure needs to (re)build its compute."""

    attempt: int = 0
    resume: bool = False         # True on every retry: restore from last checkpoint
    mesh: object = None          # current (possibly degraded) mesh, or None
    plan: Optional[MeshPlan] = None
    lr_scale: float = 1.0        # combined non-finite backoff × plan rescale
    batch_scale: float = 1.0     # plan.global_batch / base batch


class RunSupervisor:
    """Bounded retry + exponential backoff + elastic re-planning."""

    def __init__(
        self,
        *,
        label: str = "run",
        planner: Optional[ElasticPlanner] = None,
        mesh=None,
        devices_fn: Optional[Callable[[], int]] = None,
        remesh: Optional[Callable[[MeshPlan], object]] = None,
        config: Optional[FTConfig] = None,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.label = label
        self.planner = planner
        self.mesh = mesh
        self.devices_fn = devices_fn
        self.remesh = remesh
        self.config = config
        self.sleep = sleep
        self.events: list[dict] = []

    # ------------------------------------------------------------------ retry

    @staticmethod
    def _retryable(exc: BaseException) -> bool:
        return isinstance(exc, RuntimeError) and not isinstance(exc, _NON_RETRYABLE)

    def _n_alive(self) -> int:
        if self.devices_fn is not None:
            return int(self.devices_fn())
        import jax

        return len(jax.devices())

    def _diagnostic(self, cfg: FTConfig, last: BaseException) -> str:
        lines = [
            f"[{self.label}] retry budget exhausted after "
            f"{cfg.max_retries + 1} attempts: {type(last).__name__}: {last}",
            f"attempt history: {self.events}",
        ]
        if cfg.simulator is not None and cfg.simulator.log:
            lines.append(f"injection log: {cfg.simulator.log}")
        return "\n".join(lines)

    def run(self, attempt_fn: Callable[[RunContext], object]):
        """Run ``attempt_fn(ctx)`` to completion, retrying on RuntimeError."""
        cfg = self.config if self.config is not None else get_ft_config()
        ctx = RunContext(mesh=self.mesh)
        nf_scale = 1.0
        for attempt in range(cfg.max_retries + 1):
            ctx.attempt = attempt
            try:
                return attempt_fn(ctx)
            except Exception as exc:  # noqa: BLE001 — filtered below
                if not self._retryable(exc):
                    raise
                self.events.append(
                    {
                        "attempt": attempt,
                        "error": f"{type(exc).__name__}: {exc}",
                        "kind": "nonfinite" if isinstance(exc, NonFiniteError) else "failure",
                    }
                )
                if attempt >= cfg.max_retries:
                    raise RuntimeError(self._diagnostic(cfg, exc)) from exc
                delay = cfg.backoff_s(attempt)
                if delay > 0:
                    self.sleep(delay)
                plan_scale = 1.0
                if isinstance(exc, NonFiniteError):
                    nf_scale *= cfg.lr_backoff_factor
                    if ctx.plan is not None and cfg.rescale_lr:
                        plan_scale = ctx.plan.lr_scale
                elif self.planner is not None:
                    plan = self.planner.plan(self._n_alive())
                    ctx.plan = plan
                    ctx.mesh = self.remesh(plan) if self.remesh else mesh_from_plan(plan)
                    ctx.batch_scale = plan.global_batch / max(
                        self.planner.base_global_batch, 1
                    )
                    if cfg.rescale_lr:
                        plan_scale = plan.lr_scale
                    self.events[-1]["plan"] = {
                        "shape": plan.shape,
                        "axes": plan.axes,
                        "global_batch": plan.global_batch,
                        "lr_scale": plan.lr_scale,
                    }
                elif ctx.plan is not None and cfg.rescale_lr:
                    plan_scale = ctx.plan.lr_scale
                ctx.lr_scale = nf_scale * plan_scale
                ctx.resume = True
        raise AssertionError("unreachable")
