"""Deliberately broken programs that the analysis gate MUST fail on.

These prove the auditor has teeth: each violation seeds exactly one bug of a
class the checks exist to catch, against an honest budget a reviewer would
have written for the *correct* program. They are kept out of the main
registry (``all_programs()`` stays clean) and reached via
``scripts/analysis_gate.py --seed-violation <name>`` and the tests.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.registry import (
    CollectiveBudget,
    MaterializationBudget,
    ProgramSpec,
)

_N, _J, _D = 1024, 2, 8  # rows, dims, basis width for the toy programs


def _build_extra_psum():
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.utils.compat import make_mesh

    mesh = make_mesh((jax.device_count(),), ("data",))

    def body(y):
        # the bug: a second psum call site where one fused psum suffices
        s = jax.lax.psum(jnp.sum(y), "data")
        ss = jax.lax.psum(jnp.sum(jnp.square(y)), "data")
        return s + ss

    fn = jax.jit(shard_map(
        body, mesh=mesh, in_specs=P("data", None), out_specs=P(),
    ))
    y = np.ones((_N, _J), np.float32)
    return fn, (y,)


def _build_stacked_basis():
    import jax

    from repro.core.mctm import MCTMConfig, basis_features
    from repro.core.bernstein import DataScaler

    Y = np.random.default_rng(0).normal(size=(_N, _J)).astype(np.float32)
    cfg = MCTMConfig(J=_J, degree=3)
    scaler = DataScaler.fit(Y)
    # the bug: featurizing ALL n rows at once → an (n, J, d) basis block
    fn = jax.jit(lambda y: basis_features(cfg, scaler, y))
    return fn, (Y,)


def _build_f64_promotion():
    import jax
    import jax.numpy as jnp

    # the bug: an np.float64 scalar constant — harmless at x64=off, but it
    # promotes the whole f32 array under JAX_ENABLE_X64=1
    scale = np.float64(1.5)
    fn = jax.jit(lambda x: jnp.sum(x * scale))
    x = np.ones((64,), np.float32)
    return fn, (x,)


def _build_missing_donation():
    import jax
    import jax.numpy as jnp

    # the bug: state declared donated, but the update reshapes it, so XLA
    # cannot alias the buffer — the "in-place" update silently copies
    fn = jax.jit(lambda s: jnp.ravel(s + 1.0), donate_argnums=(0,))
    s = np.zeros((8, 8), np.float32)
    return fn, (s,)


def _build_host_callback():
    import jax
    import jax.numpy as jnp

    def log_loss(v):
        pass  # stand-in for print/logging/metrics push

    def fn(x):
        loss = jnp.sum(x)
        # the bug: a host callback inside the hot path — every step now
        # round-trips to python
        jax.debug.callback(log_loss, loss)
        return loss

    x = np.ones((64,), np.float32)
    return jax.jit(fn), (x,)


VIOLATIONS: dict[str, ProgramSpec] = {
    "extra_psum": ProgramSpec(
        name="violation_extra_psum",
        description="second psum call site against a one-all-reduce budget",
        build=_build_extra_psum,
        collectives=CollectiveBudget(all_reduce=1),
        needs_devices=2,
    ),
    "stacked_basis": ProgramSpec(
        name="violation_stacked_basis",
        description="full (n, J, d) basis materialized against a chunk budget",
        build=_build_stacked_basis,
        materialization=MaterializationBudget(row_elems=_J, fixed_elems=2048),
    ),
    "f64_promotion": ProgramSpec(
        name="violation_f64_promotion",
        description="np.float64 constant promotes an f32 array under x64",
        build=_build_f64_promotion,
    ),
    "missing_donation": ProgramSpec(
        name="violation_missing_donation",
        description="donated state silently copied (reshape breaks aliasing)",
        build=_build_missing_donation,
        donated_outputs=1,
    ),
    "host_callback": ProgramSpec(
        name="violation_host_callback",
        description="debug callback inside a jitted hot path",
        build=_build_host_callback,
    ),
}
