"""Declarative checks over lowered/compiled jax programs.

Each check consumes one artifact of the AOT pipeline — all obtainable on
CPU, no TPU and no execution:

* ``jax.jit(fn).trace(*args).jaxpr``  — the closed jaxpr (materialization
  bound, callback primitives);
* ``jax.jit(fn).lower(*args).as_text()`` under x64 off/on — StableHLO text
  (dtype-promotion audit: an f32 program must lower identically-typed under
  both modes; any ``f64`` element type under x64 is a leaked np.float64 /
  python-float weak-type promotion);
* ``.lower().compile().as_text()``    — optimized per-device HLO (collective
  census via :mod:`repro.utils.hlo`, donation aliasing, host callbacks).

``audit_program`` runs all of them against a :class:`ProgramSpec`'s declared
budgets and returns a report dict: ``failures`` (empty = program honors its
contract) plus the measured ``metrics`` the analysis gate diffs against the
committed baseline.
"""
from __future__ import annotations

import contextlib
import re
from typing import Any, Iterator

import jax
import numpy as np

from repro.analysis.registry import ProgramSpec
from repro.utils.hlo import collective_stats, input_output_aliases

try:  # the supported extension point for jaxpr types
    from jax.extend import core as _jcore
except ImportError:  # pragma: no cover - very old jax
    from jax import core as _jcore  # type: ignore[no-redef]

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# jaxpr-level host round-trips: anything here inside a hot path (worse, a
# scan body) serializes the device stream on every call
CALLBACK_PRIMITIVES = frozenset(
    {
        "pure_callback",
        "io_callback",
        "debug_callback",
        "host_callback_call",
        "outside_call",
    }
)

# compiled-HLO-level host transfers: python callbacks lower to custom-calls
# with a "callback" target; infeed/outfeed are direct host transfers
_HLO_CALLBACK_RE = re.compile(
    r'custom_call_target="[^"]*[Cc]allback[^"]*"|[%\s](?:infeed|outfeed)\('
)

# StableHLO element types introduced only by 64-bit promotion of float math.
# Ranked f64 tensors mean a DATA array was promoted (hard failure); scalar
# tensor<f64> constants are python-float/np.float64 weak types that convert
# straight back down to f32 — benign for the values, but tracked as a
# baseline metric so new weak-type hazards are visible as drift.
_F64_ANY_RE = re.compile(r"[<x](?:f64|complex<f64>)")
_F64_RANKED_RE = re.compile(r"tensor<(?:\?|\d)[x0-9?]*x(?:f64|complex<f64>)>")


def _x64_ctx(enable: bool):
    try:
        from jax.experimental import disable_x64, enable_x64

        return enable_x64() if enable else disable_x64()
    except ImportError:  # pragma: no cover - future jax without the ctx
        @contextlib.contextmanager
        def _ctx():
            prev = jax.config.jax_enable_x64
            jax.config.update("jax_enable_x64", enable)
            try:
                yield
            finally:
                jax.config.update("jax_enable_x64", prev)

        return _ctx()


def _as_jitted(fn: Any):
    return fn if hasattr(fn, "lower") else jax.jit(fn)


class ProgramArtifacts:
    """Lazily builds + caches the AOT artifacts for one program.

    The program is built and traced once under x64 OFF — the canonical f32
    contract every budget is written against, making the gate report
    identical in both CI x64 legs — and additionally *lowered* under x64 ON
    for the promotion diff.
    """

    def __init__(self, spec: ProgramSpec):
        self.spec = spec
        self._fn = None
        self._args: tuple | None = None
        self._jaxpr = None
        self._stablehlo: dict[bool, str] = {}
        self._compiled_text: str | None = None

    def _built(self):
        if self._fn is None:
            with _x64_ctx(False):
                self._fn, self._args = self.spec.build()
            self._fn = _as_jitted(self._fn)
        return self._fn, self._args

    @property
    def jaxpr(self):
        if self._jaxpr is None:
            fn, args = self._built()
            with _x64_ctx(False):
                self._jaxpr = fn.trace(*args).jaxpr
        return self._jaxpr

    def stablehlo(self, x64: bool) -> str:
        if x64 not in self._stablehlo:
            fn, args = self._built()
            with _x64_ctx(x64):
                self._stablehlo[x64] = fn.lower(*args).as_text()
        return self._stablehlo[x64]

    @property
    def compiled_text(self) -> str:
        if self._compiled_text is None:
            fn, args = self._built()
            with _x64_ctx(False):
                self._compiled_text = fn.lower(*args).compile().as_text()
        return self._compiled_text


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _sub_jaxprs(value: Any) -> Iterator[Any]:
    """Recursively yield raw Jaxprs hiding inside an eqn param value."""
    if isinstance(value, _jcore.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, _jcore.Jaxpr):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def iter_eqn_avals(jaxpr) -> Iterator[tuple[str, Any]]:
    """Yield (primitive_name, output_aval) for every eqn, recursing through
    scan/while/cond/pjit/shard_map/custom-derivative sub-jaxprs."""
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                yield name, aval
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from iter_eqn_avals(sub)


def iter_primitives(jaxpr) -> Iterator[str]:
    for eqn in jaxpr.eqns:
        yield eqn.primitive.name
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from iter_primitives(sub)


# ---------------------------------------------------------------------------
# individual checks — each returns (metrics_fragment, failures)
# ---------------------------------------------------------------------------


def check_collectives(spec: ProgramSpec, compiled_text: str):
    stats = collective_stats(compiled_text)
    budget = spec.collectives.as_dict()
    failures = []
    counts = {
        op: stats["by_op"].get(op, {"count": 0})["count"] for op in COLLECTIVE_OPS
    }
    for op, want in budget.items():
        got = counts[op]
        if spec.collectives.exact:
            if got != want:
                failures.append(
                    f"collective census: {got} × {op}, budget declares exactly "
                    f"{want} — a refactor changed the program's reduction "
                    f"structure"
                )
        elif got > want:
            failures.append(
                f"collective census: {got} × {op} exceeds ceiling {want}"
            )
    if stats["async_unmatched"]:
        failures.append(
            f"unbalanced async collective pairs: {stats['async_unmatched']}"
        )
    metrics = {
        "collectives": counts,
        "collective_bytes": int(stats["total_bytes"]),
    }
    return metrics, failures


def check_materialization(spec: ProgramSpec, jaxpr):
    budget = spec.materialization
    max_elems = 0
    failures: list[str] = []
    if budget is None:
        return {"max_intermediate_elems": 0}, failures
    seen: set[tuple[str, str]] = set()
    for prim, aval in iter_eqn_avals(jaxpr.jaxpr):
        shape = tuple(int(d) for d in aval.shape if isinstance(d, (int, np.integer)))
        size = int(np.prod(shape)) if shape else 1
        max_elems = max(max_elems, size)
        ratio = size // max(shape) if shape else 1
        if ratio <= budget.row_elems or size <= budget.fixed_elems:
            continue
        key = (prim, aval.str_short())
        if key in seen:
            continue
        seen.add(key)
        failures.append(
            f"materialization: {prim} produces {aval.str_short()} "
            f"({size} elems, {ratio}/row) — wider than row budget "
            f"{budget.row_elems} and larger than chunk budget "
            f"{budget.fixed_elems}; an n-scaled basis block is being "
            f"materialized"
        )
    return {"max_intermediate_elems": max_elems}, failures


def check_dtypes(spec: ProgramSpec, text_x32: str, text_x64: str):
    n32 = len(_F64_ANY_RE.findall(text_x32))
    ranked64 = len(_F64_RANKED_RE.findall(text_x64))
    weak64 = len(_F64_ANY_RE.findall(text_x64)) - ranked64
    failures = []
    if not spec.allow_f64:
        if n32:
            failures.append(
                f"dtype audit: {n32} f64 tensor type(s) in the x64=off "
                f"lowering — hard-coded double precision"
            )
        if ranked64:
            failures.append(
                f"dtype audit: {ranked64} ranked f64 tensor(s) appear under "
                f"JAX_ENABLE_X64=1 with f32 inputs — an np.float64 constant "
                f"or python-float weak type promotes a data array"
            )
    metrics = {
        "f64_types_x32": n32,
        "f64_arrays_x64": ranked64,
        # scalar tensor<f64> weak-type constants (python floats / np.float64
        # scalars) that convert straight back to f32 — value-benign, but a
        # rising count is new weak-type hazards, caught by the baseline diff
        "weak_f64_consts_x64": weak64,
    }
    return metrics, failures


def check_donation(spec: ProgramSpec, compiled_text: str):
    aliases = input_output_aliases(compiled_text)
    failures = []
    if spec.donated_outputs is not None and len(aliases) != spec.donated_outputs:
        failures.append(
            f"donation audit: compiled executable aliases {len(aliases)} "
            f"output buffer(s), declared {spec.donated_outputs} — a donated "
            f"input is being silently copied (or a non-donated one aliased)"
        )
    return {"aliased_outputs": len(aliases)}, failures


def check_callbacks(spec: ProgramSpec, jaxpr, compiled_text: str):
    prim_hits = [p for p in iter_primitives(jaxpr.jaxpr) if p in CALLBACK_PRIMITIVES]
    hlo_hits = _HLO_CALLBACK_RE.findall(compiled_text)
    count = len(prim_hits) + len(hlo_hits)
    failures = []
    if count and not spec.allow_callbacks:
        what = ", ".join(sorted(set(prim_hits))) or "host transfer"
        failures.append(
            f"callback audit: {count} host round-trip(s) ({what}) inside a "
            f"jitted hot path — every call serializes the device stream"
        )
    return {"host_callbacks": count}, failures


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def audit_program(spec: ProgramSpec) -> dict:
    """Run every check against one registered program.

    Returns ``{"name", "ok", "failures": [...], "metrics": {...}}``;
    ``metrics`` is what the analysis gate diffs against the committed
    baseline. Never executes the program.
    """
    report: dict = {"name": spec.name, "failures": [], "metrics": {}}
    if jax.device_count() < spec.needs_devices:
        report["failures"].append(
            f"needs {spec.needs_devices} devices, have {jax.device_count()} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{spec.needs_devices} before importing jax)"
        )
        report["ok"] = False
        return report
    art = ProgramArtifacts(spec)
    for metrics, failures in (
        check_collectives(spec, art.compiled_text),
        check_materialization(spec, art.jaxpr),
        check_dtypes(spec, art.stablehlo(False), art.stablehlo(True)),
        check_donation(spec, art.compiled_text),
        check_callbacks(spec, art.jaxpr, art.compiled_text),
    ):
        report["metrics"].update(metrics)
        report["failures"].extend(failures)
    report["ok"] = not report["failures"]
    return report
