"""Program registry for the compile-time invariant auditor.

A :class:`ProgramSpec` names one jitted hot path, a zero-argument ``build``
that reconstructs it on small symbolic shapes, and the declarative budgets
the checks in :mod:`repro.analysis.checks` enforce over its lowered
jaxpr/StableHLO/compiled-HLO. Registration is data, not behavior: the specs
for the real repo programs live in :mod:`repro.analysis.programs`; the
deliberately-broken ones used to prove the gate *can* fail live in
:mod:`repro.analysis.violations`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class CollectiveBudget:
    """Expected collective-op census of the compiled per-device program.

    ``exact=True`` programs (shard_map bodies, where every collective is
    written by hand) must match the count per op exactly. ``exact=False``
    programs (GSPMD-partitioned jits, where the compiler chooses the
    reduction placement) gate on a ceiling instead: count per op must stay
    ≤ the budget, so a refactor can only *remove* collectives silently,
    never add them.
    """

    all_reduce: int = 0
    all_gather: int = 0
    reduce_scatter: int = 0
    all_to_all: int = 0
    collective_permute: int = 0
    exact: bool = True

    def as_dict(self) -> dict[str, int]:
        return {
            "all-reduce": self.all_reduce,
            "all-gather": self.all_gather,
            "reduce-scatter": self.reduce_scatter,
            "all-to-all": self.all_to_all,
            "collective-permute": self.collective_permute,
        }


@dataclasses.dataclass(frozen=True)
class MaterializationBudget:
    """Static bound proving "never materialize an (n, J, d) basis".

    Every eqn output aval in the jaxpr (recursively, through scan / pjit /
    shard_map / while / cond sub-jaxprs) must be either

    * **row-like** — ``size / max(shape) ≤ row_elems`` — at most
      ``row_elems`` elements per leading entry, which admits the (n, J)
      inputs, (n,) weights/scores and (n, q) projected-sketch outputs that
      legitimately scale with n, but NOT a basis block, whose per-row width
      is J·d (keep ``row_elems < J·d``); or
    * **chunk-bounded** — total ``size ≤ fixed_elems``, sized to admit one
      (chunk, J, d) block (and the fixed Gram/sketch/direction state) with
      slack, but not a per-shard or global stacked basis.

    The ratio form makes the check independent of shard count: inside a
    shard_map body the avals are per-shard, and a per-shard materialized
    basis has ratio J·d > row_elems and size cps·chunk·J·d > fixed_elems.
    """

    row_elems: int
    fixed_elems: int


@dataclasses.dataclass(frozen=True)
class ProgramSpec:
    """One registered jitted hot path + its declared invariants.

    ``build()`` → ``(fn, args)`` where ``fn`` is jit-wrapped (or already a
    jitted callable) and ``args`` are concrete arrays / ShapeDtypeStructs on
    the small symbolic shapes. The auditor only traces/lowers/compiles —
    it never executes, so builders are cheap and TPU-free.
    """

    name: str
    description: str
    build: Callable[[], tuple[Any, tuple]]
    collectives: CollectiveBudget = CollectiveBudget()
    materialization: MaterializationBudget | None = None
    # expected number of aliased (donated) output buffers in the compiled
    # executable; None skips the donation audit
    donated_outputs: int | None = None
    allow_f64: bool = False
    allow_callbacks: bool = False
    needs_devices: int = 1
    # invariant ids from docs/INVARIANTS.md this program is bound by
    invariants: tuple[str, ...] = ()


_REGISTRY: dict[str, ProgramSpec] = {}


def register(spec: ProgramSpec) -> ProgramSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"duplicate program spec {spec.name!r}")
    _REGISTRY[spec.name] = spec
    return spec


def _ensure_loaded() -> None:
    # registration-by-import; deferred so `import repro.analysis` stays light
    from repro.analysis import programs  # noqa: F401


def get_program(name: str) -> ProgramSpec:
    _ensure_loaded()
    if name not in _REGISTRY:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"no program spec {name!r} (known: {known})")
    return _REGISTRY[name]


def all_programs() -> list[ProgramSpec]:
    _ensure_loaded()
    return list(_REGISTRY.values())
