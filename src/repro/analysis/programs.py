"""The registered jitted hot paths, on small symbolic audit shapes.

Importing this module populates the registry (see
:mod:`repro.analysis.registry`). Builders reconstruct each program exactly
the way its production call site does — same maker functions, same
jit/in_shardings wrapping — but on shapes small enough that tracing,
lowering and compiling all run in seconds on CPU. The auditor never
executes anything.

Shape choices (why these numbers):

* ``N=3072`` rows over ``SHARDS=8`` fake devices, ``CHUNK=32`` with
  ``CPS=12`` chunks per shard — a per-shard stacked basis
  (CPS·CHUNK·J·d = 3072 elems) overflows the 2048-elem chunk budget, so
  stacking is *detectable* by the materialization bound, while the largest
  legitimate fixed block (the hull score tile, m_dirs × chunk·J = 1536)
  stays inside it.
* ``J=2, DEGREE=3`` → d=4, basis width D=J·d=8: every basis block has
  8 elements per row, strictly wider than every legitimate row-scaled array
  (Y has J=2, the one-pass z keeps q=2 < D), which is what lets
  ``row_elems=2`` separate "streams with n" from "materializes the basis".
* Collective budgets are **exact** for shard_map programs (the collectives
  are written by hand). Note XLA lowers ONE fused tuple psum call as one
  all-reduce *per tuple element*, so the census pins the element count:
  a new psum call site OR a new element in the fused carry both show up as
  drift. GSPMD-partitioned jits use **ceilings** instead, since the
  partitioner chooses reduction placement.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.registry import (
    CollectiveBudget,
    MaterializationBudget,
    ProgramSpec,
    register,
)

# ---------------------------------------------------------------------------
# symbolic audit shapes
# ---------------------------------------------------------------------------

SHARDS = 8
CHUNK = 32
CPS = 12                     # chunks per shard
N = SHARDS * CPS * CHUNK     # 3072 padded rows
J = 2
DEGREE = 3                   # d = DEGREE + 1 = 4, D = J*d = 8
D_BASIS = J * (DEGREE + 1)
HULL_K = 4                   # dirs: max(4*HULL_K, 8) + 2*d = 24
SKETCH = 16
PROJ_Q = 2                   # one-pass projection width; MUST stay < J*d
MB = 4                       # train-step microbatches
SEG_CHUNKS = 10              # per-segment chunks; 10·(chunk·J·d) > FIXED_SEGMENTED
TOTAL_CHUNKS = 2 * SEG_CHUNKS

# Chunk-bounded budget for the sharded scoring sweeps: must admit the hull
# score tile (m_dirs · chunk·J = 24·64 = 1536 elems, the largest legitimate
# fixed intermediate) while staying below a per-shard stacked basis
# (CPS·chunk·J·d = 3072 elems) so stacking is detectable.
FIXED_SHARDED = 2048
# The train paths legitimately featurize one (N/MB, J·d) microbatch basis at
# a time; a full-batch basis (N·J·d elems) must overflow.
FIXED_TRAIN = 2 * (N // MB) * D_BASIS
# Segmented sweeps carry per-shard-stacked state (shards, sketch, D) at the
# top level and emit the same 1536-elem hull score tile; a segment-stacked
# basis (SEG_CHUNKS·chunk·J·d = 2560 elems) must overflow.
FIXED_SEGMENTED = 2 * SHARDS * SKETCH * D_BASIS


def _data():
    rng = np.random.default_rng(0)
    Y = rng.normal(size=(N, J)).astype(np.float32)
    w = np.ones(N, np.float32)
    return Y, w


def _cfg_scaler():
    from repro.core.bernstein import DataScaler
    from repro.core.mctm import MCTMConfig

    Y, _ = _data()
    cfg = MCTMConfig(J=J, degree=DEGREE)
    return cfg, DataScaler.fit(Y)


def _params(cfg):
    import jax

    from repro.core.mctm import init_params

    return init_params(jax.random.PRNGKey(0), cfg)


def _mesh():
    import jax

    from repro.utils.compat import make_mesh

    return make_mesh((jax.device_count(),), ("data",))


def _dirs():
    import jax

    from repro.core.scoring import upfront_directions

    return upfront_directions(jax.random.PRNGKey(1), DEGREE + 1, HULL_K)


def _row_shardings(mesh):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    return (
        NamedSharding(mesh, P("data", None)),  # (n, J) rows
        NamedSharding(mesh, P("data")),        # (n,) rows
        NamedSharding(mesh, P()),              # replicated
    )


# ---------------------------------------------------------------------------
# fit-layer programs
# ---------------------------------------------------------------------------


def _build_streamed_nll_chunk():
    from repro.core.mctm_fit import _chunk_nll_fn, fit_featurize

    cfg, scaler = _cfg_scaler()
    feat = fit_featurize(cfg, scaler)
    Y, w = _data()
    return _chunk_nll_fn(feat, cfg), (_params(cfg), Y[:CHUNK], w[:CHUNK])


register(ProgramSpec(
    name="streamed_nll_chunk",
    description="single-host streamed_nll body: featurize → nll_terms on one "
                "(chunk, J) block (mctm_fit._chunk_nll_fn)",
    build=_build_streamed_nll_chunk,
    collectives=CollectiveBudget(),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_SHARDED),
    donated_outputs=0,
    invariants=("MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _build_streamed_nll_sharded():
    from repro.core.mctm_fit import _make_sharded_nll_fn, fit_featurize

    cfg, scaler = _cfg_scaler()
    feat = fit_featurize(cfg, scaler)
    mesh = _mesh()
    fn = _make_sharded_nll_fn(feat, cfg, mesh, ("data",), CHUNK, CPS)
    Y, w = _data()
    return fn, (_params(cfg), Y, w)


register(ProgramSpec(
    name="streamed_nll_sharded",
    description="ONE-psum sharded NLL sweep (mctm_fit._make_sharded_nll_fn): "
                "the (1±ε) validation evaluator",
    build=_build_streamed_nll_sharded,
    collectives=CollectiveBudget(all_reduce=1),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_SHARDED),
    donated_outputs=0,
    needs_devices=SHARDS,
    invariants=("COLL-ONE-PSUM", "MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _model_opt():
    from repro.core.mctm_fit import MCTMDensityModel, default_fit_optimizer

    cfg, scaler = _cfg_scaler()
    model = MCTMDensityModel(cfg, scaler, norm=float(N) / MB)
    opt = default_fit_optimizer(1e-2, 10)
    return cfg, model, opt


def _build_adam_train_step():
    import jax

    from repro.train import init_train_state, make_train_step

    cfg, model, opt = _model_opt()
    step = jax.jit(make_train_step(model, opt, microbatches=MB),
                   donate_argnums=(0,))
    state = init_train_state(_params(cfg), opt)
    Y, w = _data()
    return step, (state, {"Y": Y, "weights": w})


register(ProgramSpec(
    name="adam_train_step",
    description="single-host microbatched adam train step "
                "(train.make_train_step, donate_argnums=(0,))",
    build=_build_adam_train_step,
    collectives=CollectiveBudget(),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_TRAIN),
    # TrainState has 8 leaves (step + (θ_raw, λ) + (count, μ×2, ν×2)), but the
    # int32 step feeds BOTH the new state and the metrics output, so XLA can
    # alias only 7 of them — one copy is structurally unavoidable
    donated_outputs=7,
    invariants=("MAT-CHUNK", "DTYPE-F32", "DONATE-STATE", "HOST-FREE"),
))


def _build_adam_train_step_sharded():
    import jax
    import numpy as np

    from repro.core.mctm_fit import _replicated_specs
    from repro.train import init_train_state, make_train_step, shard_train_step

    cfg, model, opt = _model_opt()
    params0 = _params(cfg)
    Y, w = _data()
    batch = {"Y": Y, "weights": w}
    step_fn, _, _ = shard_train_step(
        make_train_step(model, opt, microbatches=MB),
        model,
        opt,
        _mesh(),
        params_shapes=params0,
        specs=_replicated_specs(params0),
        batch_shapes={
            k: jax.ShapeDtypeStruct(np.shape(v), v.dtype) for k, v in batch.items()
        },
    )
    return step_fn, (init_train_state(params0, opt), batch)


register(ProgramSpec(
    name="adam_train_step_sharded",
    description="SPMD adam train step (train.shard_train_step: row-sharded "
                "batch, replicated params, donated state); GSPMD places the "
                "grad reduction, so the census is a ceiling",
    build=_build_adam_train_step_sharded,
    collectives=CollectiveBudget(all_reduce=4, all_gather=2, exact=False),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_TRAIN),
    donated_outputs=7,  # step leaf feeds metrics too — see adam_train_step
    needs_devices=SHARDS,
    invariants=("COLL-CEILING", "MAT-CHUNK", "DTYPE-F32", "DONATE-STATE",
                "HOST-FREE"),
))


def _lbfgs_jits():
    import jax
    import numpy as np

    from repro.core.mctm_fit import make_streamed_oracles
    from repro.distributed.sharding import batch_specs, default_rules, replicated

    cfg, model, _ = _model_opt()
    params0 = _params(cfg)
    mesh = _mesh()
    value_and_grad, value, hvp = make_streamed_oracles(model, MB)
    Y, w = _data()
    batch = {"Y": Y, "weights": w}
    param_sh = jax.tree.map(lambda _: replicated(mesh), params0)
    batch_shapes = {
        k: jax.ShapeDtypeStruct(np.shape(v), v.dtype) for k, v in batch.items()
    }
    batch_sh = batch_specs(batch_shapes, mesh, default_rules(mesh))
    vg_j = jax.jit(value_and_grad, in_shardings=(param_sh, batch_sh))
    hvp_j = jax.jit(hvp, in_shardings=(param_sh, param_sh, batch_sh))
    return params0, batch, vg_j, hvp_j


def _build_lbfgs_value_and_grad_sharded():
    params0, batch, vg_j, _ = _lbfgs_jits()
    return vg_j, (params0, batch)


register(ProgramSpec(
    name="lbfgs_value_and_grad_sharded",
    description="streaming L-BFGS value+grad oracle, GSPMD-sharded batch "
                "(mctm_fit.make_streamed_oracles / _fit_lbfgs layout)",
    build=_build_lbfgs_value_and_grad_sharded,
    collectives=CollectiveBudget(all_reduce=4, all_gather=2, exact=False),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_TRAIN),
    donated_outputs=0,
    needs_devices=SHARDS,
    invariants=("COLL-CEILING", "MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _build_lbfgs_hvp_sharded():
    import jax
    import jax.numpy as jnp

    params0, batch, _, hvp_j = _lbfgs_jits()
    vec = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params0)
    return hvp_j, (params0, vec, batch)


register(ProgramSpec(
    name="lbfgs_hvp_sharded",
    description="streaming L-BFGS HVP oracle (jvp-of-grad inside the scan "
                "body — the curvature pass stays chunk-streamed)",
    build=_build_lbfgs_hvp_sharded,
    collectives=CollectiveBudget(all_reduce=6, all_gather=2, exact=False),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_TRAIN),
    donated_outputs=0,
    needs_devices=SHARDS,
    invariants=("COLL-CEILING", "MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


# ---------------------------------------------------------------------------
# scoring-engine programs (Algorithm 1, sharded)
# ---------------------------------------------------------------------------


def _scoring_featurize():
    from repro.core.scoring import _mctm_featurize

    cfg, scaler = _cfg_scaler()
    return _mctm_featurize(cfg, scaler)


def _two_pass_fns():
    from repro.core.distributed_coreset import make_sharded_pass_fns

    mesh = _mesh()
    pass1, pass2 = make_sharded_pass_fns(
        _scoring_featurize(),
        mesh,
        ("data",),
        chunk=CHUNK,
        chunks_per_shard=CPS,
        rows_per_point=J,
        hull=True,
        D=D_BASIS,
        p=DEGREE + 1,
    )
    return mesh, pass1, pass2


def _build_two_pass_pass1_sharded():
    import jax

    mesh, pass1, _ = _two_pass_fns()
    x_sh, r_sh, _ = _row_shardings(mesh)
    Y, w = _data()
    fn = jax.jit(pass1, in_shardings=(x_sh, r_sh, r_sh))
    return fn, (Y, w, w)


register(ProgramSpec(
    name="two_pass_pass1_sharded",
    description="sharded two-pass pass 1: per-shard chunk scan accumulating "
                "(G, Σp, Σppᵀ), ONE fused tuple psum call site "
                "(distributed_coreset.make_sharded_pass_fns)",
    build=_build_two_pass_pass1_sharded,
    # the single fused psum of the 3-element tuple (G, Σp, Σppᵀ) lowers as
    # one all-reduce per element; pinning 3 catches both a new psum call
    # site and a new element sneaking into the fused carry
    collectives=CollectiveBudget(all_reduce=3),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_SHARDED),
    donated_outputs=0,
    needs_devices=SHARDS,
    invariants=("COLL-ONE-PSUM", "MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _build_two_pass_pass2_hull_sharded():
    import jax
    import numpy as np

    mesh, _, pass2 = _two_pass_fns()
    x_sh, r_sh, rep = _row_shardings(mesh)
    Y, w = _data()
    V = np.eye(D_BASIS, dtype=np.float32)
    inv = np.ones(D_BASIS, np.float32)
    fn = jax.jit(pass2, in_shardings=(x_sh, r_sh, r_sh, rep, rep, rep))
    return fn, (Y, w, w, V, inv, _dirs())


register(ProgramSpec(
    name="two_pass_pass2_hull_sharded",
    description="sharded two-pass pass 2 + hull: chunked leverage emission, "
                "cross-shard extreme reduction = exactly one all_gather pair "
                "(values + indices)",
    build=_build_two_pass_pass2_hull_sharded,
    collectives=CollectiveBudget(all_gather=2),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_SHARDED),
    donated_outputs=0,
    needs_devices=SHARDS,
    invariants=("COLL-HULL-GATHER", "MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _build_one_pass_sharded():
    import jax
    import numpy as np

    from repro.core.distributed_coreset import make_sharded_onepass_fn

    mesh = _mesh()
    onepass = make_sharded_onepass_fn(
        _scoring_featurize(),
        mesh,
        ("data",),
        chunk=CHUNK,
        chunks_per_shard=CPS,
        rows_per_point=J,
        hull=True,
        D=D_BASIS,
        q=PROJ_Q,
        sketch_size=SKETCH,
    )
    x_sh, r_sh, rep = _row_shardings(mesh)
    Y, w = _data()
    rng = np.random.default_rng(2)
    rows = rng.integers(0, SKETCH, size=N).astype(np.int32)
    signs = np.where(rng.random(N) < 0.5, -1.0, 1.0).astype(np.float32)
    omega = rng.normal(size=(D_BASIS, PROJ_Q)).astype(np.float32)
    fn = jax.jit(
        onepass, in_shardings=(x_sh, r_sh, r_sh, r_sh, r_sh, rep, rep)
    )
    return fn, (Y, w, w, rows, signs, omega, _dirs())


register(ProgramSpec(
    name="one_pass_sharded",
    description="sharded ONE-pass sketched sweep: CountSketch + projected z "
                "+ running hull extremes in a single scan; one psum + one "
                "all_gather pair (distributed_coreset.make_sharded_onepass_fn)",
    build=_build_one_pass_sharded,
    collectives=CollectiveBudget(all_reduce=1, all_gather=2),
    materialization=MaterializationBudget(row_elems=max(J, PROJ_Q),
                                          fixed_elems=FIXED_SHARDED),
    donated_outputs=0,
    needs_devices=SHARDS,
    invariants=("COLL-ONE-PSUM", "COLL-HULL-GATHER", "SWEEP-FUSED",
                "MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _seg_rows():
    return SHARDS * SEG_CHUNKS * CHUNK


def _build_segmented_pass1_sharded():
    import jax
    import numpy as np

    from repro.core.distributed_coreset import make_segmented_pass_fns

    mesh = _mesh()
    pass1, _ = make_segmented_pass_fns(
        _scoring_featurize(),
        mesh,
        ("data",),
        chunk=CHUNK,
        seg_chunks=SEG_CHUNKS,
        total_chunks=TOTAL_CHUNKS,
        rows_per_point=J,
        hull=True,
        D=D_BASIS,
        p=DEGREE + 1,
    )
    Y, w = _data()
    rows = _seg_rows()
    G = np.zeros((SHARDS, D_BASIS, D_BASIS), np.float32)
    s1 = np.zeros((SHARDS, DEGREE + 1), np.float32)
    s2 = np.zeros((SHARDS, DEGREE + 1, DEGREE + 1), np.float32)
    return jax.jit(pass1), (Y[:rows], w[:rows], w[:rows], G, s1, s2)


register(ProgramSpec(
    name="segmented_pass1_sharded",
    description="segmented (resumable) pass-1 sweep: per-shard partials carry "
                "to the host checkpoint — ZERO collectives by contract, which "
                "is what makes resume bit-identical "
                "(distributed_coreset.make_segmented_pass_fns)",
    build=_build_segmented_pass1_sharded,
    collectives=CollectiveBudget(),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_SEGMENTED),
    donated_outputs=0,
    needs_devices=SHARDS,
    invariants=("COLL-SEG-NONE", "MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _build_segmented_onepass_sharded():
    import jax
    import numpy as np

    from repro.core.distributed_coreset import make_segmented_onepass_fn

    mesh = _mesh()
    onepass = make_segmented_onepass_fn(
        _scoring_featurize(),
        mesh,
        ("data",),
        chunk=CHUNK,
        seg_chunks=SEG_CHUNKS,
        total_chunks=TOTAL_CHUNKS,
        rows_per_point=J,
        hull=True,
        D=D_BASIS,
        q=PROJ_Q,
        sketch_size=SKETCH,
    )
    Y, w = _data()
    rows_n = _seg_rows()
    rng = np.random.default_rng(3)
    rows = rng.integers(0, SKETCH, size=rows_n).astype(np.int32)
    signs = np.where(rng.random(rows_n) < 0.5, -1.0, 1.0).astype(np.float32)
    SX = np.zeros((SHARDS, SKETCH, D_BASIS), np.float32)
    c0 = np.int32(0)
    omega = rng.normal(size=(D_BASIS, PROJ_Q)).astype(np.float32)
    m = _dirs().shape[0]
    bmax = np.full((SHARDS, m), -np.inf, np.float32)
    imax = np.zeros((SHARDS, m), np.int32)
    bmin = np.full((SHARDS, m), np.inf, np.float32)
    imin = np.zeros((SHARDS, m), np.int32)
    return jax.jit(onepass), (
        Y[:rows_n], w[:rows_n], w[:rows_n], rows, signs, SX, c0,
        omega, bmax, imax, bmin, imin, _dirs(),
    )


register(ProgramSpec(
    name="segmented_onepass_sharded",
    description="segmented (resumable) one-pass sweep: per-shard CountSketch "
                "+ extremes carried host-side, ZERO collectives "
                "(distributed_coreset.make_segmented_onepass_fn)",
    build=_build_segmented_onepass_sharded,
    collectives=CollectiveBudget(),
    materialization=MaterializationBudget(row_elems=max(J, PROJ_Q),
                                          fixed_elems=FIXED_SEGMENTED),
    donated_outputs=0,
    needs_devices=SHARDS,
    invariants=("COLL-SEG-NONE", "SWEEP-FUSED", "MAT-CHUNK", "DTYPE-F32",
                "HOST-FREE"),
))


# ---------------------------------------------------------------------------
# featurize + Pallas kernel wrappers (interpret mode: CPU-traceable)
# ---------------------------------------------------------------------------


def _build_bernstein_featurize():
    Y, _ = _data()
    return _scoring_featurize(), (Y[:CHUNK],)


register(ProgramSpec(
    name="bernstein_featurize",
    description="fused Bernstein basis+derivative featurize for one chunk "
                "(scoring._mctm_featurize — shared by scoring AND fit paths)",
    build=_build_bernstein_featurize,
    collectives=CollectiveBudget(),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_SHARDED),
    donated_outputs=0,
    invariants=("MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _build_gram_kernel_interpret():
    import jax

    from repro.kernels.gram.ops import gram_matrix

    Y, _ = _data()
    X = np.tile(Y[:CHUNK], (1, (DEGREE + 1))).astype(np.float32)  # (CHUNK, D)
    fn = jax.jit(lambda x: gram_matrix(x, backend="pallas", interpret=True))
    return fn, (X,)


register(ProgramSpec(
    name="gram_kernel_interpret",
    description="Pallas gram kernel wrapper (interpret mode — the CPU-"
                "traceable realization of the TPU kernel dispatch)",
    build=_build_gram_kernel_interpret,
    collectives=CollectiveBudget(),
    # lane padding widens rows to 128 inside the kernel; budget is the
    # padded block, not n-scaled
    materialization=MaterializationBudget(row_elems=128,
                                          fixed_elems=4 * CHUNK * 128),
    donated_outputs=0,
    invariants=("MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _build_extremes_kernel_interpret():
    import jax

    from repro.kernels.extremes.ops import directional_extremes

    rng = np.random.default_rng(4)
    Pr = rng.normal(size=(CHUNK * J, DEGREE + 1)).astype(np.float32)
    mask = np.ones(CHUNK * J, np.float32)
    dirs = np.asarray(_dirs())  # host-side: direction sampling is not traceable
    fn = jax.jit(
        lambda P, m: directional_extremes(
            P, dirs, m, backend="pallas", interpret=True
        )
    )
    return fn, (Pr, mask)


register(ProgramSpec(
    name="extremes_kernel_interpret",
    description="Pallas directional-extremes kernel wrapper (interpret mode)",
    build=_build_extremes_kernel_interpret,
    collectives=CollectiveBudget(),
    # rows, dirs AND the (block, m_pad) score tile are all lane-padded to 128
    materialization=MaterializationBudget(row_elems=2 * 128,
                                          fixed_elems=4 * CHUNK * J * 128),
    donated_outputs=0,
    invariants=("MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


# ---------------------------------------------------------------------------
# serving-layer programs (DensityServeEngine hot paths, one bucket each)
# ---------------------------------------------------------------------------

SERVE_BUCKET = CHUNK        # one padded request bucket
SERVE_GRID = 512            # conditional-sample inversion grid (engine default)
# The sampler legitimately holds the (SERVE_GRID, d) inversion-grid basis and
# the (SERVE_GRID, J) grid values as fixed state; a bucket-stacked basis
# would be n-scaled and is caught by row_elems < J·d as usual.
FIXED_SERVE = SERVE_GRID * (DEGREE + 1)


def _build_serve_log_density():
    import jax

    from repro.serve.density import make_log_density_fn

    cfg, scaler = _cfg_scaler()
    Y, _ = _data()
    fn = jax.jit(make_log_density_fn(cfg))
    return fn, (
        _params(cfg),
        np.asarray(scaler.low, np.float32),
        np.asarray(scaler.high, np.float32),
        np.asarray(scaler.inv_span, np.float32),
        Y[:SERVE_BUCKET],
    )


register(ProgramSpec(
    name="serve_log_density_bucket",
    description="DensityServeEngine batched log-density executable for one "
                "padded bucket; params AND scaler bounds are jit arguments so "
                "hot swaps never retrace (serve.density.make_log_density_fn)",
    build=_build_serve_log_density,
    collectives=CollectiveBudget(),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_SHARDED),
    donated_outputs=0,
    invariants=("MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _build_serve_conditional_sample():
    import jax

    from repro.serve.density import make_conditional_sample_fn

    cfg, scaler = _cfg_scaler()
    Y, _ = _data()
    fn = jax.jit(make_conditional_sample_fn(cfg, n_grid=SERVE_GRID))
    seeds = np.arange(SERVE_BUCKET, dtype=np.int32)
    n_obs = np.tile(np.arange(J + 1, dtype=np.int32),
                    SERVE_BUCKET)[:SERVE_BUCKET]
    return fn, (
        _params(cfg),
        np.asarray(scaler.low, np.float32),
        np.asarray(scaler.high, np.float32),
        jax.random.PRNGKey(0),
        Y[:SERVE_BUCKET],
        n_obs,
        seeds,
    )


register(ProgramSpec(
    name="serve_conditional_sample_bucket",
    description="DensityServeEngine batched conditional sampler for one "
                "padded bucket: per-row fold_in randomness (bucket-invariant "
                "draws), fixed (grid, d) inversion basis — nothing scales "
                "past the bucket (serve.density.make_conditional_sample_fn)",
    build=_build_serve_conditional_sample,
    collectives=CollectiveBudget(),
    materialization=MaterializationBudget(row_elems=J,
                                          fixed_elems=max(FIXED_SERVE,
                                                          FIXED_SHARDED)),
    donated_outputs=0,
    invariants=("MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


# ---------------------------------------------------------------------------
# streaming-layer programs (drift detector's per-window NLL evaluator)
# ---------------------------------------------------------------------------


def _build_drift_nll_chunk():
    from repro.core.mctm_fit import fit_featurize
    from repro.core.streaming import _drift_chunk_fn

    cfg, scaler = _cfg_scaler()
    feat = fit_featurize(cfg, scaler)
    Y, w = _data()
    return _drift_chunk_fn(feat, cfg), (_params(cfg), Y[:CHUNK], w[:CHUNK])


register(ProgramSpec(
    name="drift_nll_chunk",
    description="single-host drift-window NLL body: featurize → nll_terms on "
                "one (chunk, J) block, fused (Σw·nll, Σw) pair "
                "(streaming._drift_chunk_fn)",
    build=_build_drift_nll_chunk,
    collectives=CollectiveBudget(),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_SHARDED),
    donated_outputs=0,
    invariants=("MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _build_drift_nll_sharded():
    from repro.core.mctm_fit import fit_featurize
    from repro.core.streaming import make_sharded_drift_nll_fn

    cfg, scaler = _cfg_scaler()
    feat = fit_featurize(cfg, scaler)
    fn = make_sharded_drift_nll_fn(feat, cfg, _mesh(), ("data",), CHUNK, CPS)
    Y, w = _data()
    return fn, (_params(cfg), Y, w)


register(ProgramSpec(
    name="drift_nll_sharded",
    description="sharded drift-window NLL sweep (streaming."
                "make_sharded_drift_nll_fn): per-shard chunk scan carrying "
                "the fused (Σw·nll, Σw) pair, ONE psum call site closing the "
                "window — the DriftDetector's live ε̂ evaluator",
    build=_build_drift_nll_sharded,
    # the single fused psum of the 2-tuple lowers as one all-reduce per
    # element; pinning 2 catches a new psum call site and a new element in
    # the fused pair alike
    collectives=CollectiveBudget(all_reduce=2),
    materialization=MaterializationBudget(row_elems=J, fixed_elems=FIXED_SHARDED),
    donated_outputs=0,
    needs_devices=SHARDS,
    invariants=("COLL-ONE-PSUM", "MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))


def _build_sweep_kernel_interpret():
    import jax

    from repro.kernels.sweep.ops import fused_sweep_update

    rng = np.random.default_rng(5)
    X = rng.normal(size=(CHUNK, D_BASIS)).astype(np.float32)
    Pr = rng.normal(size=(CHUNK * J, DEGREE + 1)).astype(np.float32)
    sw = np.ones(CHUNK, np.float32)
    rows = rng.integers(0, SKETCH, size=CHUNK).astype(np.int32)
    signs = np.where(rng.random(CHUNK) < 0.5, -1.0, 1.0).astype(np.float32)
    omega = rng.normal(size=(D_BASIS, PROJ_Q)).astype(np.float32)
    dirs = np.asarray(_dirs())  # host-side: direction sampling is not traceable
    SX = np.zeros((SKETCH, D_BASIS), np.float32)
    fn = jax.jit(
        lambda SX, X, P, sw, r, s, om: fused_sweep_update(
            SX, X, P, sw, r, s, dirs=dirs, omega=om,
            backend="pallas", interpret=True,
        )
    )
    return fn, (SX, X, Pr, sw, rows, signs, omega)


register(ProgramSpec(
    name="sweep_kernel_interpret",
    description="fused one-pass sweep Pallas kernel wrapper (interpret mode): "
                "CountSketch + projected z + hull extremes in one residency "
                "(kernels.sweep — the OnePassSketched chunk body)",
    build=_build_sweep_kernel_interpret,
    collectives=CollectiveBudget(),
    # X/P/z rows and the padded dirs/Ω blocks are lane-padded to 128; the
    # largest fixed intermediates are the (128, 128) dirs/Ω pads and the
    # (m_pad, block·r) score tile — all under 4·CHUNK·J·128
    materialization=MaterializationBudget(row_elems=2 * 128,
                                          fixed_elems=4 * CHUNK * J * 128),
    donated_outputs=0,
    invariants=("SWEEP-FUSED", "MAT-CHUNK", "DTYPE-F32", "HOST-FREE"),
))
