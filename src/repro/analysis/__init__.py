"""Compile-time invariant auditor: static analysis of every jitted hot path.

The repo's performance claims rest on contracts the tests can only
spot-check at runtime: exactly ONE fused psum in the sharded engines, ZERO
collectives in the segmented resume sweeps, never materializing an
(n, J, d) basis block, no silent f32→f64 promotion, donated train state
actually aliased by the compiled executable, no host callbacks inside scan
bodies. This package proves them *statically*, against the lowered
programs themselves:

* :mod:`repro.analysis.registry` — ``ProgramSpec``: one jitted hot path +
  its declared budgets (collective census, materialization bound, donation,
  dtype, callbacks).
* :mod:`repro.analysis.programs` — the registered hot paths (fit steps,
  streamed-NLL evaluators, sharded two-/one-pass scoring, segmented resume
  sweeps, Pallas kernel wrappers) rebuilt on small symbolic shapes exactly
  as their production call sites build them.
* :mod:`repro.analysis.checks` — the checks over jaxpr / StableHLO /
  compiled HLO. ``audit_program(spec)`` lowers on CPU (no TPU, no
  execution) and returns ``{failures, metrics}``.
* :mod:`repro.analysis.ast_lints` — Python-level hazards the jaxpr can't
  see: PRNG key reuse after split/fold_in, ``np.`` math inside traced
  functions, mutable default arguments.
* :mod:`repro.analysis.violations` — deliberately broken programs that the
  gate must fail on (used by ``--seed-violation`` and the tests).

The CI entry point is ``scripts/analysis_gate.py``, which diffs the
measured per-program metrics against the committed baseline in
``benchmarks/baselines/ANALYSIS_budgets.json`` (bench_gate-style) and fails
on drift. The invariant catalogue — which invariant binds which program,
and which check enforces it — is ``docs/INVARIANTS.md``.
"""
from repro.analysis.checks import audit_program
from repro.analysis.registry import (
    CollectiveBudget,
    MaterializationBudget,
    ProgramSpec,
    all_programs,
    get_program,
    register,
)

__all__ = [
    "CollectiveBudget",
    "MaterializationBudget",
    "ProgramSpec",
    "all_programs",
    "audit_program",
    "get_program",
    "register",
]
