"""Python-level hazard lints the jaxpr can't see.

Three hazards, each of which has bitten (or nearly bitten) a jax codebase:

* **AL001 — PRNG key reuse**: a key variable is passed to
  ``jax.random.split`` / ``fold_in`` (consuming it) and then reused as a key
  in a later ``jax.random.*`` call without being rebound. Reuse silently
  correlates "independent" draws.
* **AL002 — np. math on traced values**: a ``np.<mathfn>(...)`` call inside
  a jit-traced function whose arguments mention a formal parameter of that
  function. numpy silently calls back to host on tracers (ConcretizationError
  at best, a constant-folded wrong value at worst). np math on *static*
  config values is fine and not flagged.
* **AL003 — mutable default argument**: ``def f(x, cache={})`` shares one
  dict across calls; config objects accumulate state between runs.

Findings are suppressed per-line with ``# noqa: AL00x`` for audited,
intentional cases. The linter is deliberately first-order: it tracks names,
not values, and prefers a suppressible false positive over a silent miss.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib

__all__ = ["LintFinding", "lint_source", "lint_file", "lint_paths"]


@dataclasses.dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    code: str
    message: str

    def __str__(self) -> str:  # gate report formatting
        return f"{self.path}:{self.line}: {self.code} {self.message}"


# np functions that do real math (vs. dtype constructors / static helpers)
_NP_MATH = frozenset(
    """sum mean dot matmul einsum exp log log1p expm1 sqrt square power abs
    maximum minimum clip where tanh sinh cosh sin cos tan prod cumsum cumprod
    std var argmax argmin argsort sort median quantile percentile outer trace
    tensordot cross diff gradient convolve corrcoef cov floor ceil round rint
    sign reciprocal divide multiply add subtract mod remainder""".split()
)

# jax entry points whose function-valued arguments get traced
_TRACING_ENTRY_POINTS = frozenset(
    """jit pmap vmap grad value_and_grad jacfwd jacrev hessian jvp vjp
    linearize checkpoint remat custom_jvp custom_vjp scan while_loop cond
    switch fori_loop map associative_scan shard_map pallas_call""".split()
)

# jax.random functions that take a key as their first argument
_KEY_CONSUMERS = frozenset({"split", "fold_in"})


def _noqa_lines(source: str) -> dict[int, set[str]]:
    """line number → set of suppressed codes (empty set = bare noqa)."""
    out: dict[int, set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        if "# noqa" not in line:
            continue
        _, _, tail = line.partition("# noqa")
        codes = {c.strip() for c in tail.lstrip(": ").split(",") if c.strip()}
        out[i] = codes
    return out


class _ImportAliases(ast.NodeVisitor):
    """Map local names to fully dotted module paths (numpy, jax.random, …)."""

    def __init__(self) -> None:
        self.aliases: dict[str, str] = {}

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.aliases[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0]
            )

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module is None or node.level:
            return
        for a in node.names:
            self.aliases[a.asname or a.name] = f"{node.module}.{a.name}"


def _dotted(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Resolve a call target to a dotted path through the import aliases."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = aliases.get(node.id, node.id)
    parts.append(root)
    return ".".join(reversed(parts))


def _collect_traced_functions(tree: ast.Module, aliases: dict[str, str]) -> set[ast.FunctionDef]:
    """Functions that get traced: jit-decorated, or passed (by name) into a
    jax tracing entry point anywhere in the module, plus functions nested
    inside one of those (closures trace with their parent)."""
    by_name: dict[str, list[ast.FunctionDef]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            by_name.setdefault(node.name, []).append(node)

    def _is_tracing_target(call_fn: ast.AST) -> bool:
        dotted = _dotted(call_fn, aliases)
        if dotted is None:
            return False
        tail = dotted.rsplit(".", 1)[-1]
        return tail in _TRACING_ENTRY_POINTS

    traced: set[ast.FunctionDef] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if _is_tracing_target(target):
                    traced.add(node)
                # functools.partial(jax.jit, ...) style decorators
                if isinstance(dec, ast.Call):
                    for arg in dec.args:
                        if _is_tracing_target(arg):
                            traced.add(node)
        elif isinstance(node, ast.Call) and _is_tracing_target(node.func):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    traced.update(by_name.get(arg.id, []))

    # closures defined inside a traced function trace with it
    grown = True
    while grown:
        grown = False
        for fn in list(traced):
            for node in ast.walk(fn):
                if isinstance(node, ast.FunctionDef) and node not in traced:
                    traced.add(node)
                    grown = True
    return traced


def _mentions_param(node: ast.AST, params: set[str]) -> bool:
    return any(
        isinstance(n, ast.Name) and n.id in params for n in ast.walk(node)
    )


def _function_params(fn: ast.FunctionDef) -> set[str]:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


class _FunctionLinter:
    """Per-function linear walk, in source order, for AL001/AL002."""

    def __init__(self, fn: ast.FunctionDef, aliases: dict[str, str],
                 traced: bool, findings: list, path: str,
                 params: set[str] | None = None) -> None:
        self.fn = fn
        self.aliases = aliases
        self.traced = traced
        self.findings = findings
        self.path = path
        # a closure sees its ancestors' (traced) parameters too
        self.params = _function_params(fn) if params is None else params
        self.consumed: dict[str, int] = {}  # key name → line it was consumed

    def run(self) -> None:
        for stmt in self.fn.body:
            self._visit(stmt)

    # -- helpers -----------------------------------------------------------

    def _random_fn(self, call: ast.Call) -> str | None:
        dotted = _dotted(call.func, self.aliases)
        if dotted is None:
            return None
        if ".random." in f".{dotted}" or dotted.startswith("jax.random"):
            return dotted.rsplit(".", 1)[-1]
        # `from jax.random import split` resolves to jax.random.split
        if dotted.startswith("random."):
            return dotted.rsplit(".", 1)[-1]
        return None

    def _np_math_fn(self, call: ast.Call) -> str | None:
        dotted = _dotted(call.func, self.aliases)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        if head != "numpy" and not dotted.startswith("numpy."):
            return None
        tail = dotted.rsplit(".", 1)[-1]
        return tail if tail in _NP_MATH else None

    def _rebind(self, target: ast.AST) -> None:
        for n in ast.walk(target):
            if isinstance(n, ast.Name):
                self.consumed.pop(n.id, None)

    # -- linear traversal --------------------------------------------------

    def _visit(self, node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return  # nested defs are linted as their own functions
        if isinstance(node, ast.If):
            # exclusive branches: a consume in one arm must not poison the
            # other; afterwards only keys consumed in EVERY arm stay consumed
            self._visit(node.test)
            before = dict(self.consumed)
            self._visit_block(node.body)
            after_body = self.consumed
            self.consumed = dict(before)
            self._visit_block(node.orelse)
            after_else = self.consumed
            self.consumed = {
                k: v for k, v in after_body.items() if k in after_else
            }
            return
        if isinstance(node, ast.Assign):
            self._visit(node.value)
            for t in node.targets:
                self._rebind(t)
            return
        if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            if node.value is not None:
                self._visit(node.value)
            self._rebind(node.target)
            return
        if isinstance(node, ast.Call):
            for child in list(node.args) + [kw.value for kw in node.keywords]:
                self._visit(child)
            self._check_call(node)
            return
        for child in ast.iter_child_nodes(node):
            self._visit(child)

    def _visit_block(self, stmts: list[ast.stmt]) -> None:
        for s in stmts:
            self._visit(s)

    def _check_call(self, call: ast.Call) -> None:
        rfn = self._random_fn(call)
        if rfn is not None and call.args:
            # only the first positional argument is the key (the rest are
            # counts / shapes / fold_in data)
            key_arg = call.args[0]
            if isinstance(key_arg, ast.Name):
                if key_arg.id in self.consumed:
                    self.findings.append(LintFinding(
                        self.path, call.lineno, "AL001",
                        f"PRNG key {key_arg.id!r} reused after being consumed "
                        f"by split/fold_in on line "
                        f"{self.consumed[key_arg.id]} — rebind the key or use "
                        f"a fresh subkey",
                    ))
                if rfn in _KEY_CONSUMERS:
                    self.consumed.setdefault(key_arg.id, call.lineno)
        nfn = self._np_math_fn(call)
        if nfn is not None and self.traced and _mentions_param(call, self.params):
            self.findings.append(LintFinding(
                self.path, call.lineno, "AL002",
                f"np.{nfn} applied to a traced argument inside a jitted "
                f"function — use jnp (np forces host concretization)",
            ))


def _lint_mutable_defaults(tree: ast.Module, findings: list, path: str) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None
        ]
        for d in defaults:
            if isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call)
                and isinstance(d.func, ast.Name)
                and d.func.id in {"list", "dict", "set"}
            ):
                findings.append(LintFinding(
                    path, d.lineno, "AL003",
                    f"mutable default argument in {node.name}() — shared "
                    f"across calls; default to None and construct inside",
                ))


def lint_source(source: str, path: str = "<string>") -> list[LintFinding]:
    tree = ast.parse(source)
    imports = _ImportAliases()
    imports.visit(tree)
    aliases = imports.aliases
    traced = _collect_traced_functions(tree, aliases)
    findings: list[LintFinding] = []

    def _walk(node: ast.AST, outer_params: set[str]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.FunctionDef):
                params = outer_params | _function_params(child)
                _FunctionLinter(
                    child, aliases, traced=child in traced,
                    findings=findings, path=path, params=params,
                ).run()
                _walk(child, params)
            else:
                _walk(child, outer_params)

    _walk(tree, set())
    _lint_mutable_defaults(tree, findings, path)
    noqa = _noqa_lines(source)
    return [
        f for f in findings
        if not (f.line in noqa and (not noqa[f.line] or f.code in noqa[f.line]))
    ]


def lint_file(path: str | pathlib.Path) -> list[LintFinding]:
    p = pathlib.Path(path)
    return lint_source(p.read_text(), str(p))


def lint_paths(root: str | pathlib.Path) -> list[LintFinding]:
    """Lint every .py file under ``root`` (or the single file ``root``)."""
    p = pathlib.Path(root)
    files = [p] if p.is_file() else sorted(p.rglob("*.py"))
    findings: list[LintFinding] = []
    for f in files:
        findings.extend(lint_file(f))
    return findings
