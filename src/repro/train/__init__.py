from repro.train.loop import restore_train_state, train_loop
from repro.train.state import TrainState, init_train_state
from repro.train.trainer import make_train_step, make_serve_steps, shard_train_step

__all__ = [
    "TrainState",
    "init_train_state",
    "make_train_step",
    "make_serve_steps",
    "shard_train_step",
    "restore_train_state",
    "train_loop",
]
