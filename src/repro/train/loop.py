"""Shared step-loop and checkpoint-resume mechanics.

The launch drivers (``launch.train``, ``launch.train_mctm``) and every mode
of the MCTM fit layer (``core.mctm_fit`` — the adam/minibatch ``TrainState``
steps AND the L-BFGS driver with its ``LBFGSState``) drive the same loop:
step → collect loss → periodic log → periodic checkpoint → final checkpoint,
with restart-after-failure resuming from the latest restorable step. The
state is any pytree of arrays carrying a ``step`` field; ``batch_fn(i)`` may
return a fixed batch (full-batch modes) or a per-step sample (minibatch —
pure in ``i``, so resume replays the draw sequence). Written once here so
the launchers cannot drift.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["restore_train_state", "train_loop"]


def restore_train_state(mgr, state, *, shardings=None):
    """Restore the latest checkpoint into ``state``'s structure.

    No-op (returns ``(state, 0)``) when ``mgr`` is None or holds no steps.
    ``shardings``: optional pytree of NamedShardings matching ``state`` —
    restored host arrays are device_put straight to their target shardings
    (the sharded-fit resume path); otherwise plain ``jnp.asarray``.
    """
    if mgr is None or mgr.latest_step() is None:
        return state, 0
    host = mgr.restore(jax.tree.map(np.asarray, state))
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), host, shardings
        )
    else:
        state = jax.tree.map(jnp.asarray, host)
    return state, int(np.asarray(state.step))


def train_loop(
    step_fn: Callable,
    state,
    batch_fn: Callable[[int], dict],
    steps: int,
    *,
    start: int = 0,
    mgr=None,
    ckpt_every: int = 0,
    log_every: int = 0,
    label: str = "train",
    keep_losses: bool = True,
):
    """Drive ``step_fn(state, batch_fn(i))`` from ``start`` to ``steps``.

    Returns ``(state, losses)`` with one loss scalar per executed step
    (device scalars — callers convert lazily, avoiding a sync per step).
    ``keep_losses=False`` retains only the latest loss (long production runs:
    one live device buffer instead of one per step). Checkpoints every
    ``ckpt_every`` steps plus a final save when ``mgr`` is given and any
    step ran.
    """
    losses = []
    t0 = time.time()
    metrics = None
    for i in range(start, steps):
        state, metrics = step_fn(state, batch_fn(i))
        if keep_losses:
            losses.append(metrics["loss"])
        else:
            losses = [metrics["loss"]]
        if log_every and (i + 1) % log_every == 0:
            print(
                f"[{label}] step {i + 1:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({(time.time() - t0) / (i - start + 1):.3f}s/step)",
                flush=True,
            )
        if mgr is not None and ckpt_every and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, state)
    if mgr is not None and steps > start:
        mgr.save(steps, state)
    return state, losses
