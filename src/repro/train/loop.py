"""Shared step-loop and checkpoint-resume mechanics.

The launch drivers (``launch.train``, ``launch.train_mctm``) and every mode
of the MCTM fit layer (``core.mctm_fit`` — the adam/minibatch ``TrainState``
steps AND the L-BFGS driver with its ``LBFGSState``) drive the same loop:
step → collect loss → periodic log → periodic checkpoint → final checkpoint,
with restart-after-failure resuming from the latest restorable step. The
state is any pytree of arrays carrying a ``step`` field; ``batch_fn(i)`` may
return a fixed batch (full-batch modes) or a per-step sample (minibatch —
pure in ``i``, so resume replays the draw sequence). Written once here so
the launchers cannot drift.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.config import get_ft_config, maybe_inject
from repro.ft.failure import NonFiniteError

__all__ = ["restore_train_state", "train_loop"]


def restore_train_state(mgr, state, *, shardings=None):
    """Restore the latest checkpoint into ``state``'s structure.

    No-op (returns ``(state, 0)``) when ``mgr`` is None or holds no steps.
    ``shardings``: optional pytree of NamedShardings matching ``state`` —
    restored host arrays are device_put straight to their target shardings
    (the sharded-fit resume path); otherwise plain ``jnp.asarray``.
    """
    if mgr is None or mgr.latest_step() is None:
        return state, 0
    host = mgr.restore(jax.tree.map(np.asarray, state))
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), host, shardings
        )
    else:
        state = jax.tree.map(jnp.asarray, host)
    return state, int(np.asarray(state.step))


def train_loop(
    step_fn: Callable,
    state,
    batch_fn: Callable[[int], dict],
    steps: int,
    *,
    start: int = 0,
    mgr=None,
    ckpt_every: int = 0,
    log_every: int = 0,
    label: str = "train",
    keep_losses: bool = True,
):
    """Drive ``step_fn(state, batch_fn(i))`` from ``start`` to ``steps``.

    Returns ``(state, losses)`` with one loss scalar per executed step
    (device scalars — callers convert lazily, avoiding a sync per step).
    ``keep_losses=False`` retains only the latest loss (long production runs:
    one live device buffer instead of one per step). Checkpoints every
    ``ckpt_every`` steps plus a final save when ``mgr`` is given and any
    step ran (skipped when the last periodic save already covered ``steps``).

    Graceful degradation: when ``ft`` config enables ``nonfinite_rollback``
    (default), a non-finite loss or grad norm raises ``NonFiniteError``
    *before* the poisoned state can be checkpointed — the supervisor catches
    it, backs off the LR, and resumes from the last good checkpoint.
    """
    ft = get_ft_config()
    losses = []
    t0 = time.time()
    metrics = None
    last_saved = None
    for i in range(start, steps):
        maybe_inject("fit", i)
        state, metrics = step_fn(state, batch_fn(i))
        if ft.nonfinite_rollback and (i + 1) % max(ft.nonfinite_check_every, 1) == 0:
            loss_v = float(metrics["loss"])
            gn = metrics.get("grad_norm")
            gn_v = float(gn) if gn is not None else 0.0
            if not (np.isfinite(loss_v) and np.isfinite(gn_v)):
                raise NonFiniteError(i, loss=loss_v, grad_norm=gn_v)
        if keep_losses:
            losses.append(metrics["loss"])
        else:
            losses = [metrics["loss"]]
        if log_every and (i + 1) % log_every == 0:
            print(
                f"[{label}] step {i + 1:5d} loss {float(metrics['loss']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({(time.time() - t0) / (i - start + 1):.3f}s/step)",
                flush=True,
            )
        if mgr is not None and ckpt_every and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, state)
            last_saved = i + 1
    if mgr is not None and steps > start and last_saved != steps:
        mgr.save(steps, state)
    return state, losses
