"""Training / serving step builders with mesh-aware sharding.

``make_train_step`` produces a jitted SPMD step:
  * per-example weighted loss (coreset weights flow straight through)
  * optional microbatch gradient accumulation (sequential lax.scan — the
    standard memory/batch trade for the big configs)
  * optimizer update (any repro.optim Optimizer)
  * donated state for in-place HBM reuse

``make_serve_steps`` builds prefill/decode for the serving shapes. Both honor
logical sharding rules resolved against the active mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.distributed.sharding import (
    ShardingRules,
    batch_specs,
    default_rules,
    replicated,
    resolve_tree,
)
from repro.optim import Optimizer, apply_updates
from repro.train.state import TrainState, init_train_state

PyTree = Any


def microbatch_split(batch: dict, microbatches: int) -> dict:
    """Reshape every batch leaf (b, ...) → (microbatches, b/microbatches, ...)
    for a sequential accumulation scan — the one chunk-geometry rule shared
    by the train step and the fit layer's L-BFGS oracles."""

    def reshape(x):
        b = x.shape[0]
        return x.reshape(microbatches, b // microbatches, *x.shape[1:])

    return jax.tree.map(reshape, batch)


def tree_acc(acc: PyTree, new: PyTree) -> PyTree:
    """Accumulate ``new`` into ``acc`` in ``acc``'s dtype. Under
    JAX_ENABLE_X64 a term that promotes to f64 would otherwise change the
    scan carry type mid-body (carry input/output dtype mismatch)."""
    return jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, new)


def loss_and_grads(model, params, batch):
    (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
        params, batch
    )
    return loss, metrics, grads


def make_train_step(
    model,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    """Pure step function (jit/shard outside via `shard_train_step`)."""

    def single_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def accum_grads(params, batch):
        """Split the global batch into microbatches and accumulate grads."""
        mb = microbatch_split(batch, microbatches)

        def body(carry, mbatch):
            loss_acc, grads_acc = carry
            loss, _, grads = single_grads(params, mbatch)
            return (tree_acc(loss_acc, loss), tree_acc(grads_acc, grads)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), mb)
        scale = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * scale, grads)
        return loss * scale, {}, grads

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        fn = accum_grads if microbatches > 1 else single_grads
        loss, metrics, grads = fn(state.params, batch)
        updates, opt_state = optimizer.update(
            grads, state.opt_state, state.params, state.step
        )
        params = apply_updates(state.params, updates)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        out_metrics = {"loss": loss, "grad_norm": gnorm, "step": state.step}
        return (
            state.replace(step=state.step + 1, params=params, opt_state=opt_state),
            out_metrics,
        )

    return train_step


def shard_train_step(
    train_step,
    model,
    optimizer,
    mesh: Mesh,
    rules: ShardingRules | None = None,
    *,
    params_shapes: PyTree | None = None,
    specs: PyTree | None = None,
    batch_shapes: dict | None = None,
    donate: bool = True,
):
    """jit the step with NamedShardings resolved from logical specs.

    Returns (jitted_step, state_shardings, batch_shardings).
    """
    rules = rules or default_rules(mesh)
    if params_shapes is None or specs is None:
        from repro.models.transformer import shapes_and_specs

        params_shapes, specs = shapes_and_specs(model)
    param_sh = resolve_tree(specs, params_shapes, mesh, rules)
    opt_shapes = jax.eval_shape(optimizer.init, params_shapes)

    if optimizer.state_specs is not None:
        opt_specs = optimizer.state_specs(specs, params_shapes)
        opt_sh = resolve_tree(opt_specs, opt_shapes, mesh, rules)
    else:
        opt_sh = jax.tree.map(lambda _: replicated(mesh), opt_shapes)
    state_sh = TrainState(step=replicated(mesh), params=param_sh, opt_state=opt_sh)
    if batch_shapes is not None:
        batch_sh = batch_specs(batch_shapes, mesh, rules)
    else:
        batch_sh = None
    jitted = jax.jit(
        train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, state_sh, batch_sh


def make_serve_steps(model):
    """(prefill_fn, decode_fn) pure functions ready for jit with shardings."""

    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache)

    def decode(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    return prefill, decode
