"""TrainState pytree."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jax.Array       # scalar int32
    params: Any
    opt_state: Any

    def replace(self, **kw) -> "TrainState":
        return self._replace(**kw)


def init_train_state(params, optimizer) -> TrainState:
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=optimizer.init(params),
    )
