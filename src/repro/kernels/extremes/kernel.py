"""Fused directional-extremes Pallas kernel: running (max, argmax) accumulator.

The hull stage of Algorithm 1 scores every derivative row against a direction
net — ``dirs @ Pᵀ`` followed by per-direction argmax/argmin. Done naively the
(m, rows) score block round-trips HBM; done here the grid walks row blocks of
P, the MXU emits one (m, block_rows) score tile per step, and the four
running extremes (max, argmax, min, argmin) are folded into revisited
(1, m) output blocks that never leave VMEM — the same accumulation idiom as
the Gram kernel, with an argmax carried next to the max.

Row validity is a *count*: rows with global index ≥ n_valid score ∓inf. Every
engine mask is a prefix-ones pattern (real rows, then shard padding), so the
count is the whole mask — see ``ops.directional_extremes``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 512
LANE = 128


def _kernel(p_ref, d_ref, nv_ref, vmax_ref, imax_ref, vmin_ref, imin_ref,
            *, block_rows: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        vmax_ref[...] = jnp.full(vmax_ref.shape, -jnp.inf, jnp.float32)
        imax_ref[...] = jnp.zeros(imax_ref.shape, jnp.int32)
        vmin_ref[...] = jnp.full(vmin_ref.shape, jnp.inf, jnp.float32)
        imin_ref[...] = jnp.zeros(imin_ref.shape, jnp.int32)

    # (m, block_rows) score tile: contraction over the (lane-padded) feature
    # dim; zero-padded lanes contribute nothing
    S = jax.lax.dot_general(
        d_ref[...], p_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    base = i * block_rows
    ridx = base + jax.lax.broadcasted_iota(jnp.int32, S.shape, 1)
    valid = ridx < nv_ref[0, 0]
    smax = jnp.where(valid, S, -jnp.inf)
    smin = jnp.where(valid, S, jnp.inf)

    # within-block argmax picks the lowest row; strict comparisons against the
    # running best keep the first-occurrence (lowest-global-row) tie-break of
    # a dense argmax — identical to scoring.RunningExtremes
    lv = jnp.max(smax, axis=1)[None, :]
    gi = (base + jnp.argmax(smax, axis=1).astype(jnp.int32))[None, :]
    upd = lv > vmax_ref[...]
    imax_ref[...] = jnp.where(upd, gi, imax_ref[...])
    vmax_ref[...] = jnp.where(upd, lv, vmax_ref[...])

    lv = jnp.min(smin, axis=1)[None, :]
    gi = (base + jnp.argmin(smin, axis=1).astype(jnp.int32))[None, :]
    upd = lv < vmin_ref[...]
    imin_ref[...] = jnp.where(upd, gi, imin_ref[...])
    vmin_ref[...] = jnp.where(upd, lv, vmin_ref[...])


def extremes_kernel(
    p: jax.Array,
    dirs: jax.Array,
    n_valid: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
):
    """p: (n_pad, d_pad) rows, dirs: (m_pad, d_pad), n_valid: (1, 1) int32.

    n_pad % block_rows == 0, d_pad lane-padded, m_pad lane-padded (it is the
    lane dimension of the outputs). Returns (vmax, imax, vmin, imin), each
    (1, m_pad) with indices global row ids into p.
    """
    n, _ = p.shape
    m_pad = dirs.shape[0]
    grid = (n // block_rows,)
    out = jax.ShapeDtypeStruct((1, m_pad), jnp.float32)
    iout = jax.ShapeDtypeStruct((1, m_pad), jnp.int32)
    return pl.pallas_call(
        functools.partial(_kernel, block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, p.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((m_pad, dirs.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (0, 0)),
        ],
        out_shape=[out, iout, out, iout],
        interpret=interpret,
    )(p, dirs, n_valid)
