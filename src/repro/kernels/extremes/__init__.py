from repro.kernels.extremes.ops import default_extremes_backend, directional_extremes
from repro.kernels.extremes.ref import directional_extremes_ref

__all__ = [
    "directional_extremes",
    "directional_extremes_ref",
    "default_extremes_backend",
]
