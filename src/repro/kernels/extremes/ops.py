"""Backend-dispatching wrapper: fused directional extremes per row block.

``directional_extremes`` mirrors ``gram_matrix``'s dispatch: the tiled Pallas
running-(max, argmax) kernel compiled on TPU, the XLA oracle elsewhere.
Interpret-mode Pallas is a *debug* path (orders of magnitude slower than XLA
on CPU) and only runs when explicitly requested.

The Pallas path realizes row masking as a valid-row COUNT (rows ≥ n_valid
score ∓inf inside the kernel): every engine call site masks a prefix-ones /
tail-zeros pattern (real rows followed by shard padding), so the count is
exactly ``mask.sum()``. The jnp oracle honors arbitrary masks.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.extremes.kernel import DEFAULT_BLOCK_ROWS, LANE, extremes_kernel
from repro.kernels.extremes.ref import directional_extremes_ref


def default_extremes_backend() -> str:
    """'pallas' (compiled kernel) on TPU, 'jnp' (XLA oracle) elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    out = jnp.zeros((rows, cols), jnp.float32)
    return out.at[: x.shape[0], : x.shape[1]].set(x.astype(jnp.float32))


@partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _extremes_pallas(P, dirs, n_valid, *, block_rows: int, interpret: bool):
    """Pads rows/lanes (pad rows are masked by the n_valid count, pad lanes
    contribute zero to the scores, pad directions are sliced off)."""
    n, d = P.shape
    m = dirs.shape[0]
    block_rows = min(block_rows, -(-n // 8) * 8)
    n_pad = -(-n // block_rows) * block_rows
    d_pad = -(-d // LANE) * LANE
    m_pad = -(-m // LANE) * LANE
    nv = jnp.reshape(jnp.asarray(n_valid, jnp.int32), (1, 1))
    vmax, imax, vmin, imin = extremes_kernel(
        _pad_to(P, n_pad, d_pad),
        _pad_to(dirs, m_pad, d_pad),
        nv,
        block_rows=block_rows,
        interpret=interpret,
    )
    return vmax[0, :m], imax[0, :m], vmin[0, :m], imin[0, :m]


def directional_extremes(
    P: jax.Array,
    dirs: jax.Array,
    mask: jax.Array | None = None,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    backend: str | None = None,
    interpret: bool | None = None,
):
    """Fused (max, argmax, min, argmin) of ``dirs @ Pᵀ`` per direction.

    P: (rows, d) points, dirs: (m, d) unit directions, mask: optional (rows,)
    row validity (the Pallas backend requires the engines' prefix-ones
    pattern; the jnp oracle accepts any mask). Returns per-direction
    (vmax, imax, vmin, imin) with indices into P's rows. Pure — traceable
    inside jit / lax.scan / shard_map bodies; the backend branch resolves at
    trace time exactly like ``gram_matrix``.
    """
    if interpret and backend is None:
        backend = "pallas"
    if backend is None:
        backend = default_extremes_backend()
    if backend == "jnp":
        return directional_extremes_ref(P, dirs, mask)
    if backend != "pallas":
        raise ValueError(f"unknown extremes backend: {backend}")
    n_valid = P.shape[0] if mask is None else jnp.sum(mask.astype(jnp.int32))
    return _extremes_pallas(
        P, dirs, n_valid, block_rows=block_rows, interpret=bool(interpret)
    )
