"""jnp oracle: fused directional extremes (max, argmax, min, argmin).

This is the exact math of the scoring engines' hull stage — kept here so the
Pallas kernel and every engine path validate against a single reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def directional_extremes_ref(P, dirs, mask=None):
    """Per-block directional extremes: (max, argmax, min, argmin) per direction.

    Laid out (m, c·r) so the reductions run along the contiguous last axis —
    axis-0 argmax over a (c·r, m) matrix is an order of magnitude slower on
    CPU (strided) and tiles badly on TPU (sublane reduction). ``mask`` (c·r,)
    excludes padding rows (sharded inputs padded to a shard multiple) by
    sending their scores to ∓inf. Pure (traceable in jit / scan / shard_map).
    """
    S = dirs @ P.T  # (m, c·r) — block-local only, never (n·r, m)
    if mask is None:
        Smax = Smin = S
    else:
        Smax = jnp.where(mask[None, :], S, -jnp.inf)
        Smin = jnp.where(mask[None, :], S, jnp.inf)
    imax = jnp.argmax(Smax, axis=1)
    imin = jnp.argmin(Smin, axis=1)
    # gather the extreme values instead of separate max/min passes — argmax
    # and argmin are the only full sweeps over S
    vmax = jnp.take_along_axis(Smax, imax[:, None], axis=1)[:, 0]
    vmin = jnp.take_along_axis(Smin, imin[:, None], axis=1)[:, 0]
    return vmax, imax, vmin, imin
