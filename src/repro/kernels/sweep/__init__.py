from repro.kernels.sweep.ops import (  # noqa: F401
    DEFAULT_BLOCK_ROWS,
    default_sweep_backend,
    fused_sweep_update,
)
from repro.kernels.sweep.ref import fused_sweep_ref  # noqa: F401
