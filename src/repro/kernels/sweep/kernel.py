"""Fused one-pass sweep Pallas kernel: one VMEM residency per basis block.

The pre-fused one-pass engine issued three ops per chunk — the CountSketch
scatter, the sketch-projected z emission, and the directional-extremes
reduction — each round-tripping the (chunk, Jd) basis block through HBM.
Here the grid walks row blocks ONCE and everything the sweep accumulates
stays resident:

* ``dSX += E_b @ (√w·X_b)`` — the CountSketch update realized as a one-hot
  matmul (``E_b[s, i] = sign_i·[row_i = s]``), which puts the scatter on the
  MXU instead of a serialized gather/scatter unit;
* ``z_b = (√w·X_b)Ω`` (or the scaled rows themselves when Ω is identity) —
  written straight from the registers that produced the sketch update;
* the running (max, argmax, min, argmin) of ``dirs @ P_bᵀ`` — the same
  revisited-accumulator idiom as ``kernels.extremes``, folded next to the
  sketch so the derivative rows are read once;
* optionally ``(Σp, Σppᵀ)`` hull-moment accumulation for the sketched
  two-pass strategy's pass 1.

Outputs follow the accumulate-OUTSIDE convention: the kernel emits the
block-scan's *delta* (dSX, moment deltas, block-local extremes) and the ops
wrapper folds them into the caller's carried state — the (sketch, D)-sized
add is noise next to the streamed rows, and it keeps the engine state layout
(and sweep checkpoints) byte-identical to the unfused path.

Row validity is a count (prefix-ones masks only, like ``kernels.extremes``):
padded X rows carry sw = 0 so they cannot touch the sketch, z or moments;
padded P rows score ∓inf via ``n_valid``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.extremes.kernel import DEFAULT_BLOCK_ROWS, LANE  # noqa: F401


def _kernel(*refs, block_rows: int, r: int, has_p: bool, hull: bool,
            has_omega: bool, want_moments: bool, want_z: bool):
    it = iter(refs)
    x_ref = next(it)
    p_ref = next(it) if has_p else None
    sw_ref = next(it)
    rows_ref = next(it)
    signs_ref = next(it)
    nv_ref = next(it)
    dirs_ref = next(it) if hull else None
    omega_ref = next(it) if has_omega else None
    dsx_ref = next(it)
    z_ref = next(it) if want_z else None
    if hull:
        vmax_ref, imax_ref, vmin_ref, imin_ref = (
            next(it), next(it), next(it), next(it)
        )
    if want_moments:
        s1_ref, s2_ref = next(it), next(it)

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dsx_ref[...] = jnp.zeros(dsx_ref.shape, jnp.float32)
        if hull:
            vmax_ref[...] = jnp.full(vmax_ref.shape, -jnp.inf, jnp.float32)
            imax_ref[...] = jnp.zeros(imax_ref.shape, jnp.int32)
            vmin_ref[...] = jnp.full(vmin_ref.shape, jnp.inf, jnp.float32)
            imin_ref[...] = jnp.zeros(imin_ref.shape, jnp.int32)
        if want_moments:
            s1_ref[...] = jnp.zeros(s1_ref.shape, jnp.float32)
            s2_ref[...] = jnp.zeros(s2_ref.shape, jnp.float32)

    # (block_rows, D) weighted rows; padded rows have sw = 0
    Xw = x_ref[...] * sw_ref[...]

    # CountSketch as a one-hot matmul: E (sketch, block_rows) has sign_i at
    # (row_i, i), zero elsewhere (pad rows: sign 0 → no contribution)
    E = jnp.where(
        jax.lax.broadcasted_iota(
            jnp.int32, (dsx_ref.shape[0], block_rows), 0
        ) == rows_ref[...],
        signs_ref[...],
        0.0,
    )
    dsx_ref[...] += jax.lax.dot_general(
        E, Xw, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    if want_z:
        z_ref[...] = (
            jax.lax.dot_general(
                Xw, omega_ref[...], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            if has_omega
            else Xw
        )

    if want_moments:
        # padded P rows are zero — they vanish from both moment sums
        Pb = p_ref[...]
        s1_ref[...] += jnp.sum(Pb, axis=0)[None, :]
        s2_ref[...] += jax.lax.dot_general(
            Pb, Pb, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    if hull:
        # (m, block_rows·r) score tile; same running fold as kernels.extremes,
        # with the validity count in points scaled to P rows
        S = jax.lax.dot_general(
            dirs_ref[...], p_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        base = i * block_rows * r
        ridx = base + jax.lax.broadcasted_iota(jnp.int32, S.shape, 1)
        valid = ridx < nv_ref[0, 0] * r
        smax = jnp.where(valid, S, -jnp.inf)
        smin = jnp.where(valid, S, jnp.inf)

        lv = jnp.max(smax, axis=1)[None, :]
        gi = (base + jnp.argmax(smax, axis=1).astype(jnp.int32))[None, :]
        upd = lv > vmax_ref[...]
        imax_ref[...] = jnp.where(upd, gi, imax_ref[...])
        vmax_ref[...] = jnp.where(upd, lv, vmax_ref[...])

        lv = jnp.min(smin, axis=1)[None, :]
        gi = (base + jnp.argmin(smin, axis=1).astype(jnp.int32))[None, :]
        upd = lv < vmin_ref[...]
        imin_ref[...] = jnp.where(upd, gi, imin_ref[...])
        vmin_ref[...] = jnp.where(upd, lv, vmin_ref[...])


def sweep_kernel(
    x: jax.Array,
    p: jax.Array | None,
    sw: jax.Array,
    rows: jax.Array,
    signs: jax.Array,
    n_valid: jax.Array,
    dirs: jax.Array | None,
    omega: jax.Array | None,
    *,
    sketch_rows: int,
    r: int,
    want_moments: bool = False,
    want_z: bool = True,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
):
    """x: (n_pad, D_pad), p: (n_pad·r, d_pad) or None, sw: (n_pad, 1),
    rows/signs: (1, n_pad) int32/f32, n_valid: (1, 1) int32 point count,
    dirs: (m_pad, d_pad) or None, omega: (D_pad, q_pad) or None.

    n_pad % block_rows == 0; every trailing dim lane-padded; ``sketch_rows``
    sublane-padded (multiple of 8). Returns the tuple
    ``(dSX, [z], [vmax, imax, vmin, imin], [ds1, ds2])`` with the optional
    groups present per (want_z, dirs, want_moments): dSX (sketch_rows, D_pad)
    is this call's sketch DELTA, z (n_pad, q_pad or D_pad), extremes
    (1, m_pad) block-local with global row ids into p, moment deltas
    (1, d_pad) / (d_pad, d_pad).
    """
    n_pad, D_pad = x.shape
    hull = dirs is not None
    has_p = p is not None
    has_omega = omega is not None
    grid = (n_pad // block_rows,)

    operands = [x]
    in_specs = [pl.BlockSpec((block_rows, D_pad), lambda i: (i, 0))]
    if has_p:
        d_pad = p.shape[1]
        operands.append(p)
        in_specs.append(pl.BlockSpec((block_rows * r, d_pad), lambda i: (i, 0)))
    operands += [sw, rows, signs, n_valid]
    in_specs += [
        pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        pl.BlockSpec((1, block_rows), lambda i: (0, i)),
        pl.BlockSpec((1, block_rows), lambda i: (0, i)),
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
    ]
    if hull:
        m_pad = dirs.shape[0]
        operands.append(dirs)
        in_specs.append(pl.BlockSpec(dirs.shape, lambda i: (0, 0)))
    if has_omega:
        operands.append(omega)
        in_specs.append(pl.BlockSpec(omega.shape, lambda i: (0, 0)))

    out_shape = [jax.ShapeDtypeStruct((sketch_rows, D_pad), jnp.float32)]
    out_specs = [pl.BlockSpec((sketch_rows, D_pad), lambda i: (0, 0))]
    if want_z:
        q_pad = omega.shape[1] if has_omega else D_pad
        out_shape.append(jax.ShapeDtypeStruct((n_pad, q_pad), jnp.float32))
        out_specs.append(pl.BlockSpec((block_rows, q_pad), lambda i: (i, 0)))
    if hull:
        for dt in (jnp.float32, jnp.int32, jnp.float32, jnp.int32):
            out_shape.append(jax.ShapeDtypeStruct((1, m_pad), dt))
            out_specs.append(pl.BlockSpec((1, m_pad), lambda i: (0, 0)))
    if want_moments:
        out_shape.append(jax.ShapeDtypeStruct((1, d_pad), jnp.float32))
        out_specs.append(pl.BlockSpec((1, d_pad), lambda i: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((d_pad, d_pad), jnp.float32))
        out_specs.append(pl.BlockSpec((d_pad, d_pad), lambda i: (0, 0)))

    return pl.pallas_call(
        functools.partial(
            _kernel,
            block_rows=block_rows,
            r=r,
            has_p=has_p,
            hull=hull,
            has_omega=has_omega,
            want_moments=want_moments,
            want_z=want_z,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
