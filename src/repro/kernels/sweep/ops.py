"""Backend-dispatching wrapper: the fused one-pass sweep step.

``fused_sweep_update`` mirrors ``gram_matrix``/``directional_extremes``'s
dispatch contract: the single-VMEM-residency Pallas kernel compiled on TPU,
the fused-jnp oracle (one XLA dispatch with the two-level extremes
reduction) elsewhere. Interpret-mode Pallas is a *debug* path and only runs
when explicitly requested. ``block_rows`` is the same tuning knob as
``kernels.extremes`` (the two kernels tile the same streamed rows).

The Pallas path realizes row masking as a valid-POINT count (prefix-ones
masks only — real rows, then shard padding; the P-row validity is the count
scaled by rows-per-point). The jnp oracle honors arbitrary masks. The f64
CountSketch accumulator (``gram_dtype="float64"``) is oracle-only, exactly
like the f64 Gram carry bypasses the Pallas gram kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.extremes.kernel import DEFAULT_BLOCK_ROWS, LANE
from repro.kernels.sweep.kernel import sweep_kernel
from repro.kernels.sweep.ref import fused_sweep_ref

__all__ = ["DEFAULT_BLOCK_ROWS", "default_sweep_backend", "fused_sweep_update"]


def default_sweep_backend() -> str:
    """'pallas' (compiled kernel) on TPU, 'jnp' (fused XLA oracle) elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _pad_to(x, rows: int, cols: int):
    out = jnp.zeros((rows, cols), jnp.float32)
    return out.at[: x.shape[0], : x.shape[1]].set(x.astype(jnp.float32))


def _sweep_pallas(
    SX, X, P, sw, rows, signs, n_valid, dirs, omega, moments,
    *, want_z: bool, block_rows: int, interpret: bool,
):
    """Pads rows/lanes, runs the kernel, folds the deltas into the carried
    state. Pad X rows get sw = signs = 0 (sketch/z/moment-inert); pad P rows
    are zero and masked off the extremes by the validity count."""
    n, D = X.shape
    sk = SX.shape[0]
    block_rows = min(block_rows, -(-n // 8) * 8)
    n_pad = -(-n // block_rows) * block_rows
    D_pad = -(-D // LANE) * LANE
    sk_pad = -(-sk // 8) * 8
    xp = _pad_to(X, n_pad, D_pad)
    swp = jnp.zeros((n_pad, 1), jnp.float32).at[:n, 0].set(sw)
    rowsp = jnp.zeros((1, n_pad), jnp.int32).at[0, :n].set(rows.astype(jnp.int32))
    signsp = jnp.zeros((1, n_pad), jnp.float32).at[0, :n].set(signs)
    nv = jnp.reshape(jnp.asarray(n_valid, jnp.int32), (1, 1))

    r = 1
    pp = dirsp = omegap = None
    if P is not None:
        r = P.shape[0] // n
        d = P.shape[1]
        d_pad = -(-d // LANE) * LANE
        pp = _pad_to(P, n_pad * r, d_pad)
    if dirs is not None:
        m = dirs.shape[0]
        m_pad = -(-m // LANE) * LANE
        dirsp = _pad_to(dirs, m_pad, d_pad)
    if omega is not None:
        q = omega.shape[1]
        omegap = _pad_to(omega, D_pad, -(-q // LANE) * LANE)

    outs = list(
        sweep_kernel(
            xp, pp, swp, rowsp, signsp, nv, dirsp, omegap,
            sketch_rows=sk_pad,
            r=r,
            want_moments=moments is not None,
            want_z=want_z,
            block_rows=block_rows,
            interpret=interpret,
        )
    )
    SX = SX + outs.pop(0)[:sk, :D]
    z = None
    if want_z:
        width = q if omega is not None else D
        z = outs.pop(0)[:n, :width]
    ext = None
    if dirs is not None:
        vmax, imax, vmin, imin = (outs.pop(0) for _ in range(4))
        ext = (vmax[0, :m], imax[0, :m], vmin[0, :m], imin[0, :m])
    out_moments = None
    if moments is not None:
        s1, s2 = moments
        out_moments = (s1 + outs.pop(0)[0, :d], s2 + outs.pop(0)[:d, :d])
    return SX, z, ext, out_moments


def fused_sweep_update(
    SX,
    X,
    P,
    sw,
    rows,
    signs,
    *,
    dirs=None,
    omega=None,
    mask=None,
    moments=None,
    want_z: bool = True,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    backend: str | None = None,
    interpret: bool | None = None,
):
    """One fused sweep step over a (chunk, D) basis block.

    SX: (sketch, D) CountSketch carry (f32, or f64 under x64 — oracle only);
    X: (c, D) basis rows; P: (c·r, d) derivative rows or None; sw: (c,)
    √weights; rows/signs: the chunk's CountSketch plan slice; dirs: (m, d)
    direction net or None; omega: (D, q) projection or None; mask: optional
    row validity — per point (c,) or per P row (c·r,); the Pallas backend
    requires the engines' prefix-ones pattern. moments: optional (Σp, Σppᵀ)
    carry to accumulate. Returns ``(SX', z, ext, moments')`` — z the emitted
    (√w·X)Ω block (None when ``want_z`` is False), ext the block-LOCAL
    (vmax, imax, vmin, imin) against dirs (None when dirs is — the caller
    folds them into its running extremes with its own row offset, keeping
    engine state layouts byte-identical to the unfused path), moments' the
    accumulated moment carry. Pure — traceable inside jit / lax.scan /
    shard_map bodies; the backend branch resolves at trace time exactly like
    ``gram_matrix``.
    """
    if interpret and backend is None:
        backend = "pallas"
    if backend is None:
        backend = default_sweep_backend()
    if backend == "jnp":
        return fused_sweep_ref(
            SX, X, P, sw, rows, signs,
            dirs=dirs, omega=omega, mask=mask, moments=moments,
            want_z=want_z, tile=block_rows,
        )
    if backend != "pallas":
        raise ValueError(f"unknown sweep backend: {backend}")
    if SX.dtype != jnp.float32:
        raise ValueError(
            "the fused sweep Pallas kernel is f32-only — "
            "gram_dtype='float64' sketch accumulation runs on the jnp oracle"
        )
    if mask is None:
        n_valid = X.shape[0]
    else:
        n_valid = jnp.sum((mask > 0).astype(jnp.int32))
        if P is not None and mask.shape[0] == P.shape[0] != X.shape[0]:
            # per-P-row mask → valid-point count
            n_valid = n_valid // (P.shape[0] // X.shape[0])
    return _sweep_pallas(
        SX, X, P, sw, rows, signs, n_valid, dirs, omega, moments,
        want_z=want_z, block_rows=block_rows, interpret=bool(interpret),
    )
