"""jnp oracle: the fused one-pass sweep body, as ONE traceable function.

This is the exact math of ``OnePassSketched``'s per-chunk work — CountSketch
accumulation, optional hull-moment accumulation, the directional extremes of
the derivative rows, and the sketch-projected z emission — fused so a single
dispatch (one jit call single-host, one scan-body inline sharded) replaces
the three separate ops the pre-fused engine issued per chunk.

The extremes reduction is restructured relative to
``kernels.extremes.ref.directional_extremes_ref``: instead of a dense
per-direction ``argmax`` over the full (m, c·r) score block (XLA lowers the
variadic value+index reduce ~7x slower than a plain ``max`` on CPU), the
block is folded in two levels — per-tile max/min, an argmax over the tiny
(m, tiles) tile-maxima, then an argmax inside the single winning
(m, block_rows) tile. The results are IDENTICAL bit for bit, including the
first-occurrence tie-break (the tile argmax picks the first tile attaining
the global extreme; the within-tile argmax picks the first row inside it),
which is what keeps fused and unfused engine paths interchangeable and
resume checkpoints bit-identical. This mirrors the Pallas kernel's running
per-tile accumulation, so oracle and kernel share the reduction shape.
"""
from __future__ import annotations

import jax.numpy as jnp

# tile width of the two-level extremes reduction — shared default with the
# Pallas kernels (see ops.DEFAULT_BLOCK_ROWS, re-exported from
# kernels.extremes)
_REF_TILE = 512


def _direct_extremes(Smax, Smin):
    """Dense single-level extremes of one (m, k) score block — the
    ``directional_extremes_ref`` formulation, used for the ragged tail."""
    imax = jnp.argmax(Smax, axis=1)
    imin = jnp.argmin(Smin, axis=1)
    vmax = jnp.take_along_axis(Smax, imax[:, None], axis=1)[:, 0]
    vmin = jnp.take_along_axis(Smin, imin[:, None], axis=1)[:, 0]
    return vmax, imax, vmin, imin


def _two_level_extremes(Smax, Smin, tile: int):
    """Two-level extremes over a (m, nb·tile) score block (see module doc)."""
    m, nmain = Smax.shape
    nb = nmain // tile
    SbM = Smax.reshape(m, nb, tile)
    Sbm = Smin.reshape(m, nb, tile)
    tmax = jnp.max(SbM, axis=2)          # (m, nb) tile maxima
    tmin = jnp.min(Sbm, axis=2)
    jmax = jnp.argmax(tmax, axis=1)      # first tile attaining the extreme
    jmin = jnp.argmin(tmin, axis=1)
    vmax = jnp.take_along_axis(tmax, jmax[:, None], axis=1)[:, 0]
    vmin = jnp.take_along_axis(tmin, jmin[:, None], axis=1)[:, 0]
    wmax = jnp.take_along_axis(SbM, jmax[:, None, None], axis=1)[:, 0]
    wmin = jnp.take_along_axis(Sbm, jmin[:, None, None], axis=1)[:, 0]
    imax = jmax * tile + jnp.argmax(wmax, axis=1)
    imin = jmin * tile + jnp.argmin(wmin, axis=1)
    return vmax, imax, vmin, imin


def blocked_extremes_ref(P, dirs, mask=None, *, tile: int = _REF_TILE):
    """Directional extremes of one block, two-level formulation.

    Same contract and bit-identical results as ``directional_extremes_ref``
    (P: (rows, d), dirs: (m, d), mask: optional (rows,) validity) — only the
    reduction order differs. The ragged tail (rows % tile) is reduced
    densely and folded with strict comparisons, preserving first-occurrence
    tie-breaking across the tail boundary.
    """
    S = dirs @ P.T  # (m, rows) — block-local only, never (n·r, m)
    if mask is None:
        Smax = Smin = S
    else:
        Smax = jnp.where(mask[None, :], S, -jnp.inf)
        Smin = jnp.where(mask[None, :], S, jnp.inf)
    rows = S.shape[1]
    nb = rows // tile
    if nb <= 1:  # too small for two levels — the dense reduce is cheap here
        return _direct_extremes(Smax, Smin)
    main = nb * tile
    vmax, imax, vmin, imin = _two_level_extremes(
        Smax[:, :main], Smin[:, :main], tile
    )
    if main < rows:
        tv, ti, tw, tj = _direct_extremes(Smax[:, main:], Smin[:, main:])
        # strict comparisons: the main block wins ties (its rows come first)
        upd = tv > vmax
        vmax = jnp.where(upd, tv, vmax)
        imax = jnp.where(upd, ti + main, imax)
        upd = tw < vmin
        vmin = jnp.where(upd, tw, vmin)
        imin = jnp.where(upd, tj + main, imin)
    return vmax, imax, vmin, imin


def fused_sweep_ref(
    SX,
    X,
    P,
    sw,
    rows,
    signs,
    *,
    dirs=None,
    omega=None,
    mask=None,
    moments=None,
    want_z: bool = True,
    tile: int = _REF_TILE,
):
    """One fused sweep step — see ``ops.fused_sweep_update`` for the contract.

    Returns ``(SX', z, ext, moments')`` where ``ext`` is the block-LOCAL
    (vmax, imax, vmin, imin) against ``dirs`` (``None`` when ``dirs`` is),
    ``z = (√w·X)Ω`` (``None`` when ``want_z`` is False) and ``moments'`` the
    accumulated (Σp, Σppᵀ) (``None`` when ``moments`` is). The CountSketch
    update is cast to ``SX.dtype`` so an f64 accumulator
    (``gram_dtype="float64"`` under x64) keeps full precision.
    """
    Xw = X * sw[:, None]
    SX = SX.at[rows].add((signs[:, None] * Xw).astype(SX.dtype))
    out_moments = None
    if moments is not None:
        s1, s2 = moments
        out_moments = (s1 + jnp.sum(P, axis=0), s2 + P.T @ P)
    z = None
    if want_z:
        z = Xw if omega is None else Xw @ omega
    ext = None
    if dirs is not None:
        pmask = mask
        if pmask is not None:
            if pmask.shape[0] != P.shape[0]:  # per-point mask → per-P-row
                pmask = jnp.repeat(pmask, P.shape[0] // pmask.shape[0])
            pmask = pmask > 0
        ext = blocked_extremes_ref(P, dirs, pmask, tile=tile)
    return SX, z, ext, out_moments
