"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel subpackage ships three files:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper with shape plumbing / padding
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
  bernstein        — fused Bernstein basis + derivative evaluation (the
                     coreset scoring front-end: bandwidth-bound, one pass)
  gram             — tiled Gram-matrix accumulation XᵀX (leverage scores)
  extremes         — fused directional extremes dirs @ Pᵀ → running
                     (max, argmax, min, argmin) accumulator (hull selection)
  flash_attention  — blockwise-softmax causal attention (training hot-spot)
  ssd              — Mamba2 SSD within-chunk kernel (ssm family hot-spot)

Kernels are validated on CPU via ``interpret=True`` (executes the kernel body
in Python) against the oracle; on a real TPU the same pallas_call lowers to
Mosaic. BlockSpec tiles are MXU/VPU aligned (multiples of (8,128) f32 /
(16,128) bf16; matmul dims multiples of 128).
"""
