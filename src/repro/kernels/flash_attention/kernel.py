"""Blockwise-softmax (flash) causal attention Pallas kernel.

Grid: (batch·heads, S/block_q). Each step holds one q tile in VMEM and runs
an online-softmax fori_loop over k/v tiles, carrying (acc, m, l) in f32
registers. Causal skipping: key tiles strictly above the diagonal contribute
nothing and are masked (Mosaic DCEs the fully-masked tail on TPU).

VMEM budget per step: q (bq, d) + k,v (S, d) + acc ≈ (2S + 2·bq)·d·2B — with
S ≤ 8k, d = 128, bf16 that is ≤ 4.2 MB, comfortably inside 16 MB VMEM. For
longer S, wire block_k through the BlockSpec instead (same inner loop).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool, sm_scale: float):
    bq, d = q_ref.shape[-2], q_ref.shape[-1]
    S = k_ref.shape[-2]
    iq = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale  # (bq, d)

    nk = S // block_k

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(j * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        if causal:
            q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    # causal: key tiles strictly beyond this q tile's diagonal are skipped.
    upper = ((iq + 1) * bq + block_k - 1) // block_k if causal else nk
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """q, k, v: (BH, S, d) → (BH, S, d). S must divide by block_q/block_k."""
    BH, S, d = q.shape
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    grid = (BH, S // block_q)
    return pl.pallas_call(
        functools.partial(_kernel, block_k=block_k, causal=causal, sm_scale=sm_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
