"""Pure-jnp oracle: dense softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, sm_scale: float | None = None):
    """q, k, v: (BH, S, d) → (BH, S, d), fp32 softmax."""
    d = q.shape[-1]
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * sm_scale
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
