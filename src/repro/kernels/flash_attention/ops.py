"""jit'd wrapper: (B, S, H, d) GQA-ready flash attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, S, H, d); k/v: (B, S, KV, d) with H % KV == 0 → (B, S, H, d)."""
    B, S, H, d = q.shape
    KV = k.shape[2]
    groups = H // KV
    # expand kv to per-q-head layout and flatten (B, H) → grid rows
    kq = jnp.repeat(k, groups, axis=2)
    vq = jnp.repeat(v, groups, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kf = kq.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    vf = vq.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    bq = min(block_q, S)
    bk = min(block_k, S)
    out = flash_attention_kernel(
        qf, kf, vf, causal=causal, block_q=bq, block_k=bk, interpret=interpret
    )
    return out.reshape(B, H, S, d).transpose(0, 2, 1, 3)
