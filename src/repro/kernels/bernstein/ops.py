"""jit'd public wrapper: flat input of any length → (basis, deriv) (n, d)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bernstein.kernel import DEFAULT_ROWS, LANE, bernstein_kernel


@partial(jax.jit, static_argnames=("degree", "interpret"))
def bernstein_basis_deriv(t: jax.Array, degree: int, *, interpret: bool = True):
    """t: (n,) in [0,1] → (basis (n, d), deriv (n, d)), d = degree+1.

    Pads to (8·k, 128) tiles, runs the fused kernel, and untiles. `interpret`
    defaults True (CPU validation); pass False on a real TPU.
    """
    n = t.shape[0]
    tile = DEFAULT_ROWS * LANE
    n_pad = (n + tile - 1) // tile * tile
    tp = jnp.zeros((n_pad,), jnp.float32).at[:n].set(t.astype(jnp.float32))
    tiles = tp.reshape(n_pad // LANE, LANE)
    basis, deriv = bernstein_kernel(tiles, degree, interpret=interpret)
    d = degree + 1
    basis = basis.transpose(1, 2, 0).reshape(n_pad, d)[:n]
    deriv = deriv.transpose(1, 2, 0).reshape(n_pad, d)[:n]
    return basis, deriv
