"""Pure-jnp oracle for the bernstein kernel (shares repro.core.bernstein)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bernstein import bernstein_design, bernstein_deriv_design


def bernstein_basis_deriv_ref(t: jax.Array, degree: int):
    """t: any shape → (basis, deriv) each t.shape + (d,) — d/dt (unscaled)."""
    return bernstein_design(t, degree), bernstein_deriv_design(t, degree)
