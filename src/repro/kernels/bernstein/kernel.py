"""Fused Bernstein basis + derivative Pallas kernel.

The coreset front-end evaluates a_j(y) and a'_j(y) for n·J points — two
arrays of (n·J, d). Done naively that is 2(d+1) HBM round-trips of the input;
the fused kernel reads each 8×128 input tile into VMEM once and emits every
basis function and derivative from registers (bandwidth-bound, one pass).

Layout: inputs are tiled (rows, 128) lanes; outputs are (d, rows, 128) with
the small basis index d as the *leading* (sublane-cheap) dimension so the
lane dimension stays 128-aligned for the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bernstein import binomial_coefficients

LANE = 128
DEFAULT_ROWS = 8  # sublanes per tile → (8, 128) f32 native tile


def _kernel(t_ref, basis_ref, deriv_ref, *, degree: int, coeff, coeff_lo):
    t = t_ref[...]  # (R, LANE) f32 in [0,1]
    one_m = 1.0 - t
    # powers t^k and (1-t)^k, k = 0..degree, built iteratively in registers
    tp = [jnp.ones_like(t)]
    op = [jnp.ones_like(t)]
    for _ in range(degree):
        tp.append(tp[-1] * t)
        op.append(op[-1] * one_m)
    for k in range(degree + 1):
        basis_ref[k, :, :] = coeff[k] * tp[k] * op[degree - k]
    # derivative: d b_{k,M}/dt = M (b_{k-1,M-1} − b_{k,M-1})
    if degree == 0:
        deriv_ref[0, :, :] = jnp.zeros_like(t)
        return
    lower = [coeff_lo[k] * tp[k] * op[degree - 1 - k] for k in range(degree)]
    for k in range(degree + 1):
        left = lower[k - 1] if k >= 1 else jnp.zeros_like(t)
        right = lower[k] if k <= degree - 1 else jnp.zeros_like(t)
        deriv_ref[k, :, :] = degree * (left - right)


def bernstein_kernel(
    t: jax.Array, degree: int, *, rows: int = DEFAULT_ROWS, interpret: bool = False
):
    """t: (M, 128) f32 tiles → (basis, deriv) each (d, M, 128)."""
    M = t.shape[0]
    d = degree + 1
    coeff = tuple(float(c) for c in binomial_coefficients(degree))
    coeff_lo = tuple(float(c) for c in binomial_coefficients(max(degree - 1, 0)))
    grid = (M // rows,)
    out_shape = [
        jax.ShapeDtypeStruct((d, M, LANE), jnp.float32),
        jax.ShapeDtypeStruct((d, M, LANE), jnp.float32),
    ]
    return pl.pallas_call(
        functools.partial(_kernel, degree=degree, coeff=coeff, coeff_lo=coeff_lo),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANE), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((d, rows, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((d, rows, LANE), lambda i: (0, i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(t)
