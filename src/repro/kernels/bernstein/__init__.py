from repro.kernels.bernstein.ops import bernstein_basis_deriv

__all__ = ["bernstein_basis_deriv"]
