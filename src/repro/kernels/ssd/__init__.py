from repro.kernels.ssd.ops import ssd_chunked

__all__ = ["ssd_chunked"]
