"""Mamba2 SSD chunk-scan Pallas kernel.

Grid: (B·H, T/chunk). TPU executes the grid sequentially in row-major order,
so the (N, P) recurrent state lives in a VMEM scratch buffer carried across
the chunk dimension (reset via pl.when at chunk 0 — the canonical Pallas
sequential-scan idiom). Per step the MXU runs three small matmuls:

    cb     = C_q B_qᵀ                (Q × N) @ (N × Q)
    y_intra= (cb ⊙ decay_mask) X_dt  (Q × Q) @ (Q × P)
    y_inter= (C_q state) ⊙ exp(la)   (Q × N) @ (N × P)
    state' = exp(la_Q) state + B_qᵀ (X_dt ⊙ tail)

All decay factors are exp of non-positive numbers (A < 0, dt > 0) → stable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 128


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)    # (Q, 1)
    A = a_ref[0, 0]                       # scalar (per head)
    Bm = b_ref[0].astype(jnp.float32)     # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)     # (Q, N)

    la = jnp.cumsum(dt * A, axis=0)       # (Q, 1), non-increasing
    # intra-chunk quadratic form
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    diff = la - la.T                      # la_i − la_j, (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(jj <= ii, jnp.exp(diff), 0.0)
    xdt = x * dt                          # (Q, P)
    y = jax.lax.dot_general(cb * decay, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk contribution from the carried state
    state = state_ref[...]                # (N, P)
    y += jnp.exp(la) * jax.lax.dot_general(
        Cm, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # state update
    tail = jnp.exp(la[-1:] - la)          # (Q, 1) decay to chunk end
    state_ref[...] = jnp.exp(la[-1, 0]) * state + jax.lax.dot_general(
        Bm, xdt * tail, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[0] = y.astype(y_ref.dtype)


def ssd_kernel(
    x: jax.Array,      # (BH, T, P)
    dt: jax.Array,     # (BH, T, 1)
    A: jax.Array,      # (BH, 1)
    Bm: jax.Array,     # (BH, T, N)
    Cm: jax.Array,     # (BH, T, N)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jax.Array:
    BH, T, P = x.shape
    N = Bm.shape[-1]
    grid = (BH, T // chunk)
    from jax.experimental.pallas import tpu as pltpu

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
