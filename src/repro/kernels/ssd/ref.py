"""Pure-jnp oracle: sequential SSD recurrence (the definitional form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm):
    """Sequential state-space recurrence.

    x: (BH, T, P); dt: (BH, T, 1); A: (BH, 1); Bm/Cm: (BH, T, N).
    h_t = exp(dt_t A) h_{t-1} + dt_t · B_t x_tᵀ ;  y_t = C_t h_t.
    """
    BH, T, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp  # (BH,P), (BH,1), (BH,N), (BH,N)
        a = jnp.exp(dtt * A)  # (BH, 1)
        h = h * a[:, :, None] + jnp.einsum("bn,bp->bnp", bt, xt * dtt)
        y = jnp.einsum("bn,bnp->bp", ct, h)
        return h, y

    h0 = jnp.zeros((BH, N, P), jnp.float32)
    xs = (
        x.swapaxes(0, 1).astype(jnp.float32),
        dt.swapaxes(0, 1).astype(jnp.float32),
        Bm.swapaxes(0, 1).astype(jnp.float32),
        Cm.swapaxes(0, 1).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)  # (BH, T, P)
