"""jit'd wrapper for the SSD chunk kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import DEFAULT_CHUNK, ssd_kernel


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = True,
) -> jax.Array:
    """x (BH,T,P), dt (BH,T), A (BH,), Bm/Cm (BH,T,N) → y (BH,T,P).

    T is padded to a chunk multiple with dt=0 steps (decay 1, no state
    contribution) and sliced back.
    """
    BH, T, P = x.shape
    Tp = (T + chunk - 1) // chunk * chunk
    pad = Tp - T

    def padt(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    xp, dtp, Bp, Cp = padt(x), padt(dt[..., None]), padt(Bm), padt(Cm)
    y = ssd_kernel(xp, dtp, A[:, None], Bp, Cp, chunk=min(chunk, Tp), interpret=interpret)
    return y[:, :T]
