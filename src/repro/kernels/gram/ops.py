"""jit'd wrapper: arbitrary (n, D) → exact (D, D) Gram with padding."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.gram.kernel import DEFAULT_BLOCK_ROWS, gram_kernel

LANE = 128


@partial(jax.jit, static_argnames=("interpret", "block_rows"))
def gram_matrix(
    x: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = True
) -> jax.Array:
    """G = XᵀX. Zero-pads rows (no effect on the sum) and lanes (sliced off)."""
    n, D = x.shape
    n_pad = (n + block_rows - 1) // block_rows * block_rows
    d_pad = (D + LANE - 1) // LANE * LANE
    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :D].set(x)
    G = gram_kernel(xp, block_rows=block_rows, interpret=interpret)
    return G[:D, :D]
