"""Backend-dispatching wrapper: arbitrary (n, D) → exact (D, D) Gram.

``gram_matrix`` picks the execution path per backend: the tiled Pallas kernel
compiled on TPU, the XLA oracle (`gram_ref`) elsewhere. Interpret-mode Pallas
is a *debug* path (orders of magnitude slower than XLA on CPU) and is only
used when explicitly requested — it must never be a silent default.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.gram.kernel import DEFAULT_BLOCK_ROWS, gram_kernel
from repro.kernels.gram.ref import gram_ref

LANE = 128


def default_gram_backend() -> str:
    """'pallas' (compiled kernel) on TPU, 'jnp' (XLA oracle) elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


@partial(jax.jit, static_argnames=("interpret", "block_rows"))
def _gram_pallas(
    x: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = False
) -> jax.Array:
    """Zero-pads rows (no effect on the sum) and lanes (sliced off)."""
    n, D = x.shape
    n_pad = (n + block_rows - 1) // block_rows * block_rows
    d_pad = (D + LANE - 1) // LANE * LANE
    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :D].set(x)
    G = gram_kernel(xp, block_rows=block_rows, interpret=interpret)
    return G[:D, :D]


def gram_matrix(
    x: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    backend: str | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """G = XᵀX, f32.

    backend: None → ``default_gram_backend()``; "pallas" → tiled Pallas
    kernel; "jnp" → XLA oracle. ``interpret=True`` forces the Pallas
    interpreter (kernel validation on CPU — slow, debug only) and implies
    ``backend="pallas"``.
    """
    if interpret and backend is None:
        backend = "pallas"
    if backend is None:
        backend = default_gram_backend()
    if backend == "jnp":
        return gram_ref(x)
    if backend != "pallas":
        raise ValueError(f"unknown gram backend: {backend}")
    return _gram_pallas(x, block_rows=block_rows, interpret=bool(interpret))
