"""Tiled Gram-matrix accumulation  G = XᵀX  (leverage-score front-end).

Grid iterates over row blocks of X; the (D, D) output block is revisited by
every grid step (index_map → (0, 0)) and accumulated in VMEM — the standard
Pallas reduction idiom. Row blocks are (256, D) with D padded to a lane
multiple; the MXU sees (D, 256) @ (256, D) per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _kernel(x_ref, g_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)

    x = x_ref[...].astype(jnp.float32)
    g_ref[...] += jax.lax.dot_general(
        x, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def gram_kernel(
    x: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = False
) -> jax.Array:
    """x: (n, D) with n % block_rows == 0, D lane-padded → (D, D) f32."""
    n, D = x.shape
    grid = (n // block_rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((D, D), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((D, D), jnp.float32),
        interpret=interpret,
    )(x)
