"""Pure-jnp oracle for the gram kernel."""
import jax
import jax.numpy as jnp


def gram_ref(x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    return xf.T @ xf
