from repro.kernels.gram.ops import gram_matrix

__all__ = ["gram_matrix"]
