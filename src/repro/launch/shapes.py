"""Assigned input shapes × per-(arch, shape) input ShapeDtypeStructs.

The four assigned LM shapes:
  train_4k     seq 4096   global_batch 256   → train_step
  prefill_32k  seq 32768  global_batch 32    → serve prefill
  decode_32k   seq 32768  global_batch 128   → serve_step (1 token, 32k cache)
  long_500k    seq 524288 global_batch 1     → serve_step (sub-quadratic only)

``input_specs(cfg, shape, kind)`` returns weak-type-correct ShapeDtypeStructs
— no device allocation, the dry-run contract.

Family mapping notes (also in DESIGN.md):
  * [vlm]: seq_len budget covers `n_modality_positions` stub patch embeddings
    prepended to text tokens (text len = seq − P).
  * [audio] enc-dec: seq_len = encoder frames (stub embeddings); the decoder
    operates on its own dec_max_len window (whisper: 448).
  * long_500k is SKIPPED for pure full-attention archs (quadratic), RUNS for
    ssm/hybrid.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

f32 = jnp.float32
bf16 = jnp.bfloat16
i32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str        # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: long_500k needs sub-quadratic attention"
    return True, ""


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        Sd = cfg.dec_max_len
        return {
            "frames": sds((B, S, cfg.d_model), bf16),
            "tokens": sds((B, Sd), i32),
            "labels": sds((B, Sd), i32),
            "weights": sds((B,), f32),
        }
    batch = {}
    S_text = S
    if cfg.modality == "vision":
        P = cfg.n_modality_positions
        S_text = S - P
        batch["patch_embeds"] = sds((B, P, cfg.d_model), bf16)
    batch.update(
        {
            "tokens": sds((B, S_text), i32),
            "labels": sds((B, S_text), i32),
            "weights": sds((B,), f32),
        }
    )
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {
            "frames": sds((B, S, cfg.d_model), bf16),
            "tokens": sds((B, cfg.dec_max_len), i32),
        }
    batch = {}
    S_text = S
    if cfg.modality == "vision":
        P = cfg.n_modality_positions
        S_text = S - P
        batch["patch_embeds"] = sds((B, P, cfg.d_model), bf16)
    batch["tokens"] = sds((B, S_text), i32)
    return batch


def decode_token_specs(shape: ShapeConfig) -> jax.ShapeDtypeStruct:
    return sds((shape.global_batch, 1), i32)


def cache_shapes(model, cfg: ModelConfig, shape: ShapeConfig):
    """(cache ShapeDtypeStructs, logical specs) for the serve cache."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        cache, specs = jax.eval_shape(lambda: model.init_cache(B, S))
        _, specs = model.init_cache(1, 2)  # specs are shape-independent
        return cache, specs
    cache, _ = jax.eval_shape(lambda: model.init_cache(B, S))
    _, specs = model.init_cache(1, 2)
    return cache, specs
