"""End-to-end driver for the paper's experiment: DGP → distributed coreset →
sharded MCTM fit → streamed full-data (1±ε) NLL validation.

``python -m repro.launch.train_mctm --reduced --smoke``

Stages (every data-sized computation on the device mesh):
  1. DGP sample (paper §E.1.1 generators) + full-data scaler.
  2. ``distributed_build_coreset`` — any pass strategy (``--strategy
     two-pass`` exact, ``--strategy one-pass`` with ``--sketch-size``).
  3. Sharded weighted-NLL coreset fit (``core.mctm_fit`` on the trainer's
     SPMD step + ``repro.optim``; ``--ckpt-dir``/``--resume`` route through
     ``CheckpointManager``). ``--fit-method`` picks any fit mode of the
     ``core.mctm_fit`` method table: ``adam`` (default), ``lbfgs``
     (streaming-HVP quasi-Newton), or ``minibatch`` (``--batch-size``
     sampled weighted rows per step — for coresets beyond device memory).
  4. Full-data reference fit with the basis STREAMED microbatch-by-
     microbatch — never an (n, J, d) tensor — for wall-clock + quality.
     ``--ref-method`` defaults to the streaming ``lbfgs`` (the paper's
     experiments fit the full-data baseline quasi-Newton; streaming makes
     that ε̂ baseline scale past coreset-sized data).
  5. Streamed full-data NLL of both fits (strict η) through the one-psum
     shard_map sweep; per-k measured ε̂ (``coreset_epsilon``) and the
     likelihood-ratio check against the (1±ε̂) band: theory gives
     NLL(θ̂_C)/NLL(θ̂) ≤ (1+ε)/(1−ε) for exact minimizers, so the driver
     checks 1−ε̂−δ ≤ ratio ≤ (1+ε̂)/(1−ε̂)+δ with a small optimization
     slack δ (both sides are finite Adam runs, not exact minimizers).

Writes the ε-vs-k + wall-clock record to BENCH_mctm_fit.json at the repo
root (results/bench/BENCH_mctm_fit_smoke.json under ``--smoke``) and exits
nonzero if any ratio leaves its band.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dgp", default="normal_mixture")
    ap.add_argument("--n", type=int, default=250_001)
    ap.add_argument("--ks", default=None,
                    help="coreset sizes (default by scale: 500,1000,2000,4000 "
                    "full / 500,2000 --reduced / 300,600 --smoke)")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--fit-method", default="adam",
                    choices=("adam", "lbfgs", "minibatch"),
                    help="coreset-fit mode (core.mctm_fit method table)")
    ap.add_argument("--ref-method", default="lbfgs",
                    choices=("adam", "lbfgs", "minibatch"),
                    help="full-data reference-fit mode (default: streaming "
                    "lbfgs, the paper's quasi-Newton baseline)")
    ap.add_argument("--batch-size", type=int, default=4096,
                    help="minibatch-mode rows sampled per step")
    ap.add_argument("--gtol", type=float, default=1e-5,
                    help="lbfgs-mode gradient-norm early stop (the objective "
                    "is mean-normalized, so this is scale-free)")
    ap.add_argument("--lr", type=float, default=5e-2)
    ap.add_argument("--degree", type=int, default=6)
    ap.add_argument("--alpha", type=float, default=0.8)
    ap.add_argument("--chunk", type=int, default=16_384)
    ap.add_argument("--strategy", default="two-pass", choices=("two-pass", "one-pass"))
    ap.add_argument("--sketch-size", type=int, default=0,
                    help="one-pass CountSketch rows (0 → 4·(Jd)² auto)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-container scale: fewer steps / fewer k points")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end run (seconds — the CI job)")
    ap.add_argument("--fake-devices", type=int, default=8,
                    help="force N CPU devices when only one real device "
                    "exists (0 → use the devices jax reports)")
    ap.add_argument("--opt-slack", type=float, default=0.02,
                    help="likelihood-ratio tolerance for finite-step fits")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--inject-failures", nargs="?", const="scoring,fit,checkpoint",
                    default=None, metavar="PHASES",
                    help="failure-injected recovery drill: crash mid-scoring / "
                    "mid-fit / mid-checkpoint (comma list of phases; bare flag "
                    "= all three) and recover through the ft supervisor + "
                    "resumable sweeps; results tagged _ft, never gated")
    args = ap.parse_args(argv)
    if args.reduced:
        args.steps = min(args.steps, 250)
    if args.smoke:
        args.n = min(args.n, 30_001)
        args.steps = min(args.steps, 120)
        args.chunk = min(args.chunk, 4096)
        args.batch_size = min(args.batch_size, 1024)
    if args.ks is None:  # an explicitly passed --ks always wins
        args.ks = (
            "300,600" if args.smoke
            else "500,2000" if args.reduced
            else "500,1000,2000,4000"
        )
    return args


def run(args) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import CheckpointManager
    from repro.core import mctm as M
    from repro.core.bernstein import DataScaler
    from repro.core.distributed_coreset import distributed_build_coreset
    from repro.core.mctm_fit import (
        coreset_epsilon,
        fit_mctm_streaming,
        likelihood_ratio,
        streamed_nll,
    )
    from repro.data.dgp import generate
    from repro.ft import ElasticPlanner, FailureSimulator, RunSupervisor
    from repro.ft.config import get_ft_config
    from repro.launch.stages import data_mesh

    mesh = data_mesh()
    devices = int(np.prod(list(mesh.shape.values())))

    ft_cfg = get_ft_config()
    sim = None
    sup = None
    if args.inject_failures:
        import tempfile

        phases = [p.strip() for p in args.inject_failures.split(",") if p.strip()]
        if not args.ckpt_dir:
            args.ckpt_dir = tempfile.mkdtemp(prefix="ft_ckpt_")
        if not args.ckpt_every:
            args.ckpt_every = 20
        # several chunks per shard so mid-scoring checkpoints exist to resume
        args.chunk = min(args.chunk, 1024)
        sim = FailureSimulator()
        if "scoring" in phases:
            sim.inject("scoring", 2)
        if "fit" in phases:
            sim.inject("fit", max(args.steps // 3, 1))
        if "checkpoint" in phases:
            sim.inject("checkpoint", 2 * args.ckpt_every)
        ft_cfg.simulator = sim
        ft_cfg.sweep_ckpt_every_chunks = 2
        # the build has no internal supervisor — this outer one re-plans the
        # mesh (identity here: all devices stay alive) and replays the sweep
        # from its latest segment checkpoint via resume=ctx.resume
        sup = RunSupervisor(
            label="train_mctm",
            planner=ElasticPlanner(
                model_parallel=1,
                base_data_parallel=devices,
                base_global_batch=args.batch_size,
            ),
            remesh=lambda plan: mesh,
        )
    ks = [int(k) for k in args.ks.split(",")]
    cfg = M.MCTMConfig(J=2, degree=args.degree)
    D = cfg.J * cfg.d
    sketch = args.sketch_size
    if args.strategy == "one-pass" and sketch == 0:
        sketch = 4 * D * D

    print(f"[train_mctm] dgp={args.dgp} n={args.n} devices={devices} "
          f"strategy={args.strategy} sketch={sketch} steps={args.steps} "
          f"fit={args.fit_method} ref={args.ref_method}",
          flush=True)
    Y = generate(args.dgp, args.n, seed=args.seed).astype(np.float32)
    scaler = DataScaler.fit(Y)
    key = jax.random.PRNGKey(args.seed)
    k_full_fit, k_build, k_cs_fit = jax.random.split(key, 3)

    def mgr(tag):
        if not args.ckpt_dir:
            return None
        return CheckpointManager(os.path.join(args.ckpt_dir, tag), keep=2)

    # ---- full-data reference fit: basis streamed, step sharded on the mesh
    # (default --ref-method lbfgs — the quasi-Newton full-data baseline the
    # paper's ε̂ comparison assumes, streaming-HVP so it scales with n)
    t0 = time.perf_counter()
    full = fit_mctm_streaming(
        cfg, scaler, Y, steps=args.steps, lr=args.lr, key=k_full_fit,
        method=args.ref_method, batch_size=args.batch_size, gtol=args.gtol,
        mesh=mesh, chunk_size=args.chunk,
        checkpoint=mgr("full"), ckpt_every=args.ckpt_every,
        resume=args.resume, log_every=args.log_every,
    )
    full_fit_s = time.perf_counter() - t0
    nll_full_at_full = streamed_nll(
        cfg, scaler, full.params, Y, chunk=args.chunk, mesh=mesh, eta=1e-9
    )
    print(f"[train_mctm] full fit {full_fit_s:.1f}s  "
          f"NLL/pt {nll_full_at_full / args.n:.4f}", flush=True)

    per_k = []
    for k in ks:
        kb = jax.random.fold_in(k_build, k)
        t0 = time.perf_counter()

        def build(ctx=None):
            return distributed_build_coreset(
                cfg, scaler, Y, k, "l2-hull", mesh=mesh, key=kb,
                alpha=args.alpha, sketch_size=sketch, chunk_size=args.chunk,
                sweep_ckpt=(os.path.join(args.ckpt_dir, f"build_k{k}")
                            if args.inject_failures else None),
                resume=bool(ctx is not None and ctx.resume),
            )

        # under --inject-failures the sweep crash is retried here, resuming
        # from the latest scoring-segment checkpoint on the re-planned mesh
        cs = sup.run(build) if sup is not None else build()
        build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        fit = fit_mctm_streaming(
            cfg, scaler, Y[cs.indices],
            weights=np.asarray(cs.weights, np.float32),
            steps=args.steps, lr=args.lr, key=jax.random.fold_in(k_cs_fit, k),
            method=args.fit_method, batch_size=args.batch_size, gtol=args.gtol,
            mesh=mesh, chunk_size=args.chunk,
            checkpoint=mgr(f"k{k}"), ckpt_every=args.ckpt_every,
            resume=args.resume, log_every=args.log_every,
        )
        fit_s = time.perf_counter() - t0
        nll_full_at_cs = streamed_nll(
            cfg, scaler, fit.params, Y, chunk=args.chunk, mesh=mesh, eta=1e-9
        )
        eps = coreset_epsilon(
            cfg, scaler, Y, Y[cs.indices], np.asarray(cs.weights, np.float32),
            [fit.params, full.params],
            chunk=args.chunk, mesh=mesh, eta=1e-9,
            # full-data sweeps already ran for the ratio — don't pay them twice
            full_nlls=[nll_full_at_cs, nll_full_at_full],
        )
        ratio = likelihood_ratio(nll_full_at_cs, nll_full_at_full)
        lo = 1.0 - eps - args.opt_slack
        hi = (1.0 + eps) / max(1.0 - eps, 1e-6) + args.opt_slack
        within = lo <= ratio <= hi
        speedup = full_fit_s / max(build_s + fit_s, 1e-9)
        per_k.append({
            "k": k,
            "build_s": build_s,
            "fit_s": fit_s,
            "total_s": build_s + fit_s,
            "speedup_vs_full_fit": speedup,
            "eps_hat": eps,
            "ratio": ratio,
            "band": [lo, hi],
            "within_band": bool(within),
            "nll_full_at_cs_per_point": nll_full_at_cs / args.n,
        })
        print(f"[train_mctm] k={k:6d}  build {build_s:6.2f}s fit {fit_s:6.2f}s  "
              f"eps={eps:.4f}  ratio={ratio:.4f} in ({lo:.3f}, {hi:.3f}) "
              f"{'OK' if within else 'VIOLATION'}  "
              f"speedup {speedup:.1f}x", flush=True)

    rec = {
        "dgp": args.dgp,
        "n": args.n,
        "J": cfg.J,
        "degree": args.degree,
        "steps": args.steps,
        "fit_method": args.fit_method,
        "ref_method": args.ref_method,
        "batch_size": args.batch_size,
        "lr": args.lr,
        "chunk": args.chunk,
        "alpha": args.alpha,
        "strategy": args.strategy,
        "sketch_size": sketch,
        "devices": devices,
        "smoke": bool(args.smoke),
        "reduced": bool(args.reduced),
        "opt_slack": args.opt_slack,
        "full_fit_s": full_fit_s,
        "full_nll_per_point": nll_full_at_full / args.n,
        "per_k": per_k,
        "all_within_band": all(r["within_band"] for r in per_k),
        "coreset_beats_full_fit": all(
            r["total_s"] < full_fit_s for r in per_k
        ),
    }
    if sim is not None:
        rec["ft"] = {
            "injected": list(sim.log),
            "supervisor_events": list(sup.events),
        }
        print(f"[train_mctm] injected {len(sim.log)} failures "
              f"({args.inject_failures}); all recovered", flush=True)
    out = args.out
    if out is None:
        if args.smoke:
            # smoke runs land in results/ so they don't churn the committed
            # full-scale artifact at the repo root (kernel_bench convention);
            # non-default fit methods get their own file so the CI matrix's
            # per-method runs don't clobber the gated adam record, and
            # failure-injected drills (timings include crash+replay) get _ft
            tag = "" if args.fit_method == "adam" else f"_{args.fit_method}"
            if args.inject_failures:
                tag += "_ft"
            out = os.path.join(
                REPO_ROOT, "results", "bench", f"BENCH_mctm_fit_smoke{tag}.json"
            )
        else:
            tag = "_ft" if args.inject_failures else ""
            out = os.path.join(REPO_ROOT, f"BENCH_mctm_fit{tag}.json")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[train_mctm] wrote {out}", flush=True)
    return rec


def main(argv=None):
    args = parse_args(argv)
    # force a multi-device CPU mesh BEFORE the first jax device query — the
    # sharded stages then genuinely shard on the container (same mechanism as
    # launch.dryrun); skipped when real accelerators are present
    if args.fake_devices and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
        import jax

        if jax.default_backend() == "cpu" and len(jax.devices()) == 1:
            print("[train_mctm] single-device CPU backend: re-exec with "
                  f"{args.fake_devices} fake devices", flush=True)
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                env.get("XLA_FLAGS", "")
                + f" --xla_force_host_platform_device_count={args.fake_devices}"
            ).strip()
            os.execve(sys.executable,
                      [sys.executable, "-m", "repro.launch.train_mctm"]
                      + (argv if argv is not None else sys.argv[1:]), env)
    try:
        rec = run(args)
    finally:
        if args.inject_failures:
            from repro.ft.config import FTConfig, get_ft_config

            cfg = get_ft_config()
            cfg.simulator = None
            cfg.sweep_ckpt_every_chunks = FTConfig.sweep_ckpt_every_chunks
    if not rec["all_within_band"]:
        sys.exit(1)
    if args.inject_failures and not rec.get("ft", {}).get("injected"):
        print("[train_mctm] --inject-failures requested but nothing fired",
              flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
