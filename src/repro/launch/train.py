"""Training driver: config-driven launcher for real (host-scale) runs.

``python -m repro.launch.train --arch olmo-1b --steps 200 --reduced \
      --coreset l2-hull --coreset-k 512``

Wires together: model zoo → data pipeline (optional coreset selection stage)
→ sharded train step → checkpoint manager → failure-resilient step loop.
On the CPU container use ``--reduced``; on a pod the same driver runs the
full config over ``make_production_mesh()``.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced_config
from repro.data.synthetic_lm import TokenStreamConfig, sample_batch, sample_modality_stub
from repro.launch.stages import coreset_subset_loader
from repro.models import build_model
from repro.optim import adamw, chain, clip_by_global_norm, cosine_warmup
from repro.train import init_train_state, make_train_step, restore_train_state, train_loop


def build_batch_fn(cfg, batch_size: int, seq_len: int, coreset: str, coreset_k: int, key):
    stream = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=seq_len)

    def augment(b, step):
        if cfg.modality == "vision":
            b["patch_embeds"] = sample_modality_stub(
                b["tokens"].shape[0], cfg.n_modality_positions, cfg.d_model, step
            )
        if cfg.family == "encdec":
            b["frames"] = sample_modality_stub(
                b["tokens"].shape[0], seq_len, cfg.d_model, step
            )
        return b

    if coreset == "none":
        return lambda step: augment(sample_batch(stream, batch_size, step), step)

    # coreset data-reduction stage (shared with launch.train_mctm's stage
    # helpers): score a corpus once, train on the weighted subset
    corpus = [sample_batch(stream, 64, s) for s in range(max(coreset_k // 16, 8))]
    data = {k: np.concatenate([c[k] for c in corpus]) for k in ("tokens", "labels")}
    rng = np.random.default_rng(0)
    proj = rng.standard_normal((cfg.vocab_size, 32)).astype(np.float32) * 0.05

    def featurize(tokens):  # cheap proxy: random-projected bag of tokens
        return proj[tokens].mean(axis=1)

    fn = coreset_subset_loader(
        data, featurize, method=coreset, k=coreset_k, key=key, batch=batch_size
    )
    return lambda step: augment(fn(step), step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--coreset", default="none", choices=("none", "l2-hull", "l2-only", "uniform"))
    ap.add_argument("--coreset-k", type=int, default=512)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = chain(
        clip_by_global_norm(1.0),
        adamw(cosine_warmup(args.lr, warmup=20, total=args.steps)),
    )
    state = init_train_state(params, opt)
    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start = 0
    if mgr and args.resume:
        state, start = restore_train_state(mgr, state)
        if start:
            print(f"[resume] from step {start}")

    batch_fn = build_batch_fn(
        cfg, args.batch, args.seq, args.coreset, args.coreset_k, jax.random.PRNGKey(7)
    )
    step_fn = jax.jit(make_train_step(model, opt))
    state, losses = train_loop(
        step_fn,
        state,
        batch_fn,
        args.steps,
        start=start,
        mgr=mgr,
        ckpt_every=args.ckpt_every,
        log_every=args.log_every,
        label="train",
        keep_losses=False,  # production runs: only the final loss is read
    )
    final = float(losses[-1]) if losses else float("nan")
    print(f"done: {args.steps} steps, final loss {final:.4f}")


if __name__ == "__main__":
    main()
