"""End-to-end serving driver: DGP stream → coreset → fit → serve → refresh.

``python -m repro.launch.serve_mctm --smoke``

The live-service loop of ROADMAP item 1, wired over the paper's pipeline:

  1. A DGP stream is consumed chunk-by-chunk into ``MergeReduceCoreset``
     (the first half of the stream seeds the initial model).
  2. Streamed L-BFGS fit on the maintained coreset
     (``core.mctm_fit.fit_mctm_streaming``) → initial publish.
  3. ``DensityServeEngine`` warms its bucket ladder and serves mixed
     open-loop traffic (``log_density`` + conditional ``sample``).
  4. Mid-traffic, the rest of the stream arrives; a background refit on the
     refreshed coreset publishes atomically while queries are in flight
     (the refresh cycle: cheap refits are the coreset's economics).

Prints a latency/throughput/consistency summary and exits nonzero if any
query was dropped, served with mixed params, or the steady state recompiled.
``benchmarks/serve_bench.py`` is the measured version of this loop.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", "..", ".."))


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dgp", default="normal_mixture")
    ap.add_argument("--n", type=int, default=200_000,
                    help="total stream length (first half seeds the model)")
    ap.add_argument("--k", type=int, default=1000, help="coreset size")
    ap.add_argument("--degree", type=int, default=6)
    ap.add_argument("--steps", type=int, default=200, help="fit iterations")
    ap.add_argument("--chunk", type=int, default=16_384,
                    help="stream chunk size (also the fit chunk)")
    ap.add_argument("--queries", type=int, default=4096,
                    help="total queries of mixed traffic")
    ap.add_argument("--sample-frac", type=float, default=0.25,
                    help="fraction of traffic that is conditional-sample")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--min-bucket", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny end-to-end run (seconds — the CI job)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.n = min(args.n, 20_000)
        args.k = min(args.k, 400)
        args.steps = min(args.steps, 60)
        args.chunk = min(args.chunk, 4096)
        args.queries = min(args.queries, 1024)
        args.max_batch = min(args.max_batch, 64)
    return args


def run(args) -> dict:
    import jax
    import numpy as np

    from repro.core import mctm as M
    from repro.core.bernstein import DataScaler
    from repro.core.mctm_fit import fit_mctm_streaming
    from repro.core.streaming import MergeReduceCoreset
    from repro.data.dgp import generate
    from repro.serve.density import DensityServeEngine, start_background_refit

    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)
    k_cs, k_fit, k_refit, k_serve = jax.random.split(key, 4)

    cfg = M.MCTMConfig(J=2, degree=args.degree)
    Y = generate(args.dgp, args.n, seed=args.seed).astype(np.float32)
    scaler = DataScaler.fit(Y)  # full-range scaler, shared by every fit
    half = args.n // 2

    # ---- 1+2: stream first half into the coreset, fit, publish v0
    t0 = time.perf_counter()
    stream = MergeReduceCoreset(cfg, scaler, args.k, k_cs)
    for s in range(0, half, args.chunk):
        stream.push(Y[s:s + args.chunk])
    ws = stream.result()
    fit = fit_mctm_streaming(
        cfg, scaler, ws.Y, weights=np.asarray(ws.weights, np.float32),
        key=k_fit, steps=args.steps, method="lbfgs", chunk_size=args.chunk,
    )
    boot_s = time.perf_counter() - t0
    print(f"[serve_mctm] boot: {stream.n_seen} rows streamed → k={ws.size} "
          f"coreset → lbfgs fit in {boot_s:.1f}s", flush=True)

    # ---- 3: serve mixed open-loop traffic
    engine = DensityServeEngine(
        cfg, fit.params, scaler, max_batch=args.max_batch,
        min_bucket=args.min_bucket, sample_key=k_serve,
    )
    compiled = engine.warmup()
    warm_compiles = engine.compile_count
    print(f"[serve_mctm] warmup: {compiled} executables over buckets "
          f"{engine.buckets}", flush=True)

    n_sample = int(args.queries * args.sample_frac)
    n_logd = args.queries - n_sample
    qY = Y[rng.integers(0, args.n, size=max(n_logd, 1))]
    refit_thread = None
    refit_at = args.queries // 3
    submitted = 0
    all_reqs = []
    si = li = 0
    serve_t0 = time.perf_counter()
    while (
        submitted < args.queries
        or any(engine.queues.values())
        # keep traffic flowing until the refit's publish is served live —
        # the whole point is a hot swap with queries in flight
        or (refit_thread is not None and engine.version < 1)
    ):
        # open-loop arrivals: a burst per tick, mixed kinds
        burst = min(args.max_batch // 2, max(args.queries - submitted, 4))
        for _ in range(burst):
            if (si + li) % 4 == 3 and (si < n_sample or li >= n_logd):
                all_reqs += engine.submit_sample(
                    1, y_obs=Y[si % args.n], n_obs=1, seeds=[si])
                si += 1
            else:
                all_reqs += engine.submit_log_density(qY[li % len(qY)][None])
                li += 1
            submitted += 1
        if refit_thread is None and submitted >= refit_at:
            # ---- 4: rest of the stream arrives → background refit+publish
            for s in range(half, args.n, args.chunk):
                stream.push(Y[s:s + args.chunk])
            ws2 = stream.result()

            def _refit(engine=engine):
                f2 = fit_mctm_streaming(
                    cfg, scaler, ws2.Y,
                    weights=np.asarray(ws2.weights, np.float32),
                    key=k_refit, steps=args.steps, method="lbfgs",
                    chunk_size=args.chunk,
                )
                engine.publish(f2.params)

            import threading

            refit_thread = threading.Thread(target=_refit, daemon=True)
            refit_thread.start()
        engine.step()
    if refit_thread is not None:
        refit_thread.join()
    serve_s = time.perf_counter() - serve_t0

    # ---- consistency + latency summary
    lat = np.asarray([r.latency_s for r in all_reqs], np.float64)
    versions = sorted({r.version for r in all_reqs})
    dropped = sum(1 for r in all_reqs if not r.done)
    recompiles = engine.compile_count - warm_compiles
    stall = [e["visible_s"] - e["published_s"]
             for e in engine.swap_events if e["visible_s"]]
    rec = {
        "queries": len(all_reqs),
        "dropped": dropped,
        "versions_served": versions,
        "steady_state_recompiles": recompiles,
        "qps": len(all_reqs) / max(serve_s, 1e-9),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "swap_stall_ms": float(max(stall) * 1e3) if stall else 0.0,
        "final_version": engine.version,
    }
    print(f"[serve_mctm] served {rec['queries']} queries in {serve_s:.2f}s "
          f"({rec['qps']:.0f} QPS)  p50 {rec['p50_ms']:.2f}ms  "
          f"p99 {rec['p99_ms']:.2f}ms", flush=True)
    print(f"[serve_mctm] hot swap: versions {versions} served, "
          f"publish→visible {rec['swap_stall_ms']:.2f}ms, "
          f"dropped={dropped}, steady-state recompiles={recompiles}",
          flush=True)
    return rec


def main(argv=None):
    args = parse_args(argv)
    rec = run(args)
    ok = (
        rec["dropped"] == 0
        and rec["steady_state_recompiles"] == 0
        and rec["final_version"] >= 1
        # the refit's publish was served LIVE: traffic straddled the swap
        and set(rec["versions_served"]) >= {0, 1}
    )
    if not ok:
        print("[serve_mctm] FAILED consistency checks", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
