import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER'S TECHNIQUE at pod scale: Algorithm-1 coreset scoring
(leverage + sensitivity) for n = 4.2M rows of Bernstein features on the
production mesh. Four variants:

  naive     — gather the full feature matrix to every chip, then Gram+scores
              (what a straight port of the single-node algorithm does)
  psum      — the shard_map formulation: per-shard Gram, one (dJ)² psum,
              local projections (repro.core.distributed_coreset)
  sketch    — the engine's ONE-PASS sketched sweep (make_sharded_onepass_fn,
              the sharded OnePassSketched strategy): scan over per-shard
              chunks accumulating the row CountSketch, one fused state psum,
              leverage read off the retained rows — each row touched once
              (Woodruff Thm 2.13 path; least FLOPs AND least I/O)
  engine    — the DistributedScoringEngine two-pass structure: the chunk
              loop runs INSIDE the shard body (lax.scan over per-shard
              chunks), one fused pass-1 psum, chunked pass-2 leverage
              emission — per-chip peak O(chunk·D) instead of O(per_shard·D)

Writes results/dryrun/coreset__score__<mesh>__opt-<variant>.json — the
paper-representative §Perf cell.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from repro.utils.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed_coreset import (
    make_sharded_onepass_fn,
    make_sharded_pass_fns,
)
from repro.core.leverage import leverage_from_gram
from repro.core.scoring import gram_projection
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.utils.hlo import collective_stats

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def score_fn(variant: str, mesh, n: int, D: int, sketch: int = 0, chunk: int = 4096):
    """Returns (fn, in_shardings, arg ShapeDtypeStructs)."""
    X_sds = jax.ShapeDtypeStruct((n, D), jnp.float32)
    axes = data_axes(mesh)
    x_shard = NamedSharding(mesh, P(axes, None))
    axis = axes if len(axes) > 1 else axes[0]

    if variant == "naive":

        def fn(X):
            # straight port: replicate X, then Gram + scores everywhere
            Xr = jax.lax.with_sharding_constraint(X, P())
            G = Xr.T @ Xr
            u = leverage_from_gram(Xr, G)
            return u + 1.0 / n

        return fn, (x_shard,), (X_sds,)

    if variant == "psum":

        def body(xs):
            G = jax.lax.psum(xs.T @ xs, axis)
            return leverage_from_gram(xs, G) + 1.0 / n

        fn = shard_map(
            body, mesh=mesh, in_specs=(P(data_axes, None),), out_specs=P(data_axes)
        )
        return fn, (x_shard,), (X_sds,)

    if variant == "sketch":
        # the sharded OnePassSketched strategy: ONE fused sweep — scan over
        # per-shard chunks accumulating the row CountSketch (state joins the
        # single psum), leverage read off the retained z rows. n divisible by
        # the shard count at dry-run scale, as for "engine".
        shards = int(np.prod([mesh.shape[a] for a in axes]))
        per = n // shards
        chunk = min(chunk, per)
        assert per % chunk == 0, "dry-run shapes: per-shard rows % chunk == 0"
        onepass = make_sharded_onepass_fn(
            lambda x: (x, x),
            mesh,
            axes,
            chunk=chunk,
            chunks_per_shard=per // chunk,
            rows_per_point=1,
            hull=False,
            D=D,
            q=None,
            sketch_size=sketch,
        )
        sw_sds = jax.ShapeDtypeStruct((n,), jnp.float32)
        rows_sds = jax.ShapeDtypeStruct((n,), jnp.int32)
        r_shard = NamedSharding(mesh, P(axes))

        def fn(X, sw, mask, rows, signs):
            z, SX = onepass(X, sw, mask, rows, signs)
            V, inv = gram_projection(SX.T @ SX)  # (D,D) algebra, replicated
            return jnp.sum(jnp.square(z @ V) * inv, axis=1) + 1.0 / n

        return (
            fn,
            (x_shard, r_shard, r_shard, r_shard, r_shard),
            (X_sds, sw_sds, sw_sds, rows_sds, sw_sds),
        )

    if variant == "engine":
        # the DistributedScoringEngine's sharded+chunked Algorithm 1 on raw
        # feature rows (identity featurize, hull off): scan over per-shard
        # chunks, ONE fused pass-1 psum, chunked pass-2 leverage. n must be
        # divisible by the data-shard count at dry-run scale (it is: 2^22
        # rows over 2^8 chips).
        shards = int(np.prod([mesh.shape[a] for a in axes]))
        per = n // shards
        chunk = min(chunk, per)
        assert per % chunk == 0, "dry-run shapes: per-shard rows % chunk == 0"
        pass1, pass2 = make_sharded_pass_fns(
            lambda x: (x, x),
            mesh,
            axes,
            chunk=chunk,
            chunks_per_shard=per // chunk,
            rows_per_point=1,
            hull=False,
            D=D,
            p=1,  # no hull stage → no (D, D) dead weight in the psum
        )
        sw_sds = jax.ShapeDtypeStruct((n,), jnp.float32)
        r_shard = NamedSharding(mesh, P(axes))

        def fn(X, sw, mask):
            G, _, _ = pass1(X, sw, mask)
            V, inv = gram_projection(G)  # (D,D) algebra, replicated
            return pass2(X, sw, V, inv) + 1.0 / n

        return fn, (x_shard, r_shard, r_shard), (X_sds, sw_sds, sw_sds)

    raise ValueError(variant)


def run(variant: str, multi_pod: bool, n: int, J: int, d: int, out_dir: str):
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    D = J * d
    t0 = time.time()
    fn, shardings, args = score_fn(variant, mesh, n, D, sketch=4 * D)
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = collective_stats(compiled.as_text())
    ma = compiled.memory_analysis()
    flops = float(ca.get("flops", 0.0))
    bytes_ = float(ca.get("bytes accessed", 0.0))
    rec = {
        "arch": "coreset-score",
        "shape": f"n{n}_J{J}_d{d}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "variant": variant,
        "hlo_flops": flops,
        "hlo_bytes": bytes_,
        "collective_bytes": float(coll["total_bytes"]),
        "collective_by_op": coll["by_op"],
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_ / HBM_BW,
        "collective_s": coll["total_bytes"] / ICI_BW,
        "memory_analysis": {
            "argument_size_in_bytes": int(ma.argument_size_in_bytes),
            "temp_size_in_bytes": int(ma.temp_size_in_bytes),
        },
        "compile_seconds": time.time() - t0,
        "skipped": False,
    }
    terms = {k: rec[k] for k in ("compute_s", "memory_s", "collective_s")}
    rec["dominant"] = max(terms, key=terms.get)
    os.makedirs(out_dir, exist_ok=True)
    tag = f"coreset__score__{rec['mesh']}__opt-{variant}"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(
        f"[done] {tag}: compute={rec['compute_s']:.5f}s mem={rec['memory_s']:.5f}s "
        f"coll={rec['collective_s']:.5f}s dom={rec['dominant']}",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--variant", default="psum", choices=("naive", "psum", "sketch", "engine")
    )
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--n", type=int, default=4_194_304)
    ap.add_argument("--J", type=int, default=20)
    ap.add_argument("--d", type=int, default=7)
    ap.add_argument("--out", default=os.path.normpath(RESULTS_DIR))
    args = ap.parse_args()
    run(args.variant, args.multi_pod, args.n, args.J, args.d, args.out)


if __name__ == "__main__":
    main()
