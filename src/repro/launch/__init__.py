"""Launchers: mesh construction, multi-pod dry-run, training/serving drivers.

NOTE: import ``repro.launch.dryrun`` only in a fresh process — it sets
XLA_FLAGS (512 placeholder devices) at import time, before jax initializes.
"""
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
