import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be executed as its own process (``python -m repro.launch.dryrun``) so the
XLA_FLAGS above take effect before jax initializes — the two lines at the top
of this file run before ANY other import.

For each cell this produces:
  * ``compiled.memory_analysis()``  — proves the program fits per-device HBM
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline
  * collective wire bytes parsed from the compiled HLO text
and writes one JSON per cell under ``results/dryrun/`` for the roofline
aggregator (benchmarks/roofline_table.py).

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape decode_32k
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import batch_specs, default_rules, replicated, resolve_tree
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analytic_flops, count_params, roofline_terms
from repro.launch.shapes import (
    SHAPES,
    cell_supported,
    decode_token_specs,
    prefill_batch_specs,
    train_batch_specs,
)
from repro.models import build_model
from repro.optim import adafactor, adamw
from repro.train.state import TrainState
from repro.utils.hlo import collective_stats

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

# per-arch training knobs (memory-driven): microbatch count + optimizer
TRAIN_MICROBATCHES = {"arctic-480b": 16, "minicpm3-4b": 8}
DEFAULT_MICROBATCHES = 8
ADAFACTOR_ARCHS = {"arctic-480b"}  # 0.5T params: factored moments required


def _cost(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def _mem(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        out[k] = int(getattr(ma, k, 0) or 0)
    return out


def _optimizer(arch: str):
    if arch in ADAFACTOR_ARCHS:
        return adafactor(1e-4)
    return adamw(3e-4)


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    remat: str = "full",
    xent_chunk: int = 512,
    fsdp: bool = True,
    microbatches: int | None = None,
    rules_override=None,
    overrides: dict | None = None,
    act_constraints: bool = False,
    prefill_chunk: int = 0,
) -> dict:
    """Lower + compile one cell; return the roofline record (raises on failure)."""
    t0 = time.time()
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True, "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    mesh_name = "x".join(str(s) for s in mesh.shape.values())
    # serving: no FSDP (params replicated over the batch axes, TP over model)
    # and bf16 weights; training: FSDP fp32 masters.
    serve = shape.kind != "train"
    rules = rules_override or default_rules(mesh, fsdp=fsdp and not serve)
    from repro.distributed.sharding import set_activation_axes

    set_activation_axes(
        batch=rules.get("batch"),
        model=("model",),
        enabled=act_constraints or cfg.decode_seq_shard,
    )
    model = build_model(cfg, remat=remat, xent_chunk=xent_chunk)

    from repro.models.transformer import shapes_and_specs

    params_shapes, specs = shapes_and_specs(model)
    if serve:
        params_shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating)
            else x,
            params_shapes,
        )
    param_sh = resolve_tree(specs, params_shapes, mesh, rules)
    n_params = count_params(params_shapes)

    if shape.kind == "train":
        from repro.train.trainer import make_train_step

        mb = microbatches or TRAIN_MICROBATCHES.get(arch, DEFAULT_MICROBATCHES)
        opt = _optimizer(arch)
        step_fn = make_train_step(model, opt, microbatches=mb)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        opt_specs = opt.state_specs(specs, params_shapes)
        opt_sh = resolve_tree(opt_specs, opt_shapes, mesh, rules)
        state_shapes = TrainState(
            step=jax.ShapeDtypeStruct((), jnp.int32), params=params_shapes, opt_state=opt_shapes
        )
        state_sh = TrainState(step=replicated(mesh), params=param_sh, opt_state=opt_sh)
        b_shapes = train_batch_specs(cfg, shape)
        b_sh = batch_specs(b_shapes, mesh, rules)
        with mesh:
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, b_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_shapes, b_shapes)
            compiled = lowered.compile()
        kind = "train"
    else:
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)[0]
        )
        _, cache_specs = model.init_cache(1, 2)
        cache_sh = resolve_tree(_cache_logical(cache_specs), cache_shapes, mesh, rules)
        if shape.kind == "prefill":
            b_shapes = prefill_batch_specs(cfg, shape)
            if prefill_chunk and cfg.family != "encdec":
                # chunked prefill: compile the per-chunk incremental step
                # (writes into the full-length cache at `pos`); the whole
                # prefill = S/chunk sequential invocations, so FLOPs/bytes/
                # collective totals are scaled back up by that factor while
                # peak memory is the per-chunk figure — the HBM-capacity fix.
                b_shapes = dict(b_shapes)
                b_shapes["tokens"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, prefill_chunk), jnp.int32
                )
                b_shapes.pop("patch_embeds", None)  # patch prefix: chunk 0 only
            b_sh = batch_specs(b_shapes, mesh, rules)

            def step(params, batch, cache):
                return model.prefill(params, batch, cache)

            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(param_sh, b_sh, cache_sh),
                    out_shardings=(None, cache_sh),
                    donate_argnums=(2,),
                ).lower(params_shapes, b_shapes, cache_shapes)
                compiled = lowered.compile()
            kind = "prefill"
        else:
            tok = decode_token_specs(shape)
            tok_sh = batch_specs({"tokens": tok}, mesh, rules)["tokens"]

            def step(params, tokens, cache):
                return model.decode_step(params, tokens, cache)

            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(param_sh, tok_sh, cache_sh),
                    out_shardings=(None, cache_sh),
                    donate_argnums=(2,),
                ).lower(params_shapes, tok, cache_shapes)
                compiled = lowered.compile()
            kind = "decode"

    cost = _cost(compiled)
    mem = _mem(compiled)
    hlo_text = compiled.as_text()
    coll = collective_stats(hlo_text)
    ana, model_flops = analytic_flops(cfg, n_params, shape, kind)
    scale = 1.0
    if kind == "prefill" and prefill_chunk and cfg.family != "encdec":
        scale = shape.seq_len / prefill_chunk  # whole prefill = scale chunks
    report = roofline_terms(
        arch=arch,
        shape=shape_name,
        mesh_name=mesh_name,
        chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)) * scale,
        hlo_bytes=float(cost.get("bytes accessed", 0.0)) * scale,
        collective_bytes=float(coll["total_bytes"]) * scale,
        collective_by_op=coll["by_op"],
        model_flops=model_flops,
        analytic=ana,
        peak_memory_bytes=float(mem.get("temp_size_in_bytes", 0) + mem.get("argument_size_in_bytes", 0)),
    )
    rec = report.to_json()
    rec.update(
        {
            "kind": kind,
            "n_params": n_params,
            "memory_analysis": mem,
            "compile_seconds": time.time() - t0,
            "multi_pod": multi_pod,
            "skipped": False,
            "remat": remat,
            "fsdp": fsdp,
        }
    )
    return rec


def _cache_logical(cache_specs):
    """Cache logical specs: first data axis is 'layer', second is batch."""

    def fix(s):
        s = tuple(s)
        if len(s) >= 2 and s[0] == "layer":
            return s
        return s

    return jax.tree.map(fix, cache_specs, is_leaf=lambda s: isinstance(s, tuple))


def run_cell_to_file(arch, shape_name, multi_pod, out_dir, skip_existing=True, variant="", **kw):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    if variant:
        tag += f"__opt-{variant}"
    path = os.path.join(out_dir, tag + ".json")
    if skip_existing and os.path.exists(path):
        print(f"[skip existing] {tag}")
        return path
    print(f"[lower+compile] {tag} ...", flush=True)
    try:
        rec = lower_cell(arch, shape_name, multi_pod=multi_pod, **kw)
        rec["variant"] = variant or "baseline"
    except Exception as e:  # record failures — they are bugs to fix
        rec = {
            "arch": arch,
            "shape": shape_name,
            "multi_pod": multi_pod,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
            "skipped": False,
        }
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    status = "ERROR " + rec["error"][:120] if "error" in rec else (
        "SKIP " + rec.get("reason", "") if rec.get("skipped") else
        f"ok compute={rec['compute_s']:.4f}s mem={rec['memory_s']:.4f}s coll={rec['collective_s']:.4f}s dom={rec['dominant']}"
    )
    print(f"[done] {tag}: {status}", flush=True)
    return path


def _parse_overrides(pairs: list[str]) -> dict:
    out = {}
    for p in pairs or []:
        k, _, v = p.partition("=")
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(RESULTS_DIR))
    ap.add_argument("--skip-existing", action="store_true", default=True)
    ap.add_argument("--no-skip-existing", dest="skip_existing", action="store_false")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--xent-chunk", type=int, default=512)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument(
        "--override", action="append", default=[],
        help="ModelConfig field=value (e.g. decode_seq_shard=true scan_dtype=bfloat16)",
    )
    ap.add_argument("--variant", default="", help="tag for §Perf variant records")
    ap.add_argument("--act-constraints", action="store_true",
                    help="enable logical activation sharding constraints")
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false", default=True,
                    help="replicate params over the data axis (small models)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="compile the per-chunk incremental prefill step")
    args = ap.parse_args()

    arch_list = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shape_list = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.both_meshes or (args.all and not args.multi_pod)) else [args.multi_pod]
    overrides = _parse_overrides(args.override)

    for mp in meshes:
        for arch in arch_list:
            for shape_name in shape_list:
                run_cell_to_file(
                    arch, shape_name, mp, args.out,
                    skip_existing=args.skip_existing, remat=args.remat,
                    xent_chunk=args.xent_chunk, microbatches=args.microbatches,
                    overrides=overrides, variant=args.variant,
                    act_constraints=args.act_constraints, fsdp=args.fsdp,
                    prefill_chunk=args.prefill_chunk,
                )


if __name__ == "__main__":
    main()
