"""Shared launcher stages.

Both launch drivers (``launch.train`` for the LM configs, ``launch.train_mctm``
for the paper's density experiment) build their runs from the same pieces so
they cannot drift: the corpus→coreset data-reduction stage lives here, the
step loop + checkpoint resume live in ``repro.train.loop``, and the fit-layer
mechanics in ``repro.core.mctm_fit`` — whose ``method=`` table (full-batch
``adam``, streaming-HVP ``lbfgs``, sampled ``minibatch`` on
``data.pipeline``'s loaders) is what ``train_mctm --fit-method/--ref-method``
selects after the data-reduction stage. Every mode checkpoints/resumes
through the one ``train.loop`` driver, so a launcher restart replays
identically regardless of method.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.data.pipeline import CoresetSelector, subset_loader
from repro.utils.compat import make_mesh

__all__ = ["coreset_subset_loader", "data_mesh"]


def data_mesh(axis: str = "data"):
    """Mesh over all available devices with a single data axis — the layout
    every data-sharded stage here uses (``DistributedScoringEngine``, the
    sharded fit step, the streamed evaluator). On a multi-pod run build the
    mesh with ``make_production_mesh`` + ``data_axes`` instead."""
    return make_mesh((len(jax.devices()),), (axis,))


def coreset_subset_loader(
    data: dict,
    featurize: Callable,
    *,
    k: int,
    key: jax.Array,
    batch: int,
    method: str = "l2-hull",
    examples_key: str = "tokens",
    mesh=None,
    axis="data",
    sketch_size: int = 0,
    chunk_size: int | None = None,
):
    """The generic coreset data-reduction stage: score ``data[examples_key]``
    once with Algorithm 1 (``CoresetSelector`` — optionally on a mesh, or
    through the one-pass sketched strategy) and return a ``sample_fn`` over
    the weighted subset, coreset weights attached per example for the
    trainer's per-example-weight loss path.
    """
    kwargs = {} if chunk_size is None else {"chunk_size": chunk_size}
    sel = CoresetSelector(
        featurize=featurize,
        method=method,
        mesh=mesh,
        axis=axis,
        sketch_size=sketch_size,
        **kwargs,
    )
    subset = sel.select(data[examples_key], k=k, key=key)
    return subset_loader(data, subset, batch)
