"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the 512-placeholder-device dry-run must set
XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import jax

from repro.utils.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the actually-available devices (tests / examples)."""
    n = len(jax.devices())
    return make_mesh((n // model, model), ("data", "model"))
