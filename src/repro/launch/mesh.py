"""Production mesh construction + mesh-aware launch helpers.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the 512-placeholder-
device dry-run must set XLA_FLAGS before the first jax call.

``data_axes`` / ``host_gather`` are the two pieces every launch script needs
to drive the sharded coreset path (``core.distributed_coreset``): which mesh
axes carry the data sharding, and how to pull row-sharded results back to
the host safely under multi-process jax.
"""
from __future__ import annotations

import jax

from repro.core.distributed_coreset import host_gather  # re-export  # noqa: F401
from repro.utils.compat import make_mesh

__all__ = ["make_production_mesh", "make_host_mesh", "data_axes", "host_gather"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the actually-available devices (tests / examples)."""
    n = len(jax.devices())
    return make_mesh((n // model, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The mesh axes that shard data rows: ("pod", "data") on multi-pod
    meshes, ("data",) otherwise. Feed the tuple to
    ``DistributedScoringEngine(axis=...)`` / shard_map PartitionSpecs so a
    script works unchanged on both mesh shapes."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
