"""Roofline derivation from compiled dry-run artifacts (TPU v5e targets).

Convention: the compiled artifact is the **per-device SPMD program**, so
``cost_analysis()`` FLOPs/bytes and the HLO collective bytes are per-chip
quantities. The three roofline terms (seconds) are therefore

    compute    = per_chip_FLOPs   / 197e12 bf16 FLOP/s
    memory     = per_chip_bytes   / 819e9  B/s HBM
    collective = per_chip_coll_B  / 50e9   B/s ICI link

which equals the spec's global formulation (global = per-chip × chips divided
by chips × peak). ``cost_analysis()`` can undercount FLOPs inside `while`
bodies (scan over layers), so we also compute an *analytic* global FLOP count
(6·N_active·tokens + attention quadratic terms); compute uses
max(hlo, analytic/chips) and MODEL_FLOPS/(chips·flops_used) is the
useful-compute ratio.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

PEAK_FLOPS = 197e12       # bf16 per chip, TPU v5e
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

__all__ = [
    "RooflineReport",
    "roofline_terms",
    "analytic_flops",
    "PEAK_FLOPS",
    "HBM_BW",
    "ICI_BW",
]


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    analytic_flops: float
    hlo_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    peak_memory_bytes: float
    collective_by_op: dict
    note: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute_term / max-term: 1.0 = perfectly compute-bound."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    collective_by_op: dict,
    model_flops: float,
    analytic: float,
    peak_memory_bytes: float = 0.0,
    note: str = "",
) -> RooflineReport:
    flops_per_chip = max(hlo_flops, analytic / chips)
    compute_s = flops_per_chip / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    collective_s = collective_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = (model_flops / chips) / flops_per_chip if flops_per_chip > 0 else 0.0
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=hlo_flops,
        analytic_flops=analytic,
        hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        useful_ratio=useful,
        peak_memory_bytes=peak_memory_bytes,
        collective_by_op=collective_by_op,
        note=note,
    )


# ---------------------------------------------------------------------------
# analytic FLOPs (6·N·D convention)
# ---------------------------------------------------------------------------


def count_params(shapes_tree) -> int:
    import jax

    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes_tree))


def active_param_fraction(cfg) -> float:
    """MoE: fraction of expert params active per token (top_k / n_experts)."""
    if cfg.family != "moe" or cfg.n_experts == 0:
        return 1.0
    # approximate: expert params dominate; scale them by k/E, keep the rest.
    d, f, E, L = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.n_layers
    expert = 3 * d * f * E * L
    attn = 4 * d * cfg.n_heads * cfg.head_dim * L
    shared = 3 * d * f * cfg.n_shared_experts * L
    dense = 3 * d * f * L if cfg.moe_dense_residual else 0
    other = attn + shared + dense
    total = expert + other
    active = expert * (cfg.top_k / E) + other
    return active / total


def analytic_flops(cfg, n_params: int, shape, kind: str) -> tuple[float, float]:
    """(analytic_total, model_flops = 6·N_active·D).

    analytic_total adds the quadratic attention term; both count the global
    step (all chips).
    """
    B, S = shape.global_batch, shape.seq_len
    embed_params = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_body = max(n_params - embed_params, 1)
    n_active = n_body * active_param_fraction(cfg) + cfg.d_model * cfg.vocab_size  # logits matmul
    if kind == "train":
        tokens = B * S
        passes = 6.0  # fwd 2 + bwd 4
    elif kind == "prefill":
        tokens = B * S
        passes = 2.0
    else:  # decode: one token per sequence
        tokens = B * 1
        passes = 2.0
    base = passes * n_active * tokens
    # attention quadratic term (full attention archs; window caps it)
    attn = 0.0
    if cfg.family in ("dense", "moe", "encdec"):
        eff = S if kind != "decode" else S  # decode reads S keys for 1 query
        q_tokens = tokens
        attn = passes * 2 * cfg.n_layers * q_tokens * eff * cfg.n_heads * cfg.head_dim
    elif cfg.family == "hybrid":
        w = cfg.attn_window or S
        n_attn_layers = sum(1 for k in (cfg.block_pattern * cfg.n_layers)[: cfg.n_layers] if k == "attn")
        eff = min(w, S)
        attn = passes * 2 * n_attn_layers * tokens * eff * cfg.n_heads * cfg.head_dim
    # MODEL_FLOPS convention: 6·N_active·D for training, 2·N_active·D for
    # forward-only (prefill/decode) — the "useful" model compute.
    return base + attn, passes * n_active * tokens
