"""Model assembly for every assigned architecture family.

``build_model(cfg)`` returns a :class:`Model` with functional entry points:

  * ``init(key) -> (params, specs)``      — params + logical sharding specs
  * ``loss_fn(params, batch) -> (loss, metrics)``  — per-example-weighted CE
  * ``prefill(params, batch, cache) -> (logits_last, cache)``
  * ``decode_step(params, tokens, cache) -> (logits, cache)``
  * ``init_cache(batch, max_len) -> (cache, specs)``

Layers are stacked over a leading L axis and executed with ``jax.lax.scan``
(homogeneous stacks) so the HLO stays small for 30–60-layer configs and remat
policies apply uniformly. Hybrids scan over super-blocks of the pattern.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import rglru as RG
from repro.models import ssm as SSM
from repro.models.config import ModelConfig

Params = dict
PyTree = Any


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable
    remat: str = "none"


def _stack_init(key, n: int, init_fn) -> tuple[Params, dict]:
    """vmap a per-layer init over n layers; prepend 'layer' to every spec."""
    if n == 0:
        return {}, {}
    params = jax.vmap(lambda k: init_fn(k)[0])(jax.random.split(key, n))
    _, specs = init_fn(key)  # same structure, specs are layer-local
    specs = jax.tree.map(
        lambda s: ("layer",) + tuple(s), specs, is_leaf=lambda s: isinstance(s, tuple)
    )
    return params, specs


def _maybe_remat(fn, remat: str):
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


# ---------------------------------------------------------------------------
# decoder-only LM (dense / mla / moe): shared assembly
# ---------------------------------------------------------------------------


def _init_lm(key, cfg: ModelConfig):
    keys = jax.random.split(key, 4)
    p, s = {}, {}
    p["emb"], s["emb"] = L.init_embeddings(keys[0], cfg)

    def layer_init(k):
        ks = jax.random.split(k, 6)
        lp, ls = {}, {}
        lp["ln_attn"], ls["ln_attn"] = L.init_norm(cfg)
        lp["ln_mlp"], ls["ln_mlp"] = L.init_norm(cfg)
        if cfg.attn_type == "mla":
            lp["attn"], ls["attn"] = L.init_mla(ks[0], cfg)
        else:
            lp["attn"], ls["attn"] = L.init_attention(ks[0], cfg)
        if cfg.family == "moe":
            lp["moe"], ls["moe"] = L.init_moe(ks[1], cfg)
            if cfg.n_shared_experts > 0:
                lp["shared"], ls["shared"] = L.init_mlp(
                    ks[2], cfg, d_ff=cfg.d_ff * cfg.n_shared_experts
                )
            if cfg.moe_dense_residual:
                lp["dense"], ls["dense"] = L.init_mlp(ks[3], cfg)
        else:
            lp["mlp"], ls["mlp"] = L.init_mlp(ks[1], cfg)
        return lp, ls

    p["layers"], s["layers"] = _stack_init(keys[1], cfg.n_layers, layer_init)
    p["ln_f"], s["ln_f"] = L.init_norm(cfg)
    return p, s


def _lm_layer(
    lp: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions,
    cache=None,
    window: int = 0,
):
    """One decoder layer; returns (x, new_cache_slice, aux)."""
    h = L.apply_norm(lp["ln_attn"], x, cfg.norm_type)
    if cfg.attn_type == "mla":
        attn_out, new_cache = L.mla_apply(lp["attn"], h, cfg, positions=positions, cache=cache)
    else:
        attn_out, new_cache = L.attention_apply(
            lp["attn"], h, cfg, positions=positions, cache=cache, window=window
        )
    x = x + attn_out
    h = L.apply_norm(lp["ln_mlp"], x, cfg.norm_type)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        mo, aux = L.moe_apply(lp["moe"], h, cfg, cfg.mlp_act)
        if cfg.n_shared_experts > 0:
            mo = mo + L.mlp_apply(lp["shared"], h, cfg.mlp_act)
        if cfg.moe_dense_residual:
            mo = mo + L.mlp_apply(lp["dense"], h, cfg.mlp_act)
        x = x + mo
    else:
        x = x + L.mlp_apply(lp["mlp"], h, cfg.mlp_act)
    return x, new_cache, aux


def _lm_hidden(params, cfg: ModelConfig, x, positions, remat: str):
    """Run the layer stack in full-sequence (train/prefill-no-cache) mode."""

    def body(carry, lp):
        x, aux = carry
        x, _, a = _lm_layer(lp, x, cfg, positions=positions, cache=None)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(_maybe_remat(body, remat), (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = L.apply_norm(params["ln_f"], x, cfg.norm_type)
    return x, aux


def _embed_with_prefix(params, cfg: ModelConfig, batch, dtype):
    """Token embeddings, with [vlm] patch prefix when provided."""
    x = L.embed_tokens(params["emb"], batch["tokens"], cfg, dtype)
    if cfg.modality == "vision" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(dtype), x], axis=1)
    return x


def _build_lm(cfg: ModelConfig, remat: str, xent_chunk: int) -> Model:
    dtype = jnp.dtype(cfg.dtype)

    def init(key):
        return _init_lm(key, cfg)

    def loss_fn(params, batch):
        x = _embed_with_prefix(params, cfg, batch, dtype)
        S_total = x.shape[1]
        positions = jnp.arange(S_total)
        x, aux = _lm_hidden(params, cfg, x, positions, remat)
        n_text = batch["tokens"].shape[1]
        x = x[:, S_total - n_text :]
        table = params["emb"].get("unembed", params["emb"]["embed"])
        weights = batch.get("weights", jnp.ones((x.shape[0],), jnp.float32))
        ce = L.chunked_xent_weighted(x, table, batch["labels"], weights, chunk=xent_chunk)
        loss = ce + cfg.router_aux_coef * aux / max(cfg.n_layers, 1)
        return loss, {"ce": ce, "aux": aux}

    def init_cache(batch: int, max_len: int):
        if cfg.attn_type == "mla":
            return L.init_mla_cache(cfg, batch, max_len, cfg.n_layers, dtype)
        return L.init_kv_cache(cfg, batch, max_len, cfg.n_layers, dtype)

    def _run_with_cache(params, x, cache):
        pos = cache["pos"]
        S = x.shape[1]
        # scalar pos → (S,) positions; per-slot vector pos → (B, S)
        positions = (pos[:, None] if pos.ndim == 1 else pos) + jnp.arange(S)

        def body(carry, slices):
            x, aux = carry
            lp, lc = slices
            lc = dict(lc, pos=pos)
            x, new_lc, a = _lm_layer(lp, x, cfg, positions=positions, cache=lc)
            new_lc.pop("pos")
            return (x, aux + a), new_lc

        layer_cache = {k: v for k, v in cache.items() if k != "pos"}
        (x, _), new_layer_cache = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params["layers"], layer_cache)
        )
        x = L.apply_norm(params["ln_f"], x, cfg.norm_type)
        new_cache = dict(new_layer_cache, pos=pos + S)
        return x, new_cache

    def prefill(params, batch, cache):
        x = _embed_with_prefix(params, cfg, batch, dtype)
        x, cache = _run_with_cache(params, x, cache)
        logits = L.logits_from_hidden(params["emb"], x[:, -1:], cfg)
        return logits, cache

    def decode_step(params, tokens, cache):
        x = L.embed_tokens(params["emb"], tokens, cfg, dtype)
        x, cache = _run_with_cache(params, x, cache)
        logits = L.logits_from_hidden(params["emb"], x, cfg)
        return logits, cache

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache, remat)


# ---------------------------------------------------------------------------
# SSM (mamba2): norm → SSD block → residual, no MLP (per published config)
# ---------------------------------------------------------------------------


def _build_ssm(cfg: ModelConfig, remat: str, xent_chunk: int) -> Model:
    dtype = jnp.dtype(cfg.dtype)

    def init(key):
        keys = jax.random.split(key, 3)
        p, s = {}, {}
        p["emb"], s["emb"] = L.init_embeddings(keys[0], cfg)

        def layer_init(k):
            lp, ls = {}, {}
            lp["ln"], ls["ln"] = L.init_norm(cfg)
            lp["ssd"], ls["ssd"] = SSM.init_ssd(k, cfg)
            return lp, ls

        p["layers"], s["layers"] = _stack_init(keys[1], cfg.n_layers, layer_init)
        p["ln_f"], s["ln_f"] = L.init_norm(cfg)
        return p, s

    def _hidden(params, x, cache):
        pos = None if cache is None else cache["pos"]

        def body(carry, slices):
            x = carry
            if cache is None:
                lp = slices
                h = L.apply_norm(lp["ln"], x, cfg.norm_type)
                out, _ = SSM.ssd_apply(lp["ssd"], h, cfg, cache=None)
                return x + out, None
            lp, lc = slices
            lc = dict(lc, pos=pos)
            h = L.apply_norm(lp["ln"], x, cfg.norm_type)
            out, new_lc = SSM.ssd_apply(lp["ssd"], h, cfg, cache=lc)
            new_lc.pop("pos")
            return x + out, new_lc

        if cache is None:
            x, _ = jax.lax.scan(_maybe_remat(lambda c, lp: body(c, lp), remat), x, params["layers"])
            new_cache = None
        else:
            layer_cache = {k: v for k, v in cache.items() if k != "pos"}
            x, new_layer = jax.lax.scan(body, x, (params["layers"], layer_cache))
            new_cache = dict(new_layer, pos=cache["pos"] + x.shape[1])
        x = L.apply_norm(params["ln_f"], x, cfg.norm_type)
        return x, new_cache

    def loss_fn(params, batch):
        x = L.embed_tokens(params["emb"], batch["tokens"], cfg, dtype)
        x, _ = _hidden(params, x, None)
        table = params["emb"].get("unembed", params["emb"]["embed"])
        weights = batch.get("weights", jnp.ones((x.shape[0],), jnp.float32))
        ce = L.chunked_xent_weighted(x, table, batch["labels"], weights, chunk=xent_chunk)
        return ce, {"ce": ce, "aux": jnp.zeros(())}

    def init_cache(batch: int, max_len: int):
        return SSM.init_ssd_cache(cfg, batch, cfg.n_layers)

    def prefill(params, batch, cache):
        x = L.embed_tokens(params["emb"], batch["tokens"], cfg, dtype)
        x, cache = _hidden(params, x, cache)
        logits = L.logits_from_hidden(params["emb"], x[:, -1:], cfg)
        return logits, cache

    def decode_step(params, tokens, cache):
        x = L.embed_tokens(params["emb"], tokens, cfg, dtype)
        x, cache = _hidden(params, x, cache)
        logits = L.logits_from_hidden(params["emb"], x, cfg)
        return logits, cache

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache, remat)


# ---------------------------------------------------------------------------
# hybrid (recurrentgemma): pattern-tiled super-blocks of {rec, attn} + MLP
# ---------------------------------------------------------------------------


def _build_hybrid(cfg: ModelConfig, remat: str, xent_chunk: int) -> Model:
    dtype = jnp.dtype(cfg.dtype)
    pattern = cfg.block_pattern
    plen = len(pattern)
    n_groups, n_tail = divmod(cfg.n_layers, plen)
    tail_pattern = pattern[:n_tail]

    def _block_init(kind: str):
        def init_one(k):
            ks = jax.random.split(k, 2)
            lp, ls = {}, {}
            lp["ln_mix"], ls["ln_mix"] = L.init_norm(cfg)
            lp["ln_mlp"], ls["ln_mlp"] = L.init_norm(cfg)
            if kind == "rec":
                lp["mix"], ls["mix"] = RG.init_rglru_block(ks[0], cfg)
            else:
                lp["mix"], ls["mix"] = L.init_attention(ks[0], cfg)
            lp["mlp"], ls["mlp"] = L.init_mlp(ks[1], cfg)
            return lp, ls

        return init_one

    def _group_init(k, pat):
        ks = jax.random.split(k, len(pat))
        p, s = {}, {}
        for i, kind in enumerate(pat):
            p[f"b{i}"], s[f"b{i}"] = _block_init(kind)(ks[i])
        return p, s

    def init(key):
        keys = jax.random.split(key, 4)
        p, s = {}, {}
        p["emb"], s["emb"] = L.init_embeddings(keys[0], cfg)
        p["groups"], s["groups"] = _stack_init(
            keys[1], n_groups, lambda k: _group_init(k, pattern)
        )
        if n_tail:
            p["tail"], s["tail"] = _group_init(keys[2], tail_pattern)
        p["ln_f"], s["ln_f"] = L.init_norm(cfg)
        return p, s

    def _block_apply(kind, lp, x, positions, cache):
        h = L.apply_norm(lp["ln_mix"], x, cfg.norm_type)
        if kind == "rec":
            out, new_cache = RG.rglru_block_apply(lp["mix"], h, cfg, cache=cache)
        else:
            out, new_cache = L.attention_apply(
                lp["mix"], h, cfg, positions=positions, cache=cache, window=cfg.attn_window
            )
        x = x + out
        h = L.apply_norm(lp["ln_mlp"], x, cfg.norm_type)
        x = x + L.mlp_apply(lp["mlp"], h, cfg.mlp_act)
        return x, new_cache

    def _group_apply(gp, x, positions, caches, pat):
        new_caches = {}
        for i, kind in enumerate(pat):
            c = None if caches is None else caches[f"b{i}"]
            x, nc = _block_apply(kind, gp[f"b{i}"], x, positions, c)
            if caches is not None:
                new_caches[f"b{i}"] = nc
        return x, (new_caches if caches is not None else None)

    def _hidden(params, x, positions, cache):
        if cache is None:
            def body(x, gp):
                x, _ = _group_apply(gp, x, positions, None, pattern)
                return x, None

            x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["groups"])
            if n_tail:
                x, _ = _group_apply(params["tail"], x, positions, None, tail_pattern)
            new_cache = None
        else:
            pos = cache["pos"]

            def body(x, slices):
                gp, gc = slices
                gc = jax.tree.map(lambda v: v, gc)
                for i in range(plen):
                    gc[f"b{i}"] = dict(gc[f"b{i}"], pos=pos)
                x, nc = _group_apply(gp, x, positions, gc, pattern)
                for i in range(plen):
                    nc[f"b{i}"].pop("pos")
                return x, nc

            group_cache = cache["groups"]
            x, new_groups = jax.lax.scan(body, x, (params["groups"], group_cache))
            new_cache = {"groups": new_groups, "pos": pos + x.shape[1]}
            if n_tail:
                tc = {
                    f"b{i}": dict(cache["tail"][f"b{i}"], pos=pos) for i in range(n_tail)
                }
                x, ntc = _group_apply(params["tail"], x, positions, tc, tail_pattern)
                for i in range(n_tail):
                    ntc[f"b{i}"].pop("pos")
                new_cache["tail"] = ntc
        x = L.apply_norm(params["ln_f"], x, cfg.norm_type)
        return x, new_cache

    def loss_fn(params, batch):
        x = L.embed_tokens(params["emb"], batch["tokens"], cfg, dtype)
        positions = jnp.arange(batch["tokens"].shape[1])
        x, _ = _hidden(params, x, positions, None)
        table = params["emb"].get("unembed", params["emb"]["embed"])
        weights = batch.get("weights", jnp.ones((x.shape[0],), jnp.float32))
        ce = L.chunked_xent_weighted(x, table, batch["labels"], weights, chunk=xent_chunk)
        return ce, {"ce": ce, "aux": jnp.zeros(())}

    def _one_block_cache(kind, batch, max_len):
        if kind == "rec":
            c, s = RG.init_rglru_cache(cfg, batch, 1)
        else:
            window = cfg.attn_window or max_len
            c, s = L.init_kv_cache(cfg, batch, min(window, max_len), 1, dtype)
        c = {k: (v[0] if k != "pos" else v) for k, v in c.items()}
        c.pop("pos")
        s = {k: v for k, v in s.items() if k != "pos"}
        s = jax.tree.map(lambda t: tuple(t[1:]), s, is_leaf=lambda t: isinstance(t, tuple))
        return c, s

    def init_cache(batch: int, max_len: int):
        # stacked over groups for the scan; tail separate
        caches, specs = {}, {}
        gc, gs = {}, {}
        for i, kind in enumerate(pattern):
            c, s = _one_block_cache(kind, batch, max_len)
            gc[f"b{i}"] = jax.tree.map(lambda v: jnp.broadcast_to(v[None], (n_groups,) + v.shape), c)
            gs[f"b{i}"] = jax.tree.map(
                lambda t: ("layer",) + tuple(t), s, is_leaf=lambda t: isinstance(t, tuple)
            )
        caches["groups"], specs["groups"] = gc, gs
        if n_tail:
            tc, ts = {}, {}
            for i, kind in enumerate(tail_pattern):
                tc[f"b{i}"], ts[f"b{i}"] = _one_block_cache(kind, batch, max_len)
            caches["tail"], specs["tail"] = tc, ts
        caches["pos"] = jnp.zeros((), jnp.int32)
        specs["pos"] = ()
        return caches, specs

    def prefill(params, batch, cache):
        x = L.embed_tokens(params["emb"], batch["tokens"], cfg, dtype)
        pos = cache["pos"]
        positions = (pos[:, None] if pos.ndim == 1 else pos) + jnp.arange(batch["tokens"].shape[1])
        x, cache = _hidden(params, x, positions, cache)
        logits = L.logits_from_hidden(params["emb"], x[:, -1:], cfg)
        return logits, cache

    def decode_step(params, tokens, cache):
        x = L.embed_tokens(params["emb"], tokens, cfg, dtype)
        pos = cache["pos"]
        positions = (pos[:, None] if pos.ndim == 1 else pos) + jnp.arange(tokens.shape[1])
        x, cache = _hidden(params, x, positions, cache)
        logits = L.logits_from_hidden(params["emb"], x, cfg)
        return logits, cache

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache, remat)


# ---------------------------------------------------------------------------
# encoder-decoder (whisper): bidirectional encoder + causal/cross decoder
# ---------------------------------------------------------------------------


def _build_encdec(cfg: ModelConfig, remat: str, xent_chunk: int) -> Model:
    dtype = jnp.dtype(cfg.dtype)

    def init(key):
        keys = jax.random.split(key, 5)
        p, s = {}, {}
        p["emb"], s["emb"] = L.init_embeddings(keys[0], cfg)

        def enc_layer(k):
            ks = jax.random.split(k, 2)
            lp, ls = {}, {}
            lp["ln_attn"], ls["ln_attn"] = L.init_norm(cfg)
            lp["ln_mlp"], ls["ln_mlp"] = L.init_norm(cfg)
            lp["attn"], ls["attn"] = L.init_attention(ks[0], cfg)
            lp["mlp"], ls["mlp"] = L.init_mlp(ks[1], cfg)
            return lp, ls

        def dec_layer(k):
            ks = jax.random.split(k, 3)
            lp, ls = {}, {}
            lp["ln_self"], ls["ln_self"] = L.init_norm(cfg)
            lp["ln_cross"], ls["ln_cross"] = L.init_norm(cfg)
            lp["ln_mlp"], ls["ln_mlp"] = L.init_norm(cfg)
            lp["self"], ls["self"] = L.init_attention(ks[0], cfg)
            lp["cross"], ls["cross"] = ED.init_cross_attention(ks[1], cfg)
            lp["mlp"], ls["mlp"] = L.init_mlp(ks[2], cfg)
            return lp, ls

        p["enc"], s["enc"] = _stack_init(keys[1], cfg.n_enc_layers, enc_layer)
        p["dec"], s["dec"] = _stack_init(keys[2], cfg.n_dec_layers, dec_layer)
        p["ln_enc"], s["ln_enc"] = L.init_norm(cfg)
        p["ln_dec"], s["ln_dec"] = L.init_norm(cfg)
        return p, s

    def encode(params, frames):
        x = frames.astype(dtype) + ED.sinusoid_pos(frames.shape[1], cfg.d_model, dtype)[None]
        positions = jnp.arange(frames.shape[1])

        def body(x, lp):
            h = L.apply_norm(lp["ln_attn"], x, cfg.norm_type)
            a, _ = L.attention_apply(
                lp["attn"], h, cfg, positions=positions, bidirectional=True, use_rope=False
            )
            x = x + a
            h = L.apply_norm(lp["ln_mlp"], x, cfg.norm_type)
            return x + L.mlp_apply(lp["mlp"], h, cfg.mlp_act), None

        x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["enc"])
        return L.apply_norm(params["ln_enc"], x, cfg.norm_type)

    def _dec_layer(lp, x, positions, self_cache, ck, cv):
        h = L.apply_norm(lp["ln_self"], x, cfg.norm_type)
        a, new_cache = L.attention_apply(
            lp["self"], h, cfg, positions=positions, cache=self_cache, use_rope=False
        )
        x = x + a
        h = L.apply_norm(lp["ln_cross"], x, cfg.norm_type)
        x = x + ED.cross_attention_apply(lp["cross"], h, ck, cv, cfg)
        h = L.apply_norm(lp["ln_mlp"], x, cfg.norm_type)
        return x + L.mlp_apply(lp["mlp"], h, cfg.mlp_act), new_cache

    def decode_hidden(params, tokens, memory_or_kv, cache):
        x = L.embed_tokens(params["emb"], tokens, cfg, dtype)
        S = tokens.shape[1]
        if cache is None:
            x = x + ED.sinusoid_pos(S, cfg.d_model, dtype)[None]
            positions = jnp.arange(S)
        else:
            pos0 = cache["pos"]
            pe = ED.sinusoid_pos(cfg.dec_max_len, cfg.d_model, dtype)
            x = x + jax.lax.dynamic_slice_in_dim(pe, pos0, S, 0)[None]
            positions = pos0 + jnp.arange(S)

        if cache is None:
            memory = memory_or_kv

            def body(x, lp):
                ck, cv = ED.cross_kv(lp["cross"], memory)
                x, _ = _dec_layer(lp, x, positions, None, ck, cv)
                return x, None

            x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["dec"])
            new_cache = None
        else:
            def body(x, slices):
                lp, lc = slices
                sc = dict(lc["self"], pos=cache["pos"])
                x, nsc = _dec_layer(lp, x, positions, sc, lc["cross_k"], lc["cross_v"])
                nsc.pop("pos")
                return x, {"self": nsc, "cross_k": lc["cross_k"], "cross_v": lc["cross_v"]}

            layer_cache = {k: v for k, v in cache.items() if k != "pos"}
            x, new_layer = jax.lax.scan(body, x, (params["dec"], layer_cache))
            new_cache = dict(new_layer, pos=cache["pos"] + S)
        return L.apply_norm(params["ln_dec"], x, cfg.norm_type), new_cache

    def loss_fn(params, batch):
        memory = encode(params, batch["frames"])
        x, _ = decode_hidden(params, batch["tokens"], memory, None)
        table = params["emb"].get("unembed", params["emb"]["embed"])
        weights = batch.get("weights", jnp.ones((x.shape[0],), jnp.float32))
        ce = L.chunked_xent_weighted(x, table, batch["labels"], weights, chunk=xent_chunk)
        return ce, {"ce": ce, "aux": jnp.zeros(())}

    def init_cache(batch: int, max_len: int, enc_len: int | None = None):
        enc_len = enc_len or max_len
        dec_len = cfg.dec_max_len
        Ld = cfg.n_dec_layers
        c = {
            "self": {
                "k": jnp.zeros((Ld, batch, dec_len, cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((Ld, batch, dec_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            },
            "cross_k": jnp.zeros((Ld, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "cross_v": jnp.zeros((Ld, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
        kvspec = ("layer", "batch", None, "kv", None)
        seq = "seq_kv" if cfg.decode_seq_shard else None
        crossspec = ("layer", "batch", seq, "kv", None)
        s = {
            "self": {"k": kvspec, "v": kvspec},
            "cross_k": crossspec,
            "cross_v": crossspec,
            "pos": (),
        }
        return c, s

    def prefill(params, batch, cache):
        """Encode frames, install cross-KV, prefill the decoder prefix."""
        memory = encode(params, batch["frames"])

        def per_layer_kv(lp):
            return ED.cross_kv(lp["cross"], memory)

        ck, cv = jax.vmap(per_layer_kv)(params["dec"])
        cache = dict(cache, cross_k=ck.astype(dtype), cross_v=cv.astype(dtype))
        logits, cache = decode_step(params, batch["tokens"], cache)
        return logits[:, -1:], cache

    def decode_step(params, tokens, cache):
        x, cache = decode_hidden(params, tokens, None, cache)
        logits = L.logits_from_hidden(params["emb"], x, cfg)
        return logits, cache

    return Model(cfg, init, loss_fn, prefill, decode_step, init_cache, remat)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------


def shapes_and_specs(model: Model):
    """(params ShapeDtypeStructs, logical specs) without allocating params.

    ``model.init`` returns (params, specs); specs are plain-Python tuples, so
    we capture them by side effect while eval_shape traces the array part.
    """
    box = {}

    def f(key):
        p, s = model.init(key)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["specs"]


def build_model(cfg: ModelConfig, remat: str = "none", xent_chunk: int = 512) -> Model:
    if cfg.family in ("dense", "moe"):
        return _build_lm(cfg, remat, xent_chunk)
    if cfg.family == "ssm":
        return _build_ssm(cfg, remat, xent_chunk)
    if cfg.family == "hybrid":
        return _build_hybrid(cfg, remat, xent_chunk)
    if cfg.family == "encdec":
        return _build_encdec(cfg, remat, xent_chunk)
    raise ValueError(f"unknown family {cfg.family}")
