"""RG-LRU recurrent block (Griffin / RecurrentGemma) + local-attention hybrid.

The recurrent block runs a Real-Gated Linear Recurrent Unit:

    r_t = σ(W_a x_t + b_a)           (recurrence gate)
    i_t = σ(W_x x_t + b_x)           (input gate)
    a_t = exp(−c · r_t · softplus(Λ))  ∈ (0,1)         (c = 8)
    h_t = a_t h_{t-1} + √(1−a_t²) · (i_t ⊙ x_t)

Prefill uses ``jax.lax.associative_scan`` (log-depth), decode is one step —
constant state, so ``long_500k`` is exact and cheap for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

Params = dict
RGLRU_C = 8.0


def init_rglru_block(key, cfg: ModelConfig) -> tuple[Params, dict]:
    """Full Griffin recurrent block: gate branch ⊗ (conv → RG-LRU) branch."""
    from repro.models.layers import dense_init

    W = cfg.lru_width
    ks = jax.random.split(key, 6)
    # Λ init so that a ∈ (0.9, 0.999) at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[0], (W,), minval=0.9**2, maxval=0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RGLRU_C))  # softplus⁻¹(−log u / c)
    p = {
        "gate_proj": dense_init(ks[1], cfg.d_model, (W,)),
        "rec_proj": dense_init(ks[2], cfg.d_model, (W,)),
        "conv_w": 0.1 * jax.random.normal(ks[3], (cfg.conv_kernel, W)),
        "conv_b": jnp.zeros((W,)),
        "wa": dense_init(ks[4], W, (W,)),
        "ba": jnp.zeros((W,)),
        "wx": dense_init(ks[5], W, (W,)),
        "bx": jnp.zeros((W,)),
        "lam": lam,
        # fold_in(key, 7) is a derivation disjoint from split(key, 6) above;
        # switching to split(key, 7) would reseed every weight in the block
        "out_proj": dense_init(jax.random.fold_in(key, 7), W, (cfg.d_model,)),  # noqa: AL001
    }
    s = {
        "gate_proj": ("embed", "lru"),
        "rec_proj": ("embed", "lru"),
        "conv_w": (None, "lru"),
        "conv_b": ("lru",),
        "wa": (None, "lru"),
        "ba": ("lru",),
        "wx": (None, "lru"),
        "bx": ("lru",),
        "lam": ("lru",),
        "out_proj": ("lru", "embed"),
    }
    return p, s


def _rglru_scan(xw: jax.Array, params: Params, h0: jax.Array, scan_dtype=jnp.float32):
    """xw: (B,T,W) post-conv inputs. Returns (y (B,T,W), h_T).

    ``scan_dtype``: dtype of the associative-scan carry. The gates/decay are
    always computed in f32; carrying the scan in bf16 halves the dominant
    HBM traffic of the (B,T,W) scan intermediates (§Perf knob for the
    memory-bound recurrentgemma train cell).
    """
    r = jax.nn.sigmoid((xw @ params["wa"].astype(xw.dtype) + params["ba"].astype(xw.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid((xw @ params["wx"].astype(xw.dtype) + params["bx"].astype(xw.dtype)).astype(jnp.float32))
    log_a = -RGLRU_C * r * jax.nn.softplus(params["lam"])  # (B,T,W) ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, None))
    b = beta * (i * xw.astype(jnp.float32))

    # prepend h0 as a pseudo-step: h_t = a_t h_{t-1} + b_t with h_0 given
    a_all = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1).astype(scan_dtype)
    b_all = jnp.concatenate([h0.astype(jnp.float32)[:, None], b], axis=1).astype(scan_dtype)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    A, Bv = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    h = Bv[:, 1:]  # (B,T,W)
    return h.astype(xw.dtype), h[:, -1].astype(jnp.float32)


def rglru_block_apply(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Params | None = None,
) -> tuple[jax.Array, Params | None]:
    B, T, _ = x.shape
    gate = jax.nn.gelu(x @ params["gate_proj"].astype(x.dtype), approximate=True)
    xr = x @ params["rec_proj"].astype(x.dtype)

    # causal depthwise conv with history tail
    k = params["conv_w"].shape[0]
    tail = cache["conv"].astype(x.dtype) if cache is not None else jnp.zeros((B, k - 1, xr.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, xr], axis=1)
    xw = sum(xp[:, i : i + T, :] * params["conv_w"][i].astype(x.dtype) for i in range(k))
    xw = xw + params["conv_b"].astype(x.dtype)
    new_tail = xp[:, -(k - 1) :, :] if k > 1 else tail

    h0 = cache["h"] if cache is not None else jnp.zeros((B, xr.shape[-1]), jnp.float32)
    if T == 1 and cache is not None:
        r = jax.nn.sigmoid((xw @ params["wa"].astype(x.dtype) + params["ba"].astype(x.dtype)).astype(jnp.float32))
        i = jax.nn.sigmoid((xw @ params["wx"].astype(x.dtype) + params["bx"].astype(x.dtype)).astype(jnp.float32))
        log_a = -RGLRU_C * r[:, 0] * jax.nn.softplus(params["lam"])
        a = jnp.exp(log_a)
        beta = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, None))
        h = a * h0.astype(jnp.float32) + beta * (i[:, 0] * xw[:, 0].astype(jnp.float32))
        y = h[:, None].astype(x.dtype)
        hT = h
    else:
        y, hT = _rglru_scan(xw, params, h0, scan_dtype=jnp.dtype(cfg.scan_dtype))

    out = (y * gate) @ params["out_proj"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_tail.astype(cache["conv"].dtype), "h": hT.astype(jnp.float32), "pos": cache["pos"] + T}
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, n_layers: int):
    W = cfg.lru_width
    params = {
        "conv": jnp.zeros((n_layers, batch, cfg.conv_kernel - 1, W), jnp.bfloat16),
        "h": jnp.zeros((n_layers, batch, W), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }
    specs = {
        "conv": ("layer", "batch", None, "lru"),
        "h": ("layer", "batch", "lru"),
        "pos": (),
    }
    return params, specs
