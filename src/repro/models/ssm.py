"""Mamba2 / SSD (state-space duality) block — attention-free sequence mixing.

Chunked SSD algorithm (Dao & Gu 2024) with log-space decay accumulation:
within a chunk the quadratic "attention-like" form runs on the MXU; across
chunks a small recurrent state (H, P, N) is passed — O(T) time, O(1) state,
which is exactly why ``long_500k`` runs for this family. The within-chunk
einsums are mirrored by the Pallas kernel in ``repro.kernels.ssd``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

Params = dict


def ssm_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim, d_state)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    return d_inner, d_inner // cfg.ssm_headdim, cfg.ssm_headdim, cfg.ssm_state


def init_ssd(key, cfg: ModelConfig) -> tuple[Params, dict]:
    d_inner, H, P, N = ssm_dims(cfg)
    G = cfg.ssm_ngroups
    conv_dim = d_inner + 2 * G * N
    ks = jax.random.split(key, 6)
    from repro.models.layers import dense_init

    p = {
        "in_proj": dense_init(ks[0], cfg.d_model, (2 * d_inner + 2 * G * N + H,)),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim)),
        "conv_b": jnp.zeros((conv_dim,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D": jnp.ones((H,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))),  # softplus⁻¹(0.01)
        "norm_scale": jnp.ones((d_inner,)),
        "out_proj": dense_init(ks[2], d_inner, (cfg.d_model,)),
    }
    s = {
        "in_proj": ("embed", "heads"),
        "conv_w": (None, "heads"),
        "conv_b": ("heads",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm_scale": ("heads",),
        "out_proj": ("heads", "embed"),
    }
    return p, s


def _split_in_proj(cfg: ModelConfig, proj: jax.Array):
    d_inner, H, P, N = ssm_dims(cfg)
    G = cfg.ssm_ngroups
    z, xBC, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array | None):
    """Depthwise causal conv; `tail` is the (k-1)-step history for decode/resume."""
    k = w.shape[0]
    if tail is None:
        pad = jnp.zeros((xBC.shape[0], k - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = tail.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)  # (B, T+k-1, C)
    out = sum(xp[:, i : i + xBC.shape[1], :] * w[i].astype(xBC.dtype) for i in range(k))
    new_tail = xp[:, -(k - 1) :, :] if k > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(out + b.astype(xBC.dtype)), new_tail


def _ssd_chunked(xh, dt, A, Bm, Cm, state0, chunk: int):
    """Chunked SSD scan.

    xh: (B,T,H,P) inputs; dt: (B,T,H) positive steps; A: (H,) negative;
    Bm/Cm: (B,T,G,N) with G=1 broadcast over H. state0: (B,H,P,N).
    Returns (y (B,T,H,P), state_T).
    """
    Bt, T, H, P = xh.shape
    N = Bm.shape[-1]
    nc = T // chunk
    xc = xh.reshape(Bt, nc, chunk, H, P)
    dtc = dt.reshape(Bt, nc, chunk, H)
    Bc = jnp.broadcast_to(Bm.reshape(Bt, nc, chunk, -1, N)[:, :, :, :1, :], (Bt, nc, chunk, 1, N))
    Cc = jnp.broadcast_to(Cm.reshape(Bt, nc, chunk, -1, N)[:, :, :, :1, :], (Bt, nc, chunk, 1, N))

    def scan_chunk(state, inp):
        xq, dtq, Bq, Cq = inp  # (B,chunk,H,P), (B,chunk,H), (B,chunk,1,N) ×2
        la = jnp.cumsum(dtq * A, axis=1)  # (B,chunk,H) log-decay prefix (≤0 slope)
        # intra-chunk quadratic form: scores_ij = (C_i·B_j)·exp(la_i−la_j), j≤i
        cb = jnp.einsum("bign,bjgn->bij", Cq, Bq)  # G=1 → head-shared
        diff = la[:, :, None, :] - la[:, None, :, :]  # (B,i,j,H)
        mask = jnp.tril(jnp.ones((xq.shape[1], xq.shape[1]), bool))
        decay = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        scores = cb[:, :, :, None] * decay  # (B,i,j,H)
        xdt = xq * dtq[..., None]  # (B,chunk,H,P)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores.astype(xq.dtype), xdt)
        # inter-chunk contribution from incoming state
        y_inter = jnp.einsum("bign,bhpn,bih->bihp",
                             Cq.astype(xq.dtype),
                             state.astype(xq.dtype),
                             jnp.exp(la).astype(xq.dtype))
        # state update
        tail = jnp.exp(la[:, -1:, :] - la)  # (B,chunk,H) decay to chunk end
        state_add = jnp.einsum("bjgn,bjhp,bjh->bhpn", Bq.astype(xq.dtype), xdt, tail.astype(xq.dtype))
        state_new = state * jnp.exp(la[:, -1, :])[:, :, None, None].astype(state.dtype) + state_add.astype(state.dtype)
        return state_new, y_intra + y_inter

    # scan over chunks (leading axis nc)
    inps = (
        xc.swapaxes(0, 1),
        dtc.swapaxes(0, 1),
        Bc.swapaxes(0, 1),
        Cc.swapaxes(0, 1),
    )
    state_T, ys = jax.lax.scan(scan_chunk, state0, inps)
    y = ys.swapaxes(0, 1).reshape(Bt, T, H, P)
    return y, state_T


def ssd_apply(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Params | None = None,
) -> tuple[jax.Array, Params | None]:
    """Full Mamba2 block: in_proj → conv → SSD → gated norm → out_proj."""
    Bt, T, _ = x.shape
    d_inner, H, P, N = ssm_dims(cfg)
    proj = x @ params["in_proj"].astype(x.dtype)
    z, xBC, dt = _split_in_proj(cfg, proj)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,T,H)
    A = -jnp.exp(params["A_log"])  # (H,) negative

    conv_tail = cache["conv"] if cache is not None else None
    xBC, new_tail = _causal_conv(xBC, params["conv_w"], params["conv_b"], conv_tail)
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + cfg.ssm_ngroups * N], axis=-1)
    xh = xs.reshape(Bt, T, H, P)
    Bm = Bm.reshape(Bt, T, cfg.ssm_ngroups, N)
    Cm = Cm.reshape(Bt, T, cfg.ssm_ngroups, N)

    state0 = (
        cache["state"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((Bt, H, P, N), jnp.float32)
    )

    if T == 1 and cache is not None:
        # decode: one recurrent step, no chunking
        a = jnp.exp(dt[:, 0] * A)  # (B,H)
        Bq = jnp.broadcast_to(Bm[:, 0, :1], (Bt, 1, N))
        Cq = jnp.broadcast_to(Cm[:, 0, :1], (Bt, 1, N))
        upd = jnp.einsum("bgn,bhp,bh->bhpn", Bq.astype(jnp.float32), xh[:, 0].astype(jnp.float32), dt[:, 0])
        state = state0 * a[:, :, None, None] + upd
        y = jnp.einsum("bgn,bhpn->bhp", Cq.astype(jnp.float32), state).astype(x.dtype)
        y = y[:, None]  # (B,1,H,P)
    else:
        chunk = min(cfg.ssm_chunk, T)
        assert T % chunk == 0, f"T={T} must be divisible by chunk={chunk}"
        y, state = _ssd_chunked(xh, dt, A, Bm, Cm, state0, chunk)

    y = y + params["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(Bt, T, d_inner)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + 1e-6)
    y = (yf * params["norm_scale"]).astype(x.dtype)
    out = y @ params["out_proj"].astype(x.dtype)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_tail, "state": state.astype(cache["state"].dtype), "pos": cache["pos"] + T}
    return out, new_cache


def init_ssd_cache(cfg: ModelConfig, batch: int, n_layers: int, dtype=jnp.float32):
    d_inner, H, P, N = ssm_dims(cfg)
    conv_dim = d_inner + 2 * cfg.ssm_ngroups * N
    params = {
        "conv": jnp.zeros((n_layers, batch, cfg.conv_kernel - 1, conv_dim), dtype),
        "state": jnp.zeros((n_layers, batch, H, P, N), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    specs = {
        "conv": ("layer", "batch", None, "heads"),
        "state": ("layer", "batch", "heads", None, None),
        "pos": (),
    }
    return params, specs
