from repro.models.config import ModelConfig
from repro.models.transformer import Model, build_model

__all__ = ["ModelConfig", "Model", "build_model"]
