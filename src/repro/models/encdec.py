"""Encoder-decoder pieces (whisper-style): cross-attention + sinusoidal pos.

The audio frontend (log-mel + conv downsampling) is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (B, T_frames, d_model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import _sdpa, dense_init

Params = dict


def sinusoid_pos(T: int, D: int, dtype=jnp.float32) -> jax.Array:
    pos = np.arange(T)[:, None]
    div = np.exp(np.arange(0, D, 2) * (-np.log(10000.0) / D))
    pe = np.zeros((T, D), np.float32)
    pe[:, 0::2] = np.sin(pos * div)
    pe[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(pe, dtype)


def init_cross_attention(key, cfg: ModelConfig) -> tuple[Params, dict]:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": dense_init(ks[0], d, (cfg.n_heads, cfg.head_dim)),
        "wk": dense_init(ks[1], d, (cfg.n_kv_heads, cfg.head_dim)),
        "wv": dense_init(ks[2], d, (cfg.n_kv_heads, cfg.head_dim)),
        "wo": dense_init(ks[3], cfg.q_dim, (d,)).reshape(cfg.n_heads, cfg.head_dim, d),
    }
    s = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv", None),
        "wv": ("embed", "kv", None),
        "wo": ("heads", None, "embed"),
    }
    return p, s


def cross_kv(params: Params, memory: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V from encoder output (done once)."""
    k = jnp.einsum("btd,dhk->bthk", memory, params["wk"].astype(memory.dtype))
    v = jnp.einsum("btd,dhk->bthk", memory, params["wv"].astype(memory.dtype))
    return k, v


def cross_attention_apply(
    params: Params, x: jax.Array, k: jax.Array, v: jax.Array, cfg: ModelConfig
) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    out = _sdpa(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
