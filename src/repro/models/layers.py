"""Functional neural-net layers shared by all assigned architectures.

Conventions:
  * Parameters are nested dicts of jnp arrays; every init function returns
    ``(params, specs)`` where specs mirrors params with tuples of *logical*
    axis names ('embed', 'heads', 'kv', 'mlp', 'vocab', 'expert', 'state',
    'layer', None). ``repro.distributed.sharding`` resolves these to mesh
    PartitionSpecs.
  * Activations are bf16 (configurable); softmax / norms / router run fp32.
  * All sequence ops support three modes: train (full causal), prefill
    (causal, returns cache), decode (single token against a cache).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

Params = dict
Specs = dict

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_shape, dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init."""
    shape = (in_dim,) + tuple(np.atleast_1d(out_shape))
    scale = float(1.0 / np.sqrt(in_dim))
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, (vocab, dim), dtype) * 0.02


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig) -> tuple[Params, Specs]:
    if cfg.norm_type == "nonparametric_ln":
        return {}, {}
    if cfg.norm_type == "layernorm":
        return (
            {"scale": jnp.ones((cfg.d_model,)), "bias": jnp.zeros((cfg.d_model,))},
            {"scale": ("embed",), "bias": ("embed",)},
        )
    return {"scale": jnp.ones((cfg.d_model,))}, {"scale": ("embed",)}


def apply_norm(params: Params, x: jax.Array, norm_type: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
        return (xf * params["scale"].astype(jnp.float32)).astype(x.dtype)
    # layer norm (parametric or not)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    if norm_type == "layernorm":
        xf = xf * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return xf.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding (half-rotation / llama convention)
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (S,) or (B, S) int."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (S, half) or (B,S,half)
    if ang.ndim == 2:  # (S, half) → broadcast batch
        ang = ang[None]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA / MQA, optional local window, train/prefill/decode)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> tuple[Params, Specs]:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": dense_init(ks[0], d, (cfg.n_heads, cfg.head_dim)),
        "wk": dense_init(ks[1], d, (cfg.n_kv_heads, cfg.head_dim)),
        "wv": dense_init(ks[2], d, (cfg.n_kv_heads, cfg.head_dim)),
        "wo": dense_init(ks[3], cfg.q_dim, (d,)).reshape(cfg.n_heads, cfg.head_dim, d),
    }
    s = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv", None),
        "wv": ("embed", "kv", None),
        "wo": ("heads", None, "embed"),
    }
    return p, s


def _sdpa(q, k, v, mask, logits_softcap: float = 0.0):
    """Reference scaled-dot-product attention (fp32 softmax).

    q: (B, S, H, hd), k/v: (B, T, KV, hd) — H % KV == 0 (GQA broadcast).
    mask: (B, 1, S, T) or (S, T) boolean, True = attend.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    groups = H // KV
    qg = q.reshape(B, S, KV, groups, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / float(np.sqrt(hd))
    if logits_softcap > 0:
        scores = logits_softcap * jnp.tanh(scores / logits_softcap)
    if mask.ndim == 2:
        mask = mask[None, None, None]  # (1,1,1,S,T)
    else:
        mask = mask[:, :, None]  # (B,1,1,S,T) → align kv/group dims
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, H, hd)


def causal_mask(S: int, T: int, offset: int = 0, window: int = 0) -> jax.Array:
    """(S, T) boolean mask: query i attends key j iff j ≤ i+offset (and within window)."""
    qpos = jnp.arange(S)[:, None] + offset
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def blocked_causal_attention(q, k, v, block: int = 1024, logits_softcap: float = 0.0):
    """Full causal attention without the S×S score matrix (flash-style, XLA).

    Outer scan over q blocks; inner fori over k blocks up to the diagonal
    with an online-softmax accumulator — peak score memory is (H, bq, bk)
    instead of (H, S, S). This is the jnp twin of kernels/flash_attention
    (used on the XLA path for long-prefill cells; same math, same oracle).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    groups = H // KV
    pad = (-S) % block
    if pad:
        zq = jnp.zeros((B, pad, H, hd), q.dtype)
        zkv = jnp.zeros((B, pad, KV, hd), k.dtype)
        q = jnp.concatenate([q, zq], 1)
        k = jnp.concatenate([k, zkv], 1)
        v = jnp.concatenate([v, zkv], 1)
    Sp = S + pad
    nb = Sp // block
    scale = float(1.0 / np.sqrt(hd))
    qb = q.reshape(B, nb, block, KV, groups, hd).swapaxes(0, 1)  # (nb,B,bq,KV,G,hd)
    kb = k.reshape(B, nb, block, KV, hd)
    vb = v.reshape(B, nb, block, KV, hd)

    def q_block(carry, inp):
        qi, iq = inp  # (B,bq,KV,G,hd), scalar block index

        def kv_step(j, state):
            acc, m, l = state
            kj = jax.lax.dynamic_index_in_dim(kb, j, axis=1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vb, j, axis=1, keepdims=False)
            s = jnp.einsum("bqkgh,btkh->bkgqt", qi, kj).astype(jnp.float32) * scale
            if logits_softcap > 0:
                s = logits_softcap * jnp.tanh(s / logits_softcap)
            qpos = iq * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
            kpos = j * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
            s = jnp.where((kpos <= qpos)[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, -1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, -1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(vj.dtype), vj
            ).astype(jnp.float32)
            return acc, m_new, l_new

        acc0 = jnp.zeros((B, KV, groups, block, hd), jnp.float32)
        m0 = jnp.full((B, KV, groups, block, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, groups, block, 1), jnp.float32)
        acc, m, l = jax.lax.fori_loop(0, iq + 1, kv_step, (acc0, m0, l0))
        out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)  # (B,KV,G,bq,hd)
        return carry, out.transpose(0, 3, 1, 2, 4)  # (B,bq,KV,G,hd)

    _, outs = jax.lax.scan(q_block, None, (qb, jnp.arange(nb)))
    out = outs.swapaxes(0, 1).reshape(B, Sp, H, hd)
    return out[:, :S]


def local_attention_chunked(q, k, v, window: int, logits_softcap: float = 0.0):
    """Banded (local) causal attention without the S×S score matrix.

    Splits S into window-sized chunks; chunk i attends to chunks i−1 and i
    with the exact band mask — peak score memory W×2W per chunk instead of
    S×S (the recurrentgemma-32k-prefill enabler). Scan over chunks.
    """
    B, S, H, hd = q.shape
    W = window
    pad = (-S) % W
    if pad:
        zq = jnp.zeros((B, pad, H, hd), q.dtype)
        zkv = jnp.zeros((B, pad, k.shape[2], hd), k.dtype)
        q = jnp.concatenate([q, zq], 1)
        k = jnp.concatenate([k, zkv], 1)
        v = jnp.concatenate([v, zkv], 1)
    Sp = S + pad
    nc = Sp // W
    KV = k.shape[2]

    def chunks(a):
        return a.reshape(B, nc, W, a.shape[2], hd).swapaxes(0, 1)  # (nc,B,W,·,hd)

    qc, kc, vc = chunks(q), chunks(k), chunks(v)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:1]), kc[:-1]], 0)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:1]), vc[:-1]], 0)

    # band mask within a (W, 2W) window: key j (absolute offset j−W relative
    # to the chunk start) visible to query i iff 0 ≤ i−(j−W) < W.
    qpos = jnp.arange(W)[:, None]
    kpos = jnp.arange(2 * W)[None, :] - W
    band = (kpos <= qpos) & (kpos > qpos - W)

    def body(_, inp):
        qi, ki, vi, kp, vp, first = inp
        kk = jnp.concatenate([kp, ki], 1)  # (B, 2W, KV, hd)
        vv = jnp.concatenate([vp, vi], 1)
        mask = band & ~(first & (kpos < 0))  # chunk 0 has no predecessor
        out = _sdpa(qi, kk, vv, mask, logits_softcap)
        return None, out

    first_flags = jnp.zeros((nc, 1, 1), bool).at[0].set(True)
    _, outs = jax.lax.scan(body, None, (qc, kc, vc, k_prev, v_prev, first_flags))
    out = outs.swapaxes(0, 1).reshape(B, Sp, H, hd)
    return out[:, :S]


def _ring_slot_positions(total: jax.Array, W: int) -> jax.Array:
    """Absolute position held by each ring slot after `total` writes."""
    i = jnp.arange(W)
    return total - 1 - ((total - 1 - i) % W)


def _vector_pos_decode(params, q, k, v, cache, cfg, *, window: int = 0):
    """Single-token decode with per-row cache positions (continuous batching).

    q/k/v: (B, 1, H|KV, hd); cache['pos']: (B,) int32. Supports linear caches
    (scatter at pos_b) and ring caches (scatter at pos_b % W, window mask).
    """
    B = q.shape[0]
    pos = cache["pos"]  # (B,)
    W_cache = cache["k"].shape[1]
    ring = window > 0 and W_cache == window
    rows = jnp.arange(B)
    slots = (pos % window) if ring else pos
    K = cache["k"].at[rows, slots].set(k[:, 0].astype(cache["k"].dtype))
    V = cache["v"].at[rows, slots].set(v[:, 0].astype(cache["v"].dtype))
    if ring:
        abs_pos = jax.vmap(lambda t: _ring_slot_positions(t, window))(pos + 1)  # (B, W)
        mask = (abs_pos >= 0) & (abs_pos <= pos[:, None]) & (abs_pos > pos[:, None] - window)
    else:
        kpos = jnp.arange(W_cache)[None, :]
        mask = kpos <= pos[:, None]
        if window > 0:
            mask &= kpos > pos[:, None] - window
    out = _sdpa(
        q, K.astype(q.dtype), V.astype(q.dtype), mask[:, None, None, :], cfg.logits_softcap
    )
    return out, {"k": K, "v": V, "pos": pos + 1}


def attention_apply(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Params | None = None,
    window: int = 0,
    bidirectional: bool = False,
    use_rope: bool = True,
) -> tuple[jax.Array, Params | None]:
    """Returns (out, new_cache).

    cache = {'k','v','pos'}: linear buffer (global attention) or ring buffer
    (local attention, cache length == window).
    """
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        if window > 0 and S > window:
            out = local_attention_chunked(q, k, v, window, cfg.logits_softcap)
        elif (
            cfg.prefill_flash_block
            and not bidirectional
            and window == 0
            and S > cfg.prefill_flash_block
        ):
            out = blocked_causal_attention(
                q, k, v, cfg.prefill_flash_block, cfg.logits_softcap
            )
        else:
            mask = (
                jnp.ones((S, S), bool)
                if bidirectional
                else causal_mask(S, S, 0, window)
            )
            out = _sdpa(q, k, v, mask, cfg.logits_softcap)
        new_cache = None
    else:
        pos = cache["pos"]  # scalar int32, or (B,) per-slot positions (serving)
        if getattr(pos, "ndim", 0) == 1 and S == 1:
            out, new_cache = _vector_pos_decode(
                params, q, k, v, cache, cfg, window=window
            )
            out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
            return out, new_cache
        W_cache = cache["k"].shape[1]
        ring = window > 0 and W_cache == window
        if ring and S >= window:
            # prefill-from-empty into a ring cache: local attention over the
            # full sequence, then park the last W keys at slots p % W.
            out = local_attention_chunked(q, k, v, window, cfg.logits_softcap)
            tail_k = k[:, -window:].astype(cache["k"].dtype)
            tail_v = v[:, -window:].astype(cache["v"].dtype)
            shift = (pos + S) % window  # slot of tail element 0 is (pos+S-W) % W
            K = jnp.roll(tail_k, shift, axis=1)
            V = jnp.roll(tail_v, shift, axis=1)
            new_cache = {"k": K, "v": V, "pos": pos + S}
        elif ring:
            # incremental write(s) at slots (pos+i) % W, masked by absolute pos
            slots = (pos + jnp.arange(S)) % window
            K = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
            V = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
            total = pos + S
            abs_pos = _ring_slot_positions(total, window)[None, :]  # (1, W)
            qpos = pos + jnp.arange(S)[:, None]
            mask = (abs_pos >= 0) & (abs_pos <= qpos) & (abs_pos > qpos - window)
            out = _sdpa(q, K.astype(x.dtype), V.astype(x.dtype), mask, cfg.logits_softcap)
            new_cache = {"k": K, "v": V, "pos": total}
        else:
            zero = jnp.zeros((), pos.dtype)  # match pos: x64 would
            # otherwise promote the literal starts to int64 against int32 pos
            K = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (zero, pos, zero, zero)
            )
            V = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (zero, pos, zero, zero)
            )
            if cfg.decode_seq_shard:
                # §Perf flash-decode: keep the KV cache sharded over the model
                # axis along its *sequence* dim; GSPMD then computes partial
                # softmax stats per shard and combines with tiny all-reduces
                # instead of all-gathering the cache.
                from repro.distributed.sharding import constrain

                K = constrain(K, "batch", "model", None, None)
                V = constrain(V, "batch", "model", None, None)
            if cfg.prefill_flash_block and window == 0 and S > cfg.prefill_flash_block:
                # long prefill-from-empty: blocked online-softmax over the
                # *fresh* k/v (cache holds nothing before `pos`) — avoids the
                # (S, T) score buffer entirely (§Perf: memory-bound prefill).
                out = blocked_causal_attention(
                    q, k, v, cfg.prefill_flash_block, cfg.logits_softcap
                )
            else:
                T = K.shape[1]
                kpos = jnp.arange(T)[None, :]
                qpos = pos + jnp.arange(S)[:, None]
                mask = kpos <= qpos
                if window > 0:
                    mask &= kpos > qpos - window
                out = _sdpa(q, K.astype(x.dtype), V.astype(x.dtype), mask, cfg.logits_softcap)
            new_cache = {"k": K, "v": V, "pos": pos + S}
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int, dtype=jnp.bfloat16):
    """Stacked-over-layers KV cache pytree (zeros) + matching logical specs.

    The sequence dim carries the 'seq_kv' logical name: unsharded by default;
    the flash-decode §Perf variant maps it to the model axis.
    """
    kv = lambda: jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype)
    seq = "seq_kv" if cfg.decode_seq_shard else None
    spec = ("layer", "batch", seq, "kv", None)
    params = {"k": kv(), "v": kv(), "pos": jnp.zeros((), jnp.int32)}
    specs = {"k": spec, "v": spec, "pos": ()}
    return params, specs


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek family)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> tuple[Params, Specs]:
    ks = jax.random.split(key, 8)
    d, H = cfg.d_model, cfg.n_heads
    r, dc = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    p = {
        "wdq": dense_init(ks[0], d, (r,)),
        "q_norm": jnp.ones((r,)),
        "wuq": dense_init(ks[1], r, (H, nope + rdim)),
        "wdkv": dense_init(ks[2], d, (dc,)),
        "kv_norm": jnp.ones((dc,)),
        "wkr": dense_init(ks[3], d, (rdim,)),     # shared rope key (per token)
        "wuk": dense_init(ks[4], dc, (H, nope)),
        "wuv": dense_init(ks[5], dc, (H, vdim)),
        "wo": dense_init(ks[6], H * vdim, (d,)).reshape(H, vdim, d),
    }
    s = {
        "wdq": ("embed", None),
        "q_norm": (None,),
        "wuq": (None, "heads", None),
        "wdkv": ("embed", None),
        "kv_norm": (None,),
        "wkr": ("embed", None),
        "wuk": (None, "heads", None),
        "wuv": (None, "heads", None),
        "wo": ("heads", None, "embed"),
    }
    return p, s


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (xf * scale.astype(jnp.float32)).astype(x.dtype)


def mla_apply(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: Params | None = None,
) -> tuple[jax.Array, Params | None]:
    """MLA: KV compressed to a (dc + rope_dim) latent per token — the cache
    stores only the latent, the decisive memory win at long context."""
    B, S, _ = x.shape
    H, nope, rdim, vdim = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cq = _rms(x @ params["wdq"].astype(x.dtype), params["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wuq"].astype(x.dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = _rms(x @ params["wdkv"].astype(x.dtype), params["kv_norm"])  # (B,S,dc)
    krope = rope((x @ params["wkr"].astype(x.dtype))[:, :, None, :], positions, cfg.rope_theta)

    if cache is not None:
        pos = cache["pos"]
        if getattr(pos, "ndim", 0) == 1 and S == 1:
            # per-slot positions (continuous batching): scatter row-wise
            rows = jnp.arange(B)
            CKV = cache["ckv"].at[rows, pos].set(ckv[:, 0].astype(cache["ckv"].dtype))
            KR = cache["krope"].at[rows, pos].set(krope[:, 0].astype(cache["krope"].dtype))
            new_cache = {"ckv": CKV, "krope": KR, "pos": pos + 1}
            ckv_all, krope_all = CKV.astype(x.dtype), KR.astype(x.dtype)
            T = ckv_all.shape[1]
            mask = (jnp.arange(T)[None, :] <= pos[:, None])[:, None, :]  # (B,1,T)
        else:
            zero = jnp.zeros((), pos.dtype)
            CKV = jax.lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype), (zero, pos, zero))
            KR = jax.lax.dynamic_update_slice(cache["krope"], krope.astype(cache["krope"].dtype), (zero, pos, zero, zero))
            if cfg.decode_seq_shard:
                from repro.distributed.sharding import constrain

                CKV = constrain(CKV, "batch", "model", None)
                KR = constrain(KR, "batch", "model", None, None)
            new_cache = {"ckv": CKV, "krope": KR, "pos": pos + S}
            ckv_all, krope_all = CKV.astype(x.dtype), KR.astype(x.dtype)
            T = ckv_all.shape[1]
            kpos = jnp.arange(T)[None, :]
            qpos = pos + jnp.arange(S)[:, None]
            mask = kpos <= qpos
    else:
        ckv_all, krope_all = ckv, krope
        T = S
        mask = causal_mask(S, S)
        new_cache = None

    k_nope = jnp.einsum("btc,chk->bthk", ckv_all, params["wuk"].astype(x.dtype))
    vmat = jnp.einsum("btc,chk->bthk", ckv_all, params["wuv"].astype(x.dtype))
    scores = (
        jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
        + jnp.einsum("bshk,btok->bhst", q_rope, jnp.broadcast_to(krope_all, (B, T, 1, rdim)))
    ).astype(jnp.float32) / float(np.sqrt(nope + rdim))
    scores = jnp.where(mask[None, None] if mask.ndim == 2 else mask[:, None], scores, -1e30)
    w = jax.nn.softmax(scores, -1).astype(x.dtype)
    out = jnp.einsum("bhst,bthk->bshk", w, vmat)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return out, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int, dtype=jnp.bfloat16):
    seq = "seq_kv" if cfg.decode_seq_shard else None
    params = {
        "ckv": jnp.zeros((n_layers, batch, max_len, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((n_layers, batch, max_len, 1, cfg.qk_rope_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
    specs = {
        "ckv": ("layer", "batch", seq, None),
        "krope": ("layer", "batch", seq, None, None),
        "pos": (),
    }
    return params, specs


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> tuple[Params, Specs]:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    p = {
        "wi_gate": dense_init(ks[0], d, (f,)),
        "wi_up": dense_init(ks[1], d, (f,)),
        "wo": dense_init(ks[2], f, (d,)),
    }
    s = {"wi_gate": ("embed", "mlp"), "wi_up": ("embed", "mlp"), "wo": ("mlp", "embed")}
    return p, s


def mlp_apply(params: Params, x: jax.Array, act: str) -> jax.Array:
    gate = x @ params["wi_gate"].astype(x.dtype)
    up = x @ params["wi_up"].astype(x.dtype)
    actv = jax.nn.silu if act == "silu" else (lambda g: jax.nn.gelu(g, approximate=True))
    return (actv(gate) * up) @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture-of-Experts with capacity-based scatter dispatch
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> tuple[Params, Specs]:
    ks = jax.random.split(key, 5)
    d, f = cfg.d_model, cfg.d_ff
    E = max(cfg.n_experts, cfg.moe_pad_experts)  # pad for EP divisibility
    p = {
        "router": dense_init(ks[0], d, (E,)),
        "wi_gate": jax.vmap(lambda k: dense_init(k, d, (f,)))(jax.random.split(ks[1], E)),
        "wi_up": jax.vmap(lambda k: dense_init(k, d, (f,)))(jax.random.split(ks[2], E)),
        "wo": jax.vmap(lambda k: dense_init(k, f, (d,)))(jax.random.split(ks[3], E)),
    }
    s = {
        "router": ("embed", None),
        "wi_gate": ("expert", "embed", "mlp"),
        "wi_up": ("expert", "embed", "mlp"),
        "wo": ("expert", "mlp", "embed"),
    }
    return p, s


def moe_apply(
    params: Params, x: jax.Array, cfg: ModelConfig, act: str
) -> tuple[jax.Array, jax.Array]:
    """Top-k routed experts with static capacity; returns (out, aux_loss).

    Scatter-based dispatch (no (T,k,E,C) one-hot): tokens are scatter-added
    into per-expert (E, C, D) buffers, processed by batched expert matmuls,
    and gathered back weighted by router probs. Static shapes throughout.
    """
    B, S, D = x.shape
    E_real, K = cfg.n_experts, cfg.top_k
    E = max(E_real, cfg.moe_pad_experts)
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    if E > E_real:  # dummy padding experts are never routed
        pad_mask = jnp.arange(E) >= E_real
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, K)  # (T, K)
    top_p = top_p / jnp.clip(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    C = int(np.ceil(K * T / E_real * cfg.capacity_factor))
    # position of each (token, slot) within its expert, in flat (T*K) order
    flat_e = top_e.reshape(-1)  # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # exclusive cumsum
    flat_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], 1)[:, 0]  # (T*K,)
    keep = flat_pos < C

    # scatter tokens into expert buffers
    buf = jnp.zeros((E, C, D), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(T), K)
    safe_pos = jnp.where(keep, flat_pos, C - 1)
    contrib = jnp.where(keep[:, None], xt[tok_idx], 0.0)
    buf = buf.at[flat_e, safe_pos].add(contrib)

    # batched expert FFN
    actv = jax.nn.silu if act == "silu" else (lambda g: jax.nn.gelu(g, approximate=True))
    h = actv(jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["wi_up"].astype(x.dtype))
    y_e = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(x.dtype))

    # gather back with router weights
    y_tok = y_e[flat_e, safe_pos]  # (T*K, D)
    w = (top_p.reshape(-1) * keep).astype(x.dtype)
    y = jnp.sum((y_tok * w[:, None]).reshape(T, K, D), axis=1)

    # load-balancing aux loss (Switch-style, over real experts)
    frac_tokens = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), 0)
    frac_probs = jnp.mean(probs, 0)
    aux = E_real * jnp.sum(frac_tokens * frac_probs)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------


def init_embeddings(key, cfg: ModelConfig) -> tuple[Params, Specs]:
    k1, k2 = jax.random.split(key)
    p = {"embed": embed_init(k1, cfg.vocab_size, cfg.d_model)}
    s = {"embed": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(k2, cfg.vocab_size, cfg.d_model)
        s["unembed"] = ("vocab", "embed")
    return p, s


def embed_tokens(params: Params, tokens: jax.Array, cfg: ModelConfig, dtype) -> jax.Array:
    from repro.distributed.sharding import activation_axes_enabled, constrain

    table = params["embed"].astype(dtype)
    if activation_axes_enabled():
        # Pin the gather output to plain batch sharding. Without this, GSPMD
        # picks an exotic sharding for the vocab-sharded-table gather and
        # falls back to "involuntary full rematerialization" (replicate +
        # repartition) of the whole (B, S, D) activation — §Perf cell B fix.
        x = constrain(table[tokens], "batch", None, None)
    else:
        x = table[tokens]
    if cfg.scale_embeddings:
        x = x * float(np.sqrt(cfg.d_model))
    return x


def logits_from_hidden(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    table = params.get("unembed", params["embed"])
    return jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))


def softmax_xent_weighted(
    logits: jax.Array, labels: jax.Array, weights: jax.Array
) -> jax.Array:
    """Per-example-weighted token CE: logits (B,S,V), labels (B,S), weights (B,)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None], -1)[..., 0]
    tok_loss = lse - gold  # (B, S)
    w = weights[:, None].astype(jnp.float32)
    return jnp.sum(tok_loss * w) / (jnp.sum(w) * labels.shape[1])


def chunked_xent_weighted(
    x: jax.Array, table: jax.Array, labels: jax.Array, weights: jax.Array, chunk: int = 512
) -> jax.Array:
    """CE without materializing (B,S,V): loop over sequence chunks.

    Peak logits memory drops from S/chunk× — the §Perf memory optimization
    for large-vocab archs (gemma / recurrentgemma, V = 256k).
    """
    B, S, D = x.shape
    # pick the chunk count as a divisor of S with S/n ≤ chunk
    n_chunks = max(-(-S // chunk), 1)
    while S % n_chunks != 0:
        n_chunks += 1
    chunk = S // n_chunks
    xc = x.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)          # (n,B,c,D)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)         # (n,B,c)

    def body(carry, inp):
        xcb, lcb = inp
        logits = jnp.einsum("bcd,vd->bcv", xcb, table.astype(xcb.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, lcb[..., None], -1)[..., 0]
        tok = (lse - gold) * weights[:, None].astype(jnp.float32)
        return carry + jnp.sum(tok), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (jnp.sum(weights).astype(jnp.float32) * S)
