"""Model configuration for all assigned architectures.

One frozen dataclass covers every family (dense / moe / ssm / hybrid /
encdec / vlm / audio); family-specific fields default to "off". Each assigned
arch instantiates this in ``repro/configs/<id>.py`` with the exact published
numbers, and provides ``reduced()`` for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention / position
    attn_type: str = "gqa"            # "gqa" | "mla"
    rope_theta: float = 10_000.0
    logits_softcap: float = 0.0

    # norm / mlp / embeddings
    norm_type: str = "rmsnorm"        # "rmsnorm" | "nonparametric_ln" | "layernorm"
    mlp_act: str = "silu"             # "silu" (SwiGLU) | "gelu" (GeGLU)
    tie_embeddings: bool = True
    scale_embeddings: bool = False    # gemma-style sqrt(d_model) input scaling

    # MLA (minicpm3 / deepseek-style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    conv_kernel: int = 4
    ssm_chunk: int = 256

    # hybrid (recurrentgemma)
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    attn_window: int = 0              # 0 → global attention

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    dec_max_len: int = 448

    # modality frontend stub
    modality: str = "text"            # "text" | "vision" | "audio"
    n_modality_positions: int = 0     # vision: patch count prepended to text

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"      # master copy dtype (optimizer)

    # ---- performance knobs (§Perf hillclimbing) ----
    decode_seq_shard: bool = False    # shard decode KV cache seq-dim over the
                                      # model axis (flash-decode partial-softmax
                                      # combine) — the MQA/GQA long-cache fix
    scan_dtype: str = "float32"       # RG-LRU / SSD recurrent-state dtype
    moe_pad_experts: int = 0          # pad expert count to a mesh-divisible
                                      # value (dummy experts are never routed);
                                      # fixes EP sharding when E % mesh != 0
    prefill_flash_block: int = 0      # >0: blocked online-softmax attention on
                                      # the XLA path for long full-causal
                                      # sequences (kills S×S score buffers)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if decode memory/compute are sub-quadratic in context length."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
