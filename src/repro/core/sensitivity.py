"""Sensitivity-sampling framework (paper Section B, Langberg–Schulman / Feldman et al.).

Generic importance sampler: given per-item sensitivity upper bounds s_i ≥ ζ_i,
draw |R| items i.i.d. with p_i = s_i / S and weight u_i = S·w_i/(s_i·|R|).
The MCTM coreset instantiates this with s_i = u_i(leverage) + 1/n (Lemma 2.2)
plus uniform sensitivities for the negative-log part (Lemma 2.3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SensitivitySample", "sensitivity_sample", "sample_size_bound"]


@dataclasses.dataclass(frozen=True)
class SensitivitySample:
    indices: np.ndarray  # (k,) sampled item ids (with replacement, as the theorem)
    weights: np.ndarray  # (k,) importance weights u_i
    probs: np.ndarray    # (n,) sampling distribution used


def sensitivity_sample(
    key: jax.Array,
    scores: np.ndarray,
    k: int,
    base_weights: np.ndarray | None = None,
) -> SensitivitySample:
    """Draw k items w.p. ∝ scores; weights make the estimator unbiased."""
    scores = np.asarray(scores, dtype=np.float64)
    scores = np.clip(scores, 1e-12, None)
    if base_weights is not None:
        scores = scores * np.asarray(base_weights, dtype=np.float64)
    total = scores.sum()
    probs = scores / total
    idx = np.asarray(
        jax.random.choice(key, scores.shape[0], shape=(k,), replace=True, p=jnp.asarray(probs))
    )
    w_base = np.ones_like(scores) if base_weights is None else np.asarray(base_weights, np.float64)
    weights = w_base[idx] / (probs[idx] * k)
    return SensitivitySample(indices=idx, weights=weights, probs=probs)


def sample_size_bound(
    total_sensitivity: float, vc_dim: int, eps: float, delta: float = 0.01
) -> int:
    """Theorem B.2 size: O(S/ε² (Δ log S + log 1/δ)). Returned as a concrete int."""
    S = max(total_sensitivity, 1.0)
    return int(np.ceil(S / eps**2 * (vc_dim * np.log(max(S, 2.0)) + np.log(1.0 / delta))))
