"""Hybrid coreset construction for MCTMs — the paper's Algorithm 1.

Pipeline (ℓ2-hull):
  1. basis-transform the raw data:  A, A' ∈ (n, J, d)
  2. leverage scores u_i of Ã = flatten(A) (≡ leverage of the paper's block B)
  3. sensitivity proxy s_i = u_i + 1/n → probabilities p_i
  4. sample k1 = ⌊α·k⌋ points, weights 1/(k1·p_i)
  5. hull augmentation: k2 = k − k1 extremal points of {a'_ij} (ε/J-kernel,
     Blum et al. 2019), weight 1
  6. fit the MCTM on the weighted union.

Baselines from the paper's experiments: `uniform`, `l2-only`, `ridge-lss`,
`root-l2` — all share this entry point via ``method=``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.hull import stable_first_unique
from repro.core.scoring import DEFAULT_CHUNK, ScoringEngine

Method = Literal["uniform", "l2-only", "l2-hull", "ridge-lss", "root-l2"]

__all__ = [
    "CoresetResult",
    "build_coreset",
    "coreset_scores",
    "coreset_from_scoring",
    "exact_hull_points",
    "CORESET_METHODS",
]

CORESET_METHODS: tuple[str, ...] = ("uniform", "l2-only", "l2-hull", "ridge-lss", "root-l2")


@dataclasses.dataclass
class CoresetResult:
    indices: np.ndarray        # (k,) point indices into the full dataset
    weights: np.ndarray        # (k,) positive weights
    scores: np.ndarray | None  # (n,) sampling scores used (None for uniform)
    method: str
    seconds: float

    @property
    def size(self) -> int:
        return int(self.indices.shape[0])


def coreset_scores(
    cfg: M.MCTMConfig,
    scaler: DataScaler,
    Y: jax.Array,
    method: str = "l2-hull",
    *,
    sketch_size: int = 0,
    key: jax.Array | None = None,
    ridge_reg: float = 1.0,
    chunk_size: int | None = DEFAULT_CHUNK,
) -> np.ndarray:
    """Per-point sampling scores s_i (sensitivity proxies) for each method.

    Backed by the chunked ``ScoringEngine``: inputs larger than ``chunk_size``
    are streamed with O(chunk·J·d) peak memory instead of materializing the
    (n, J, d) basis tensor.
    """
    n = np.asarray(Y).shape[0]
    if method == "uniform":
        return np.full(n, 1.0 / n)
    if method not in CORESET_METHODS:
        raise ValueError(f"unknown coreset method: {method}")
    if sketch_size > 0:
        assert key is not None
    engine = ScoringEngine(cfg, scaler, chunk_size=chunk_size)
    res = engine.score(
        jnp.asarray(Y),
        method=method,
        key=key,
        sketch_size=sketch_size,
        ridge_reg=ridge_reg,
    )
    return res.scores


def exact_hull_points(res, scores: np.ndarray, k_hull: int) -> np.ndarray:
    """Exactly ``k_hull`` distinct point ids from a ``ScoringResult``'s hull
    candidates, in first-occurrence (direction-priority) order.

    The ε-kernel candidate rows can dedup to fewer than ``k_hull`` distinct
    points (low-diversity hulls: many directions extremized by the same
    point); the shortfall is topped up deterministically from the next-ranked
    points by sampling score so callers always get the size they asked for.
    Requires ``k_hull ≤ n``.
    """
    r = res.rows_per_point
    pts = (
        stable_first_unique(np.asarray(res.hull_rows) // r, k_hull)
        if res.hull_rows is not None
        else np.zeros(0, np.int64)
    )
    short = k_hull - pts.shape[0]
    if short > 0:
        chosen = set(pts.tolist())
        ranked = np.argsort(-scores, kind="stable")
        extra = np.fromiter(
            (i for i in ranked if i not in chosen), dtype=np.int64, count=short
        )
        pts = np.concatenate([pts, extra])
    return pts


def coreset_from_scoring(
    res,
    n: int,
    k: int,
    method: str,
    alpha: float,
    key_draw: jax.Array,
    t0: float,
) -> CoresetResult:
    """Sampling + hull-union step of Algorithm 1 from a ``ScoringResult``.

    Shared by ``build_coreset`` and the sharded
    ``distributed_coreset.distributed_build_coreset`` — both engines emit the
    same ``ScoringResult`` contract, so the post-scoring assembly is one code
    path. Always returns exactly ``k`` points (hull shortfall topped up — see
    ``exact_hull_points``).
    """
    k_sample = int(np.floor(alpha * k)) if method == "l2-hull" else k
    k_hull = k - k_sample if method == "l2-hull" else 0
    scores = res.scores
    probs = scores / scores.sum()
    idx = np.asarray(
        jax.random.choice(
            key_draw, n, shape=(k_sample,), replace=True, p=jnp.asarray(probs)
        )
    )
    w = 1.0 / (k_sample * probs[idx])

    if method == "l2-hull" and k_hull > 0:
        hull_pts = exact_hull_points(res, scores, k_hull)
        idx = np.concatenate([idx, hull_pts])
        w = np.concatenate([w, np.ones(k_hull)])

    return CoresetResult(idx, w, scores, method, time.perf_counter() - t0)


def build_coreset(
    cfg: M.MCTMConfig,
    scaler: DataScaler,
    Y: np.ndarray,
    k: int,
    method: str = "l2-hull",
    *,
    key: jax.Array,
    alpha: float = 0.8,
    sketch_size: int = 0,
    chunk_size: int | None = DEFAULT_CHUNK,
) -> CoresetResult:
    """Paper Algorithm 1 (and its baselines). Returns indices + weights.

    The whole pre-sampling phase (leverage + hull extremes) runs as ONE
    two-pass sweep of the ``ScoringEngine``: the basis is evaluated at most
    once per chunk per pass — the dense path evaluates it exactly once — and
    nothing of size (n, J, d) is materialized when ``n > chunk_size``.
    """
    t0 = time.perf_counter()
    Y = np.asarray(Y)
    n = Y.shape[0]
    k = min(k, n)
    k_hull = k - int(np.floor(alpha * k)) if method == "l2-hull" else 0

    if method == "uniform":
        idx = np.asarray(jax.random.choice(key, n, shape=(k,), replace=False))
        w = np.full(k, n / k)
        return CoresetResult(idx, w, None, method, time.perf_counter() - t0)

    # independent streams from the parent key: scoring (sketch), hull
    # directions, and the sample draw (k_draw must NOT be re-derived from
    # k_score — the sketch already consumed it)
    k_score, k_hull_key, k_draw = jax.random.split(key, 3)
    engine = ScoringEngine(cfg, scaler, chunk_size=chunk_size)
    res = engine.score(
        jnp.asarray(Y),
        method=method,
        key=k_score,
        sketch_size=sketch_size,
        hull_k=k_hull,
        hull_key=k_hull_key,
    )
    return coreset_from_scoring(res, n, k, method, alpha, k_draw, t0)


# ---------------------------------------------------------------------------
# End-to-end evaluation harness (paper's metrics: §E.1.3 Main Workflow)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CoresetEvaluation:
    method: str
    k: int
    param_l2: float        # ||ϑ_cs − ϑ_full||₂ (paper "Param. ℓ2 dist.")
    lambda_err: float      # ||λ_cs − λ_full||₂ (paper "λ error")
    likelihood_ratio: float  # NLL_full(θ_cs)/NLL_full(θ_full), ≥ ~1, →1 better
    fit_seconds: float
    sample_seconds: float


def evaluate_coreset(
    cfg: M.MCTMConfig,
    scaler: DataScaler,
    Y: np.ndarray,
    full_fit: M.FitResult,
    k: int,
    method: str,
    key: jax.Array,
    *,
    steps: int = 1200,
    lr: float = 5e-2,
    alpha: float = 0.8,
) -> CoresetEvaluation:
    """Build a coreset, refit, and score against the full-data fit."""
    k_build, k_fit = jax.random.split(key)
    cs = build_coreset(cfg, scaler, Y, k, method, key=k_build, alpha=alpha)
    t0 = time.perf_counter()
    fit = M.fit_mctm(
        cfg,
        scaler,
        jnp.asarray(Y[cs.indices]),
        weights=jnp.asarray(cs.weights, jnp.float32),
        key=k_fit,
        steps=steps,
        lr=lr,
    )
    fit_s = time.perf_counter() - t0

    # Evaluate with a strict η (no floor): the fit uses the paper's η = Θ(ε)
    # corrected domain, but the reported likelihood must expose any log-term
    # blow-up a coreset failed to guard against (the hull's whole purpose).
    # Streamed (mctm_fit.streamed_nll): the full-data evaluation never
    # materializes the (n, J, d) basis.
    from repro.core.mctm_fit import likelihood_ratio, streamed_nll

    nll_full_at_cs = streamed_nll(cfg, scaler, fit.params, Y, eta=1e-9)
    nll_full_at_full = streamed_nll(cfg, scaler, full_fit.params, Y, eta=1e-9)

    from repro.core.bernstein import monotone_theta

    th_cs = monotone_theta(fit.params.theta_raw, cfg.min_slope)
    th_full = monotone_theta(full_fit.params.theta_raw, cfg.min_slope)
    param_l2 = float(jnp.linalg.norm(th_cs - th_full))
    lam_err = float(jnp.linalg.norm(fit.params.lam - full_fit.params.lam))
    # Likelihood ratio: NLL_full(θ_cs)/NLL_full(θ_full) as in the paper's
    # experiments, with the shared shift normalization for non-positive NLLs
    # (mctm_fit.likelihood_ratio).
    lr_metric = likelihood_ratio(nll_full_at_cs, nll_full_at_full)
    return CoresetEvaluation(
        method=method,
        k=cs.size,
        param_l2=param_l2,
        lambda_err=lam_err,
        likelihood_ratio=float(lr_metric),
        fit_seconds=fit_s,
        sample_seconds=cs.seconds,
    )
