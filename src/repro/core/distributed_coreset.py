"""Distributed coreset construction over a device mesh (shard_map).

The scalable realization of the paper's Algorithm 1 on a TPU pod — the
sharded counterpart of ``repro.core.scoring.ScoringEngine``'s pass 1/2:

  1. Every data shard holds a slice of the basis matrix Ã (rows b_i).
  2. Gram accumulation: G = Σ_shards Ã_sᵀÃ_s via ``psum`` over the data axis —
     one (dJ)² all-reduce, independent of n. The per-shard Gram goes through
     ``gram_matrix`` (compiled Pallas kernel on TPU, XLA oracle elsewhere).
  3. Each shard computes its rows' leverage u_i = Ã_i G⁺ Ã_iᵀ locally from
     the shared ``gram_projection`` factorization.
  4. Directional hull queries: per-shard argmax ⟨p, v⟩ → global max via
     all_gather of (score, index) candidates.

``distributed_scoring_stats`` is the one-collective psum of the scoring
engine's full pass-1 state (Gram + hull moments) — the building block for
running pass 1 sharded *and* chunked per shard (see ROADMAP open items).

The same Gram-psum pattern powers the LM-pipeline coreset stage
(`repro.data.pipeline.CoresetSelector`) with model-embedding features.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.scoring import gram_projection
from repro.kernels.gram.ops import gram_matrix
from repro.utils.compat import shard_map

__all__ = [
    "distributed_gram",
    "distributed_leverage",
    "distributed_direction_argmax",
    "distributed_coreset_scores",
    "distributed_scoring_stats",
]


def distributed_gram(X: jax.Array, mesh: Mesh, axis: str = "data") -> jax.Array:
    """G = XᵀX with X row-sharded over `axis`; result replicated."""

    def shard_fn(xs):
        return jax.lax.psum(gram_matrix(xs), axis)

    spec_in = P(axis, None)
    spec_out = P(None, None)
    fn = shard_map(shard_fn, mesh=mesh, in_specs=(spec_in,), out_specs=spec_out)
    return fn(X)


def distributed_leverage(X: jax.Array, mesh: Mesh, axis: str = "data") -> jax.Array:
    """Leverage scores with X row-sharded: one psum + local projections."""

    def shard_fn(xs):
        G = jax.lax.psum(gram_matrix(xs), axis)
        V, inv = gram_projection(G)
        return jnp.sum(jnp.square(xs @ V) * inv, axis=1)

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=(P(axis, None),), out_specs=P(axis)
    )
    return fn(X)


def distributed_scoring_stats(
    X: jax.Array, P_pts: jax.Array, mesh: Mesh, axis: str = "data"
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Pass-1 sufficient statistics of the scoring engine, one psum each.

    Returns (G = XᵀX, Σp, Σppᵀ) replicated — everything needed to build the
    leverage projection and the hull direction net without gathering data.
    """

    def shard_fn(xs, ps):
        G = jax.lax.psum(gram_matrix(xs), axis)
        s1 = jax.lax.psum(jnp.sum(ps, axis=0), axis)
        s2 = jax.lax.psum(ps.T @ ps, axis)
        return G, s1, s2

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None)),
        out_specs=(P(None, None), P(None), P(None, None)),
    )
    return fn(X, P_pts)


def distributed_direction_argmax(
    P_pts: jax.Array, dirs: jax.Array, mesh: Mesh, axis: str = "data"
) -> jax.Array:
    """Global argmax_i ⟨p_i, v⟩ per direction, points row-sharded over `axis`.

    Returns global row indices, shape (m,). Implemented as a per-shard argmax
    followed by a cross-shard max over (score, global_index) pairs — the same
    running-extreme reduction the chunked engine's pass 2 performs over
    chunks, here over shards.
    """
    n = P_pts.shape[0]
    shards = mesh.shape[axis]
    per = n // shards

    def shard_fn(ps, vs):
        scores = ps @ vs.T  # (per, m)
        local_best = jnp.argmax(scores, axis=0)  # (m,)
        local_score = jnp.max(scores, axis=0)
        shard_id = jax.lax.axis_index(axis)
        global_idx = shard_id * per + local_best
        all_scores = jax.lax.all_gather(local_score, axis)  # (shards, m)
        all_idx = jax.lax.all_gather(global_idx, axis)
        win = jnp.argmax(all_scores, axis=0)  # (m,)
        return jnp.take_along_axis(all_idx, win[None, :], axis=0)[0]

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(None),
        check_vma=False,  # all_gather+argmax makes the output replicated
    )
    return fn(P_pts, dirs)


def distributed_coreset_scores(
    X: jax.Array, mesh: Mesh, axis: str = "data"
) -> jax.Array:
    """s_i = u_i + 1/n, computed fully sharded (the Algorithm-1 score step)."""
    n = X.shape[0]
    u = distributed_leverage(X, mesh, axis)
    return u + 1.0 / n
