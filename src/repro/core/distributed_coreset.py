"""Distributed coreset construction over a device mesh (shard_map).

The scalable realization of the paper's Algorithm 1 on a TPU pod — the
sharded counterpart of ``repro.core.scoring.ScoringEngine``. Two layers:

Primitive collectives (building blocks, whole-shard bodies):
  * ``distributed_gram`` / ``distributed_leverage`` — per-shard Gram, one
    (dJ)² psum, local projections.
  * ``distributed_scoring_stats`` — one-collective psum of the scoring
    engine's full pass-1 state (Gram + hull moments).
  * ``distributed_direction_argmax`` — per-shard argmax ⟨p, v⟩ → global max
    via all_gather of (score, index) pairs. Ragged inputs (n not a multiple
    of the shard count) are padded to a shard multiple with −inf scores, so
    returned indices are exact for any n ≥ 1.

``DistributedScoringEngine`` — the fully distributed Algorithm 1. It fuses
the single-host engine's chunk loop INTO the shard_map body: each shard
scans its local rows chunk-by-chunk (``lax.scan`` over ``chunks_per_shard``
slices), reusing the exact per-chunk math of the single-host engine
(``pass1_update`` / ``leverage_chunk`` / ``hull_chunk_extremes``), so

  memory:  per-chip peak is O(chunk·J·d) — no (n, J, d) basis tensor and no
           full-shard score block ever materializes; carried state is the
           O((Jd)²) pass-1 statistics plus the (m,) running hull extremes.
  collectives: exactly ONE fused psum per pass-1 sweep (the (G, Σp, Σppᵀ)
           tuple lowers to a single all-reduce) and one all_gather pair
           (values + indices, each (shards, 2, m) with m = #directions) for
           pass-2's cross-shard running-extreme hull reduction. Nothing else
           crosses the ICI; leverage scores stay row-sharded until the final
           multi-process-safe ``host_gather``.

Between the passes the engine runs the same tiny host algebra as the
single-host path (f64 eigh of the psum'd Gram, moment-derived direction
net), which is what makes the two engines agree to f32 accumulation noise
(~1e-7) on identical inputs regardless of mesh shape or chunk size.

``distributed_build_coreset`` drives the engine end-to-end and returns the
same ``CoresetResult`` contract as ``coreset.build_coreset``.

The same Gram-psum pattern powers the LM-pipeline coreset stage
(`repro.data.pipeline.CoresetSelector`) with model-embedding features — pass
``mesh=`` to its constructor to route selection through this engine.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.hull import stable_first_unique
from repro.core.scoring import (
    DEFAULT_CHUNK,
    SCORE_METHODS,
    ScoringResult,
    _mctm_featurize,
    directions_from_moments,
    finalize_scoring,
    gram_projection,
    hull_chunk_extremes,
    leverage_chunk,
    pass1_update,
    projection_from_gram,
)
from repro.kernels.gram.ops import gram_matrix
from repro.utils.compat import shard_map

__all__ = [
    "distributed_gram",
    "distributed_leverage",
    "distributed_direction_argmax",
    "distributed_coreset_scores",
    "distributed_scoring_stats",
    "DistributedScoringEngine",
    "distributed_build_coreset",
    "make_sharded_pass_fns",
    "host_gather",
]


def _axis_tuple(axis) -> tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _num_shards(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def _spec_el(axes: tuple[str, ...]):
    """PartitionSpec element for the row dimension (one axis or a tuple)."""
    return axes if len(axes) > 1 else axes[0]


def host_gather(x) -> np.ndarray:
    """Multi-process-safe device→host gather.

    Single-process (tests, fake-device meshes): plain ``np.asarray``. Under
    multi-process jax, row-sharded outputs go through
    ``multihost_utils.process_allgather`` and replicated outputs are read
    from a local shard — no path ever touches non-addressable device memory.
    """
    if getattr(x, "is_fully_addressable", True):
        return np.asarray(x)
    if getattr(x, "is_fully_replicated", False):
        return np.asarray(x.addressable_shards[0].data)
    from jax.experimental import multihost_utils

    return np.asarray(multihost_utils.process_allgather(x, tiled=True))


def distributed_gram(X: jax.Array, mesh: Mesh, axis: str = "data") -> jax.Array:
    """G = XᵀX with X row-sharded over `axis`; result replicated."""

    def shard_fn(xs):
        return jax.lax.psum(gram_matrix(xs), axis)

    spec_in = P(axis, None)
    spec_out = P(None, None)
    fn = shard_map(shard_fn, mesh=mesh, in_specs=(spec_in,), out_specs=spec_out)
    return fn(X)


def distributed_leverage(X: jax.Array, mesh: Mesh, axis: str = "data") -> jax.Array:
    """Leverage scores with X row-sharded: one psum + local projections."""

    def shard_fn(xs):
        G = jax.lax.psum(gram_matrix(xs), axis)
        V, inv = gram_projection(G)
        return jnp.sum(jnp.square(xs @ V) * inv, axis=1)

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=(P(axis, None),), out_specs=P(axis)
    )
    return fn(X)


def distributed_scoring_stats(
    X: jax.Array, P_pts: jax.Array, mesh: Mesh, axis: str = "data"
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Pass-1 sufficient statistics of the scoring engine, one psum each.

    Returns (G = XᵀX, Σp, Σppᵀ) replicated — everything needed to build the
    leverage projection and the hull direction net without gathering data.
    """

    def shard_fn(xs, ps):
        G = jax.lax.psum(gram_matrix(xs), axis)
        s1 = jax.lax.psum(jnp.sum(ps, axis=0), axis)
        s2 = jax.lax.psum(ps.T @ ps, axis)
        return G, s1, s2

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None)),
        out_specs=(P(None, None), P(None), P(None, None)),
    )
    return fn(X, P_pts)


def distributed_direction_argmax(
    P_pts: jax.Array, dirs: jax.Array, mesh: Mesh, axis: str = "data"
) -> jax.Array:
    """Global argmax_i ⟨p_i, v⟩ per direction, points row-sharded over `axis`.

    Returns global row indices, shape (m,). Implemented as a per-shard argmax
    followed by a cross-shard max over (score, global_index) pairs — the same
    running-extreme reduction the chunked engine's pass 2 performs over
    chunks, here over shards.

    Handles ragged inputs: when ``n % shards != 0`` the rows are padded to a
    shard multiple and the pad rows' scores are masked to −inf, so they can
    never win the argmax and every returned index is a real row. Ties break
    toward the lowest global row index (matching dense ``jnp.argmax``).
    """
    n = int(P_pts.shape[0])
    if n == 0:
        raise ValueError(
            "distributed_direction_argmax: empty input (every shard would be "
            "empty and the per-direction argmax is undefined)"
        )
    shards = mesh.shape[axis]
    per = -(-n // shards)  # ceil → padded rows per shard
    n_pad = per * shards
    if n_pad > n:
        pad = jnp.zeros((n_pad - n, P_pts.shape[1]), P_pts.dtype)
        P_pts = jnp.concatenate([P_pts, pad], axis=0)
    mask = jnp.arange(n_pad) < n

    def shard_fn(ps, ms, vs):
        scores = ps @ vs.T  # (per, m)
        scores = jnp.where(ms[:, 0][:, None], scores, -jnp.inf)
        local_best = jnp.argmax(scores, axis=0)  # (m,)
        local_score = jnp.take_along_axis(scores, local_best[None, :], axis=0)[0]
        shard_id = jax.lax.axis_index(axis)
        global_idx = shard_id * per + local_best
        all_scores = jax.lax.all_gather(local_score, axis)  # (shards, m)
        all_idx = jax.lax.all_gather(global_idx, axis)
        win = jnp.argmax(all_scores, axis=0)  # (m,) first shard wins ties
        return jnp.take_along_axis(all_idx, win[None, :], axis=0)[0]

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(None, None)),
        out_specs=P(None),
        check_vma=False,  # all_gather+argmax makes the output replicated
    )
    return fn(P_pts, mask[:, None], dirs)


def distributed_coreset_scores(
    X: jax.Array, mesh: Mesh, axis: str = "data"
) -> jax.Array:
    """s_i = u_i + 1/n, computed fully sharded (the Algorithm-1 score step)."""
    n = X.shape[0]
    u = distributed_leverage(X, mesh, axis)
    return u + 1.0 / n


# ---------------------------------------------------------------------------
# DistributedScoringEngine — chunked pass-1/pass-2 inside the shard_map body
# ---------------------------------------------------------------------------


def make_sharded_pass_fns(
    featurize: Callable,
    mesh: Mesh,
    axes: tuple[str, ...],
    *,
    chunk: int,
    chunks_per_shard: int,
    rows_per_point: int,
    hull: bool,
    D: int,
    p: int,
):
    """Build the (pass1, pass2) shard_map callables of the sharded engine.

    Shapes per shard: inputs are (per, …) slices with per = chunks_per_shard
    · chunk; the body reshapes them into (chunks_per_shard, chunk, …) and
    ``lax.scan``s the single-host per-chunk updates over them. Exposed
    separately from the engine so the pod dry-run can lower the exact same
    computation from ShapeDtypeStructs (``launch.dryrun_coreset`` variant
    ``engine``).

    pass1(Y, sw_masked, mask) -> (G, Σp, Σppᵀ) replicated — one fused psum.
    pass2(Y, sw_masked, mask, V, inv[, dirs]) -> row-sharded leverage, plus
    (when ``hull``) the per-direction global argmax/argmin row indices from
    the cross-shard running-extreme reduction (one all_gather pair).
    """
    r = rows_per_point
    cps = chunks_per_shard
    per = cps * chunk
    sizes = [mesh.shape[a] for a in axes]
    axis_name = axes if len(axes) > 1 else axes[0]
    row_spec = _spec_el(axes)

    def _shard_index():
        idx = jax.lax.axis_index(axes[0])
        for a, s in zip(axes[1:], sizes[1:]):
            idx = idx * s + jax.lax.axis_index(a)
        return idx

    def _chunked(a):
        return a.reshape((cps, chunk) + a.shape[1:])

    def pass1_body(ys, swm, mask):
        def step(carry, xs):
            yc, swc, mc = xs
            X, Pr = featurize(yc)
            if hull:
                # zero pad rows out of the moments: Σp / Σppᵀ must cover
                # exactly the n·r real derivative rows
                Pr = Pr * jnp.repeat(mc, r)[:, None]
            else:
                Pr = None
            return pass1_update(carry[0], carry[1], carry[2], X, Pr, swc), None

        init = (
            jnp.zeros((D, D), jnp.float32),
            jnp.zeros((p,), jnp.float32),
            jnp.zeros((p, p), jnp.float32),
        )
        carry, _ = jax.lax.scan(
            step, init, (_chunked(ys), _chunked(swm), _chunked(mask))
        )
        # ONE collective: the tuple psum lowers to a single fused all-reduce
        return jax.lax.psum(carry, axis_name)

    pass1 = shard_map(
        pass1_body,
        mesh=mesh,
        in_specs=(P(row_spec, None), P(row_spec), P(row_spec)),
        out_specs=(P(None, None), P(None), P(None, None)),
        check_vma=False,
    )

    def pass2_hull_body(ys, swm, mask, V, inv, dirs):
        m = dirs.shape[0]
        base = _shard_index() * per

        def step(carry, xs):
            bmax, imax, bmin, imin = carry
            ci, yc, swc, mc = xs
            X, Pr = featurize(yc)
            u = leverage_chunk(X, swc, V, inv)
            pm = jnp.repeat(mc, r) > 0
            vmax, lmax, vmin, lmin = hull_chunk_extremes(Pr, dirs, pm)
            off = (base + ci * chunk) * r
            gmax, gmin = off + lmax, off + lmin
            # strict comparison keeps first-occurrence (lowest-row) tie-break,
            # matching the single-host chunked pass 2
            upd = vmax > bmax
            bmax, imax = jnp.where(upd, vmax, bmax), jnp.where(upd, gmax, imax)
            upd = vmin < bmin
            bmin, imin = jnp.where(upd, vmin, bmin), jnp.where(upd, gmin, imin)
            return (bmax, imax, bmin, imin), u

        init = (
            jnp.full((m,), -jnp.inf, jnp.float32),
            jnp.zeros((m,), jnp.int32),
            jnp.full((m,), jnp.inf, jnp.float32),
            jnp.zeros((m,), jnp.int32),
        )
        (bmax, imax, bmin, imin), u = jax.lax.scan(
            step,
            init,
            (jnp.arange(cps), _chunked(ys), _chunked(swm), _chunked(mask)),
        )
        # cross-shard running-extreme reduction: one all_gather pair (values
        # + indices), then a replicated argmax — the distributed analogue of
        # the host-side chunk loop in ScoringEngine._score_chunked
        allv = jax.lax.all_gather(jnp.stack([bmax, -bmin]), axis_name)
        alli = jax.lax.all_gather(jnp.stack([imax, imin]), axis_name)
        win = jnp.argmax(allv, axis=0)  # (2, m) lowest shard wins ties
        hull_idx = jnp.take_along_axis(alli, win[None], axis=0)[0]
        return u.reshape(per), hull_idx[0], hull_idx[1]

    def pass2_body(ys, swm, V, inv):
        def step(_, xs):
            yc, swc = xs
            X, _ = featurize(yc)
            return None, leverage_chunk(X, swc, V, inv)

        _, u = jax.lax.scan(step, None, (_chunked(ys), _chunked(swm)))
        return u.reshape(per)

    if hull:
        pass2 = shard_map(
            pass2_hull_body,
            mesh=mesh,
            in_specs=(
                P(row_spec, None),
                P(row_spec),
                P(row_spec),
                P(None, None),
                P(None),
                P(None, None),
            ),
            out_specs=(P(row_spec), P(None), P(None)),
            check_vma=False,
        )
    else:
        pass2 = shard_map(
            pass2_body,
            mesh=mesh,
            in_specs=(P(row_spec, None), P(row_spec), P(None, None), P(None)),
            out_specs=P(row_spec),
            check_vma=False,
        )
    return pass1, pass2


class DistributedScoringEngine:
    """Sharded + chunked pre-sampling phase of Algorithm 1 (see module doc).

    Same contract as ``scoring.ScoringEngine.score`` — returns an identical
    ``ScoringResult`` — but every data-sized computation runs inside the mesh:
    per-chip memory is O(chunk·J·d) and the only cross-chip traffic is one
    fused pass-1 psum and one pass-2 all_gather pair.

    Parameters mirror ``ScoringEngine``; ``featurize`` must be jax-traceable
    (it runs inside the shard_map scan body). ``axis`` may be one mesh axis
    name or a tuple of names (e.g. ``("pod", "data")`` on a multi-pod mesh).
    CountSketch pass-1 (``sketch_size``) is not yet sharded — see the ROADMAP
    sketched-pass-1 item.
    """

    def __init__(
        self,
        cfg=None,
        scaler=None,
        *,
        mesh: Mesh,
        axis="data",
        featurize: Callable | None = None,
        chunk_size: int | None = DEFAULT_CHUNK,
        rows_per_point: int | None = None,
        hull_oversample: int = 4,
    ):
        if featurize is None:
            if cfg is None or scaler is None:
                raise ValueError("either (cfg, scaler) or featurize is required")
            featurize = _mctm_featurize(cfg, scaler)
            rows_per_point = cfg.J
        self.cfg = cfg
        self.scaler = scaler
        self.featurize = featurize
        self.mesh = mesh
        self.axes = _axis_tuple(axis)
        self.chunk_size = int(chunk_size) if chunk_size else 0
        self.rows_per_point = int(rows_per_point or 1)
        self.hull_oversample = hull_oversample
        self._fns: dict = {}  # (chunk, cps, hull, D, p) → jitted pass fns

    # --------------------------------------------------------------- helpers

    def _shard_layout(self, n: int) -> tuple[int, int, int]:
        """(chunk, chunks_per_shard, n_pad) for n rows over this mesh."""
        shards = _num_shards(self.mesh, self.axes)
        per_needed = -(-n // shards)
        chunk = self.chunk_size if self.chunk_size > 0 else per_needed
        chunk = max(min(chunk, per_needed), 1)
        cps = -(-per_needed // chunk)
        return chunk, cps, cps * chunk * shards

    def _pass_fns(self, chunk: int, cps: int, hull: bool, width, dtype):
        sds = jax.ShapeDtypeStruct((chunk,) + width, dtype)
        X_s, P_s = jax.eval_shape(self.featurize, sds)
        if hull and P_s is None:
            raise ValueError("hull_k > 0 requires a featurize that returns P rows")
        D = int(X_s.shape[1])
        # without a hull stage s1/s2 stay zero — carry (and psum) scalars,
        # not a (p, p) dead weight the size of the Gram
        p = int(P_s.shape[1]) if (hull and P_s is not None) else 1
        key = (chunk, cps, hull, D, p)
        if key not in self._fns:
            p1, p2 = make_sharded_pass_fns(
                self.featurize,
                self.mesh,
                self.axes,
                chunk=chunk,
                chunks_per_shard=cps,
                rows_per_point=self.rows_per_point,
                hull=hull,
                D=D,
                p=p,
            )
            self._fns[key] = (jax.jit(p1), jax.jit(p2))
        return self._fns[key]

    def _shard_put(self, x, row_sharded: bool = True):
        spec = (
            P(_spec_el(self.axes), *([None] * (x.ndim - 1)))
            if row_sharded
            else P(*([None] * x.ndim))
        )
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    # ---------------------------------------------------------------- public

    def score(
        self,
        Y,
        *,
        method: str = "l2-hull",
        weights=None,
        hull_k: int = 0,
        hull_key: jax.Array | None = None,
        ridge_reg: float = 1.0,
    ) -> ScoringResult:
        """Score all n points on the mesh; same semantics as the single-host
        ``ScoringEngine.score`` (minus ``sketch_size``)."""
        if method not in SCORE_METHODS:
            raise ValueError(f"unknown scoring method: {method}")
        if hull_k > 0 and hull_key is None:
            raise ValueError("hull_k > 0 requires hull_key")
        Y = jnp.asarray(Y)
        n = int(Y.shape[0])
        if n == 0:
            raise ValueError("cannot score an empty dataset")
        r = self.rows_per_point
        hull = hull_k > 0

        chunk, cps, n_pad = self._shard_layout(n)
        pad = n_pad - n
        # pad with copies of row 0 (valid data — no NaN risk through the
        # featurizer); masks keep pads out of every statistic
        if pad:
            Y_pad = jnp.concatenate(
                [Y, jnp.broadcast_to(Y[:1], (pad,) + Y.shape[1:])], axis=0
            )
        else:
            Y_pad = Y
        mask = (jnp.arange(n_pad) < n).astype(jnp.float32)
        sw = (
            jnp.sqrt(jnp.asarray(weights, jnp.float32))
            if weights is not None
            else jnp.ones((n,), jnp.float32)
        )
        swm = jnp.concatenate([sw, jnp.zeros((pad,), jnp.float32)]) if pad else sw

        Y_pad = self._shard_put(Y_pad)
        mask = self._shard_put(mask)
        swm = self._shard_put(swm)

        pass1, pass2 = self._pass_fns(chunk, cps, hull, Y.shape[1:], Y_pad.dtype)

        # ---- pass 1 (sharded, chunked): one fused psum of (G, Σp, Σppᵀ)
        G, s1, s2 = pass1(Y_pad, swm, mask)
        G_host = host_gather(G)

        # ---- between passes: (Jd)² host algebra, identical to single-host
        V, inv = projection_from_gram(G_host, method, ridge_reg)

        hull_rows = None
        if hull:
            dirs = directions_from_moments(
                hull_key,
                host_gather(s1),
                host_gather(s2),
                n * r,
                hull_k,
                self.hull_oversample,
            )
            u_pad, gimax, gimin = pass2(Y_pad, swm, mask, V, inv, jnp.asarray(dirs))
            cand = np.concatenate(
                [host_gather(gimax), host_gather(gimin)]
            ).astype(np.int64)
            # every distinct candidate row, first-occurrence order — matching
            # the single-host engine (truncation to k points happens at the
            # coreset assembly via exact_hull_points)
            hull_rows = stable_first_unique(cand)
        else:
            u_pad = pass2(Y_pad, swm, V, inv)

        u = host_gather(u_pad)[:n]
        shards = _num_shards(self.mesh, self.axes)
        return finalize_scoring(n, cps * shards, method, G_host, u, hull_rows, r)


def distributed_build_coreset(
    cfg,
    scaler,
    Y,
    k: int,
    method: str = "l2-hull",
    *,
    mesh: Mesh,
    key: jax.Array,
    axis="data",
    alpha: float = 0.8,
    chunk_size: int | None = DEFAULT_CHUNK,
):
    """Paper Algorithm 1 with the pre-sampling phase fully distributed.

    Same contract (and same key-split structure) as ``coreset.build_coreset``
    — returns a ``CoresetResult`` — but scoring runs on ``mesh`` through the
    ``DistributedScoringEngine``.
    """
    from repro.core.coreset import CoresetResult, coreset_from_scoring

    t0 = time.perf_counter()
    Y = np.asarray(Y)
    n = Y.shape[0]
    k = min(k, n)

    if method == "uniform":
        idx = np.asarray(jax.random.choice(key, n, shape=(k,), replace=False))
        w = np.full(k, n / k)
        return CoresetResult(idx, w, None, method, time.perf_counter() - t0)

    # same 3-way split as build_coreset (k_score reserved for the sketched
    # pass-1 follow-on) so the two paths draw identical samples when their
    # scores agree
    _k_score, k_hull_key, k_draw = jax.random.split(key, 3)
    k_hull = k - int(np.floor(alpha * k)) if method == "l2-hull" else 0
    engine = DistributedScoringEngine(
        cfg, scaler, mesh=mesh, axis=axis, chunk_size=chunk_size
    )
    res = engine.score(
        jnp.asarray(Y), method=method, hull_k=k_hull, hull_key=k_hull_key
    )
    return coreset_from_scoring(res, n, k, method, alpha, k_draw, t0)
