"""Distributed coreset construction over a device mesh (shard_map).

The scalable realization of the paper's Algorithm 1 on a TPU pod — the
sharded counterpart of ``repro.core.scoring.ScoringEngine``. Two layers:

Primitive collectives (building blocks, whole-shard bodies):
  * ``distributed_gram`` / ``distributed_leverage`` — per-shard Gram, one
    (dJ)² psum, local projections.
  * ``distributed_scoring_stats`` — one-collective psum of the scoring
    engine's full pass-1 state (Gram + hull moments).
  * ``distributed_direction_argmax`` — per-shard argmax ⟨p, v⟩ → global max
    via all_gather of (score, index) pairs. Ragged inputs (n not a multiple
    of the shard count) are padded to a shard multiple with −inf scores, so
    returned indices are exact for any n ≥ 1.

``DistributedScoringEngine`` — the fully distributed Algorithm 1. It fuses
the single-host engine's chunk loop INTO the shard_map body: each shard
scans its local rows chunk-by-chunk (``lax.scan`` over ``chunks_per_shard``
slices), reusing the exact per-chunk math of the single-host engine
(``pass1_update`` / ``leverage_chunk`` / ``hull_chunk_extremes``), so

  memory:  per-chip peak is O(chunk·J·d) — no (n, J, d) basis tensor and no
           full-shard score block ever materializes; carried state is the
           strategy's O((Jd)²)-ish statistics plus the (m,) running hull
           extremes (one-pass additionally keeps its per-shard retained z
           rows, O(per_shard·q) per chip).
  collectives: exactly ONE fused psum per accumulation sweep — the carried
           strategy state ((G, Σp, Σppᵀ) for ``TwoPassExact``, SX for
           ``OnePassSketched``) psums as one tuple, which
           lowers to a single all-reduce — and one all_gather pair (values +
           indices, each (shards, 2, m) with m = #directions) for the
           cross-shard running-extreme hull reduction. Nothing else crosses
           the ICI; leverage scores stay row-sharded until the final
           multi-process-safe ``host_gather``.

The engine drives the same ``repro.core.scoring`` pass strategies as the
single-host engine: ``TwoPassExact`` (the pass1/pass2 pair below, with an
optional x64-gated f64 Gram carry), and ``OnePassSketched`` — ONE fused
sweep (``make_sharded_onepass_fn``) that accumulates the row CountSketch
and the running hull extremes and emits the sketch-projected z rows, so
every data row is featurized exactly once per score call.

Between the sweeps the engine runs the same tiny host algebra as the
single-host path (f64 eigh of the psum'd Gram, moment-derived or upfront
direction net), which is what makes the two engines agree to f32
accumulation noise (~1e-7) on identical inputs regardless of mesh shape or
chunk size.

``distributed_build_coreset`` drives the engine end-to-end and returns the
same ``CoresetResult`` contract as ``coreset.build_coreset``.

The same Gram-psum pattern powers the LM-pipeline coreset stage
(`repro.data.pipeline.CoresetSelector`) with model-embedding features — pass
``mesh=`` to its constructor to route selection through this engine.
"""
from __future__ import annotations

import itertools
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.hull import stable_first_unique
from repro.core.scoring import (
    DEFAULT_CHUNK,
    SCORE_METHODS,
    OnePassSketched,
    ScoringResult,
    TwoPassExact,
    TwoPassSketched,
    _mctm_featurize,
    _z_leverage_jit,
    directions_from_moments,
    finalize_scoring,
    gram_projection,
    hull_chunk_extremes,
    leverage_chunk,
    pass1_update,
    projection_from_gram,
    resolve_strategy,
    sketch_plan,
    upfront_directions,
)
from repro.kernels.gram.ops import gram_matrix
from repro.kernels.sweep.ops import fused_sweep_update
from repro.utils.compat import shard_map

__all__ = [
    "distributed_gram",
    "distributed_leverage",
    "distributed_direction_argmax",
    "distributed_coreset_scores",
    "distributed_scoring_stats",
    "DistributedScoringEngine",
    "distributed_build_coreset",
    "make_sharded_pass_fns",
    "make_sharded_onepass_fn",
    "make_segmented_pass_fns",
    "make_segmented_onepass_fn",
    "host_gather",
    "kv_allreduce",
    "shard_layout",
]


def _axis_tuple(axis) -> tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _num_shards(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def shard_layout(mesh: Mesh, axis, n: int, chunk_size: int | None):
    """(chunk, chunks_per_shard, n_pad) for n rows chunk-scanned over a mesh.

    The one row-layout rule every sharded chunk driver in the repo follows —
    the scoring engine's shard_map scan bodies and the fit layer's streamed
    evaluator (``core.mctm_fit``) pad/slice with exactly this geometry, so
    arrays staged for one are directly consumable by the other.
    """
    axes = _axis_tuple(axis)
    shards = _num_shards(mesh, axes)
    per_needed = -(-n // shards)
    chunk = int(chunk_size) if chunk_size else per_needed
    chunk = max(min(chunk, per_needed), 1)
    cps = -(-per_needed // chunk)
    return chunk, cps, cps * chunk * shards


def _spec_el(axes: tuple[str, ...]):
    """PartitionSpec element for the row dimension (one axis or a tuple)."""
    return axes if len(axes) > 1 else axes[0]


# monotone per-process call counter: host_gather is SPMD (every process calls
# it in the same order), so the counter names a unique KV namespace + barrier
# per gather that all processes agree on
_KV_GATHER_SEQ = itertools.count()
_KV_ALLREDUCE_SEQ = itertools.count()
_KV_TIMEOUT_MS = 120_000


def _kv_timeout_ms() -> int:
    """KV-store barrier/get deadline — the ft config's ``kv_timeout_ms``.

    This doubles as the peer-death detector for host-level data parallelism:
    when a peer dies mid-step, the survivor's next barrier times out with a
    RuntimeError that ``ft.supervisor.RunSupervisor`` treats as retryable,
    triggering re-planning onto the surviving devices.
    """
    from repro.ft.config import get_ft_config

    return int(get_ft_config().kv_timeout_ms)


def _kv_store_gather(x) -> np.ndarray:
    """Cross-process gather over the distributed runtime's key-value store.

    The CPU backend cannot execute multi-process computations (so
    ``process_allgather`` — a jit under the hood — fails there); exchanging
    the addressable shard bytes host-side through the coordinator's KV store
    covers the gap. Collective: every participating process must call
    ``host_gather`` in the same order.
    """
    import pickle

    from jax._src import distributed

    client = distributed.global_state.client
    if client is None:
        raise RuntimeError(
            "host_gather: array is not fully addressable but jax.distributed "
            "was never initialized"
        )
    seq = next(_KV_GATHER_SEQ)
    pid = jax.process_index()
    shards = [
        (
            tuple(s.indices(dim)[:2] for s, dim in zip(shard.index, x.shape)),
            np.asarray(shard.data),
        )
        for shard in x.addressable_shards
    ]
    key = f"repro/host_gather/{seq}/{pid}"
    client.key_value_set_bytes(key, pickle.dumps(shards))
    timeout = _kv_timeout_ms()
    client.wait_at_barrier(f"repro_host_gather_{seq}", timeout)
    out = np.zeros(x.shape, x.dtype)
    for p in range(jax.process_count()):
        blob = client.blocking_key_value_get_bytes(
            f"repro/host_gather/{seq}/{p}", timeout
        )
        for bounds, data in pickle.loads(blob):
            out[tuple(slice(a, b) for a, b in bounds)] = data
    # second barrier before deleting our key: every process has read it
    client.wait_at_barrier(f"repro_host_gather_done_{seq}", timeout)
    client.key_value_delete(key)
    return out


def host_gather(x) -> np.ndarray:
    """Multi-process-safe device→host gather.

    Single-process (tests, fake-device meshes): plain ``np.asarray``. Under
    multi-process jax, row-sharded outputs go through
    ``multihost_utils.process_allgather`` and replicated outputs are read
    from a local shard — no path ever touches non-addressable device memory.
    On backends that cannot run multi-process computations (CPU), the gather
    falls back to a host-side shard exchange through the distributed
    runtime's KV store (``_kv_store_gather``).
    """
    if getattr(x, "is_fully_addressable", True):
        return np.asarray(x)
    if getattr(x, "is_fully_replicated", False):
        return np.asarray(x.addressable_shards[0].data)
    from jax.experimental import multihost_utils

    try:
        return np.asarray(multihost_utils.process_allgather(x, tiled=True))
    except Exception as e:
        # fall back ONLY for the known CPU-backend gap ("Multiprocess
        # computations aren't implemented on the CPU backend"); any other
        # failure is a real error and must stay loud
        if jax.default_backend() != "cpu" or (
            "multiprocess computations" not in str(e).lower()
        ):
            raise
        return _kv_store_gather(x)


def kv_allreduce(tree, timeout_ms: int | None = None):
    """Sum-allreduce a pytree of host arrays across jax processes via the
    coordinator's KV store.

    The backbone of CPU-backend-safe host-level data parallelism: each
    process computes local gradients with a plain local jit and exchanges
    them here (the CPU backend cannot run cross-process jit collectives).
    Collective — every process must call in the same order. Single-process:
    identity. A dead peer surfaces as a barrier timeout (RuntimeError after
    ``timeout_ms``, default the ft config's ``kv_timeout_ms``) — the
    supervisor's retryable signal for re-planning onto the survivors.
    """
    import pickle

    from jax._src import distributed

    if jax.process_count() == 1:
        return tree
    client = distributed.global_state.client
    if client is None:
        raise RuntimeError("kv_allreduce: jax.distributed was never initialized")
    timeout = int(timeout_ms) if timeout_ms is not None else _kv_timeout_ms()
    seq = next(_KV_ALLREDUCE_SEQ)
    pid = jax.process_index()
    leaves, treedef = jax.tree.flatten(tree)
    host = [np.asarray(leaf) for leaf in leaves]
    key = f"repro/allreduce/{seq}/{pid}"
    client.key_value_set_bytes(key, pickle.dumps(host))
    client.wait_at_barrier(f"repro_allreduce_{seq}", timeout)
    out = [np.zeros_like(h) for h in host]
    for p in range(jax.process_count()):
        blob = client.blocking_key_value_get_bytes(f"repro/allreduce/{seq}/{p}", timeout)
        for acc, arr in zip(out, pickle.loads(blob)):
            acc += arr
    client.wait_at_barrier(f"repro_allreduce_done_{seq}", timeout)
    client.key_value_delete(key)
    return jax.tree.unflatten(treedef, out)


def distributed_gram(X: jax.Array, mesh: Mesh, axis: str = "data") -> jax.Array:
    """G = XᵀX with X row-sharded over `axis`; result replicated."""

    def shard_fn(xs):
        return jax.lax.psum(gram_matrix(xs), axis)

    spec_in = P(axis, None)
    spec_out = P(None, None)
    fn = shard_map(shard_fn, mesh=mesh, in_specs=(spec_in,), out_specs=spec_out)
    return fn(X)


def distributed_leverage(X: jax.Array, mesh: Mesh, axis: str = "data") -> jax.Array:
    """Leverage scores with X row-sharded: one psum + local projections."""

    def shard_fn(xs):
        G = jax.lax.psum(gram_matrix(xs), axis)
        V, inv = gram_projection(G)
        return jnp.sum(jnp.square(xs @ V) * inv, axis=1)

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=(P(axis, None),), out_specs=P(axis)
    )
    return fn(X)


def distributed_scoring_stats(
    X: jax.Array, P_pts: jax.Array, mesh: Mesh, axis: str = "data"
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Pass-1 sufficient statistics of the scoring engine, one psum each.

    Returns (G = XᵀX, Σp, Σppᵀ) replicated — everything needed to build the
    leverage projection and the hull direction net without gathering data.
    """

    def shard_fn(xs, ps):
        G = jax.lax.psum(gram_matrix(xs), axis)
        s1 = jax.lax.psum(jnp.sum(ps, axis=0), axis)
        s2 = jax.lax.psum(ps.T @ ps, axis)
        return G, s1, s2

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None)),
        out_specs=(P(None, None), P(None), P(None, None)),
    )
    return fn(X, P_pts)


def distributed_direction_argmax(
    P_pts: jax.Array, dirs: jax.Array, mesh: Mesh, axis: str = "data"
) -> jax.Array:
    """Global argmax_i ⟨p_i, v⟩ per direction, points row-sharded over `axis`.

    Returns global row indices, shape (m,). Implemented as a per-shard argmax
    followed by a cross-shard max over (score, global_index) pairs — the same
    running-extreme reduction the chunked engine's pass 2 performs over
    chunks, here over shards.

    Handles ragged inputs: when ``n % shards != 0`` the rows are padded to a
    shard multiple and the pad rows' scores are masked to −inf, so they can
    never win the argmax and every returned index is a real row. Ties break
    toward the lowest global row index (matching dense ``jnp.argmax``).
    """
    n = int(P_pts.shape[0])
    if n == 0:
        raise ValueError(
            "distributed_direction_argmax: empty input (every shard would be "
            "empty and the per-direction argmax is undefined)"
        )
    shards = mesh.shape[axis]
    per = -(-n // shards)  # ceil → padded rows per shard
    n_pad = per * shards
    if n_pad > n:
        pad = jnp.zeros((n_pad - n, P_pts.shape[1]), P_pts.dtype)
        P_pts = jnp.concatenate([P_pts, pad], axis=0)
    mask = jnp.arange(n_pad) < n

    def shard_fn(ps, ms, vs):
        scores = ps @ vs.T  # (per, m)
        scores = jnp.where(ms[:, 0][:, None], scores, -jnp.inf)
        local_best = jnp.argmax(scores, axis=0)  # (m,)
        local_score = jnp.take_along_axis(scores, local_best[None, :], axis=0)[0]
        shard_id = jax.lax.axis_index(axis)
        global_idx = shard_id * per + local_best
        all_scores = jax.lax.all_gather(local_score, axis)  # (shards, m)
        all_idx = jax.lax.all_gather(global_idx, axis)
        win = jnp.argmax(all_scores, axis=0)  # (m,) first shard wins ties
        return jnp.take_along_axis(all_idx, win[None, :], axis=0)[0]

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(None, None)),
        out_specs=P(None),
        check_vma=False,  # all_gather+argmax makes the output replicated
    )
    return fn(P_pts, mask[:, None], dirs)


def distributed_coreset_scores(
    X: jax.Array, mesh: Mesh, axis: str = "data"
) -> jax.Array:
    """s_i = u_i + 1/n, computed fully sharded (the Algorithm-1 score step)."""
    n = X.shape[0]
    u = distributed_leverage(X, mesh, axis)
    return u + 1.0 / n


# ---------------------------------------------------------------------------
# DistributedScoringEngine — chunked pass-1/pass-2 inside the shard_map body
# ---------------------------------------------------------------------------


def _shard_index_fn(axes: tuple[str, ...], sizes):
    """Row-major linear shard index over (possibly multiple) mesh axes."""
    idx = jax.lax.axis_index(axes[0])
    for a, s in zip(axes[1:], sizes[1:]):
        idx = idx * s + jax.lax.axis_index(a)
    return idx


# -- running-extreme hull reduction, shared by the two-pass pass-2 body and
#    the one-pass body (the device-side analogue of scoring.RunningExtremes)


def _extremes_init(m: int):
    return (
        jnp.full((m,), -jnp.inf, jnp.float32),
        jnp.zeros((m,), jnp.int32),
        jnp.full((m,), jnp.inf, jnp.float32),
        jnp.zeros((m,), jnp.int32),
    )


def _extremes_fold(ext, block, row_offset):
    """Fold one chunk's block-local directional extremes into the running
    carry.

    Strict comparisons keep the first-occurrence (lowest-row) tie-break,
    matching the single-host running extremes. Indices are cast to int32 so
    the scan carry dtype is stable regardless of x64 mode (the engines guard
    against n·r overflowing int32 up front).
    """
    bmax, imax, bmin, imin = ext
    vmax, lmax, vmin, lmin = block
    gmax = (row_offset + lmax).astype(jnp.int32)
    gmin = (row_offset + lmin).astype(jnp.int32)
    upd = vmax > bmax
    bmax, imax = jnp.where(upd, vmax, bmax), jnp.where(upd, gmax, imax)
    upd = vmin < bmin
    bmin, imin = jnp.where(upd, vmin, bmin), jnp.where(upd, gmin, imin)
    return bmax, imax, bmin, imin


def _extremes_step(ext, Pr, dirs, pm, row_offset):
    """``_extremes_fold`` over the standalone extremes kernel — the two-pass
    scan bodies' step (the one-pass bodies fold the fused sweep's block)."""
    return _extremes_fold(ext, hull_chunk_extremes(Pr, dirs, pm), row_offset)


def _extremes_cross_shard(ext, axis_name):
    """Cross-shard running-extreme reduction: ONE all_gather pair (values +
    indices), then a replicated argmax; lowest shard wins ties. Returns the
    per-direction global (argmax, argmin) row ids."""
    bmax, imax, bmin, imin = ext
    allv = jax.lax.all_gather(jnp.stack([bmax, -bmin]), axis_name)
    alli = jax.lax.all_gather(jnp.stack([imax, imin]), axis_name)
    win = jnp.argmax(allv, axis=0)  # (2, m)
    hull_idx = jnp.take_along_axis(alli, win[None], axis=0)[0]
    return hull_idx[0], hull_idx[1]


def make_sharded_pass_fns(
    featurize: Callable,
    mesh: Mesh,
    axes: tuple[str, ...],
    *,
    chunk: int,
    chunks_per_shard: int,
    rows_per_point: int,
    hull: bool,
    D: int,
    p: int,
    gram_dtype: str = "float32",
):
    """Build the (pass1, pass2) shard_map callables of the sharded engine.

    Shapes per shard: inputs are (per, …) slices with per = chunks_per_shard
    · chunk; the body reshapes them into (chunks_per_shard, chunk, …) and
    ``lax.scan``s the single-host per-chunk updates over them. Exposed
    separately from the engine so the pod dry-run can lower the exact same
    computation from ShapeDtypeStructs (``launch.dryrun_coreset`` variant
    ``engine``).

    pass1(Y, sw_masked, mask) -> (G, Σp, Σppᵀ) replicated — one fused psum.
    pass2(Y, sw_masked, mask, V, inv[, dirs]) -> row-sharded leverage, plus
    (when ``hull``) the per-direction global argmax/argmin row indices from
    the cross-shard running-extreme reduction (one all_gather pair).

    ``gram_dtype="float64"`` carries (and psums) the Gram in f64 — the
    sharded realization of ``TwoPassExact(gram_dtype="float64")`` — which
    requires jax x64 mode (the single-host engine accumulates host-side
    instead and needs no flag).
    """
    r = rows_per_point
    cps = chunks_per_shard
    per = cps * chunk
    sizes = [mesh.shape[a] for a in axes]
    axis_name = axes if len(axes) > 1 else axes[0]
    row_spec = _spec_el(axes)
    f64 = gram_dtype == "float64"

    def _shard_index():
        return _shard_index_fn(axes, sizes)

    def _chunked(a):
        return a.reshape((cps, chunk) + a.shape[1:])

    def pass1_body(ys, swm, mask):
        def step(carry, xs):
            yc, swc, mc = xs
            X, Pr = featurize(yc)
            if hull:
                # zero pad rows out of the moments: Σp / Σppᵀ must cover
                # exactly the n·r real derivative rows
                Pr = Pr * jnp.repeat(mc, r)[:, None]
            else:
                Pr = None
            return (
                pass1_update(
                    carry[0], carry[1], carry[2], X, Pr, swc, gram_dtype=gram_dtype
                ),
                None,
            )

        init = (
            jnp.zeros((D, D), jnp.float64 if f64 else jnp.float32),
            jnp.zeros((p,), jnp.float32),
            jnp.zeros((p, p), jnp.float32),
        )
        carry, _ = jax.lax.scan(
            step, init, (_chunked(ys), _chunked(swm), _chunked(mask))
        )
        # ONE collective: the tuple psum lowers to a single fused all-reduce
        return jax.lax.psum(carry, axis_name)

    pass1 = shard_map(
        pass1_body,
        mesh=mesh,
        in_specs=(P(row_spec, None), P(row_spec), P(row_spec)),
        out_specs=(P(None, None), P(None), P(None, None)),
        check_vma=False,
    )

    def pass2_hull_body(ys, swm, mask, V, inv, dirs):
        base = _shard_index() * per

        def step(carry, xs):
            ci, yc, swc, mc = xs
            X, Pr = featurize(yc)
            u = leverage_chunk(X, swc, V, inv)
            pm = jnp.repeat(mc, r) > 0
            carry = _extremes_step(carry, Pr, dirs, pm, (base + ci * chunk) * r)
            return carry, u

        ext, u = jax.lax.scan(
            step,
            _extremes_init(dirs.shape[0]),
            (jnp.arange(cps), _chunked(ys), _chunked(swm), _chunked(mask)),
        )
        # the distributed analogue of the host-side chunk loop in
        # ScoringEngine._drive
        gimax, gimin = _extremes_cross_shard(ext, axis_name)
        return u.reshape(per), gimax, gimin

    def pass2_body(ys, swm, V, inv):
        def step(_, xs):
            yc, swc = xs
            X, _ = featurize(yc)
            return None, leverage_chunk(X, swc, V, inv)

        _, u = jax.lax.scan(step, None, (_chunked(ys), _chunked(swm)))
        return u.reshape(per)

    if hull:
        pass2 = shard_map(
            pass2_hull_body,
            mesh=mesh,
            in_specs=(
                P(row_spec, None),
                P(row_spec),
                P(row_spec),
                P(None, None),
                P(None),
                P(None, None),
            ),
            out_specs=(P(row_spec), P(None), P(None)),
            check_vma=False,
        )
    else:
        pass2 = shard_map(
            pass2_body,
            mesh=mesh,
            in_specs=(P(row_spec, None), P(row_spec), P(None, None), P(None)),
            out_specs=P(row_spec),
            check_vma=False,
        )
    return pass1, pass2


def make_sharded_onepass_fn(
    featurize: Callable,
    mesh: Mesh,
    axes: tuple[str, ...],
    *,
    chunk: int,
    chunks_per_shard: int,
    rows_per_point: int,
    hull: bool,
    D: int,
    q: int | None,
    sketch_size: int,
):
    """The sharded ``OnePassSketched`` sweep — ONE shard_map callable.

    Each shard scans its local chunks exactly once, accumulating the
    strategy's carried state (the row CountSketch SX — it joins the one
    fused psum, exactly like the two-pass (G, Σp, Σppᵀ); the one-pass net is
    fixed upfront so no hull moments are carried) while tracking the running
    directional hull extremes and emitting the sketch-projected rows
    z = (√w·X)Ω. No second data sweep exists: leverage is read off the
    row-sharded z at finalize.

    fn(Y, sw_masked, mask, rows, signs, *extras) with ``rows``/``signs`` the
    row-sharded global CountSketch plan, extras = (Ω,) when ``q`` plus
    (dirs,) when ``hull``. Returns (z row-sharded, SX replicated
    [, global argmax/argmin row ids]).
    """
    r = rows_per_point
    cps = chunks_per_shard
    per = cps * chunk
    sizes = [mesh.shape[a] for a in axes]
    axis_name = axes if len(axes) > 1 else axes[0]
    row_spec = _spec_el(axes)
    width = q if q else D

    def _chunked(a):
        return a.reshape((cps, chunk) + a.shape[1:])

    def body(ys, swm, mask, rows, signs, *extra):
        omega = extra[0] if q else None
        dirs = extra[-1] if hull else None
        m = dirs.shape[0] if hull else 0
        base = _shard_index_fn(axes, sizes) * per

        def step(carry, xs):
            SX, ext = carry
            ci, yc, swc, mc, rc, sc = xs
            X, Pr = featurize(yc)
            # ONE fused op per chunk (kernels.sweep): sketch + z + extremes
            SX, z, extb, _ = fused_sweep_update(
                SX, X, Pr if hull else None, swc, rc, sc,
                dirs=dirs, omega=omega, mask=mc if hull else None,
            )
            if hull:
                ext = _extremes_fold(ext, extb, (base + ci * chunk) * r)
            return (SX, ext), z

        init = (jnp.zeros((sketch_size, D), jnp.float32), _extremes_init(m))
        (SX, ext), z = jax.lax.scan(
            step,
            init,
            (
                jnp.arange(cps),
                _chunked(ys),
                _chunked(swm),
                _chunked(mask),
                _chunked(rows),
                _chunked(signs),
            ),
        )
        # ONE collective for the strategy state, same as the two-pass pass 1
        SX = jax.lax.psum(SX, axis_name)
        outs = (z.reshape(per, width), SX)
        if hull:
            outs = outs + _extremes_cross_shard(ext, axis_name)
        return outs

    row = P(row_spec)
    in_specs = (P(row_spec, None), row, row, row, row)
    if q:
        in_specs = in_specs + (P(None, None),)
    if hull:
        in_specs = in_specs + (P(None, None),)
    out_specs = (P(row_spec, None), P(None, None))
    if hull:
        out_specs = out_specs + (P(None), P(None))
    return shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_vma=False,
    )


def make_segmented_pass_fns(
    featurize: Callable,
    mesh: Mesh,
    axes: tuple[str, ...],
    *,
    chunk: int,
    seg_chunks: int,
    total_chunks: int,
    rows_per_point: int,
    hull: bool,
    D: int,
    p: int,
    gram_dtype: str = "float32",
):
    """Segmented (resumable) variants of ``make_sharded_pass_fns``.

    Each call scans only ``seg_chunks`` of the ``total_chunks`` per-shard
    chunks and carries the PER-SHARD partial statistics in and out (leading
    shards axis, row-sharded) instead of psumming them — the cross-shard
    reduction happens exactly once, host-side, after the last segment. That
    preserves the per-shard accumulation order bit-for-bit across any
    interrupt/resume boundary, which is what makes the segmented sweeps
    (``DistributedScoringEngine.score(sweep_ckpt=...)``) resume
    bit-identically to their uninterrupted runs.

    pass1_seg(Y_seg, swm_seg, mask_seg, G, s1, s2) -> updated per-shard
    (shards, D, D)/(shards, p)/(shards, p, p) carries.
    pass2_seg: hull variant (…, V, inv, dirs, bmax, imax, bmin, imin, c0) ->
    (u_seg row-sharded, updated per-shard extremes); plain variant
    (…, V, inv) -> u_seg. ``c0`` is the replicated starting chunk index of
    the segment, so global hull row offsets stay exact mid-sweep.
    """
    r = rows_per_point
    per_full = total_chunks * chunk
    sizes = [mesh.shape[a] for a in axes]
    row_spec = _spec_el(axes)

    def _chunked(a):
        return a.reshape((seg_chunks, chunk) + a.shape[1:])

    def pass1_body(ys, swm, mask, G, s1, s2):
        def step(carry, xs):
            yc, swc, mc = xs
            X, Pr = featurize(yc)
            if hull:
                Pr = Pr * jnp.repeat(mc, r)[:, None]
            else:
                Pr = None
            return (
                pass1_update(
                    carry[0], carry[1], carry[2], X, Pr, swc, gram_dtype=gram_dtype
                ),
                None,
            )

        carry, _ = jax.lax.scan(
            step, (G[0], s1[0], s2[0]), (_chunked(ys), _chunked(swm), _chunked(mask))
        )
        # NO psum — the per-shard partials go back to the host checkpoint
        return carry[0][None], carry[1][None], carry[2][None]

    row = P(row_spec)
    pass1 = shard_map(
        pass1_body,
        mesh=mesh,
        in_specs=(
            P(row_spec, None),
            row,
            row,
            P(row_spec, None, None),
            P(row_spec, None),
            P(row_spec, None, None),
        ),
        out_specs=(
            P(row_spec, None, None),
            P(row_spec, None),
            P(row_spec, None, None),
        ),
        check_vma=False,
    )

    def pass2_hull_body(ys, swm, mask, V, inv, dirs, bmax, imax, bmin, imin, c0):
        base = _shard_index_fn(axes, sizes) * per_full

        def step(carry, xs):
            ci, yc, swc, mc = xs
            X, Pr = featurize(yc)
            u = leverage_chunk(X, swc, V, inv)
            pm = jnp.repeat(mc, r) > 0
            carry = _extremes_step(carry, Pr, dirs, pm, (base + (c0 + ci) * chunk) * r)
            return carry, u

        ext, u = jax.lax.scan(
            step,
            (bmax[0], imax[0], bmin[0], imin[0]),
            (jnp.arange(seg_chunks), _chunked(ys), _chunked(swm), _chunked(mask)),
        )
        return (u.reshape(seg_chunks * chunk),) + tuple(e[None] for e in ext)

    def pass2_body(ys, swm, V, inv):
        def step(_, xs):
            yc, swc = xs
            X, _ = featurize(yc)
            return None, leverage_chunk(X, swc, V, inv)

        _, u = jax.lax.scan(step, None, (_chunked(ys), _chunked(swm)))
        return u.reshape(seg_chunks * chunk)

    if hull:
        pass2 = shard_map(
            pass2_hull_body,
            mesh=mesh,
            in_specs=(
                P(row_spec, None),
                row,
                row,
                P(None, None),
                P(None),
                P(None, None),
                P(row_spec, None),
                P(row_spec, None),
                P(row_spec, None),
                P(row_spec, None),
                P(),
            ),
            out_specs=(
                row,
                P(row_spec, None),
                P(row_spec, None),
                P(row_spec, None),
                P(row_spec, None),
            ),
            check_vma=False,
        )
    else:
        pass2 = shard_map(
            pass2_body,
            mesh=mesh,
            in_specs=(P(row_spec, None), row, P(None, None), P(None)),
            out_specs=row,
            check_vma=False,
        )
    return pass1, pass2


def make_segmented_onepass_fn(
    featurize: Callable,
    mesh: Mesh,
    axes: tuple[str, ...],
    *,
    chunk: int,
    seg_chunks: int,
    total_chunks: int,
    rows_per_point: int,
    hull: bool,
    D: int,
    q: int | None,
    sketch_size: int,
):
    """Segmented (resumable) ``make_sharded_onepass_fn`` — see
    ``make_segmented_pass_fns`` for the per-shard carry contract. One call
    scans ``seg_chunks`` chunks, carrying the PER-SHARD CountSketch (and
    hull extremes) in and out with no psum, and emits that segment's
    sketch-projected z rows.

    fn(Y_seg, swm_seg, mask_seg, rows_seg, signs_seg, SX, c0, *extra) with
    extra = (Ω,) when ``q`` plus (bmax, imax, bmin, imin, dirs) when
    ``hull``; returns (z_seg row-sharded, SX' per-shard[, extremes']).
    """
    r = rows_per_point
    per_full = total_chunks * chunk
    sizes = [mesh.shape[a] for a in axes]
    row_spec = _spec_el(axes)
    width = q if q else D

    def _chunked(a):
        return a.reshape((seg_chunks, chunk) + a.shape[1:])

    def body(ys, swm, mask, rows, signs, SX, c0, *extra):
        i = 0
        omega = None
        if q:
            omega = extra[0]
            i = 1
        if hull:
            bmax, imax, bmin, imin, dirs = extra[i : i + 5]
        base = _shard_index_fn(axes, sizes) * per_full

        def step(carry, xs):
            SXc, ext = carry
            ci, yc, swc, mc, rc, sc = xs
            X, Pr = featurize(yc)
            # same fused op as the non-segmented sweep — the per-shard carry
            # layout (and so the segment checkpoints) is unchanged
            SXc, z, extb, _ = fused_sweep_update(
                SXc, X, Pr if hull else None, swc, rc, sc,
                dirs=dirs if hull else None, omega=omega,
                mask=mc if hull else None,
            )
            if hull:
                ext = _extremes_fold(
                    ext, extb, (base + (c0 + ci) * chunk) * r
                )
            return (SXc, ext), z

        ext0 = (bmax[0], imax[0], bmin[0], imin[0]) if hull else ()
        (SXc, ext), z = jax.lax.scan(
            step,
            (SX[0], ext0),
            (
                jnp.arange(seg_chunks),
                _chunked(ys),
                _chunked(swm),
                _chunked(mask),
                _chunked(rows),
                _chunked(signs),
            ),
        )
        outs = (z.reshape(seg_chunks * chunk, width), SXc[None])
        if hull:
            outs = outs + tuple(e[None] for e in ext)
        return outs

    row = P(row_spec)
    in_specs = (P(row_spec, None), row, row, row, row, P(row_spec, None, None), P())
    if q:
        in_specs = in_specs + (P(None, None),)
    if hull:
        in_specs = in_specs + (P(row_spec, None),) * 4 + (P(None, None),)
    out_specs = (P(row_spec, None), P(row_spec, None, None))
    if hull:
        out_specs = out_specs + (P(row_spec, None),) * 4
    return shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_vma=False,
    )


class DistributedScoringEngine:
    """Sharded + chunked pre-sampling phase of Algorithm 1 (see module doc).

    Same contract as ``scoring.ScoringEngine.score`` — returns an identical
    ``ScoringResult`` — but every data-sized computation runs inside the mesh:
    per-chip memory is O(chunk·J·d) and the only cross-chip traffic is one
    fused pass-1 psum and one pass-2 all_gather pair.

    Parameters mirror ``ScoringEngine``; ``featurize`` must be jax-traceable
    (it runs inside the shard_map scan body). ``axis`` may be one mesh axis
    name or a tuple of names (e.g. ``("pod", "data")`` on a multi-pod mesh).
    ``sketch_size > 0`` (or an explicit ``OnePassSketched`` strategy) routes
    through the fused one-pass sweep — each row featurized exactly once, the
    sketch state joining the single pass-1 psum.
    """

    def __init__(
        self,
        cfg=None,
        scaler=None,
        *,
        mesh: Mesh,
        axis="data",
        featurize: Callable | None = None,
        chunk_size: int | None = DEFAULT_CHUNK,
        rows_per_point: int | None = None,
        hull_oversample: int = 4,
        gram_dtype: str = "float32",
    ):
        if featurize is None:
            if cfg is None or scaler is None:
                raise ValueError("either (cfg, scaler) or featurize is required")
            featurize = _mctm_featurize(cfg, scaler)
            rows_per_point = cfg.J
        self.cfg = cfg
        self.scaler = scaler
        self.featurize = featurize
        self.mesh = mesh
        self.axes = _axis_tuple(axis)
        self.chunk_size = int(chunk_size) if chunk_size else 0
        self.rows_per_point = int(rows_per_point or 1)
        self.hull_oversample = hull_oversample
        self.gram_dtype = gram_dtype
        self._fns: dict = {}  # layout/strategy signature → jitted pass fns

    # --------------------------------------------------------------- helpers

    def _shard_layout(self, n: int) -> tuple[int, int, int]:
        """(chunk, chunks_per_shard, n_pad) for n rows over this mesh."""
        return shard_layout(self.mesh, self.axes, n, self.chunk_size)

    def _feature_shapes(self, chunk: int, hull: bool, width, dtype):
        sds = jax.ShapeDtypeStruct((chunk,) + width, dtype)
        X_s, P_s = jax.eval_shape(self.featurize, sds)
        if hull and P_s is None:
            raise ValueError("hull_k > 0 requires a featurize that returns P rows")
        D = int(X_s.shape[1])
        # without a hull stage s1/s2 stay zero — carry (and psum) scalars,
        # not a (p, p) dead weight the size of the Gram
        p = int(P_s.shape[1]) if (hull and P_s is not None) else 1
        return D, p

    def _pass_fns(self, chunk: int, cps: int, hull: bool, width, dtype, gram_dtype):
        D, p = self._feature_shapes(chunk, hull, width, dtype)
        key = ("two-pass", chunk, cps, hull, D, p, gram_dtype)
        if key not in self._fns:
            p1, p2 = make_sharded_pass_fns(
                self.featurize,
                self.mesh,
                self.axes,
                chunk=chunk,
                chunks_per_shard=cps,
                rows_per_point=self.rows_per_point,
                hull=hull,
                D=D,
                p=p,
                gram_dtype=gram_dtype,
            )
            self._fns[key] = (jax.jit(p1), jax.jit(p2))
        return self._fns[key]

    def _onepass_fn(
        self, chunk: int, cps: int, hull: bool, width, dtype, proj_size, sketch_size
    ):
        D, _ = self._feature_shapes(chunk, hull, width, dtype)
        # same normalization as OnePassSketched.begin: Ω only when it shrinks
        q = proj_size if (proj_size is not None and proj_size < D) else None
        key = ("one-pass", chunk, cps, hull, D, q, sketch_size)
        if key not in self._fns:
            fn = make_sharded_onepass_fn(
                self.featurize,
                self.mesh,
                self.axes,
                chunk=chunk,
                chunks_per_shard=cps,
                rows_per_point=self.rows_per_point,
                hull=hull,
                D=D,
                q=q,
                sketch_size=sketch_size,
            )
            self._fns[key] = (jax.jit(fn), D)
        return self._fns[key]

    def _segment_fns(self, chunk, seg, cps, hull, width, dtype, gram_dtype):
        D, p = self._feature_shapes(chunk, hull, width, dtype)
        key = ("seg-two-pass", chunk, seg, cps, hull, D, p, gram_dtype)
        if key not in self._fns:
            p1, p2 = make_segmented_pass_fns(
                self.featurize,
                self.mesh,
                self.axes,
                chunk=chunk,
                seg_chunks=seg,
                total_chunks=cps,
                rows_per_point=self.rows_per_point,
                hull=hull,
                D=D,
                p=p,
                gram_dtype=gram_dtype,
            )
            self._fns[key] = (jax.jit(p1), jax.jit(p2), D, p)
        return self._fns[key]

    def _segment_onepass_fn(
        self, chunk, seg, cps, hull, width, dtype, proj_size, sketch_size
    ):
        D, _ = self._feature_shapes(chunk, hull, width, dtype)
        q = proj_size if (proj_size is not None and proj_size < D) else None
        key = ("seg-one-pass", chunk, seg, cps, hull, D, q, sketch_size)
        if key not in self._fns:
            fn = make_segmented_onepass_fn(
                self.featurize,
                self.mesh,
                self.axes,
                chunk=chunk,
                seg_chunks=seg,
                total_chunks=cps,
                rows_per_point=self.rows_per_point,
                hull=hull,
                D=D,
                q=q,
                sketch_size=sketch_size,
            )
            self._fns[key] = (jax.jit(fn), D, q)
        return self._fns[key]

    def _score_segmented(
        self, strat, key, Y, weights, method, ridge_reg, hull_k, hull_key,
        sweep_ckpt, resume, hull_dirs=None,
    ):
        """The resumable sweep driver: host-held per-shard partials, atomic
        segment checkpoints, ONE host-side cross-shard reduction at the end.

        The host keeps the full padded data (this path targets robustness,
        not peak scale) and stages one segment's rows at a time; the device
        never holds more than a segment. Checkpoint payloads have fixed
        shapes for a given (n, mesh, chunk) layout — resume requires the
        same layout that wrote the sweep checkpoints.
        """
        from repro.checkpoint.manager import CheckpointManager
        from repro.ft.config import get_ft_config, maybe_inject

        r = self.rows_per_point
        hull = hull_k > 0
        Y = np.asarray(Y)
        n = int(Y.shape[0])
        if n == 0:
            raise ValueError("cannot score an empty dataset")
        chunk, cps, n_pad = self._shard_layout(n)
        shards = _num_shards(self.mesh, self.axes)
        per = cps * chunk
        pad = n_pad - n
        dtype = jax.dtypes.canonicalize_dtype(Y.dtype)
        if pad:
            Y_pad = np.concatenate(
                [Y, np.broadcast_to(Y[:1], (pad,) + Y.shape[1:])], axis=0
            )
        else:
            Y_pad = Y
        Y_pad = np.ascontiguousarray(Y_pad, dtype)
        mask = (np.arange(n_pad) < n).astype(np.float32)
        sw = (
            np.sqrt(np.asarray(weights, np.float32))
            if weights is not None
            else np.ones((n,), np.float32)
        )
        swm = np.concatenate([sw, np.zeros((pad,), np.float32)]) if pad else sw

        root = getattr(sweep_ckpt, "directory", sweep_ckpt)
        every = max(int(get_ft_config().sweep_ckpt_every_chunks), 1)
        mgr1 = CheckpointManager(os.path.join(root, "sweep1"), keep=2)
        mgr2 = CheckpointManager(os.path.join(root, "sweep2"), keep=2)

        def seg_rows(arr, c0, c1):
            # global layout is row-sharded: shard s owns rows [s·per, (s+1)·per);
            # a segment takes each shard's chunks [c0, c1)
            tail = arr.shape[1:]
            a = arr.reshape((shards, per) + tail)[:, c0 * chunk : c1 * chunk]
            return np.ascontiguousarray(
                a.reshape((shards * (c1 - c0) * chunk,) + tail)
            )

        def segments(done):
            c0 = done
            while c0 < cps:
                yield c0, min(c0 + every, cps)
                c0 += every

        if isinstance(strat, OnePassSketched):
            return self._segmented_one_pass(
                strat, key, Y_pad, swm, mask, n, n_pad, chunk, cps, shards,
                method, ridge_reg, hull_k, hull_key, dtype,
                mgr1, seg_rows, segments, maybe_inject, resume,
                hull_dirs=hull_dirs,
            )

        # ------------------------------------------------ two-pass, sweep 1
        f64 = strat.gram_dtype == "float64"
        _, _, D, p = self._segment_fns(
            chunk, min(every, cps), cps, hull, Y_pad.shape[1:], dtype,
            strat.gram_dtype,
        )
        G_h = np.zeros((shards, D, D), np.float64 if f64 else np.float32)
        s1_h = np.zeros((shards, p), np.float32)
        s2_h = np.zeros((shards, p, p), np.float32)
        done1 = 0

        def payload1():
            return {
                "chunks": np.asarray(done1, np.int64),
                "G": G_h,
                "s1": s1_h,
                "s2": s2_h,
            }

        if resume and mgr1.latest_step() is not None:
            got = mgr1.restore(payload1())
            done1 = int(got["chunks"])
            G_h, s1_h, s2_h = (
                np.asarray(got["G"]),
                np.asarray(got["s1"]),
                np.asarray(got["s2"]),
            )
        for c0, c1 in segments(done1):
            p1, _, _, _ = self._segment_fns(
                chunk, c1 - c0, cps, hull, Y_pad.shape[1:], dtype,
                strat.gram_dtype,
            )
            G_d, s1_d, s2_d = p1(
                self._shard_put(seg_rows(Y_pad, c0, c1)),
                self._shard_put(seg_rows(swm, c0, c1)),
                self._shard_put(seg_rows(mask, c0, c1)),
                self._shard_put(G_h),
                self._shard_put(s1_h),
                self._shard_put(s2_h),
            )
            G_h, s1_h, s2_h = (
                host_gather(G_d),
                host_gather(s1_d),
                host_gather(s2_d),
            )
            done1 = c1
            mgr1.save(done1, payload1())
            maybe_inject("scoring", done1)

        # one host-side cross-shard reduction (deterministic order — the
        # resumed and uninterrupted runs sum identical per-shard partials)
        G_tot = G_h.sum(axis=0)
        V, inv = projection_from_gram(G_tot, method, ridge_reg)
        dirs = None
        if hull:
            if hull_dirs is not None:
                dirs = np.asarray(hull_dirs, np.float32)
            else:
                dirs = np.asarray(
                    directions_from_moments(
                        hull_key, s1_h.sum(axis=0), s2_h.sum(axis=0), n * r,
                        hull_k, self.hull_oversample,
                    )
                )

        # ------------------------------------------------ two-pass, sweep 2
        m = int(dirs.shape[0]) if hull else 0
        u_h = np.zeros((shards, per), np.float32)
        bmax_h = np.full((shards, m), -np.inf, np.float32)
        imax_h = np.zeros((shards, m), np.int32)
        bmin_h = np.full((shards, m), np.inf, np.float32)
        imin_h = np.zeros((shards, m), np.int32)
        done2 = 0

        def payload2():
            d = {"chunks": np.asarray(done2, np.int64), "u": u_h}
            if hull:
                d.update(bmax=bmax_h, imax=imax_h, bmin=bmin_h, imin=imin_h)
            return d

        if resume and mgr2.latest_step() is not None:
            got = mgr2.restore(payload2())
            done2 = int(got["chunks"])
            u_h = np.asarray(got["u"])
            if hull:
                bmax_h, imax_h = np.asarray(got["bmax"]), np.asarray(got["imax"])
                bmin_h, imin_h = np.asarray(got["bmin"]), np.asarray(got["imin"])
        for c0, c1 in segments(done2):
            _, p2, _, _ = self._segment_fns(
                chunk, c1 - c0, cps, hull, Y_pad.shape[1:], dtype,
                strat.gram_dtype,
            )
            ys = self._shard_put(seg_rows(Y_pad, c0, c1))
            sws = self._shard_put(seg_rows(swm, c0, c1))
            if hull:
                u_seg, bmax_d, imax_d, bmin_d, imin_d = p2(
                    ys, sws, self._shard_put(seg_rows(mask, c0, c1)),
                    jnp.asarray(V), jnp.asarray(inv), jnp.asarray(dirs),
                    self._shard_put(bmax_h), self._shard_put(imax_h),
                    self._shard_put(bmin_h), self._shard_put(imin_h),
                    jnp.asarray(c0, jnp.int32),
                )
                bmax_h, imax_h = host_gather(bmax_d), host_gather(imax_d)
                bmin_h, imin_h = host_gather(bmin_d), host_gather(imin_d)
            else:
                u_seg = p2(ys, sws, jnp.asarray(V), jnp.asarray(inv))
            u_h[:, c0 * chunk : c1 * chunk] = host_gather(u_seg).reshape(
                shards, (c1 - c0) * chunk
            )
            done2 = c1
            mgr2.save(done2, payload2())
            maybe_inject("scoring", cps + done2)

        hull_rows = None
        if hull:
            hull_rows = self._reduce_extremes_host(
                bmax_h, imax_h, bmin_h, imin_h
            )
        u = u_h.reshape(n_pad)[:n]
        return finalize_scoring(n, cps * shards, method, G_tot, u, hull_rows, r)

    def _segmented_one_pass(
        self, strat, key, Y_pad, swm, mask, n, n_pad, chunk, cps, shards,
        method, ridge_reg, hull_k, hull_key, dtype,
        mgr1, seg_rows, segments, maybe_inject, resume, hull_dirs=None,
    ):
        """Segmented one-pass sketched sweep (single data sweep, resumable)."""
        r = self.rows_per_point
        hull = hull_k > 0
        per = cps * chunk
        pad = n_pad - n
        D, _ = self._feature_shapes(chunk, hull, Y_pad.shape[1:], dtype)
        q = (
            strat.proj_size
            if (strat.proj_size is not None and strat.proj_size < D)
            else None
        )
        width = q if q else D
        rows, signs, omega = strat.begin(n, D, key)
        rows = np.asarray(rows)
        signs = np.asarray(signs)
        if pad:
            rows = np.concatenate([rows, np.zeros((pad,), rows.dtype)])
            signs = np.concatenate([signs, np.zeros((pad,), signs.dtype)])
        dirs1 = None
        m = 0
        if hull:
            dirs1 = np.asarray(
                hull_dirs
                if hull_dirs is not None
                else upfront_directions(
                    hull_key, self._p_rows_width(chunk, Y_pad), hull_k,
                    self.hull_oversample,
                ),
                np.float32,
            )
            m = int(dirs1.shape[0])

        SX_h = np.zeros((shards, strat.sketch_size, D), np.float32)
        z_h = np.zeros((shards, per, width), np.float32)
        bmax_h = np.full((shards, m), -np.inf, np.float32)
        imax_h = np.zeros((shards, m), np.int32)
        bmin_h = np.full((shards, m), np.inf, np.float32)
        imin_h = np.zeros((shards, m), np.int32)
        done = 0

        def payload():
            d = {"chunks": np.asarray(done, np.int64), "SX": SX_h, "z": z_h}
            if hull:
                d.update(bmax=bmax_h, imax=imax_h, bmin=bmin_h, imin=imin_h)
            return d

        if resume and mgr1.latest_step() is not None:
            got = mgr1.restore(payload())
            done = int(got["chunks"])
            SX_h, z_h = np.asarray(got["SX"]), np.asarray(got["z"])
            if hull:
                bmax_h, imax_h = np.asarray(got["bmax"]), np.asarray(got["imax"])
                bmin_h, imin_h = np.asarray(got["bmin"]), np.asarray(got["imin"])
        for c0, c1 in segments(done):
            fn, _, _ = self._segment_onepass_fn(
                chunk, c1 - c0, cps, hull, Y_pad.shape[1:], dtype,
                strat.proj_size, strat.sketch_size,
            )
            extras = ()
            if omega is not None:
                extras = extras + (jnp.asarray(omega),)
            if hull:
                extras = extras + (
                    self._shard_put(bmax_h), self._shard_put(imax_h),
                    self._shard_put(bmin_h), self._shard_put(imin_h),
                    jnp.asarray(dirs1),
                )
            outs = fn(
                self._shard_put(seg_rows(Y_pad, c0, c1)),
                self._shard_put(seg_rows(swm, c0, c1)),
                self._shard_put(seg_rows(mask, c0, c1)),
                self._shard_put(seg_rows(rows, c0, c1)),
                self._shard_put(seg_rows(signs, c0, c1)),
                self._shard_put(SX_h),
                jnp.asarray(c0, jnp.int32),
                *extras,
            )
            z_h[:, c0 * chunk : c1 * chunk] = host_gather(outs[0]).reshape(
                shards, (c1 - c0) * chunk, width
            )
            SX_h = host_gather(outs[1])
            if hull:
                bmax_h, imax_h = host_gather(outs[2]), host_gather(outs[3])
                bmin_h, imin_h = host_gather(outs[4]), host_gather(outs[5])
            done = c1
            mgr1.save(done, payload())
            maybe_inject("scoring", done)

        SX_tot = SX_h.sum(axis=0)
        SXp = SX_tot if omega is None else SX_tot @ np.asarray(omega)
        V, inv = projection_from_gram(SXp.T @ SXp, method, ridge_reg)
        z_flat = z_h.reshape(n_pad, width)
        u = np.concatenate(
            [
                np.asarray(_z_leverage_jit(jnp.asarray(z_flat[lo : lo + per]), V, inv))
                for lo in range(0, n_pad, per)
            ]
        )[:n]
        hull_rows = None
        if hull:
            hull_rows = self._reduce_extremes_host(bmax_h, imax_h, bmin_h, imin_h)
        G_host = SX_tot.T @ SX_tot
        return finalize_scoring(n, cps * shards, method, G_host, u, hull_rows, r)

    @staticmethod
    def _reduce_extremes_host(bmax_h, imax_h, bmin_h, imin_h):
        """Host analogue of ``_extremes_cross_shard``: lowest shard wins ties,
        then first-occurrence dedup — matching the in-mesh reduction."""
        m = bmax_h.shape[1]
        cols = np.arange(m)
        gimax = imax_h[np.argmax(bmax_h, axis=0), cols]
        gimin = imin_h[np.argmax(-bmin_h, axis=0), cols]
        return stable_first_unique(
            np.concatenate([gimax, gimin]).astype(np.int64)
        )

    def _shard_put(self, x, row_sharded: bool = True):
        spec = (
            P(_spec_el(self.axes), *([None] * (x.ndim - 1)))
            if row_sharded
            else P(*([None] * x.ndim))
        )
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    # ---------------------------------------------------------------- public

    def stage_rows(self, blocks, n: int, width: int, dtype=jnp.float32):
        """Zero-copy sharded staging of n feature rows from host blocks.

        ``blocks`` iterates host arrays of shape (b_i, width) with Σb_i = n
        (any block sizes; O(chunk) each keeps host RSS at O(chunk·width)).
        Each block is split at shard boundaries and device_put straight to
        its target device(s); the padded row-sharded (n_pad, width) global
        array — the exact layout ``score`` uses — is assembled with
        ``make_array_from_single_device_arrays`` without ever materializing
        the (n, width) matrix on the host. Pass the result to
        ``score(..., n_valid=n)``.

        Single-process meshes only (every device must be addressable).
        """
        _, _, n_pad = self._shard_layout(n)
        sharding = NamedSharding(self.mesh, P(_spec_el(self.axes), None))
        dmap = sharding.devices_indices_map((n_pad, width))
        # devices grouped by their row range (replicated non-data axes mean
        # several devices can carry the same rows)
        by_range: dict[tuple[int, int], list] = {}
        for dev, idx in dmap.items():
            lo, hi, _ = idx[0].indices(n_pad)
            by_range.setdefault((lo, hi), []).append(dev)
        pieces: dict = {dev: [] for dev in dmap}
        off = 0
        first_row = None
        for block in blocks:
            block = np.asarray(block, dtype)
            if first_row is None and block.shape[0]:
                first_row = block[:1].copy()
            hi = off + block.shape[0]
            for (rlo, rhi), devs in by_range.items():
                a, b = max(off, rlo), min(hi, rhi)
                if a < b:
                    piece = block[a - off : b - off]
                    for dev in devs:
                        pieces[dev].append(jax.device_put(piece, dev))
            off = hi
        if off != n or first_row is None:
            raise ValueError(f"stage_rows: blocks carried {off} rows, expected {n}")
        shard_arrays = []
        for dev, idx in dmap.items():
            rlo, rhi, _ = idx[0].indices(n_pad)
            have = sum(int(p.shape[0]) for p in pieces[dev])
            want = rhi - rlo
            if have < want:
                # pad with copies of a REAL row, matching score()'s own
                # padding: zeros could featurize to NaN (e.g. log features)
                # and NaN·0 masking would poison the psum'd statistics
                pieces[dev].append(
                    jax.device_put(
                        np.broadcast_to(first_row, (want - have, width)).copy(), dev
                    )
                )
            ps = pieces[dev]
            shard_arrays.append(ps[0] if len(ps) == 1 else jnp.concatenate(ps))
        return jax.make_array_from_single_device_arrays(
            (n_pad, width), sharding, shard_arrays
        )

    def score(
        self,
        Y,
        *,
        method: str = "l2-hull",
        weights=None,
        hull_k: int = 0,
        hull_key: jax.Array | None = None,
        ridge_reg: float = 1.0,
        sketch_size: int = 0,
        key: jax.Array | None = None,
        strategy=None,
        gram_dtype: str | None = None,
        hull_dirs=None,
        n_valid: int | None = None,
        sweep_ckpt=None,
        resume: bool = False,
    ) -> ScoringResult:
        """Score all n points on the mesh; same semantics (and the same pass
        strategies) as the single-host ``ScoringEngine.score``.

        ``hull_dirs`` (m, p) overrides the hull direction net (identical
        semantics to ``ScoringEngine.score(hull_dirs=...)``) — the streaming
        maintainer passes the previous block's moment-derived net here.

        ``n_valid``: pass when ``Y`` was pre-staged with ``stage_rows`` —
        ``Y`` is then the already padded+sharded (n_pad, …) array and
        ``n_valid`` the true row count.

        ``sweep_ckpt``: directory (or ``CheckpointManager``-like object with
        ``.directory``) for resumable sweeps — the scan is split into
        segments of ``ft_config.sweep_ckpt_every_chunks`` chunks whose
        PER-SHARD partial state (Gram/moments or CountSketch, running hull
        extremes, scored rows, chunk cursor) checkpoints atomically between
        segments. ``resume=True`` picks up from the latest segment; because
        per-shard accumulation order is preserved and the cross-shard
        reduction runs once at the end, the resumed result is bit-identical
        to the uninterrupted segmented run (same mesh/chunk layout required).
        """
        if method not in SCORE_METHODS:
            raise ValueError(f"unknown scoring method: {method}")
        if hull_k > 0 and hull_key is None:
            raise ValueError("hull_k > 0 requires hull_key")
        if hull_dirs is not None and hull_k <= 0:
            raise ValueError("hull_dirs requires hull_k > 0")
        strat = resolve_strategy(
            strategy,
            sketch_size=sketch_size,
            gram_dtype=gram_dtype or self.gram_dtype,
        )
        if strat.needs_key and key is None:
            raise ValueError("sketch_size > 0 requires key")
        if isinstance(strat, TwoPassSketched):
            raise NotImplementedError(
                "TwoPassSketched is not sharded (a sketch caller has already "
                "accepted constant-factor scores — use the one-pass strategy)"
            )
        f64 = isinstance(strat, TwoPassExact) and strat.gram_dtype == "float64"
        if f64 and not jax.config.jax_enable_x64:
            raise ValueError(
                "gram_dtype='float64' on the sharded engine carries the Gram "
                "in f64 inside the mesh and requires x64 mode "
                "(JAX_ENABLE_X64=1); the single-host engine accumulates "
                "host-side instead and needs no flag"
            )
        if getattr(strat, "gram_dtype", "float32") == "float64" and not f64:
            # the sharded one-pass carries (and psums) an f32 CountSketch —
            # refuse a sketched f64 request instead of silently downcasting
            raise NotImplementedError(
                "gram_dtype='float64' sketched accumulation is single-host "
                "only (the sharded one-pass sweep carries an f32 sketch)"
            )
        r = self.rows_per_point
        hull = hull_k > 0

        if hull and int(np.shape(Y)[0]) * r > np.iinfo(np.int32).max:
            # the running-extreme carries hold global P-row ids as int32 (a
            # stable scan-carry dtype with or without x64); refuse loudly
            # instead of wrapping silently at pod-extreme n·r
            raise ValueError(
                "hull selection over more than 2^31-1 derivative rows would "
                "overflow the int32 hull-index carries; shard the input or "
                "reduce rows_per_point"
            )
        if sweep_ckpt is not None:
            if n_valid is not None:
                raise ValueError(
                    "sweep_ckpt is incompatible with pre-staged inputs "
                    "(n_valid): the segmented driver stages rows per segment"
                )
            return self._score_segmented(
                strat, key, Y, weights, method, ridge_reg, hull_k, hull_key,
                sweep_ckpt, resume, hull_dirs=hull_dirs,
            )
        if n_valid is not None:
            n = int(n_valid)
            chunk, cps, n_pad = self._shard_layout(n)
            if int(Y.shape[0]) != n_pad:
                raise ValueError(
                    f"staged input has {Y.shape[0]} rows but the layout for "
                    f"n={n} needs {n_pad} (use stage_rows)"
                )
            pad = n_pad - n
            Y_pad = Y
        else:
            Y = jnp.asarray(Y)
            n = int(Y.shape[0])
            chunk, cps, n_pad = self._shard_layout(n)
            pad = n_pad - n
            # pad with copies of row 0 (valid data — no NaN risk through the
            # featurizer); masks keep pads out of every statistic
            if pad:
                Y_pad = jnp.concatenate(
                    [Y, jnp.broadcast_to(Y[:1], (pad,) + Y.shape[1:])], axis=0
                )
            else:
                Y_pad = Y
            Y_pad = self._shard_put(Y_pad)
        if n == 0:
            raise ValueError("cannot score an empty dataset")
        mask = (jnp.arange(n_pad) < n).astype(jnp.float32)
        sw = (
            jnp.sqrt(jnp.asarray(weights, jnp.float32))
            if weights is not None
            else jnp.ones((n,), jnp.float32)
        )
        swm = jnp.concatenate([sw, jnp.zeros((pad,), jnp.float32)]) if pad else sw

        mask = self._shard_put(mask)
        swm = self._shard_put(swm)
        shards = _num_shards(self.mesh, self.axes)

        if isinstance(strat, OnePassSketched):
            u, G_host, hull_rows = self._score_one_pass(
                strat, key, Y_pad, swm, mask, n, n_pad, chunk, cps,
                method, ridge_reg, hull_k, hull_key, hull_dirs=hull_dirs,
            )
            return finalize_scoring(
                n, cps * shards, method, G_host, u, hull_rows, r
            )

        pass1, pass2 = self._pass_fns(
            chunk, cps, hull, Y_pad.shape[1:], Y_pad.dtype,
            strat.gram_dtype,
        )

        # ---- pass 1 (sharded, chunked): one fused psum of (G, Σp, Σppᵀ)
        G, s1, s2 = pass1(Y_pad, swm, mask)
        G_host = host_gather(G)

        # ---- between passes: (Jd)² host algebra, identical to single-host
        V, inv = projection_from_gram(G_host, method, ridge_reg)

        hull_rows = None
        if hull:
            if hull_dirs is not None:
                dirs = np.asarray(hull_dirs, np.float32)
            else:
                dirs = directions_from_moments(
                    hull_key,
                    host_gather(s1),
                    host_gather(s2),
                    n * r,
                    hull_k,
                    self.hull_oversample,
                )
            u_pad, gimax, gimin = pass2(Y_pad, swm, mask, V, inv, jnp.asarray(dirs))
            cand = np.concatenate(
                [host_gather(gimax), host_gather(gimin)]
            ).astype(np.int64)
            # every distinct candidate row, first-occurrence order — matching
            # the single-host engine (truncation to k points happens at the
            # coreset assembly via exact_hull_points)
            hull_rows = stable_first_unique(cand)
        else:
            u_pad = pass2(Y_pad, swm, V, inv)

        u = host_gather(u_pad)[:n]
        return finalize_scoring(n, cps * shards, method, G_host, u, hull_rows, r)

    def _score_one_pass(
        self, strat, key, Y_pad, swm, mask, n, n_pad, chunk, cps,
        method, ridge_reg, hull_k, hull_key, hull_dirs=None,
    ):
        """The sharded one-pass sweep: ONE data pass, ONE fused state psum."""
        r = self.rows_per_point
        hull = hull_k > 0
        fn, D = self._onepass_fn(
            chunk, cps, hull, Y_pad.shape[1:], Y_pad.dtype,
            strat.proj_size, strat.sketch_size,
        )
        # the global CountSketch plan — identical draws to the single-host
        # engine, so the two layouts emit the same estimates; pad entries
        # carry zero sign (and zero √w) so they cannot touch the sketch
        rows, signs, omega = strat.begin(n, D, key)
        pad = n_pad - n
        if pad:
            rows = jnp.concatenate([rows, jnp.zeros((pad,), rows.dtype)])
            signs = jnp.concatenate([signs, jnp.zeros((pad,), signs.dtype)])
        rows = self._shard_put(rows)
        signs = self._shard_put(signs)
        extras = ()
        if omega is not None:
            extras = extras + (omega,)
        dirs1 = None
        if hull:
            dirs1 = jnp.asarray(
                hull_dirs
                if hull_dirs is not None
                else upfront_directions(
                    hull_key, self._p_rows_width(chunk, Y_pad),
                    hull_k, self.hull_oversample,
                )
            )
            extras = extras + (dirs1,)

        outs = fn(Y_pad, swm, mask, rows, signs, *extras)
        z, SX = outs[:2]
        SX_host = host_gather(SX)
        SXp = SX_host if omega is None else SX_host @ np.asarray(omega)
        V, inv = projection_from_gram(SXp.T @ SXp, method, ridge_reg)
        u = host_gather(_z_leverage_jit(z, V, inv))[:n]
        hull_rows = None
        if hull:
            gimax, gimin = outs[2], outs[3]
            cand = np.concatenate(
                [host_gather(gimax), host_gather(gimin)]
            ).astype(np.int64)
            hull_rows = stable_first_unique(cand)
        G_host = SX_host.T @ SX_host  # reported Gram: the full sketched Gram
        return u, G_host, hull_rows

    def _p_rows_width(self, chunk, Y_pad) -> int:
        """Width p of the featurizer's P rows (for the upfront net)."""
        sds = jax.ShapeDtypeStruct((chunk,) + Y_pad.shape[1:], Y_pad.dtype)
        _, P_s = jax.eval_shape(self.featurize, sds)
        if P_s is None:
            raise ValueError("hull_k > 0 requires a featurize that returns P rows")
        return int(P_s.shape[1])


def distributed_build_coreset(
    cfg,
    scaler,
    Y,
    k: int,
    method: str = "l2-hull",
    *,
    mesh: Mesh,
    key: jax.Array,
    axis="data",
    alpha: float = 0.8,
    sketch_size: int = 0,
    chunk_size: int | None = DEFAULT_CHUNK,
    sweep_ckpt=None,
    resume: bool = False,
):
    """Paper Algorithm 1 with the pre-sampling phase fully distributed.

    Same contract (and same key-split structure) as ``coreset.build_coreset``
    — returns a ``CoresetResult`` — but scoring runs on ``mesh`` through the
    ``DistributedScoringEngine``. ``sketch_size > 0`` routes through the
    fused one-pass sketched sweep (each row featurized exactly once).
    ``sweep_ckpt``/``resume``: resumable segmented scoring sweeps — see
    ``DistributedScoringEngine.score``. The sampling step after scoring is a
    pure function of ``key``, so a resumed build draws the same coreset.
    """
    from repro.core.coreset import CoresetResult, coreset_from_scoring

    t0 = time.perf_counter()
    Y = np.asarray(Y)
    n = Y.shape[0]
    k = min(k, n)

    if method == "uniform":
        idx = np.asarray(jax.random.choice(key, n, shape=(k,), replace=False))
        w = np.full(k, n / k)
        return CoresetResult(idx, w, None, method, time.perf_counter() - t0)

    # same 3-way split as build_coreset (k_score feeds the sketch plan) so
    # the two paths draw identical samples when their scores agree
    k_score, k_hull_key, k_draw = jax.random.split(key, 3)
    k_hull = k - int(np.floor(alpha * k)) if method == "l2-hull" else 0
    engine = DistributedScoringEngine(
        cfg, scaler, mesh=mesh, axis=axis, chunk_size=chunk_size
    )
    res = engine.score(
        Y if sweep_ckpt is not None else jnp.asarray(Y),
        method=method,
        hull_k=k_hull,
        hull_key=k_hull_key,
        sketch_size=sketch_size,
        key=k_score if sketch_size > 0 else None,
        sweep_ckpt=sweep_ckpt,
        resume=resume,
    )
    return coreset_from_scoring(res, n, k, method, alpha, k_draw, t0)
