"""Merge & Reduce streaming coreset maintenance (paper §4, Geppert et al. 2020).

Insertion-only streams: incoming chunks are reduced to weighted coresets and
merged pairwise up a binary tree, keeping O(log(n/chunk)) buckets in memory.
Reduction of a *weighted* set uses weighted leverage scores (rows scaled by
√w leave the leverage definition intact) plus the hull augmentation, so the
stream result matches the batch construction up to the usual (1±ε) slack.

``sketch_size > 0`` routes every reduction through the engine's one-pass
sketched strategy (``scoring.OnePassSketched``): each block is featurized
and streamed exactly once per reduce — the pass shape merge-reduce assumes —
at a constant-factor cost in score accuracy.

Production stream consumption (``StreamingCoresetMaintainer``) layers three
things on top of the insertion-only tree (contract: ``docs/STREAMING.md``):

* **Windowing/decay policies** — ``"insertion"`` (the tree above),
  ``"sliding"`` (only the last W windows contribute: one reduced bucket per
  window, expired buckets evicted exactly), ``"decayed"`` (every live
  bucket's weights shrink by γ per window before the new window merges in,
  so the stream total matches the closed-form geometric sum — merge-reduce
  conserves mass, Lucic et al.'s composability). All per-window randomness
  is ``fold_in(base_key, window)``-derived, so an interrupted-and-resumed
  maintainer replays bit-identically.

* **Two-round streaming direction net** — each reduce with
  ``sketch_size > 0`` tracks the block's hull moments in the same fused
  one-pass sweep (``OnePassSketched(track_moments=True)``) and seeds the
  NEXT window's net via ``directions_from_moments`` + ``hull_dirs=``,
  fixing the one-pass identity-prior (coordinate-axes) weakness without
  re-streaming any block.

* **Drift detection → refit trigger** — every pushed window is scored
  against the live serving model with the fused streamed-NLL evaluator
  (``drift_window_nll``: one (Σw·nll, Σw) psum per window sweep on a mesh);
  ``DriftDetector`` EWMAs the per-window likelihood ratio against the
  published model's reference NLL and alerts when the measured band breaks,
  which (``auto_trigger=True``) calls
  ``DensityServeEngine.start_background_refit`` on the maintainer's own
  coreset — refits become drift-driven instead of caller-initiated.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.scoring import (
    DEFAULT_CHUNK,
    OnePassSketched,
    ScoringEngine,
    directions_from_moments,
)
from repro.ft.config import maybe_inject
from repro.utils.compat import shard_map

__all__ = [
    "WeightedSet",
    "MergeReduceCoreset",
    "StreamingCoresetMaintainer",
    "DriftDetector",
    "drift_window_nll",
    "make_sharded_drift_nll_fn",
    "STREAM_POLICIES",
]


@dataclasses.dataclass
class WeightedSet:
    Y: np.ndarray        # (m, J)
    weights: np.ndarray  # (m,)

    @property
    def size(self) -> int:
        return int(self.Y.shape[0])

    @staticmethod
    def concat(a: "WeightedSet", b: "WeightedSet") -> "WeightedSet":
        return WeightedSet(
            Y=np.concatenate([a.Y, b.Y], axis=0),
            weights=np.concatenate([a.weights, b.weights], axis=0),
        )


class MergeReduceCoreset:
    """Streaming coreset: push chunks, read `result()` any time."""

    def __init__(
        self,
        cfg: M.MCTMConfig,
        scaler: DataScaler,
        k: int,
        key: jax.Array,
        alpha: float = 0.8,
        chunk_size: int | None = DEFAULT_CHUNK,
        sketch_size: int = 0,
    ):
        self.cfg = cfg
        self.scaler = scaler
        self.k = k
        self.alpha = alpha
        self.sketch_size = sketch_size
        self._key = key
        self._buckets: list[WeightedSet | None] = []
        self.n_seen = 0
        # one engine for every reduce: shares the jitted featurize traces
        self._engine = ScoringEngine(cfg, scaler, chunk_size=chunk_size)

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _reduce(self, ws: WeightedSet, key: jax.Array) -> WeightedSet:
        """Weighted hybrid (ℓ2-hull) reduction of a weighted set to ≤ k points.

        ``key`` is consumed only here — ``push`` advances the stream state
        via ``_next_key`` while ``result`` derives a read-only key, so
        peeking at the stream never perturbs subsequent reductions.
        """
        if ws.size <= self.k:
            return ws
        k1 = int(np.floor(self.alpha * self.k))
        k2 = self.k - k1
        if self.sketch_size > 0:
            # extra stream for the sketch plan; the split count differs from
            # the exact path so existing exact streams replay unchanged
            draw_key, hull_key, score_key = jax.random.split(key, 3)
        else:
            draw_key, hull_key = jax.random.split(key)
            score_key = None
        # ONE engine sweep: √w-weighted leverage + hull extremes, chunked —
        # merged buckets larger than chunk_size never materialize (m, J, d),
        # and with sketch_size > 0 each block row is streamed exactly once
        res = self._engine.score(
            jnp.asarray(ws.Y),
            method="l2-hull",
            weights=ws.weights,
            hull_k=k2,
            hull_key=hull_key,
            sketch_size=self.sketch_size,
            key=score_key,
        )
        scores = res.scores
        probs = scores / scores.sum()
        idx = np.asarray(
            jax.random.choice(
                draw_key, ws.size, shape=(k1,), replace=True, p=jnp.asarray(probs)
            )
        )
        w = ws.weights[idx] / (k1 * probs[idx])
        if k2 > 0:
            # exactly k2 distinct points, direction-priority order, topped up
            # by score rank on dedup shortfall (low-diversity buckets)
            from repro.core.coreset import exact_hull_points

            hull_pts = exact_hull_points(res, scores, k2)
        else:
            hull_pts = np.zeros(0, np.int64)  # α=1.0 → pure sampling
        hull_w = ws.weights[hull_pts]
        # conserve total mass across reduce levels: rescale the sampled part
        # so Σw_out = Σw_in (hull weights kept exact, bias doesn't compound)
        total_in = ws.weights.sum()
        target = max(total_in - hull_w.sum(), 1e-9)
        w = w * (target / max(w.sum(), 1e-9))
        return WeightedSet(
            Y=np.concatenate([ws.Y[idx], ws.Y[hull_pts]], axis=0),
            weights=np.concatenate([w, hull_w], axis=0),
        )

    def push(self, chunk: np.ndarray) -> None:
        """Insert a data chunk; merge carries up the bucket tree."""
        chunk = np.asarray(chunk)
        self.n_seen += chunk.shape[0]
        carry = self._reduce(
            WeightedSet(chunk, np.ones(chunk.shape[0])), self._next_key()
        )
        level = 0
        while True:
            if level >= len(self._buckets):
                self._buckets.append(carry)
                return
            if self._buckets[level] is None:
                self._buckets[level] = carry
                return
            merged = WeightedSet.concat(self._buckets[level], carry)
            self._buckets[level] = None
            carry = self._reduce(merged, self._next_key())
            level += 1

    def result(self) -> WeightedSet:
        """Union of live buckets, reduced once more to ≤ k points.

        Idempotent and side-effect-free: the reduction key is derived with
        ``fold_in(key, n_seen)`` instead of advancing ``self._key``, so
        calling ``result()`` any number of times returns the same coreset
        and leaves the RNG stream of subsequent ``push`` calls untouched.
        """
        live = [b for b in self._buckets if b is not None]
        if not live:
            return WeightedSet(np.zeros((0, self.cfg.J)), np.zeros((0,)))
        acc = live[0]
        for b in live[1:]:
            acc = WeightedSet.concat(acc, b)
        return self._reduce(acc, jax.random.fold_in(self._key, self.n_seen))


# ---------------------------------------------------------------------------
# fused drift-NLL evaluator (the detector's measurement device)
# ---------------------------------------------------------------------------


# same caching discipline as mctm_fit's evaluator closures: keyed on
# (cfg, scaler bounds bytes[, mesh layout]) so per-window evaluation never
# retraces; never keyed on custom featurize closures
_DRIFT_CHUNK_CACHE: dict = {}
_DRIFT_SHARDED_CACHE: dict = {}


def _drift_chunk_fn(feat, cfg):
    @jax.jit
    def chunk_drift_nll(p, Yc, wc):
        A, Ap = feat(Yc)
        return jnp.sum(wc * M.nll_terms(cfg, p, A, Ap)), jnp.sum(wc)

    return chunk_drift_nll


def make_sharded_drift_nll_fn(feat, cfg, mesh, axes, chunk: int, cps: int):
    """Sharded per-window drift sweep: each shard ``lax.scan``s its
    (cps, chunk, J) row slices through featurize → nll_terms carrying the
    fused ``(Σw·nll, Σw)`` pair, then ONE psum of the pair closes the sweep
    — the drift analogue of ``mctm_fit._make_sharded_nll_fn`` (which psums a
    bare scalar; the drift detector needs the weighted-mass denominator in
    the same collective so a window evaluation is a single fused reduction).
    """
    axis_name = axes if len(axes) > 1 else axes[0]
    row_spec = axes if len(axes) > 1 else axes[0]

    def body(params, ys, wm):
        def step(carry, xs):
            yc, wc = xs
            A, Ap = feat(yc)
            tot, wsum = carry
            return (
                tot + jnp.sum(wc * M.nll_terms(cfg, params, A, Ap)),
                wsum + jnp.sum(wc),
            ), None

        (total, wsum), _ = jax.lax.scan(
            step,
            (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (ys.reshape((cps, chunk) + ys.shape[1:]), wm.reshape(cps, chunk)),
        )
        return jax.lax.psum((total, wsum), axis_name)

    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(row_spec, None), P(row_spec)),
            out_specs=P(),
            check_vma=False,
        )
    )


def drift_window_nll(
    cfg: M.MCTMConfig,
    scaler,
    params: M.MCTMParams,
    Y,
    weights=None,
    *,
    chunk: int | None = DEFAULT_CHUNK,
    mesh=None,
    axis="data",
) -> float:
    """Per-weighted-point NLL of one stream window under ``params``:
    ``Σw·nll / Σw`` streamed in O(chunk·J·d) memory.

    Single-host: a host chunk loop over the jitted fused ``(Σw·nll, Σw)``
    body. With ``mesh``: ONE fused pair psum per window sweep
    (``make_sharded_drift_nll_fn``, registered in the ``repro.analysis``
    collective census). The per-point normalization is what makes windows of
    different sizes comparable on the detector's ratio scale.
    """
    from repro.core.mctm_fit import fit_featurize

    feat = fit_featurize(cfg, scaler)
    Y = np.asarray(Y, np.float32)
    n = int(Y.shape[0])
    if n == 0:
        raise ValueError("cannot evaluate an empty window")
    w = (
        np.ones(n, np.float32)
        if weights is None
        else np.asarray(weights, np.float32)
    )
    ck = (
        cfg,
        None if scaler is None else np.asarray(scaler.low).tobytes(),
        None if scaler is None else np.asarray(scaler.high).tobytes(),
    )
    if mesh is None:
        c = int(chunk) if chunk else n
        fn = _DRIFT_CHUNK_CACHE.get(ck)
        if fn is None:
            if len(_DRIFT_CHUNK_CACHE) > 64:
                _DRIFT_CHUNK_CACHE.clear()
            fn = _drift_chunk_fn(feat, cfg)
            _DRIFT_CHUNK_CACHE[ck] = fn
        total = wsum = 0.0
        for lo in range(0, n, c):
            hi = min(lo + c, n)
            t, s = fn(p=params, Yc=jnp.asarray(Y[lo:hi]), wc=jnp.asarray(w[lo:hi]))
            total += float(t)
            wsum += float(s)
        return total / max(wsum, 1e-9)

    from repro.core.distributed_coreset import (
        _axis_tuple,
        host_gather,
        shard_layout,
    )

    axes = _axis_tuple(axis)
    chunk_v, cps, n_pad = shard_layout(mesh, axes, n, chunk)
    pad = n_pad - n
    if pad:
        Y = np.concatenate([Y, np.broadcast_to(Y[:1], (pad,) + Y.shape[1:])])
        w = np.concatenate([w, np.zeros(pad, np.float32)])
    cache_key = ck + (mesh, axes, chunk_v, cps)
    fn = _DRIFT_SHARDED_CACHE.get(cache_key)
    if fn is None:
        if len(_DRIFT_SHARDED_CACHE) > 64:
            _DRIFT_SHARDED_CACHE.clear()
        fn = make_sharded_drift_nll_fn(feat, cfg, mesh, axes, chunk_v, cps)
        _DRIFT_SHARDED_CACHE[cache_key] = fn
    total, wsum = fn(params, jnp.asarray(Y), jnp.asarray(w))
    return float(host_gather(total)) / max(float(host_gather(wsum)), 1e-9)


# ---------------------------------------------------------------------------
# drift detector
# ---------------------------------------------------------------------------


class DriftDetector:
    """EWMA band monitor over per-window likelihood ratios.

    Each window's per-point NLL under the *live serving model* is normalized
    against a reference anchor (``mctm_fit.likelihood_ratio`` — the paper
    tables' shift normalization, stable for non-positive NLLs) and smoothed
    with an EWMA. The detector fires when the smoothed ratio leaves the
    (1±eps) band after at least ``min_windows`` observations of the current
    model version — the streaming analogue of the (1±ε) coreset check.

    Anchor protocol: on the first observation of a model version the
    reference re-anchors — to ``ref_hint`` (the engine's recorded
    ``fit_nll_pp`` for that version: the model's NLL per weighted point on
    its own coreset) when available, else to that window's own NLL — and the
    anchor observation never fires. Re-anchoring on version change is what
    closes the loop: a drift-triggered refit publishes, the next window
    re-anchors on the new version, and the measured band is honest again.

    ``state()``/``load()`` round-trip the five scalars through the
    maintainer's window checkpoints, so a resumed stream replays alerts
    bit-identically.
    """

    def __init__(self, eps: float = 0.1, alpha: float = 0.4, min_windows: int = 2):
        if not (0.0 < alpha <= 1.0):
            raise ValueError("alpha must be in (0, 1]")
        if eps <= 0.0:
            raise ValueError("eps must be positive")
        self.eps = float(eps)
        self.alpha = float(alpha)
        self.min_windows = int(min_windows)
        self.ref_nll_pp: float | None = None
        self.ref_version = -1
        self.ewma = 1.0
        self.last_ratio = 1.0
        self.count = 0
        self.alerts = 0

    @property
    def eps_hat(self) -> float:
        """Measured band deviation |EWMA − 1| — the live ε̂."""
        return abs(self.ewma - 1.0)

    @property
    def in_band(self) -> bool:
        return self.eps_hat <= self.eps

    def observe(self, nll_pp: float, version: int = 0, ref_hint=None) -> bool:
        """Feed one window's per-point NLL; returns True when drift fires."""
        from repro.core.mctm_fit import likelihood_ratio

        nll_pp = float(nll_pp)
        if self.ref_nll_pp is None or int(version) != self.ref_version:
            self.ref_version = int(version)
            self.ref_nll_pp = (
                float(ref_hint) if ref_hint is not None else nll_pp
            )
            self.last_ratio = likelihood_ratio(nll_pp, self.ref_nll_pp)
            self.ewma = self.last_ratio
            self.count = 1
            return False
        self.last_ratio = likelihood_ratio(nll_pp, self.ref_nll_pp)
        self.ewma = (1.0 - self.alpha) * self.ewma + self.alpha * self.last_ratio
        self.count += 1
        fired = self.count >= self.min_windows and not self.in_band
        if fired:
            self.alerts += 1
        return fired

    def state(self) -> np.ndarray:
        """Checkpointable snapshot (f64 — exact scalar roundtrip)."""
        return np.asarray(
            [
                np.nan if self.ref_nll_pp is None else self.ref_nll_pp,
                self.ref_version,
                self.ewma,
                self.last_ratio,
                self.count,
                self.alerts,
            ],
            np.float64,
        )

    def load(self, s) -> None:
        s = np.asarray(s, np.float64)
        self.ref_nll_pp = None if np.isnan(s[0]) else float(s[0])
        self.ref_version = int(s[1])
        self.ewma = float(s[2])
        self.last_ratio = float(s[3])
        self.count = int(s[4])
        self.alerts = int(s[5])


# ---------------------------------------------------------------------------
# the production stream consumer
# ---------------------------------------------------------------------------


STREAM_POLICIES = ("insertion", "sliding", "decayed")


@dataclasses.dataclass
class _Bucket:
    """One live merge-reduce bucket: a reduced weighted set plus the window
    index that created it (eviction clock) and its tree level."""

    Y: np.ndarray
    w: np.ndarray
    birth: int
    level: int

    def as_ws(self) -> WeightedSet:
        return WeightedSet(self.Y, self.w)


class StreamingCoresetMaintainer:
    """Windowed/decayed merge-reduce over an unbounded stream, with a
    two-round direction net and an optional drift→refit loop (module doc).

    One ``push(chunk)`` = one stream *window*. Policies:

    ``"insertion"``
        The classic binary bucket tree — every window ever seen contributes
        (O(log windows) live buckets).
    ``"sliding"``
        Only the most recent ``window`` windows contribute: each push
        reduces its chunk to one level-0 bucket, and buckets whose birth
        falls off the horizon are dropped exactly (≤ ``window`` live
        buckets; ``result()`` reduces their union).
    ``"decayed"``
        The insertion tree, but every live bucket's weights are multiplied
        by ``decay`` (γ) before the new window merges in. Merge-reduce
        conserves weight mass, so after T equal windows of n rows the
        stream total is the closed-form geometric sum n·(1−γᵀ)/(1−γ).

    Determinism under resume: all randomness derives from
    ``fold_in(base_key, window)`` (never a sequentially advanced key), and
    ``ckpt_dir`` checkpoints the full maintainer state (buckets, moments,
    detector) atomically after every window, so crash → restore → re-push
    replays bit-identically (``tests/test_stream_maintainer.py``).

    Drift loop: with ``serve_engine`` and ``detector`` attached, every
    pushed window is evaluated against the engine's live slot
    (``drift_window_nll``); a fired detector (``auto_trigger=True``) calls
    ``engine.start_background_refit(scaler, coreset=result())`` — at most
    one refit in flight, publish lands between serving ticks.
    """

    def __init__(
        self,
        cfg: M.MCTMConfig,
        scaler: DataScaler,
        k: int,
        key: jax.Array,
        *,
        policy: str = "insertion",
        window: int = 0,
        decay: float = 1.0,
        alpha: float = 0.8,
        chunk_size: int | None = DEFAULT_CHUNK,
        sketch_size: int = 0,
        serve_engine=None,
        detector: DriftDetector | None = None,
        auto_trigger: bool = True,
        refit_kwargs: dict | None = None,
        drift_chunk: int | None = DEFAULT_CHUNK,
        drift_mesh=None,
        drift_axis="data",
        ckpt_dir: str | None = None,
    ):
        if policy not in STREAM_POLICIES:
            raise ValueError(
                f"unknown stream policy {policy!r} (expected one of "
                f"{STREAM_POLICIES})"
            )
        if policy == "sliding" and window < 1:
            raise ValueError("sliding policy requires window >= 1")
        if policy == "decayed" and not (0.0 < decay < 1.0):
            raise ValueError("decayed policy requires 0 < decay < 1")
        self.cfg = cfg
        self.scaler = scaler
        self.k = int(k)
        self.policy = policy
        self.window = int(window)
        self.decay = float(decay)
        self.alpha = float(alpha)
        self.sketch_size = int(sketch_size)
        self._key = key
        self._buckets: list[_Bucket | None] = []
        self.n_seen = 0
        self.windows_done = 0
        self._moments: tuple | None = None
        self._engine = ScoringEngine(cfg, scaler, chunk_size=chunk_size)
        self.serve_engine = serve_engine
        self.detector = detector
        self.auto_trigger = bool(auto_trigger)
        self.refit_kwargs = dict(refit_kwargs or {})
        self._drift_chunk = drift_chunk
        self._drift_mesh = drift_mesh
        self._drift_axis = drift_axis
        self.drift_log: list[dict] = []
        self.triggered = 0
        self._mgr = None
        if ckpt_dir is not None:
            from repro.checkpoint import CheckpointManager

            self._mgr = CheckpointManager(str(ckpt_dir), keep=2)

    # ------------------------------------------------------------- reduction

    def _reduce(self, ws: WeightedSet, key: jax.Array, *,
                update_moments: bool = True) -> WeightedSet:
        """Weighted ℓ2-hull reduction to ≤ k points (the merge-reduce kernel;
        same split structure as ``MergeReduceCoreset._reduce``), with the
        two-round net: ``sketch_size > 0`` seeds the one-pass direction net
        from the PREVIOUS block's hull moments (``hull_dirs=``) and tracks
        this block's moments in the same fused sweep for the next one.
        ``update_moments=False`` keeps the call side-effect-free
        (``result()`` idempotence)."""
        if ws.size <= self.k:
            return ws
        k1 = int(np.floor(self.alpha * self.k))
        k2 = self.k - k1
        if self.sketch_size > 0:
            draw_key, hull_key, score_key = jax.random.split(key, 3)
        else:
            draw_key, hull_key = jax.random.split(key)
            score_key = None
        strategy = None
        hull_dirs = None
        if self.sketch_size > 0:
            strategy = OnePassSketched(self.sketch_size, track_moments=True)
            if self._moments is not None and k2 > 0:
                s1, s2, n_rows = self._moments
                hull_dirs = directions_from_moments(
                    hull_key, s1, s2, n_rows, k2, self._engine.hull_oversample
                )
        res = self._engine.score(
            jnp.asarray(ws.Y),
            method="l2-hull",
            weights=ws.weights,
            hull_k=k2,
            hull_key=hull_key,
            sketch_size=self.sketch_size,
            key=score_key,
            strategy=strategy,
            hull_dirs=hull_dirs,
        )
        if update_moments and res.moments is not None:
            self._moments = res.moments
        scores = res.scores
        probs = scores / scores.sum()
        idx = np.asarray(
            jax.random.choice(
                draw_key, ws.size, shape=(k1,), replace=True, p=jnp.asarray(probs)
            )
        )
        w = ws.weights[idx] / (k1 * probs[idx])
        if k2 > 0:
            from repro.core.coreset import exact_hull_points

            hull_pts = exact_hull_points(res, scores, k2)
        else:
            hull_pts = np.zeros(0, np.int64)
        hull_w = ws.weights[hull_pts]
        total_in = ws.weights.sum()
        target = max(total_in - hull_w.sum(), 1e-9)
        w = w * (target / max(w.sum(), 1e-9))
        return WeightedSet(
            Y=np.concatenate([ws.Y[idx], ws.Y[hull_pts]], axis=0),
            weights=np.concatenate([w, hull_w], axis=0),
        )

    # ------------------------------------------------------------ maintenance

    def live_buckets(self) -> list[_Bucket]:
        return [b for b in self._buckets if b is not None]

    def live_births(self) -> list[int]:
        """Birth windows of the live buckets (eviction observability)."""
        return sorted(b.birth for b in self.live_buckets())

    def total_weight(self) -> float:
        return float(sum(b.w.sum() for b in self.live_buckets()))

    def push(self, chunk: np.ndarray) -> None:
        """Consume one stream window: reduce, maintain buckets per policy,
        observe drift, checkpoint. Crash-safe: the failure-injection point
        fires BEFORE any state mutates, so a killed window is simply
        re-pushed after restore."""
        chunk = np.asarray(chunk)
        widx = self.windows_done
        maybe_inject("streaming", widx + 1)
        def wsub(i: int):
            # per-(window, stage) subkey — stage 0 is the chunk reduce,
            # stage L+1 the level-L merge (bit-stable under resume: derived
            # from (base key, widx, stage), never a sequentially advanced key)
            return jax.random.fold_in(jax.random.fold_in(self._key, widx), i)

        fresh = WeightedSet(chunk, np.ones(chunk.shape[0]))

        if self.policy == "sliding":
            bucket_ws = self._reduce(fresh, wsub(0))
            self._buckets.append(
                _Bucket(bucket_ws.Y, bucket_ws.weights, birth=widx, level=0)
            )
            horizon = widx - self.window
            self._buckets = [
                b for b in self._buckets if b is not None and b.birth > horizon
            ]
        else:
            if self.policy == "decayed":
                for b in self._buckets:
                    if b is not None:
                        b.w = b.w * self.decay
            carry = self._reduce(fresh, wsub(0))
            level = 0
            while True:
                if level >= len(self._buckets):
                    self._buckets.append(
                        _Bucket(carry.Y, carry.weights, birth=widx, level=level)
                    )
                    break
                if self._buckets[level] is None:
                    self._buckets[level] = _Bucket(
                        carry.Y, carry.weights, birth=widx, level=level
                    )
                    break
                merged = WeightedSet.concat(self._buckets[level].as_ws(), carry)
                self._buckets[level] = None
                carry = self._reduce(merged, wsub(level + 1))
                level += 1

        self.windows_done = widx + 1
        self.n_seen += int(chunk.shape[0])
        if self.detector is not None and self.serve_engine is not None:
            self._observe_window(chunk, widx)
        if self._mgr is not None:
            self._mgr.save(self.windows_done, self.state_dict())

    def result(self) -> WeightedSet:
        """Union of live buckets, reduced once more to ≤ k points.

        Idempotent and side-effect-free (``MergeReduceCoreset.result``'s
        contract): the key derives from ``fold_in``, moments are read but
        never written, and the bucket state is untouched.
        """
        live = self.live_buckets()
        if not live:
            return WeightedSet(np.zeros((0, self.cfg.J)), np.zeros((0,)))
        acc = live[0].as_ws()
        for b in live[1:]:
            acc = WeightedSet.concat(acc, b.as_ws())
        rkey = jax.random.fold_in(
            jax.random.fold_in(self._key, 0x57E4), self.n_seen
        )
        return self._reduce(acc, rkey, update_moments=False)

    # ------------------------------------------------------------ drift loop

    def _observe_window(self, chunk: np.ndarray, widx: int) -> None:
        eng = self.serve_engine
        slot = eng.current_slot()
        nll_pp = drift_window_nll(
            self.cfg, self.scaler, slot.params, chunk,
            chunk=self._drift_chunk, mesh=self._drift_mesh,
            axis=self._drift_axis,
        )
        ref_hint = None
        for rec in reversed(eng.refit_log):
            if rec["version"] == slot.version:
                ref_hint = rec["fit_nll_pp"]
                break
        fired = self.detector.observe(
            nll_pp, version=slot.version, ref_hint=ref_hint
        )
        entry = {
            "window": widx,
            "version": int(slot.version),
            "nll_pp": float(nll_pp),
            "ratio": float(self.detector.last_ratio),
            "ewma": float(self.detector.ewma),
            "eps_hat": float(self.detector.eps_hat),
            "fired": bool(fired),
            "triggered": False,
        }
        if fired and self.auto_trigger:
            cs = self.result()
            if cs.size:
                th = eng.start_background_refit(
                    self.scaler,
                    coreset=(cs.Y, np.asarray(cs.weights, np.float32)),
                    key=jax.random.fold_in(
                        jax.random.fold_in(self._key, 0xD21F), widx
                    ),
                    **self.refit_kwargs,
                )
                if th is not None:
                    self.triggered += 1
                    entry["triggered"] = True
        self.drift_log.append(entry)

    # ---------------------------------------------------------- checkpointing

    def state_dict(self) -> dict[str, np.ndarray]:
        """Flat named-array snapshot of the full maintainer state — ragged
        bucket shapes round-trip through ``CheckpointManager.restore_flat``
        (the template-validated ``restore`` can't express them)."""
        out: dict[str, np.ndarray] = {
            "meta": np.asarray(
                [self.windows_done, self.n_seen, len(self._buckets)], np.int64
            ),
            "slots_birth": np.asarray(
                [-1 if b is None else b.birth for b in self._buckets], np.int64
            ),
            "slots_level": np.asarray(
                [-1 if b is None else b.level for b in self._buckets], np.int64
            ),
        }
        for i, b in enumerate(self._buckets):
            if b is not None:
                out[f"b{i:03d}_Y"] = np.asarray(b.Y)
                out[f"b{i:03d}_w"] = np.asarray(b.w)
        if self._moments is not None:
            s1, s2, n_rows = self._moments
            out["mom_s1"] = np.asarray(s1)
            out["mom_s2"] = np.asarray(s2)
            out["mom_n"] = np.asarray(n_rows, np.int64)
        if self.detector is not None:
            out["det"] = self.detector.state()
        return out

    def load_state(self, state: dict[str, np.ndarray]) -> None:
        meta = np.asarray(state["meta"], np.int64)
        self.windows_done = int(meta[0])
        self.n_seen = int(meta[1])
        n_slots = int(meta[2])
        births = np.asarray(state["slots_birth"], np.int64)
        levels = np.asarray(state["slots_level"], np.int64)
        self._buckets = []
        for i in range(n_slots):
            if births[i] < 0:
                self._buckets.append(None)
            else:
                self._buckets.append(
                    _Bucket(
                        np.asarray(state[f"b{i:03d}_Y"]),
                        np.asarray(state[f"b{i:03d}_w"]),
                        birth=int(births[i]),
                        level=int(levels[i]),
                    )
                )
        if "mom_s1" in state:
            self._moments = (
                np.asarray(state["mom_s1"]),
                np.asarray(state["mom_s2"]),
                int(np.asarray(state["mom_n"])),
            )
        else:
            self._moments = None
        if self.detector is not None and "det" in state:
            self.detector.load(state["det"])

    def resume(self) -> int:
        """Restore the latest window checkpoint from ``ckpt_dir`` (no-op
        without one). Returns the number of completed windows — the caller
        re-pushes the stream from there and the replay is bit-identical."""
        if self._mgr is None or self._mgr.latest_step() is None:
            return 0
        self.load_state(self._mgr.restore_flat())
        return self.windows_done
