"""Merge & Reduce streaming coreset maintenance (paper §4, Geppert et al. 2020).

Insertion-only streams: incoming chunks are reduced to weighted coresets and
merged pairwise up a binary tree, keeping O(log(n/chunk)) buckets in memory.
Reduction of a *weighted* set uses weighted leverage scores (rows scaled by
√w leave the leverage definition intact) plus the hull augmentation, so the
stream result matches the batch construction up to the usual (1±ε) slack.

``sketch_size > 0`` routes every reduction through the engine's one-pass
sketched strategy (``scoring.OnePassSketched``): each block is featurized
and streamed exactly once per reduce — the pass shape merge-reduce assumes —
at a constant-factor cost in score accuracy.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.scoring import DEFAULT_CHUNK, ScoringEngine

__all__ = ["WeightedSet", "MergeReduceCoreset"]


@dataclasses.dataclass
class WeightedSet:
    Y: np.ndarray        # (m, J)
    weights: np.ndarray  # (m,)

    @property
    def size(self) -> int:
        return int(self.Y.shape[0])

    @staticmethod
    def concat(a: "WeightedSet", b: "WeightedSet") -> "WeightedSet":
        return WeightedSet(
            Y=np.concatenate([a.Y, b.Y], axis=0),
            weights=np.concatenate([a.weights, b.weights], axis=0),
        )


class MergeReduceCoreset:
    """Streaming coreset: push chunks, read `result()` any time."""

    def __init__(
        self,
        cfg: M.MCTMConfig,
        scaler: DataScaler,
        k: int,
        key: jax.Array,
        alpha: float = 0.8,
        chunk_size: int | None = DEFAULT_CHUNK,
        sketch_size: int = 0,
    ):
        self.cfg = cfg
        self.scaler = scaler
        self.k = k
        self.alpha = alpha
        self.sketch_size = sketch_size
        self._key = key
        self._buckets: list[WeightedSet | None] = []
        self.n_seen = 0
        # one engine for every reduce: shares the jitted featurize traces
        self._engine = ScoringEngine(cfg, scaler, chunk_size=chunk_size)

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _reduce(self, ws: WeightedSet, key: jax.Array) -> WeightedSet:
        """Weighted hybrid (ℓ2-hull) reduction of a weighted set to ≤ k points.

        ``key`` is consumed only here — ``push`` advances the stream state
        via ``_next_key`` while ``result`` derives a read-only key, so
        peeking at the stream never perturbs subsequent reductions.
        """
        if ws.size <= self.k:
            return ws
        k1 = int(np.floor(self.alpha * self.k))
        k2 = self.k - k1
        if self.sketch_size > 0:
            # extra stream for the sketch plan; the split count differs from
            # the exact path so existing exact streams replay unchanged
            draw_key, hull_key, score_key = jax.random.split(key, 3)
        else:
            draw_key, hull_key = jax.random.split(key)
            score_key = None
        # ONE engine sweep: √w-weighted leverage + hull extremes, chunked —
        # merged buckets larger than chunk_size never materialize (m, J, d),
        # and with sketch_size > 0 each block row is streamed exactly once
        res = self._engine.score(
            jnp.asarray(ws.Y),
            method="l2-hull",
            weights=ws.weights,
            hull_k=k2,
            hull_key=hull_key,
            sketch_size=self.sketch_size,
            key=score_key,
        )
        scores = res.scores
        probs = scores / scores.sum()
        idx = np.asarray(
            jax.random.choice(
                draw_key, ws.size, shape=(k1,), replace=True, p=jnp.asarray(probs)
            )
        )
        w = ws.weights[idx] / (k1 * probs[idx])
        if k2 > 0:
            # exactly k2 distinct points, direction-priority order, topped up
            # by score rank on dedup shortfall (low-diversity buckets)
            from repro.core.coreset import exact_hull_points

            hull_pts = exact_hull_points(res, scores, k2)
        else:
            hull_pts = np.zeros(0, np.int64)  # α=1.0 → pure sampling
        hull_w = ws.weights[hull_pts]
        # conserve total mass across reduce levels: rescale the sampled part
        # so Σw_out = Σw_in (hull weights kept exact, bias doesn't compound)
        total_in = ws.weights.sum()
        target = max(total_in - hull_w.sum(), 1e-9)
        w = w * (target / max(w.sum(), 1e-9))
        return WeightedSet(
            Y=np.concatenate([ws.Y[idx], ws.Y[hull_pts]], axis=0),
            weights=np.concatenate([w, hull_w], axis=0),
        )

    def push(self, chunk: np.ndarray) -> None:
        """Insert a data chunk; merge carries up the bucket tree."""
        chunk = np.asarray(chunk)
        self.n_seen += chunk.shape[0]
        carry = self._reduce(
            WeightedSet(chunk, np.ones(chunk.shape[0])), self._next_key()
        )
        level = 0
        while True:
            if level >= len(self._buckets):
                self._buckets.append(carry)
                return
            if self._buckets[level] is None:
                self._buckets[level] = carry
                return
            merged = WeightedSet.concat(self._buckets[level], carry)
            self._buckets[level] = None
            carry = self._reduce(merged, self._next_key())
            level += 1

    def result(self) -> WeightedSet:
        """Union of live buckets, reduced once more to ≤ k points.

        Idempotent and side-effect-free: the reduction key is derived with
        ``fold_in(key, n_seen)`` instead of advancing ``self._key``, so
        calling ``result()`` any number of times returns the same coreset
        and leaves the RNG stream of subsequent ``push`` calls untouched.
        """
        live = [b for b in self._buckets if b is not None]
        if not live:
            return WeightedSet(np.zeros((0, self.cfg.J)), np.zeros((0,)))
        acc = live[0]
        for b in live[1:]:
            acc = WeightedSet.concat(acc, b)
        return self._reduce(acc, jax.random.fold_in(self._key, self.n_seen))
