"""ℓ2 leverage scores for the MCTM block matrix B (paper Section 2, part 1).

Structural reduction (verified in tests/test_leverage.py): the paper's
B ∈ R^{nJ×dJ²} repeats the row vector b_i = (a_{i1},…,a_{iJ}) ∈ R^{dJ} in J
disjoint column blocks, so BᵀB = blockdiag(ÃᵀÃ ×J) with Ã ∈ R^{n×dJ} the
per-point concatenated basis matrix. The leverage of B-row (i,j) equals the
leverage of Ã-row i for every j — we therefore compute leverage scores of the
small matrix Ã. This is exactly what makes the scheme TPU/cluster friendly:
the Gram ÃᵀÃ is a psum over data shards followed by one tiny host eigh.

Variants implemented (all used as baselines in the paper's Table 2):
  - exact via QR                      (`leverage_scores_qr`)
  - exact via Gram + eigh pinv        (`leverage_scores_gram`)
  - sketched (CountSketch + QR), Woodruff (2014) Thm 2.13   (`sketched_leverage`)
  - ridge leverage scores             (`ridge_leverage_scores`)
  - root leverage scores              (`root_leverage_scores`)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "flatten_features",
    "block_B_matrix",
    "leverage_scores_qr",
    "leverage_scores_gram",
    "leverage_from_gram",
    "sketched_leverage",
    "ridge_leverage_scores",
    "root_leverage_scores",
]


def flatten_features(A: jax.Array) -> jax.Array:
    """(n, J, d) basis tensor → Ã ∈ (n, J·d) with rows b_i."""
    n = A.shape[0]
    return A.reshape(n, -1)


def block_B_matrix(A: np.ndarray) -> np.ndarray:
    """Explicit paper matrix B ∈ R^{nJ × dJ²} (tests / small n only).

    Row (i, j) carries b_i in column block j: B[(i·J)+j, j·dJ:(j+1)·dJ] = b_i.
    """
    A = np.asarray(A)
    n, J, d = A.shape
    b = A.reshape(n, J * d)
    B = np.zeros((n * J, J * J * d), dtype=A.dtype)
    for i in range(n):
        for j in range(J):
            B[i * J + j, j * J * d : (j + 1) * J * d] = b[i]
    return B


@jax.jit
def leverage_scores_qr(X: jax.Array) -> jax.Array:
    """Exact leverage scores via thin QR: u_i = ||Q_i||²."""
    Q, _ = jnp.linalg.qr(X)
    return jnp.sum(jnp.square(Q), axis=1)


@jax.jit
def leverage_from_gram(X: jax.Array, G: jax.Array, rcond: float = 1e-6) -> jax.Array:
    """u_i = X_i G⁺ X_iᵀ given a (possibly psum-accumulated) Gram G = XᵀX.

    Eigendecomposition pseudo-inverse handles rank deficiency (e.g. Bernstein
    bases are a partition of unity, so intercept columns introduce collinearity).

    ``rcond`` must sit ABOVE the f32 summation noise floor (~1e-8·λmax): an
    exactly-null mode surfaces from eigh at ±O(1e-8)·λmax, and a threshold
    below that would include it — with an enormous 1/λ weight — depending on
    nothing but accumulation order (dense vs chunked vs psum grams would
    disagree wildly).
    """
    w, V = jnp.linalg.eigh(G)
    wmax = jnp.max(jnp.abs(w))
    inv = jnp.where(w > rcond * wmax, 1.0 / jnp.maximum(w, 1e-30), 0.0)
    P = X @ V  # (n, D)
    return jnp.sum(jnp.square(P) * inv, axis=1)


@jax.jit
def leverage_scores_gram(X: jax.Array) -> jax.Array:
    return leverage_from_gram(X, X.T @ X)


@partial(jax.jit, static_argnames=("sketch_size",))
def sketched_leverage(X: jax.Array, key: jax.Array, sketch_size: int) -> jax.Array:
    """Constant-factor approximate leverage scores via CountSketch + QR.

    S is a CountSketch (one ±1 per column of Sᵀ); R from QR(SX) gives
    u_i ≈ ||X_i R⁻¹||². Runs in O(nnz(X)) + poly(D) exactly as the paper's
    Algorithm 1 prescribes ("fast leverage score computation, Woodruff Thm 2.13").
    """
    n, D = X.shape
    k1, k2 = jax.random.split(key)
    rows = jax.random.randint(k1, (n,), 0, sketch_size)
    signs = jax.random.rademacher(k2, (n,), dtype=X.dtype)
    SX = jnp.zeros((sketch_size, D), X.dtype).at[rows].add(signs[:, None] * X)
    # R may be singular if sketch under-samples: fall back to Gram pinv form.
    G = SX.T @ SX
    return leverage_from_gram(X, G)


@jax.jit
def ridge_leverage_scores(X: jax.Array, reg: float = 1.0) -> jax.Array:
    """u_i(λ) = X_i (XᵀX + λI)⁻¹ X_iᵀ (baseline `ridge-lss`)."""
    D = X.shape[1]
    G = X.T @ X + reg * jnp.eye(D, dtype=X.dtype)
    sol = jnp.linalg.solve(G, X.T)  # (D, n)
    return jnp.sum(X * sol.T, axis=1)


def root_leverage_scores(X: jax.Array) -> jax.Array:
    """sqrt(u_i) scores (baseline `root-l2`) — flattens the sampling distribution."""
    return jnp.sqrt(jnp.clip(leverage_scores_gram(X), 0.0, None))
