"""Multivariate Conditional Transformation Models (Klein et al. 2022) in JAX.

Model: Z = Λ h̃(Y) ~ N(0, I) with Λ unit lower triangular and
h̃_j(y) = a_j(y)ᵀ ϑ_j a monotone Bernstein expansion. Negative log-likelihood
of point y_i (paper Eq. 1, plus the Gaussian constant so likelihood *ratios*
are meaningful):

    Σ_j ½ (Σ_{l<j} λ_{jl} h̃_l(y_il) + h̃_j(y_ij))² − log h̃'_j(y_ij)
        + J/2 log(2π)

This module is the pure-model layer: parameter pytrees, NLL, sampling, and a
(weighted) maximum-likelihood fit — everything the coreset layer needs to
reproduce the paper's experiments.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bernstein import (
    DataScaler,
    bernstein_deriv_design,
    bernstein_design,
    monotone_theta,
)

LOG_2PI = float(np.log(2.0 * np.pi))


@dataclasses.dataclass(frozen=True)
class MCTMConfig:
    """Static model configuration."""

    J: int                   # output dimension
    degree: int = 6          # Bernstein degree M; d = degree + 1 coefficients
    eta: float = 1e-3        # D(η) floor for the log-Jacobian term (paper: η = 2ε)
    min_slope: float = 1e-4  # strict-monotonicity margin of ϑ

    @property
    def d(self) -> int:
        return self.degree + 1

    @property
    def n_params(self) -> int:
        return self.J * self.d + self.J * (self.J - 1) // 2


class MCTMParams(NamedTuple):
    """Unconstrained parameters: ϑ via cumulative-softplus, λ strict-lower."""

    theta_raw: jax.Array  # (J, d)
    lam: jax.Array        # (J*(J-1)//2,) strict lower-triangular entries


def init_params(key: jax.Array, cfg: MCTMConfig, dtype=jnp.float32) -> MCTMParams:
    k1, _ = jax.random.split(key)
    # Start near the identity transform: h̃(y) ≈ 4·t − 2 (covers N(0,1) mass).
    base = jnp.linspace(-2.0, 2.0, cfg.d, dtype=dtype)
    from repro.core.bernstein import monotone_theta_inverse

    theta_raw = jnp.tile(monotone_theta_inverse(base, cfg.min_slope), (cfg.J, 1))
    theta_raw = theta_raw + 0.01 * jax.random.normal(k1, theta_raw.shape, dtype)
    lam = jnp.zeros((cfg.J * (cfg.J - 1) // 2,), dtype)
    return MCTMParams(theta_raw=theta_raw, lam=lam)


def lambda_matrix(cfg: MCTMConfig, lam_flat: jax.Array) -> jax.Array:
    """Unit lower-triangular Λ from the flat strict-lower entries."""
    J = cfg.J
    eye = jnp.eye(J, dtype=lam_flat.dtype)
    if J == 1:
        return eye
    # static indices: np, not jnp — jnp.tril_indices traces a tril(ones(J,J))
    # mask at the default float dtype (f64 under JAX_ENABLE_X64)
    rows, cols = np.tril_indices(J, k=-1)
    return eye.at[rows, cols].set(lam_flat)


def basis_features(
    cfg: MCTMConfig, scaler: DataScaler, Y: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Evaluate (A, A′): a_j(y_ij) and d/dy a_j(y_ij), shapes (n, J, d)."""
    T = scaler.transform(Y)  # (n, J) in [0,1]
    A = bernstein_design(T, cfg.degree)
    Ap = bernstein_deriv_design(T, cfg.degree) * jnp.asarray(
        scaler.inv_span, dtype=T.dtype
    )[..., None]
    return A, Ap


def transform_parts(
    cfg: MCTMConfig, params: MCTMParams, A: jax.Array, Ap: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Return (z, h̃, h̃′): copula inputs and marginal transform/derivative."""
    theta = monotone_theta(params.theta_raw, cfg.min_slope)  # (J, d)
    htilde = jnp.einsum("njd,jd->nj", A, theta)
    hprime = jnp.einsum("njd,jd->nj", Ap, theta)
    Lam = lambda_matrix(cfg, params.lam)
    z = htilde @ Lam.T  # z_ij = Σ_{k≤j} λ_{jk} h̃_k(y_ik)
    return z, htilde, hprime


def nll_terms(
    cfg: MCTMConfig, params: MCTMParams, A: jax.Array, Ap: jax.Array
) -> jax.Array:
    """Per-point negative log-likelihood contributions, shape (n,)."""
    z, _, hprime = transform_parts(cfg, params, A, Ap)
    # D(η): floor the Jacobian term away from the log's asymptote. With the
    # monotone reparameterization hprime > 0 always; the floor additionally
    # realizes the paper's η-shifted domain for *unconstrained* parameters.
    log_jac = jnp.log(jnp.maximum(hprime, cfg.eta))
    per_dim = 0.5 * jnp.square(z) - log_jac + 0.5 * LOG_2PI
    return jnp.sum(per_dim, axis=-1)


def nll(
    cfg: MCTMConfig,
    params: MCTMParams,
    A: jax.Array,
    Ap: jax.Array,
    weights: jax.Array | None = None,
) -> jax.Array:
    """(Weighted) total negative log-likelihood — the paper's f(A, ϑ, λ)."""
    terms = nll_terms(cfg, params, A, Ap)
    if weights is None:
        return jnp.sum(terms)
    return jnp.sum(weights * terms)


def loss_parts(
    cfg: MCTMConfig,
    params: MCTMParams,
    A: jax.Array,
    Ap: jax.Array,
    weights: jax.Array | None = None,
) -> dict[str, jax.Array]:
    """The paper's split f = f1 (squared) + f2 (log⁺) − ... per Section 2.

    f1 = ½ Σ w_ij z_ij²;  f2 = Σ w_ij max(log h̃′, 0);  f3 = Σ w_ij max(−log h̃′, 0).
    """
    z, _, hprime = transform_parts(cfg, params, A, Ap)
    log_jac = jnp.log(jnp.maximum(hprime, cfg.eta))
    w = jnp.ones(z.shape[0], z.dtype) if weights is None else weights
    w = w[:, None]
    return {
        "f1": 0.5 * jnp.sum(w * jnp.square(z)),
        "f2": jnp.sum(w * jnp.maximum(log_jac, 0.0)),
        "f3": jnp.sum(w * jnp.maximum(-log_jac, 0.0)),
    }


# ---------------------------------------------------------------------------
# Fitting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FitResult:
    params: MCTMParams
    losses: np.ndarray
    final_nll: float


def fit_mctm(
    cfg: MCTMConfig,
    scaler: DataScaler,
    Y: jax.Array,
    weights: jax.Array | None = None,
    *,
    key: jax.Array | None = None,
    init: MCTMParams | None = None,
    steps: int = 1500,
    lr: float = 5e-2,
    method: str = "adam",
    mesh=None,
    chunk_size: int | None = None,
    microbatches: int | None = None,
    batch_size: int | None = None,
    optimizer=None,
    checkpoint=None,
    ckpt_every: int = 0,
    resume: bool = False,
) -> FitResult:
    """Weighted maximum-likelihood fit of an MCTM.

    ``weights`` are the coreset weights (None → unweighted full-data fit).
    The mean-normalized objective keeps the lr scale-free across coreset
    sizes.

    ``method`` selects a fit-subsystem mode (``repro.core.mctm_fit`` — see
    its module-doc method table): ``"adam"`` full-batch first-order,
    ``"lbfgs"`` streaming-HVP quasi-Newton (``steps`` are iterations), or
    ``"minibatch"`` (``batch_size`` sampled weighted rows per step). All
    three stream the basis microbatch-by-microbatch (inputs beyond
    ``chunk_size`` rows — default ``scoring.DEFAULT_CHUNK`` — never
    materialize an (n, J, d) tensor), run SPMD-sharded with ``mesh=``, and
    support ``checkpoint=`` (a ``CheckpointManager``) periodic saves +
    ``resume=True`` restart. ``method="scipy-lbfgs"`` is the dense small-n
    oracle kept for tests (scipy L-BFGS-B on a materialized basis).
    """
    if init is None:
        if key is None:
            key = jax.random.PRNGKey(0)
        init = init_params(key, cfg)
    if method in ("adam", "lbfgs", "minibatch"):
        from repro.core import mctm_fit
        from repro.core.scoring import DEFAULT_CHUNK

        return mctm_fit.fit_mctm_streaming(
            cfg,
            scaler,
            Y,
            weights,
            init=init,
            steps=steps,
            lr=lr,
            optimizer=optimizer,
            method=method,
            mesh=mesh,
            chunk_size=DEFAULT_CHUNK if chunk_size is None else chunk_size,
            microbatches=microbatches,
            batch_size=batch_size,
            checkpoint=checkpoint,
            ckpt_every=ckpt_every,
            resume=resume,
        )
    if method != "scipy-lbfgs":
        raise ValueError(f"unknown fit method: {method}")

    Yj = jnp.asarray(Y)
    wj = None if weights is None else jnp.asarray(weights)
    total_w = float(Y.shape[0]) if weights is None else float(jnp.sum(wj))

    def loss_fn(params: MCTMParams) -> jax.Array:
        # featurize INSIDE the (jitted) objective: the (n, J, d) basis exists
        # only for the duration of each evaluation instead of sitting in this
        # closure for the whole optimize
        A, Ap = basis_features(cfg, scaler, Yj)
        return nll(cfg, params, A, Ap, wj) / total_w

    params, losses = _scipy_lbfgs_fit(loss_fn, init)
    final = float(jax.jit(loss_fn)(params)) * total_w
    return FitResult(params=params, losses=np.asarray(losses), final_nll=final)


def _scipy_lbfgs_fit(loss_fn, params0: MCTMParams):
    """L-BFGS-B via scipy on the flattened parameter vector — the dense
    small-n oracle the streaming L-BFGS (``mctm_fit``, ``method="lbfgs"``)
    is tested against. ``loss_fn`` should featurize inside its (jitted) body
    rather than close over a materialized basis, so nothing O(n·J·d) lives
    across the optimize."""
    import jax.flatten_util  # not auto-imported on all supported jax versions
    from scipy.optimize import minimize

    flat0, unravel = jax.flatten_util.ravel_pytree(params0)
    vg = jax.jit(jax.value_and_grad(lambda f: loss_fn(unravel(f))))
    losses = []

    def fun(x):
        v, g = vg(jnp.asarray(x, dtype=jnp.float32))
        losses.append(float(v))
        return float(v), np.asarray(g, dtype=np.float64)

    res = minimize(fun, np.asarray(flat0, np.float64), jac=True, method="L-BFGS-B",
                   options={"maxiter": 500})
    return unravel(jnp.asarray(res.x, jnp.float32)), np.asarray(losses)


# ---------------------------------------------------------------------------
# Density / sampling utilities (used by examples and DGP visualization)
# ---------------------------------------------------------------------------


def log_density(
    cfg: MCTMConfig, params: MCTMParams, scaler: DataScaler, Y: jax.Array
) -> jax.Array:
    A, Ap = basis_features(cfg, scaler, Y)
    return -nll_terms(cfg, params, A, Ap)


def sample(
    cfg: MCTMConfig,
    params: MCTMParams,
    scaler: DataScaler,
    key: jax.Array,
    n: int,
    n_grid: int = 512,
) -> jax.Array:
    """Draw samples by inverting h̃ on a grid (h is triangular: solve per dim)."""
    z = jax.random.normal(key, (n, cfg.J))
    Lam = lambda_matrix(cfg, params.lam)
    # h̃(Y) = Λ^{-1} z  → invert each monotone marginal on a grid.
    htilde_target = jax.scipy.linalg.solve_triangular(Lam, z.T, lower=True).T
    theta = monotone_theta(params.theta_raw, cfg.min_slope)
    t_grid = jnp.linspace(0.0, 1.0, n_grid)
    basis = bernstein_design(t_grid, cfg.degree)  # (G, d)
    vals = basis @ theta.T  # (G, J) monotone in G per column
    low = jnp.asarray(scaler.low, jnp.float32)
    high = jnp.asarray(scaler.high, jnp.float32)

    def invert_dim(j, tgt):
        idx = jnp.searchsorted(vals[:, j], tgt)
        idx = jnp.clip(idx, 1, n_grid - 1)
        v0, v1 = vals[idx - 1, j], vals[idx, j]
        t0, t1 = t_grid[idx - 1], t_grid[idx]
        frac = jnp.clip((tgt - v0) / jnp.maximum(v1 - v0, 1e-12), 0.0, 1.0)
        t = t0 + frac * (t1 - t0)
        return low[j] + t * (high[j] - low[j])

    cols = [invert_dim(j, htilde_target[:, j]) for j in range(cfg.J)]
    return jnp.stack(cols, axis=1)


# Convenience jitted evaluators --------------------------------------------------

full_nll = jax.jit(nll, static_argnums=0)
full_nll_terms = jax.jit(nll_terms, static_argnums=0)
