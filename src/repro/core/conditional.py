"""Conditional MCTM extension (paper §4 'Choice of copula and basis functions'):

    h̃_j(y_j | x) = a_j(y_j)ᵀ ϑ_j + xᵀ β_j          (linear conditional shift)

The paper notes the coreset extension "only increases the dimension
dependence by the number of features conditioned on": the leverage feature
row becomes (b_i, x_i) ∈ R^{dJ+F}, everything else (sensitivity proxy,
hull on a'(y)) is unchanged — which is exactly what
:func:`conditional_coreset_scores` implements.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mctm as M
from repro.core.bernstein import DataScaler, monotone_theta
from repro.core.hull import epsilon_kernel_indices
from repro.core.leverage import leverage_scores_gram

__all__ = [
    "CMCTMConfig",
    "CMCTMParams",
    "init_cparams",
    "cnll",
    "fit_cmctm",
    "conditional_coreset_scores",
    "build_conditional_coreset",
]


@dataclasses.dataclass(frozen=True)
class CMCTMConfig:
    J: int
    n_features: int
    degree: int = 6
    eta: float = 1e-3
    min_slope: float = 1e-4

    @property
    def d(self) -> int:
        return self.degree + 1

    @property
    def base(self) -> M.MCTMConfig:
        return M.MCTMConfig(J=self.J, degree=self.degree, eta=self.eta, min_slope=self.min_slope)


class CMCTMParams(NamedTuple):
    theta_raw: jax.Array  # (J, d)
    lam: jax.Array        # (J(J−1)/2,)
    beta: jax.Array       # (J, F) conditional shift coefficients


def init_cparams(key, cfg: CMCTMConfig) -> CMCTMParams:
    base = M.init_params(key, cfg.base)
    beta = jnp.zeros((cfg.J, cfg.n_features), jnp.float32)
    return CMCTMParams(theta_raw=base.theta_raw, lam=base.lam, beta=beta)


def _transform_parts(cfg: CMCTMConfig, params: CMCTMParams, A, Ap, X):
    theta = monotone_theta(params.theta_raw, cfg.min_slope)
    htilde = jnp.einsum("njd,jd->nj", A, theta) + X @ params.beta.T
    hprime = jnp.einsum("njd,jd->nj", Ap, theta)  # shift has zero dy-derivative
    Lam = M.lambda_matrix(cfg.base, params.lam)
    z = htilde @ Lam.T
    return z, hprime


def cnll_terms(cfg: CMCTMConfig, params: CMCTMParams, A, Ap, X) -> jax.Array:
    z, hprime = _transform_parts(cfg, params, A, Ap, X)
    log_jac = jnp.log(jnp.maximum(hprime, cfg.eta))
    per_dim = 0.5 * jnp.square(z) - log_jac + 0.5 * M.LOG_2PI
    return jnp.sum(per_dim, axis=-1)


def cnll(cfg, params, A, Ap, X, weights=None) -> jax.Array:
    terms = cnll_terms(cfg, params, A, Ap, X)
    return jnp.sum(terms if weights is None else weights * terms)


def fit_cmctm(
    cfg: CMCTMConfig,
    scaler: DataScaler,
    Y: np.ndarray,
    X: np.ndarray,
    weights=None,
    *,
    key=None,
    steps: int = 1500,
    lr: float = 5e-2,
) -> M.FitResult:
    if key is None:
        key = jax.random.PRNGKey(0)
    params0 = init_cparams(key, cfg)
    A, Ap = M.basis_features(cfg.base, scaler, jnp.asarray(Y))
    Xj = jnp.asarray(X, jnp.float32)
    total_w = float(Y.shape[0]) if weights is None else float(np.sum(weights))
    w = None if weights is None else jnp.asarray(weights, jnp.float32)

    def loss_fn(p):
        return cnll(cfg, p, A, Ap, Xj, w) / total_w

    params, losses = jax.jit(lambda p: M._adam_fit(loss_fn, p, steps, lr))(params0)
    final = float(cnll(cfg, params, A, Ap, Xj, w))
    return M.FitResult(params=params, losses=np.asarray(losses), final_nll=final)


# ---------------------------------------------------------------------------
# conditional coreset: leverage over the augmented feature row (b_i, x_i)
# ---------------------------------------------------------------------------


def conditional_coreset_scores(
    cfg: CMCTMConfig, scaler: DataScaler, Y, X
) -> np.ndarray:
    A, _ = M.basis_features(cfg.base, scaler, jnp.asarray(Y))
    n = A.shape[0]
    feats = jnp.concatenate(
        [A.reshape(n, -1), jnp.asarray(X, jnp.float32)], axis=1
    )  # (n, dJ + F)
    u = np.asarray(leverage_scores_gram(feats))
    return u + 1.0 / n


def build_conditional_coreset(
    cfg: CMCTMConfig, scaler: DataScaler, Y, X, k: int, *, key, alpha: float = 0.8
):
    """Algorithm-1 hybrid for the conditional model; returns (idx, weights)."""
    Y = np.asarray(Y)
    n = Y.shape[0]
    scores = conditional_coreset_scores(cfg, scaler, Y, X)
    probs = scores / scores.sum()
    k1 = int(np.floor(alpha * k))
    k_draw, k_hull = jax.random.split(key)
    idx = np.asarray(
        jax.random.choice(k_draw, n, shape=(k1,), replace=True, p=jnp.asarray(probs))
    )
    w = 1.0 / (k1 * probs[idx])
    _, Ap = M.basis_features(cfg.base, scaler, jnp.asarray(Y))
    P = np.asarray(Ap).reshape(n * cfg.J, cfg.d)
    hull_rows = epsilon_kernel_indices(P, k - k1, k_hull)
    hull_pts = np.unique(hull_rows // cfg.J)[: k - k1]
    idx = np.concatenate([idx, hull_pts])
    w = np.concatenate([w, np.ones(hull_pts.shape[0])])
    return idx, w
