"""Conditional MCTM extension (paper §4 'Choice of copula and basis functions'):

    h̃_j(y_j | x) = a_j(y_j)ᵀ ϑ_j + xᵀ β_j          (linear conditional shift)

The paper notes the coreset extension "only increases the dimension
dependence by the number of features conditioned on": the leverage feature
row becomes (b_i, x_i) ∈ R^{dJ+F}, everything else (sensitivity proxy,
hull on a'(y)) is unchanged. ``conditional_coreset_scores`` realizes this
through the chunked ``ScoringEngine`` with a custom featurize that emits the
augmented row (b_i, x_i) AND the derivative rows in one fused evaluation —
the basis is computed once per chunk per pass (once total on the dense
path), and inputs beyond ``chunk_size`` stream in O(chunk·(dJ+F)) memory.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mctm as M
from repro.core.bernstein import DataScaler, monotone_theta
from repro.core.coreset import coreset_from_scoring
from repro.core.scoring import DEFAULT_CHUNK, ScoringEngine

__all__ = [
    "CMCTMConfig",
    "CMCTMParams",
    "init_cparams",
    "cnll",
    "fit_cmctm",
    "conditional_scoring_engine",
    "conditional_coreset_scores",
    "build_conditional_coreset",
]


@dataclasses.dataclass(frozen=True)
class CMCTMConfig:
    J: int
    n_features: int
    degree: int = 6
    eta: float = 1e-3
    min_slope: float = 1e-4

    @property
    def d(self) -> int:
        return self.degree + 1

    @property
    def base(self) -> M.MCTMConfig:
        return M.MCTMConfig(J=self.J, degree=self.degree, eta=self.eta, min_slope=self.min_slope)


class CMCTMParams(NamedTuple):
    theta_raw: jax.Array  # (J, d)
    lam: jax.Array        # (J(J−1)/2,)
    beta: jax.Array       # (J, F) conditional shift coefficients


def init_cparams(key, cfg: CMCTMConfig) -> CMCTMParams:
    base = M.init_params(key, cfg.base)
    beta = jnp.zeros((cfg.J, cfg.n_features), jnp.float32)
    return CMCTMParams(theta_raw=base.theta_raw, lam=base.lam, beta=beta)


def _transform_parts(cfg: CMCTMConfig, params: CMCTMParams, A, Ap, X):
    theta = monotone_theta(params.theta_raw, cfg.min_slope)
    htilde = jnp.einsum("njd,jd->nj", A, theta) + X @ params.beta.T
    hprime = jnp.einsum("njd,jd->nj", Ap, theta)  # shift has zero dy-derivative
    Lam = M.lambda_matrix(cfg.base, params.lam)
    z = htilde @ Lam.T
    return z, hprime


def cnll_terms(cfg: CMCTMConfig, params: CMCTMParams, A, Ap, X) -> jax.Array:
    z, hprime = _transform_parts(cfg, params, A, Ap, X)
    log_jac = jnp.log(jnp.maximum(hprime, cfg.eta))
    per_dim = 0.5 * jnp.square(z) - log_jac + 0.5 * M.LOG_2PI
    return jnp.sum(per_dim, axis=-1)


def cnll(cfg, params, A, Ap, X, weights=None) -> jax.Array:
    terms = cnll_terms(cfg, params, A, Ap, X)
    return jnp.sum(terms if weights is None else weights * terms)


class CMCTMDensityModel:
    """``loss_fn(params, batch)`` adapter for the fit layer's generic driver
    (``mctm_fit.fit_density_model``): conditional rows travel column-
    concatenated (y_i, x_i) — the same layout as the conditional scoring
    featurize — and the basis is evaluated per microbatch INSIDE the loss,
    so conditional fits stream with the same O(chunk·J·d) discipline as the
    unconditional ones."""

    def __init__(self, cfg: CMCTMConfig, scaler: DataScaler, *, norm: float = 1.0):
        self.cfg = cfg
        self.scaler = scaler
        self.norm = float(norm)

    def loss_fn(self, params, batch):
        if "A" in batch:  # dense fast path: features precomputed once
            A, Ap, Xc = batch["A"], batch["Ap"], batch["X"]
        else:
            YX = batch["YX"]
            Yc, Xc = YX[:, : self.cfg.J], YX[:, self.cfg.J :]
            A, Ap = M.basis_features(self.cfg.base, self.scaler, Yc)
        terms = cnll_terms(self.cfg, params, A, Ap, Xc)
        w = batch.get("weights")
        total = jnp.sum(terms if w is None else w * terms)
        return total / self.norm, {}


def fit_cmctm(
    cfg: CMCTMConfig,
    scaler: DataScaler,
    Y: np.ndarray,
    X: np.ndarray,
    weights=None,
    *,
    key=None,
    steps: int = 1500,
    lr: float = 5e-2,
    method: str = "adam",
    mesh=None,
    chunk_size: int | None = None,
    microbatches: int | None = None,
    batch_size: int | None = None,
    checkpoint=None,
    ckpt_every: int = 0,
    resume: bool = False,
) -> M.FitResult:
    """Conditional-MCTM fit through the shared fit subsystem: ``mesh=`` runs
    the step SPMD-sharded, ``chunk_size`` streams the basis evaluation
    microbatch-by-microbatch for full-data fits beyond one chunk, and
    ``method`` selects any fit mode of the ``mctm_fit`` method table
    (``"adam"`` / ``"lbfgs"`` streaming-HVP / ``"minibatch"`` with
    ``batch_size`` sampled rows per step) — the conditional rows travel
    column-concatenated (y_i, x_i), so the sampled-minibatch loader and the
    L-BFGS oracles stream them like any other batch. ``checkpoint=`` +
    ``resume=True`` restart from the latest saved step in every mode."""
    from repro.core.mctm_fit import (
        default_fit_optimizer,
        fit_density_model,
        method_batch_plan,
    )

    if key is None:
        key = jax.random.PRNGKey(0)
    params0 = init_cparams(key, cfg)
    Yn = np.asarray(Y, np.float32)
    n = int(Yn.shape[0])
    w, total_w, chunk, microbatches, batch_size, norm = method_batch_plan(
        method, n, weights, chunk_size, microbatches, batch_size, mesh
    )
    YX = np.concatenate([Yn, np.asarray(X, np.float32)], axis=1)
    model = CMCTMDensityModel(cfg, scaler, norm=norm)
    if method == "adam" and microbatches == 1:
        # dense fast path (mirrors fit_mctm_streaming): featurize exactly
        # once outside the step instead of once per optimizer step
        A, Ap = M.basis_features(cfg.base, scaler, jnp.asarray(Yn))
        batch = {"A": np.asarray(A), "Ap": np.asarray(Ap),
                 "X": YX[:, cfg.J :], "weights": w}
    else:
        batch = {"YX": YX, "weights": w}
    params, losses, _ = fit_density_model(
        model,
        params0,
        batch,
        optimizer=default_fit_optimizer(lr, steps),
        steps=steps,
        method=method,
        mesh=mesh,
        microbatches=microbatches,
        batch_size=batch_size,
        checkpoint=checkpoint,
        ckpt_every=ckpt_every,
        resume=resume,
        label=f"cmctm-{method}",
    )
    params = CMCTMParams(*params)

    @jax.jit
    def _chunk_nll(p, YXc, wc):
        Yc, Xc = YXc[:, : cfg.J], YXc[:, cfg.J :]
        A, Ap = M.basis_features(cfg.base, scaler, Yc)
        return jnp.sum(wc * cnll_terms(cfg, p, A, Ap, Xc))

    final = sum(
        float(_chunk_nll(params, jnp.asarray(YX[lo : lo + chunk]),
                         jnp.asarray(w[lo : lo + chunk])))
        for lo in range(0, n, chunk)
    )
    return M.FitResult(params=params, losses=losses, final_nll=final)


# ---------------------------------------------------------------------------
# conditional coreset: leverage over the augmented feature row (b_i, x_i)
# ---------------------------------------------------------------------------


# jitted featurize closures keyed on (cfg, scaler bounds) — same rationale as
# scoring._MCTM_FEATURIZE_CACHE: each build constructs a fresh engine, and an
# uncached closure would recompile the fused basis evaluation every call
_COND_FEATURIZE_CACHE: dict = {}


def _conditional_featurize(cfg: CMCTMConfig, scaler: DataScaler) -> Callable:
    """Fused featurize for the engine: one basis evaluation per chunk emits
    both the augmented leverage row (b_i, x_i) ∈ R^{dJ+F} and the derivative
    rows {a'_ij} the hull stage queries.

    The engine streams a single array per chunk, so Y and X travel
    concatenated column-wise: input rows are (y_i ∈ R^J, x_i ∈ R^F).
    """
    cache_key = (
        cfg,
        np.asarray(scaler.low).tobytes(),
        np.asarray(scaler.high).tobytes(),
    )
    cached = _COND_FEATURIZE_CACHE.get(cache_key)
    if cached is not None:
        return cached

    base = cfg.base

    @jax.jit
    def featurize(YX: jax.Array) -> tuple[jax.Array, jax.Array]:
        Yc, Xc = YX[:, : cfg.J], YX[:, cfg.J :]
        A, Ap = M.basis_features(base, scaler, Yc)
        c = A.shape[0]
        feats = jnp.concatenate([A.reshape(c, base.J * base.d), Xc], axis=1)
        return feats, Ap.reshape(c * cfg.J, cfg.d)

    if len(_COND_FEATURIZE_CACHE) > 64:  # bound growth across many configs
        _COND_FEATURIZE_CACHE.clear()
    _COND_FEATURIZE_CACHE[cache_key] = featurize
    return featurize


def conditional_scoring_engine(
    cfg: CMCTMConfig, scaler: DataScaler, chunk_size: int | None = DEFAULT_CHUNK
) -> ScoringEngine:
    """Chunked scoring engine over the augmented conditional feature rows."""
    return ScoringEngine(
        featurize=_conditional_featurize(cfg, scaler),
        chunk_size=chunk_size,
        rows_per_point=cfg.J,
    )


def _stack_yx(cfg: CMCTMConfig, Y, X) -> jnp.ndarray:
    YX = np.concatenate(
        [np.asarray(Y, np.float32), np.asarray(X, np.float32)], axis=1
    )
    assert YX.shape[1] == cfg.J + cfg.n_features
    return jnp.asarray(YX)


def conditional_coreset_scores(
    cfg: CMCTMConfig,
    scaler: DataScaler,
    Y,
    X,
    *,
    chunk_size: int | None = DEFAULT_CHUNK,
    sketch_size: int = 0,
    key=None,
) -> np.ndarray:
    """s_i = u_i + 1/n over the augmented rows (b_i, x_i), chunked.

    ``sketch_size > 0`` (requires ``key``) streams the augmented rows through
    the engine's one-pass sketched strategy — each (y_i, x_i) row featurized
    exactly once."""
    engine = conditional_scoring_engine(cfg, scaler, chunk_size)
    return engine.score(
        _stack_yx(cfg, Y, X), method="l2-only", sketch_size=sketch_size, key=key
    ).scores


def build_conditional_coreset(
    cfg: CMCTMConfig,
    scaler: DataScaler,
    Y,
    X,
    k: int,
    *,
    key,
    alpha: float = 0.8,
    chunk_size: int | None = DEFAULT_CHUNK,
    sketch_size: int = 0,
):
    """Algorithm-1 hybrid for the conditional model; returns (idx, weights).

    One engine sweep produces both the sampling scores and the hull
    candidates (the basis is evaluated once on the dense path; with
    ``sketch_size > 0`` every chunked row is streamed exactly once through
    the one-pass sketched strategy). The result always has exactly
    ``min(k, n)`` entries: when the ε-kernel candidate rows dedup to fewer
    than k − k1 distinct points (low-diversity hulls), the shortfall is
    topped up from the next-ranked points by sampling score, keeping the
    log-term guard deterministic.
    """
    t0 = time.perf_counter()
    Y = np.asarray(Y)
    n = Y.shape[0]
    k = min(k, n)
    k2 = k - int(np.floor(alpha * k))
    if sketch_size > 0:
        # extra stream for the sketch plan; exact builds keep the old split
        k_draw, k_hull, k_score = jax.random.split(key, 3)
    else:
        k_draw, k_hull = jax.random.split(key)
        k_score = None

    engine = conditional_scoring_engine(cfg, scaler, chunk_size)
    res = engine.score(
        _stack_yx(cfg, Y, X),
        method="l2-hull" if k2 > 0 else "l2-only",
        hull_k=k2,
        hull_key=k_hull if k2 > 0 else None,
        sketch_size=sketch_size,
        key=k_score,
    )
    cs = coreset_from_scoring(
        res, n, k, "l2-hull" if k2 > 0 else "l2-only", alpha, k_draw, t0
    )
    return cs.indices, cs.weights
