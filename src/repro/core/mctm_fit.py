"""End-to-end MCTM fit layer: streamed featurization, sharded weighted-NLL
training, and the streamed full-data evaluator behind the (1±ε) validation.

Fit-layer contract (the training-side mirror of the PassStrategy contract in
``core.scoring``)
-----------------------------------------------------------------------------
Fit methods — ``fit_density_model(method=...)`` is the single entry point
under every MCTM-family fit; each method is one row of this table (state /
update / streaming guarantee):

===========  =======================  ==========================  ===========================
method       state                    update                      streaming guarantee
===========  =======================  ==========================  ===========================
``adam``     ``TrainState`` (params,  one full-batch first-order  basis featurized per
(default)    ``repro.optim`` moments  step per iteration          microbatch inside the
             — O(|params|))           (``make_train_step``,       gradient-accumulation scan;
                                      grad-accumulated over       O(chunk·J·d) peak, never
                                      microbatches)               (n, J, d)
``lbfgs``    ``LBFGSState`` (flat     quasi-Newton two-loop       every oracle — loss, grad,
             iterate + (m, P)         direction + Armijo          AND the Hessian-vector
             curvature-pair ring,     backtracking line search;   product that forms the
             m·P ≪ data)              curvature pairs y = H·s     curvature pairs — is the
                                      from a streamed HVP         same microbatched chunk
                                      (Byrd et al. 2016 style)    scan; O(chunk·J·d) peak
``minibatch``  ``TrainState``         one first-order step per    each step touches only
             (identical to adam)      iteration on a sampled      ``batch_size`` sampled rows
                                      weighted microbatch         (``data.pipeline``'s
                                      (unbiased estimate of the   ``subset_loader`` — pure
                                      full weighted-NLL           function of (seed, step),
                                      objective)                  so resume replays exactly)
===========  =======================  ==========================  ===========================

All three run single-host or SPMD row-sharded (``mesh=``), all three support
``CheckpointManager`` periodic save + ``resume=True`` replay through the one
shared ``train.loop`` (``adam``/``minibatch`` checkpoint a ``TrainState``;
``lbfgs`` checkpoints its ``LBFGSState`` — params, curvature ring, counters —
so a resumed run replays the identical deterministic iteration sequence).
``minibatch`` is the mode for datasets whose *coreset* exceeds device memory;
``lbfgs`` makes the paper's quasi-Newton full-data reference fit streaming-
scalable (the dense ``mctm._scipy_lbfgs_fit`` stays only as a small-n test
oracle).

What streams — basis featurization. No path below materializes an (n, J, d)
basis tensor beyond one chunk: the train step featurizes each microbatch
INSIDE the jitted loss (``MCTMDensityModel``), so a step over n rows with
``microbatches = ⌈n/chunk⌉`` holds one (chunk, J, d) block at a time while
the gradient-accumulation scan carries only O(|params|) state; the evaluator
(``streamed_nll``) featurizes chunk-by-chunk inside a ``lax.scan``. Both
reuse the scoring engine's fused cached featurize (``scoring._mctm_featurize``)
and the engine's chunk/shard geometry (``distributed_coreset.shard_layout``)
— the same chunk-driver discipline as Algorithm 1's pre-sampling phase, and
the same ``featurize=`` override point (which is how the counting tests
assert the no-materialization property).

What shards — rows. With ``mesh=`` the step jits through
``train.trainer.make_train_step`` / ``shard_train_step`` with the batch
row-sharded over the data axes and the (tiny) parameter + ``repro.optim``
optimizer state replicated, so the identical step function runs single-host
or on a pod; ragged row counts are padded with zero-weight copies of row 0
(valid data — no NaN through the featurizer), exactly like
``DistributedScoringEngine``. The streamed evaluator runs its chunk scan
INSIDE a shard_map body and reduces with ONE psum — the evaluator analogue
of the engine's fused pass-1 collective. ``CheckpointManager`` resume is
supported on both layouts (``train.loop.restore_train_state``).

What the evaluator guarantees — ``streamed_nll`` computes the total weighted
NLL Σᵢ wᵢ·nllᵢ(θ): the same statistic as ``mctm.nll`` on a materialized
basis, up to f32 reassociation across chunk/shard boundaries, at
O(chunk·J·d) peak memory on any mesh layout. It is the measurement device
for the paper's headline claim — ``coreset_epsilon`` measures the coreset's
realized ε = max_θ |NLL_C(θ) − NLL(θ)| / |NLL(θ)| over the fitted
parameters, and ``launch.train_mctm`` checks the coreset-fit/full-fit
likelihood ratio against the (1±ε) band that ε implies.

Coreset weights flow through the trainer's per-example-weight path
(``batch["weights"]``); the objective is Σ w·nll / Σw — a constant
normalizer, so gradients match ``mctm.nll`` up to scale and the lr stays
scale-free across coreset sizes (the contract ``fit_mctm`` always had).
Every method optimizes this same objective: ``adam``/``lbfgs`` evaluate it
exactly per step, ``minibatch`` estimates it unbiasedly (uniform rows with
replacement, the sampled Σ w·nll rescaled by n/batch — see
``method_batch_plan``, the one place the per-method normalizer rules live).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from typing import NamedTuple

from repro.core import mctm as M
from repro.core.distributed_coreset import _axis_tuple, host_gather, shard_layout
from repro.core.scoring import DEFAULT_CHUNK, _mctm_featurize
from repro.distributed.sharding import batch_specs, default_rules, replicated
from repro.ft import RunSupervisor
from repro.ft.config import get_ft_config
from repro.ft.failure import NonFiniteError
from repro.optim import Optimizer, adamw, scale_updates
from repro.train import (
    init_train_state,
    make_train_step,
    restore_train_state,
    shard_train_step,
    train_loop,
)
from repro.train.trainer import microbatch_split, tree_acc
from repro.utils.compat import shard_map

__all__ = [
    "MCTMDensityModel",
    "LBFGSState",
    "LAST_LBFGS_SWEEPS",
    "fit_featurize",
    "fit_density_model",
    "fit_mctm_streaming",
    "batch_plan",
    "method_batch_plan",
    "resolve_batch_size",
    "make_streamed_oracles",
    "streamed_nll",
    "coreset_epsilon",
    "likelihood_ratio",
    "cosine_decay",
    "FIT_METHODS",
]

FIT_METHODS = ("adam", "lbfgs", "minibatch")


def cosine_decay(lr: float, steps: int):
    """The fit layer's default schedule — lr·½(1+cos(π·i/steps)), the exact
    decay the retired hand-rolled ``mctm._adam_fit`` applied, so fits through
    ``repro.optim.adamw`` reproduce the seed trajectories."""

    def fn(step):
        frac = step.astype(jnp.float32) / max(steps, 1)
        return jnp.asarray(lr, jnp.float32) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))

    return fn


def default_fit_optimizer(lr: float, steps: int) -> Optimizer:
    """Adam + cosine decay matching ``_adam_fit``'s exact update math."""
    return adamw(cosine_decay(lr, steps), b1=0.9, b2=0.999, eps=1e-8)


def fit_featurize(cfg: M.MCTMConfig, scaler, featurize: Callable | None = None):
    """Chunk featurizer for the fit layer: Y chunk (c, J) → (A, Ap) each
    (c, J, d). Wraps the scoring engine's fused cached featurize (one jitted
    trace per chunk length, shared with Algorithm 1's scoring sweeps);
    ``featurize`` overrides the base evaluation (counting tests, custom
    bases) with the engine's flat (X (c, J·d), P (c·J, d)) contract.
    """
    base = featurize if featurize is not None else _mctm_featurize(cfg, scaler)

    def feat(Yc):
        X, Pr = base(Yc)
        c = X.shape[0]
        return X.reshape(c, cfg.J, cfg.d), Pr.reshape(c, cfg.J, cfg.d)

    return feat


class MCTMDensityModel:
    """``loss_fn(params, batch)`` adapter for ``train.make_train_step``.

    batch is ``{"Y": (b, J), "weights": (b,)}`` — featurized INSIDE the loss
    so a microbatched step only ever holds one (b/microbatches, J, d) block —
    or ``{"A", "Ap", "weights"}`` when the caller pre-featurized (the dense
    single-chunk fast path, mirroring the scoring engine's). ``norm`` is the
    constant objective normalizer (Σ real weights / microbatches, so the
    microbatch-mean the trainer computes equals Σ w·nll / Σw globally).
    """

    def __init__(self, cfg: M.MCTMConfig, scaler=None, *, norm: float = 1.0,
                 featurize: Callable | None = None):
        self.cfg = cfg
        self.norm = float(norm)
        self._feat = (
            fit_featurize(cfg, scaler, featurize)
            if (scaler is not None or featurize is not None)
            else None
        )

    def features(self, batch):
        if "A" in batch:
            return batch["A"], batch["Ap"]
        return self._feat(batch["Y"])

    def loss_fn(self, params, batch):
        A, Ap = self.features(batch)
        terms = M.nll_terms(self.cfg, params, A, Ap)
        w = batch.get("weights")
        total = jnp.sum(terms if w is None else w * terms)
        return total / self.norm, {}


def _pad_batch(batch: dict, multiple: int) -> tuple[dict, int, int]:
    """Pad batch rows to a multiple: zero weights, row-0 copies elsewhere
    (valid data — no NaN through the featurizer), the same padding rule as
    ``DistributedScoringEngine.score``. Returns (batch, n, n_pad)."""
    n = int(batch["weights"].shape[0])
    n_pad = -(-n // multiple) * multiple
    if n_pad == n:
        return batch, n, n_pad
    pad = n_pad - n
    out = {}
    for k, v in batch.items():
        v = np.asarray(v)
        if k == "weights":
            out[k] = np.concatenate([v, np.zeros(pad, v.dtype)])
        else:
            out[k] = np.concatenate(
                [v, np.broadcast_to(v[:1], (pad,) + v.shape[1:])]
            )
    return out, n, n_pad


def _replicated_specs(params):
    """Logical sharding specs that replicate every (tiny) parameter leaf."""
    return jax.tree.map(lambda p: (None,) * np.ndim(p), params)


def batch_plan(n: int, weights, chunk_size: int | None, microbatches: int | None):
    """Shared scaffolding of every full-batch density fit (MCTM and
    conditional): resolved per-example weights, their total (the constant
    objective normalizer), the chunk length, and the microbatch count
    (⌈n/chunk⌉ unless given). One implementation so the two fit entry points
    cannot drift on the streaming/normalization rules."""
    w = (
        np.ones(n, np.float32)
        if weights is None
        else np.asarray(weights, np.float32)
    )
    chunk = int(chunk_size) if chunk_size else n
    if microbatches is None:
        microbatches = max(1, -(-n // chunk))
    return w, float(w.sum()), chunk, microbatches


def _num_shards(mesh) -> int:
    return 1 if mesh is None else int(np.prod(list(mesh.shape.values())))


def resolve_batch_size(batch_size: int, microbatches: int = 1, mesh=None) -> int:
    """Round a requested minibatch size UP to the (microbatches × shards)
    multiple the step geometry needs — sampled batches carry no padding, so
    the size itself must already be divisible."""
    mult = max(1, microbatches) * _num_shards(mesh)
    return -(-int(batch_size) // mult) * mult


def method_batch_plan(
    method: str,
    n: int,
    weights,
    chunk_size: int | None,
    microbatches: int | None,
    batch_size: int | None = None,
    mesh=None,
):
    """``batch_plan`` extended with the per-method microbatch + objective-
    normalizer rules — the ONE place they live, shared by ``fit_mctm_streaming``
    and ``conditional.fit_cmctm`` so the entry points cannot drift.

    Returns ``(w, total_w, chunk, microbatches, batch_size, norm)`` where
    ``norm`` is the constant divisor handed to the density model so that:

    * ``adam`` — the trainer's microbatch-mean equals Σ w·nll / Σw
      (norm = Σw / microbatches);
    * ``lbfgs`` — the oracles SUM over microbatches, so the streamed loss
      equals Σ w·nll / Σw exactly (norm = Σw);
    * ``minibatch`` — uniform-with-replacement sampling of ``batch_size``
      rows makes E[Σ_sampled w·nll] = (batch_size/n)·Σ w·nll, so
      norm = Σw·batch_size / (n·microbatches) gives an unbiased estimate of
      the same Σ w·nll / Σw objective.
    """
    w, total_w, chunk, mb_full = batch_plan(n, weights, chunk_size, microbatches)
    if method == "minibatch":
        # clamp to n: past that, extra with-replacement draws only add cost
        # and variance over a full-batch step of the same size
        bs = min(int(batch_size), n) if batch_size else min(n, 4096)
        mb = microbatches or max(1, -(-bs // chunk))
        bs = resolve_batch_size(bs, mb, mesh)
        return w, total_w, chunk, mb, bs, total_w * bs / (n * mb)
    if method == "lbfgs":
        return w, total_w, chunk, mb_full, None, total_w
    if method == "adam":
        return w, total_w, chunk, mb_full, None, total_w / mb_full
    raise ValueError(f"unknown fit method: {method!r} (one of {FIT_METHODS})")


def fit_density_model(
    model,
    params0,
    batch: dict,
    *,
    optimizer: Optimizer | None = None,
    steps: int,
    method: str = "adam",
    mesh=None,
    microbatches: int = 1,
    batch_size: int | None = None,
    sample_seed: int = 0,
    sampling: str = "uniform",
    history: int = 10,
    gtol: float = 1e-6,
    max_linesearch: int = 20,
    checkpoint=None,
    ckpt_every: int = 0,
    resume: bool = False,
    log_every: int = 0,
    label: str = "fit",
):
    """The generic density-fit driver under every MCTM-family fit — one
    ``method=`` contract over the three modes of the module-doc table.

    ``model`` follows the trainer's ``loss_fn(params, batch)`` contract (the
    MCTM and conditional-MCTM adapters both do); ``batch`` must carry a
    ``"weights"`` row. ``method="adam"`` (any first-order ``repro.optim``
    ``optimizer``) takes one full-batch step per iteration, rows padded here
    to a (microbatches × shards) multiple with zero weight.
    ``method="lbfgs"`` ignores ``optimizer`` and runs the streaming-HVP
    quasi-Newton driver (``history`` curvature pairs, Armijo backtracking
    capped at ``max_linesearch`` halvings, convergence at ``gtol`` gradient
    norm). ``method="minibatch"`` samples ``batch_size`` weighted rows per
    step via ``data.pipeline.subset_loader`` (seeded by ``sample_seed``; the
    caller sets the model's normalizer so the estimate is unbiased — see
    ``method_batch_plan``; ``sampling="importance"`` draws rows
    w-proportionally with the constant 1/p correction instead of uniformly —
    both modes are unbiased under the same normalizer, importance kills the
    weight contribution to gradient variance for heavy-tailed coreset
    weights). With ``mesh`` every mode jits its step/oracles
    with the batch row-sharded and params (plus any optimizer/curvature
    state) replicated; without, a plain jit. ``checkpoint`` is a
    ``CheckpointManager``; ``resume=True`` restarts from its latest step and
    replays identically in every mode.

    Returns ``(params, losses, final_state)`` with params gathered to host
    and losses one float per executed step.
    """
    if method == "lbfgs":
        return _fit_lbfgs(
            model, params0, batch, steps=steps, mesh=mesh,
            microbatches=microbatches, history=history, gtol=gtol,
            max_linesearch=max_linesearch, checkpoint=checkpoint,
            ckpt_every=ckpt_every, resume=resume, log_every=log_every,
            label=label,
        )
    if method not in FIT_METHODS:
        raise ValueError(f"unknown fit method: {method!r} (one of {FIT_METHODS})")
    if optimizer is None:
        raise ValueError(f"method={method!r} requires an optimizer")
    if method == "minibatch":
        if not batch_size:
            raise ValueError("method='minibatch' requires batch_size")
        return _fit_minibatch(
            model, params0, batch, optimizer=optimizer, steps=steps,
            mesh=mesh, microbatches=microbatches, batch_size=batch_size,
            sample_seed=sample_seed, sampling=sampling, checkpoint=checkpoint,
            ckpt_every=ckpt_every, resume=resume, log_every=log_every,
            label=label,
        )
    batch, _, _ = _pad_batch(batch, max(1, microbatches) * _num_shards(mesh))
    return _train_state_loop(
        model, params0, batch,
        # full-batch: device_put the padded batch once, reuse it every step
        lambda put: (lambda i, b=put(batch): b),
        optimizer=optimizer, steps=steps, mesh=mesh, microbatches=microbatches,
        checkpoint=checkpoint, ckpt_every=ckpt_every, resume=resume,
        log_every=log_every, label=label,
    )


def _train_state_loop(
    model,
    params0,
    batch_template: dict,
    make_batch_fn,
    *,
    optimizer: Optimizer,
    steps: int,
    mesh=None,
    microbatches: int = 1,
    checkpoint=None,
    ckpt_every: int = 0,
    resume: bool = False,
    log_every: int = 0,
    label: str = "fit",
):
    """The shared ``TrainState`` driver tail of the adam and minibatch modes:
    step construction, sharding, resume, loop, host gather — written once so
    the two first-order modes cannot drift. ``batch_template`` fixes the
    per-step batch shapes/dtypes; ``make_batch_fn(put)`` receives the
    device-placement function for those shapes and returns ``batch_fn(i)``.

    Supervised (``ft.RunSupervisor``): retryable failures — injected faults,
    non-finite losses/grads (``NonFiniteError`` → LR backoff via
    ``scale_updates``, which keeps the optimizer-state structure so earlier
    checkpoints still restore), runtime errors — roll back to the latest
    atomic checkpoint and re-run; the retry budget and backoffs come from
    ``ft_config``. Returned losses cover the final (successful) attempt only.
    """

    def attempt(ctx):
        opt = scale_updates(optimizer, ctx.lr_scale)
        step_pure = make_train_step(model, opt, microbatches=microbatches)
        # fresh param buffers per attempt: the jitted step donates the state,
        # so attempt 0's first step would otherwise delete params0's buffers
        # out from under any retry (and from under the caller)
        state = init_train_state(
            jax.tree.map(lambda x: jnp.array(x, copy=True), params0), opt
        )
        state_sh = None
        if mesh is not None:
            batch_shapes = {
                k: jax.ShapeDtypeStruct(np.shape(v), np.asarray(v).dtype)
                for k, v in batch_template.items()
            }
            step_fn, state_sh, batch_sh = shard_train_step(
                step_pure,
                model,
                opt,
                mesh,
                params_shapes=params0,
                specs=_replicated_specs(params0),
                batch_shapes=batch_shapes,
            )

            def put(b):
                return {
                    k: jax.device_put(jnp.asarray(v), batch_sh[k])
                    for k, v in b.items()
                }

            state = jax.device_put(state, state_sh)
        else:
            step_fn = jax.jit(step_pure, donate_argnums=(0,))

            def put(b):
                return {k: jnp.asarray(v) for k, v in b.items()}

        start = 0
        if resume or ctx.resume:
            state, start = restore_train_state(checkpoint, state, shardings=state_sh)
        return train_loop(
            step_fn,
            state,
            make_batch_fn(put),
            steps,
            start=start,
            mgr=checkpoint,
            ckpt_every=ckpt_every,
            log_every=log_every,
            label=label,
        )

    sup = RunSupervisor(label=label, mesh=mesh)
    state, losses = sup.run(attempt)
    params = jax.tree.map(lambda x: jnp.asarray(host_gather(x)), state.params)
    return params, np.asarray([float(x) for x in losses], np.float64), state


# ---------------------------------------------------------------------------
# streaming-HVP L-BFGS
# ---------------------------------------------------------------------------


def make_streamed_oracles(model, microbatches: int):
    """``(value_and_grad, value, hvp)`` pure functions over a padded batch.

    Each streams the batch microbatch-by-microbatch through ``model.loss_fn``
    with an O(|params|) ``lax.scan`` carry — the identical chunk driver
    ``make_train_step`` uses for gradient accumulation, so the featurize-
    inside-the-loss streaming guarantee carries over verbatim (the basis
    exists one (chunk, J, d) block at a time, for the HVP too: ``jvp`` of the
    per-microbatch gradient keeps the tangent pass inside the scan body).
    Totals are SUMS over microbatches (no 1/microbatches) — the L-BFGS
    objective normalizer is the model's ``norm`` alone.
    """
    microbatches = max(1, microbatches)

    def _mb(batch):
        return microbatch_split(batch, microbatches)

    def value_and_grad(params, batch):
        def body(carry, mbatch):
            loss, grads = carry
            (li, _), gi = jax.value_and_grad(model.loss_fn, has_aux=True)(
                params, mbatch
            )
            return (tree_acc(loss, li), tree_acc(grads, gi)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), _mb(batch))
        return loss, grads

    def value(params, batch):
        def body(loss, mbatch):
            li, _ = model.loss_fn(params, mbatch)
            return tree_acc(loss, li), None

        loss, _ = jax.lax.scan(body, jnp.zeros(()), _mb(batch))
        return loss

    def hvp(params, vec, batch):
        def body(carry, mbatch):
            grad_fn = jax.grad(lambda p: model.loss_fn(p, mbatch)[0])
            _, hv = jax.jvp(grad_fn, (params,), (vec,))
            return tree_acc(carry, hv), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        out, _ = jax.lax.scan(body, zeros, _mb(batch))
        return out

    return value_and_grad, value, hvp


class LBFGSState(NamedTuple):
    """Checkpointable L-BFGS iteration state (a pytree of arrays, so
    ``CheckpointManager``/``restore_train_state`` handle it like a
    ``TrainState``). The curvature ring holds at most ``history`` (s, y, ρ)
    pairs — O(history·|params|), independent of n."""

    step: jax.Array       # int32 iteration counter (train-loop contract)
    flat: jax.Array       # (P,) f32 current iterate (ravel_pytree order)
    loss: jax.Array       # f32 objective at ``flat``
    grad: jax.Array       # (P,) f32 gradient at ``flat`` (fused-oracle carry)
    have_grad: jax.Array  # bool — loss/grad are valid (skip the opening sweep)
    mem_s: jax.Array      # (history, P) iterate displacements s = x₊ − x
    mem_y: jax.Array      # (history, P) curvature responses y = ∇²f(x₊)·s
    mem_rho: jax.Array    # (history,) 1 / sᵀy
    count: jax.Array      # int32 number of valid pairs (rows [0:count])
    converged: jax.Array  # bool — further steps are no-ops (replay-stable)


# Streamed-sweep census of the most recent ``_fit_lbfgs`` call on this
# thread of execution: {"vg": fused value-and-grad sweeps, "hvp": HVP
# sweeps, "iters": active (non-latched) iterations}. Diagnostics for the
# pass-count contract (~2 sweeps/iter with the fused Armijo oracle) —
# benchmarks and tests read it; concurrent fits (a background serving
# refit) each overwrite it, so read it right after the fit returns.
LAST_LBFGS_SWEEPS: dict[str, int] = {"vg": 0, "hvp": 0, "iters": 0}


def _two_loop(g, S, Yv, rho, count: int):
    """Standard two-loop recursion: approximate H⁻¹·g from the curvature
    ring (rows [0:count], oldest → newest). All host-side f64 on O(m·P)
    data — the history is tiny by construction."""
    q = g.copy()
    alpha = np.zeros(count)
    for i in reversed(range(count)):
        alpha[i] = rho[i] * (S[i] @ q)
        q -= alpha[i] * Yv[i]
    if count:
        gamma = (S[count - 1] @ Yv[count - 1]) / max(
            Yv[count - 1] @ Yv[count - 1], 1e-30
        )
    else:
        gamma = 1.0
    r = gamma * q
    for i in range(count):
        beta = rho[i] * (Yv[i] @ r)
        r += S[i] * (alpha[i] - beta)
    return r


def _fit_lbfgs(
    model,
    params0,
    batch: dict,
    *,
    steps: int,
    mesh=None,
    microbatches: int = 1,
    history: int = 10,
    gtol: float = 1e-6,
    max_linesearch: int = 20,
    checkpoint=None,
    ckpt_every: int = 0,
    resume: bool = False,
    log_every: int = 0,
    label: str = "lbfgs",
):
    """Streaming-HVP L-BFGS: quasi-Newton over the streamed oracles.

    Pass-count contract (~2 streamed sweeps per iteration): the Armijo
    backtracker evaluates the FUSED value-and-grad oracle at each candidate
    (a trial costs one sweep either way — the data is read once), and the
    accepted candidate's (f, ∇f) are carried in the state, so the next
    iteration opens with no sweep at all. With the typical first-trial
    acceptance of a quasi-Newton step that is 1 fused sweep + 1 streamed
    HVP sweep forming the curvature pair y = ∇²f(x₊)·s (more robust than
    gradient differences and exactly one extra pass) — down from ~3.5
    (separate value+grad open, value-only trials) per iteration. The
    two-loop direction and ring update run host-side in f64 on
    O(history·P) data; state is stored f32, and every iteration is a pure
    function of (state, batch), so checkpoint resume replays the straight
    run bit-for-bit (the carried gradient is part of the state). Once
    ``gtol`` is reached (or no Armijo point exists along a descent
    direction — the float-noise plateau), ``converged`` latches and
    remaining steps are free no-ops.
    """
    from jax.flatten_util import ravel_pytree

    microbatches = max(1, microbatches)
    batch, _, _ = _pad_batch(batch, microbatches * _num_shards(mesh))
    value_and_grad, _, hvp = make_streamed_oracles(model, microbatches)
    if mesh is None:
        vg_j = jax.jit(value_and_grad)
        hvp_j = jax.jit(hvp)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
    else:
        # batch row-sharded, params/tangents replicated — the same layout
        # rule as shard_train_step, GSPMD inserting the grad/HVP reductions
        param_sh = jax.tree.map(lambda _: replicated(mesh), params0)
        batch_shapes = {
            k: jax.ShapeDtypeStruct(np.shape(v), np.asarray(v).dtype)
            for k, v in batch.items()
        }
        batch_sh = batch_specs(batch_shapes, mesh, default_rules(mesh))
        vg_j = jax.jit(value_and_grad, in_shardings=(param_sh, batch_sh))
        hvp_j = jax.jit(hvp, in_shardings=(param_sh, param_sh, batch_sh))
        batch = {
            k: jax.device_put(jnp.asarray(v), batch_sh[k]) for k, v in batch.items()
        }
    flat0, unravel = ravel_pytree(params0)
    P = int(flat0.shape[0])
    m = max(1, int(history))
    sweeps = {"vg": 0, "hvp": 0, "iters": 0}

    def _flat_grad(grads) -> np.ndarray:
        return np.asarray(
            ravel_pytree(jax.tree.map(host_gather, grads))[0], np.float64
        )

    def step_fn(state: LBFGSState, batch):
        metrics = {"loss": state.loss, "grad_norm": np.float32(0.0),
                   "step": state.step}
        if bool(state.converged):
            return state._replace(step=state.step + 1), metrics
        sweeps["iters"] += 1
        x = np.asarray(state.flat, np.float64)
        if bool(state.have_grad):
            # fused-oracle carry: (f, ∇f) at x were computed by the sweep
            # that ACCEPTED x in the previous line search — no opening sweep
            f0 = float(state.loss)
            g = np.asarray(state.grad, np.float64)
        else:
            loss, grads = vg_j(unravel(jnp.asarray(x, jnp.float32)), batch)
            sweeps["vg"] += 1
            g = _flat_grad(grads)
            f0 = float(host_gather(loss))
        gnorm = float(np.linalg.norm(g))
        metrics = {"loss": np.float32(f0), "grad_norm": np.float32(gnorm),
                   "step": state.step}
        if not np.isfinite(f0):
            if get_ft_config().nonfinite_rollback:
                # deterministic objective — a non-finite loss here would
                # repeat every retry, exhaust the budget, and abort cleanly
                # with the supervisor's diagnostic (the intended crash-loop
                # semantics); disable nonfinite_rollback to latch instead
                raise NonFiniteError(int(state.step), loss=f0, grad_norm=gnorm)
            return state._replace(
                step=state.step + 1, loss=jnp.asarray(f0, jnp.float32),
                converged=jnp.asarray(True),
            ), metrics
        if gnorm <= gtol:
            return state._replace(
                step=state.step + 1, loss=jnp.asarray(f0, jnp.float32),
                converged=jnp.asarray(True),
            ), metrics
        count = int(state.count)
        S = np.asarray(state.mem_s, np.float64)
        Yv = np.asarray(state.mem_y, np.float64)
        rho = np.asarray(state.mem_rho, np.float64)
        d = -_two_loop(g, S, Yv, rho, count)
        gd = float(g @ d)
        if not np.isfinite(gd) or gd >= 0.0:  # ring gone stale → steepest descent
            d, gd = -g, -(gnorm * gnorm)
        t = min(1.0, 1.0 / max(float(np.abs(g).sum()), 1e-12)) if count == 0 else 1.0
        f_t, g_t, armijo = f0, None, False
        for _ in range(max_linesearch):
            cand = unravel(jnp.asarray(x + t * d, jnp.float32))
            # fused trial: value AND gradient in the same streamed sweep —
            # the accepted trial's gradient seeds the next iteration free
            loss_t, grads_t = vg_j(cand, batch)
            sweeps["vg"] += 1
            f_t = float(host_gather(loss_t))
            if np.isfinite(f_t) and f_t <= f0 + 1e-4 * t * gd:
                g_t = _flat_grad(grads_t)
                armijo = True
                break
            t *= 0.5
        if not armijo:
            return state._replace(
                step=state.step + 1, loss=jnp.asarray(f0, jnp.float32),
                converged=jnp.asarray(True),
            ), metrics
        s = t * d
        x_new = x + s
        hv = hvp_j(
            unravel(jnp.asarray(x_new, jnp.float32)),
            unravel(jnp.asarray(s, jnp.float32)),
            batch,
        )
        sweeps["hvp"] += 1
        y = np.asarray(ravel_pytree(jax.tree.map(host_gather, hv))[0], np.float64)
        sy = float(s @ y)
        # curvature-pair acceptance (skip, don't damp: the HVP y is exact
        # curvature, so a tiny sᵀy means genuinely indefinite local curvature)
        if np.isfinite(sy) and sy > 1e-10 * np.linalg.norm(s) * np.linalg.norm(y):
            if count < m:
                S[count], Yv[count], rho[count] = s, y, 1.0 / sy
                count += 1
            else:
                S, Yv, rho = np.roll(S, -1, 0), np.roll(Yv, -1, 0), np.roll(rho, -1, 0)
                S[-1], Yv[-1], rho[-1] = s, y, 1.0 / sy
        metrics["loss"] = np.float32(f_t)
        return state._replace(
            step=state.step + 1,
            flat=jnp.asarray(x_new, jnp.float32),
            loss=jnp.asarray(f_t, jnp.float32),
            grad=jnp.asarray(g_t, jnp.float32),
            have_grad=jnp.asarray(True),
            mem_s=jnp.asarray(S, jnp.float32),
            mem_y=jnp.asarray(Yv, jnp.float32),
            mem_rho=jnp.asarray(rho, jnp.float32),
            count=jnp.asarray(count, jnp.int32),
        ), metrics

    def attempt(ctx):
        # fresh iterate per attempt; resume pulls the latest good checkpoint
        state = LBFGSState(
            step=jnp.zeros((), jnp.int32),
            flat=jnp.asarray(flat0, jnp.float32),
            loss=jnp.asarray(np.inf, jnp.float32),
            grad=jnp.zeros((P,), jnp.float32),
            have_grad=jnp.zeros((), jnp.bool_),
            mem_s=jnp.zeros((m, P), jnp.float32),
            mem_y=jnp.zeros((m, P), jnp.float32),
            mem_rho=jnp.zeros((m,), jnp.float32),
            count=jnp.zeros((), jnp.int32),
            converged=jnp.zeros((), jnp.bool_),
        )
        start = 0
        if resume or ctx.resume:
            state, start = restore_train_state(checkpoint, state)
        return train_loop(
            step_fn, state, lambda i: batch, steps, start=start, mgr=checkpoint,
            ckpt_every=ckpt_every, log_every=log_every, label=label,
        )

    sup = RunSupervisor(label=label, mesh=mesh)
    state, losses = sup.run(attempt)
    LAST_LBFGS_SWEEPS.clear()
    LAST_LBFGS_SWEEPS.update(sweeps)
    params = unravel(jnp.asarray(state.flat))
    return params, np.asarray([float(x) for x in losses], np.float64), state


# ---------------------------------------------------------------------------
# sampled-minibatch fitting
# ---------------------------------------------------------------------------


def _fit_minibatch(
    model,
    params0,
    batch: dict,
    *,
    optimizer: Optimizer,
    steps: int,
    mesh=None,
    microbatches: int = 1,
    batch_size: int,
    sample_seed: int = 0,
    sampling: str = "uniform",
    checkpoint=None,
    ckpt_every: int = 0,
    resume: bool = False,
    log_every: int = 0,
    label: str = "minibatch",
):
    """Sampled-minibatch driver: each step draws ``batch_size`` weighted rows
    through ``data.pipeline.subset_loader`` over the full index set (uniform
    with replacement, or w-proportional with the 1/p correction under
    ``sampling="importance"`` — the caller's normalizer makes the
    weighted-NLL estimate unbiased either way, see ``method_batch_plan``)
    and takes one
    ``make_train_step`` step, sharded exactly like the full-batch path.
    Batches are a pure function of (sample_seed, step), so checkpoint resume
    replays the straight run's sample sequence.

    With ``ft_config.straggler_deadline_ms > 0`` each primary draw is
    deadlined (``data.pipeline.with_backup_draws``): a draw slower than the
    deadline is replaced by the deterministic backup draw of the same step —
    also pure in ``step``, so resume stays replayable.
    """
    from repro.data.pipeline import (
        BACKUP_SEED_OFFSET,
        full_data_loader,
        with_backup_draws,
    )
    from repro.ft.failure import StragglerPolicy

    microbatches = max(1, microbatches)
    w = np.asarray(batch["weights"], np.float32)
    b = resolve_batch_size(batch_size, microbatches, mesh)
    data = {k: np.asarray(v) for k, v in batch.items() if k != "weights"}
    sample_fn = full_data_loader(data, w, b, seed=sample_seed, sampling=sampling)
    ft = get_ft_config()
    if ft.straggler_deadline_ms > 0:
        backup_fn = full_data_loader(
            data, w, b, seed=sample_seed + BACKUP_SEED_OFFSET, sampling=sampling
        )
        sample_fn = with_backup_draws(
            sample_fn,
            backup_fn,
            StragglerPolicy(
                deadline_ms=ft.straggler_deadline_ms,
                backup_factor=ft.straggler_backup_factor,
            ),
        )
    return _train_state_loop(
        model, params0, sample_fn(0),
        lambda put: (lambda i: put(sample_fn(i))),
        optimizer=optimizer, steps=steps, mesh=mesh, microbatches=microbatches,
        checkpoint=checkpoint, ckpt_every=ckpt_every, resume=resume,
        log_every=log_every, label=label,
    )


def fit_mctm_streaming(
    cfg: M.MCTMConfig,
    scaler,
    Y,
    weights=None,
    *,
    key: jax.Array | None = None,
    init: M.MCTMParams | None = None,
    steps: int = 1500,
    lr: float = 5e-2,
    optimizer: Optimizer | None = None,
    method: str = "adam",
    mesh=None,
    chunk_size: int | None = DEFAULT_CHUNK,
    microbatches: int | None = None,
    batch_size: int | None = None,
    sample_seed: int = 0,
    sampling: str = "uniform",
    history: int = 10,
    gtol: float = 1e-6,
    featurize: Callable | None = None,
    checkpoint=None,
    ckpt_every: int = 0,
    resume: bool = False,
    log_every: int = 0,
) -> M.FitResult:
    """Weighted maximum-likelihood MCTM fit — the engine behind
    ``mctm.fit_mctm`` (see the module doc for the method table and the
    streaming/sharding contract). ``weights`` are the coreset weights (None →
    unweighted full-data fit); inputs beyond ``chunk_size`` rows are
    featurized microbatch-by-microbatch inside the step, never as one
    (n, J, d) tensor. ``method`` selects the fit mode: ``"adam"`` (any
    first-order ``optimizer``), ``"lbfgs"`` (streaming-HVP quasi-Newton;
    ``steps`` are iterations, early-stopping at ``gtol``), or
    ``"minibatch"`` (``batch_size`` sampled weighted rows per step;
    ``sampling="importance"`` for w-proportional draws with the 1/p
    correction).
    """
    Y = np.asarray(Y, np.float32)
    n = int(Y.shape[0])
    if n == 0:
        raise ValueError("cannot fit an empty dataset")
    if init is None:
        if key is None:
            key = jax.random.PRNGKey(0)
        init = M.init_params(key, cfg)
    w, total_w, chunk, microbatches, batch_size, norm = method_batch_plan(
        method, n, weights, chunk_size, microbatches, batch_size, mesh
    )
    model = MCTMDensityModel(cfg, scaler, norm=norm, featurize=featurize)
    batch = {"Y": Y, "weights": w}
    if method == "adam" and microbatches == 1 and featurize is None:
        # dense fast path (the scoring engine's single-chunk rule): featurize
        # exactly once outside the step instead of once per optimizer step.
        # adam only — lbfgs holds its batch across many oracle sweeps, where
        # a cached (n, J, d) basis is exactly the liveness bug this layer
        # exists to avoid, and minibatch rows change every step.
        A, Ap = fit_featurize(cfg, scaler)(jnp.asarray(Y))
        batch = {"A": np.asarray(A), "Ap": np.asarray(Ap), "weights": w}
    params, losses, _ = fit_density_model(
        model,
        init,
        batch,
        optimizer=optimizer or default_fit_optimizer(lr, steps),
        steps=steps,
        method=method,
        mesh=mesh,
        microbatches=microbatches,
        batch_size=batch_size,
        sample_seed=sample_seed,
        sampling=sampling,
        history=history,
        gtol=gtol,
        checkpoint=checkpoint,
        ckpt_every=ckpt_every,
        resume=resume,
        log_every=log_every,
        label=f"mctm-{method}",
    )
    params = M.MCTMParams(*params)
    final = streamed_nll(
        cfg, scaler, params, Y,
        weights=None if weights is None else w,
        chunk=chunk, mesh=mesh, featurize=featurize,
    )
    return M.FitResult(params=params, losses=losses, final_nll=float(final))


# ---------------------------------------------------------------------------
# streamed full-data NLL evaluator
# ---------------------------------------------------------------------------


# evaluator closures keyed on (cfg, scaler bounds[, mesh/layout]): the driver
# evaluates several parameter sets over the same data layout, and an uncached
# closure would recompile the featurize→nll_terms body every call. Custom
# featurize callables are never cached (per-call closures; an id()-keyed
# entry could alias a GC'd closure's reused address).
_CHUNK_NLL_CACHE: dict = {}
_SHARDED_NLL_CACHE: dict = {}


def _chunk_nll_fn(feat, cfg):
    @jax.jit
    def chunk_nll(p, Yc, wc):
        A, Ap = feat(Yc)
        return jnp.sum(wc * M.nll_terms(cfg, p, A, Ap))

    return chunk_nll


def _make_sharded_nll_fn(feat, cfg, mesh, axes, chunk: int, cps: int):
    """One-psum sharded NLL sweep: each shard lax.scans its (cps, chunk, J)
    row slices through featurize → nll_terms, then the scalar totals psum —
    the evaluator analogue of the scoring engine's fused pass-1 collective."""
    axis_name = axes if len(axes) > 1 else axes[0]
    row_spec = axes if len(axes) > 1 else axes[0]

    def body(params, ys, wm):
        def step(carry, xs):
            yc, wc = xs
            A, Ap = feat(yc)
            return carry + jnp.sum(wc * M.nll_terms(cfg, params, A, Ap)), None

        total, _ = jax.lax.scan(
            step,
            jnp.zeros((), jnp.float32),
            (ys.reshape((cps, chunk) + ys.shape[1:]), wm.reshape(cps, chunk)),
        )
        return jax.lax.psum(total, axis_name)

    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(row_spec, None), P(row_spec)),
            out_specs=P(),
            check_vma=False,
        )
    )


def streamed_nll(
    cfg: M.MCTMConfig,
    scaler,
    params: M.MCTMParams,
    Y,
    weights=None,
    *,
    chunk: int | None = DEFAULT_CHUNK,
    mesh=None,
    axis="data",
    featurize: Callable | None = None,
    eta: float | None = None,
) -> float:
    """Total (weighted) NLL Σ w·nll(θ) streamed in O(chunk·J·d) memory.

    Single-host: a host chunk loop over the jitted featurize→nll_terms body.
    With ``mesh``: ONE psum'd shard_map sweep (chunks scanned inside the
    body, ``DistributedScoringEngine``-style; padding rows carry zero
    weight). ``eta`` overrides the Jacobian floor for strict evaluation
    (``eta=1e-9`` exposes log-term blow-ups a coreset failed to guard
    against — the convention of ``coreset.evaluate_coreset``).
    """
    cfg_eval = dataclasses.replace(cfg, eta=eta) if eta is not None else cfg
    feat = fit_featurize(cfg_eval, scaler, featurize)
    Y = np.asarray(Y, np.float32)
    n = int(Y.shape[0])
    w = (
        np.ones(n, np.float32)
        if weights is None
        else np.asarray(weights, np.float32)
    )
    if mesh is None:
        c = int(chunk) if chunk else n
        if featurize is not None:
            chunk_nll = _chunk_nll_fn(feat, cfg_eval)
        else:
            ck = (
                cfg_eval,
                None if scaler is None else np.asarray(scaler.low).tobytes(),
                None if scaler is None else np.asarray(scaler.high).tobytes(),
            )
            chunk_nll = _CHUNK_NLL_CACHE.get(ck)
            if chunk_nll is None:
                if len(_CHUNK_NLL_CACHE) > 64:
                    _CHUNK_NLL_CACHE.clear()
                chunk_nll = _chunk_nll_fn(feat, cfg_eval)
                _CHUNK_NLL_CACHE[ck] = chunk_nll
        total = 0.0
        for lo in range(0, n, c):
            hi = min(lo + c, n)
            total += float(chunk_nll(p=params, Yc=jnp.asarray(Y[lo:hi]),
                                     wc=jnp.asarray(w[lo:hi])))
        return total

    axes = _axis_tuple(axis)
    chunk_v, cps, n_pad = shard_layout(mesh, axes, n, chunk)
    pad = n_pad - n
    if pad:
        Y = np.concatenate([Y, np.broadcast_to(Y[:1], (pad,) + Y.shape[1:])])
        w = np.concatenate([w, np.zeros(pad, np.float32)])
    if featurize is not None:
        # custom featurize closures are per-call objects — an id()-keyed
        # cache could alias a GC'd closure's reused address; build fresh
        fn = _make_sharded_nll_fn(feat, cfg_eval, mesh, axes, chunk_v, cps)
    else:
        cache_key = (
            cfg_eval,
            None if scaler is None else np.asarray(scaler.low).tobytes(),
            None if scaler is None else np.asarray(scaler.high).tobytes(),
            mesh, axes, chunk_v, cps,
        )
        fn = _SHARDED_NLL_CACHE.get(cache_key)
        if fn is None:
            if len(_SHARDED_NLL_CACHE) > 64:
                _SHARDED_NLL_CACHE.clear()
            fn = _make_sharded_nll_fn(feat, cfg_eval, mesh, axes, chunk_v, cps)
            _SHARDED_NLL_CACHE[cache_key] = fn
    return float(host_gather(fn(params, jnp.asarray(Y), jnp.asarray(w))))


# ---------------------------------------------------------------------------
# (1±ε) validation helpers
# ---------------------------------------------------------------------------


def likelihood_ratio(nll_model: float, nll_ref: float) -> float:
    """NLL_ref-normalized likelihood ratio (≥ ~1, →1 better), computed as
    1 + (NLL_model − NLL_ref)/|NLL_ref|. For positive references this IS the
    raw ratio NLL_model/NLL_ref; for non-positive references (high-density
    data, where the raw ratio is meaningless) it equals the paper tables'
    shift normalization (shift by −2·NLL_ref) — and unlike the two-branch
    form it stays finite and correctly-signed for references near zero."""
    return float(1.0 + (nll_model - nll_ref) / max(abs(nll_ref), 1e-6))


def coreset_epsilon(
    cfg: M.MCTMConfig,
    scaler,
    Y,
    cs_Y,
    cs_weights,
    params_list,
    *,
    chunk: int | None = DEFAULT_CHUNK,
    mesh=None,
    axis="data",
    eta: float | None = None,
    full_nlls=None,
) -> float:
    """Measured coreset approximation parameter ε̂.

    The coreset property the paper proves is |NLL_C(θ) − NLL(θ)| ≤ ε·NLL(θ);
    this measures the realized ε at the parameters that matter (the coreset
    fit and the full fit): ε̂ = max_θ |Σ w·nll_C(θ) − NLL_full(θ)|/|NLL_full(θ)|,
    the full-data side streamed on the mesh, the (small) coreset side
    single-host. ``full_nlls``: optional per-θ precomputed full-data NLLs
    (aligned with ``params_list``, None entries computed here) — drivers that
    already ran the full sweep for the ratio pass them in instead of paying
    a second full-data pass per θ.
    """
    if full_nlls is None:
        full_nlls = [None] * len(params_list)
    eps = 0.0
    for p, full in zip(params_list, full_nlls):
        if full is None:
            full = streamed_nll(
                cfg, scaler, p, Y, chunk=chunk, mesh=mesh, axis=axis, eta=eta
            )
        cs = streamed_nll(
            cfg, scaler, p, cs_Y, weights=cs_weights, chunk=chunk, eta=eta
        )
        eps = max(eps, abs(cs - full) / max(abs(full), 1e-9))
    return float(eps)
