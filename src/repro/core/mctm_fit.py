"""End-to-end MCTM fit layer: streamed featurization, sharded weighted-NLL
training, and the streamed full-data evaluator behind the (1±ε) validation.

Fit-layer contract (the training-side mirror of the PassStrategy contract in
``core.scoring``)
-----------------------------------------------------------------------------
What streams — basis featurization. No path below materializes an (n, J, d)
basis tensor beyond one chunk: the train step featurizes each microbatch
INSIDE the jitted loss (``MCTMDensityModel``), so a step over n rows with
``microbatches = ⌈n/chunk⌉`` holds one (chunk, J, d) block at a time while
the gradient-accumulation scan carries only O(|params|) state; the evaluator
(``streamed_nll``) featurizes chunk-by-chunk inside a ``lax.scan``. Both
reuse the scoring engine's fused cached featurize (``scoring._mctm_featurize``)
and the engine's chunk/shard geometry (``distributed_coreset.shard_layout``)
— the same chunk-driver discipline as Algorithm 1's pre-sampling phase, and
the same ``featurize=`` override point (which is how the counting tests
assert the no-materialization property).

What shards — rows. With ``mesh=`` the step jits through
``train.trainer.make_train_step`` / ``shard_train_step`` with the batch
row-sharded over the data axes and the (tiny) parameter + ``repro.optim``
optimizer state replicated, so the identical step function runs single-host
or on a pod; ragged row counts are padded with zero-weight copies of row 0
(valid data — no NaN through the featurizer), exactly like
``DistributedScoringEngine``. The streamed evaluator runs its chunk scan
INSIDE a shard_map body and reduces with ONE psum — the evaluator analogue
of the engine's fused pass-1 collective. ``CheckpointManager`` resume is
supported on both layouts (``train.loop.restore_train_state``).

What the evaluator guarantees — ``streamed_nll`` computes the total weighted
NLL Σᵢ wᵢ·nllᵢ(θ): the same statistic as ``mctm.nll`` on a materialized
basis, up to f32 reassociation across chunk/shard boundaries, at
O(chunk·J·d) peak memory on any mesh layout. It is the measurement device
for the paper's headline claim — ``coreset_epsilon`` measures the coreset's
realized ε = max_θ |NLL_C(θ) − NLL(θ)| / |NLL(θ)| over the fitted
parameters, and ``launch.train_mctm`` checks the coreset-fit/full-fit
likelihood ratio against the (1±ε) band that ε implies.

Coreset weights flow through the trainer's per-example-weight path
(``batch["weights"]``); the objective is Σ w·nll / Σw — a constant
normalizer, so gradients match ``mctm.nll`` up to scale and the lr stays
scale-free across coreset sizes (the contract ``fit_mctm`` always had).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import mctm as M
from repro.core.distributed_coreset import _axis_tuple, host_gather, shard_layout
from repro.core.scoring import DEFAULT_CHUNK, _mctm_featurize
from repro.optim import Optimizer, adamw
from repro.train import (
    init_train_state,
    make_train_step,
    restore_train_state,
    shard_train_step,
    train_loop,
)
from repro.utils.compat import shard_map

__all__ = [
    "MCTMDensityModel",
    "fit_featurize",
    "fit_density_model",
    "fit_mctm_streaming",
    "batch_plan",
    "streamed_nll",
    "coreset_epsilon",
    "likelihood_ratio",
    "cosine_decay",
]


def cosine_decay(lr: float, steps: int):
    """The fit layer's default schedule — lr·½(1+cos(π·i/steps)), the exact
    decay the retired hand-rolled ``mctm._adam_fit`` applied, so fits through
    ``repro.optim.adamw`` reproduce the seed trajectories."""

    def fn(step):
        frac = step.astype(jnp.float32) / max(steps, 1)
        return jnp.asarray(lr, jnp.float32) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))

    return fn


def default_fit_optimizer(lr: float, steps: int) -> Optimizer:
    """Adam + cosine decay matching ``_adam_fit``'s exact update math."""
    return adamw(cosine_decay(lr, steps), b1=0.9, b2=0.999, eps=1e-8)


def fit_featurize(cfg: M.MCTMConfig, scaler, featurize: Callable | None = None):
    """Chunk featurizer for the fit layer: Y chunk (c, J) → (A, Ap) each
    (c, J, d). Wraps the scoring engine's fused cached featurize (one jitted
    trace per chunk length, shared with Algorithm 1's scoring sweeps);
    ``featurize`` overrides the base evaluation (counting tests, custom
    bases) with the engine's flat (X (c, J·d), P (c·J, d)) contract.
    """
    base = featurize if featurize is not None else _mctm_featurize(cfg, scaler)

    def feat(Yc):
        X, Pr = base(Yc)
        c = X.shape[0]
        return X.reshape(c, cfg.J, cfg.d), Pr.reshape(c, cfg.J, cfg.d)

    return feat


class MCTMDensityModel:
    """``loss_fn(params, batch)`` adapter for ``train.make_train_step``.

    batch is ``{"Y": (b, J), "weights": (b,)}`` — featurized INSIDE the loss
    so a microbatched step only ever holds one (b/microbatches, J, d) block —
    or ``{"A", "Ap", "weights"}`` when the caller pre-featurized (the dense
    single-chunk fast path, mirroring the scoring engine's). ``norm`` is the
    constant objective normalizer (Σ real weights / microbatches, so the
    microbatch-mean the trainer computes equals Σ w·nll / Σw globally).
    """

    def __init__(self, cfg: M.MCTMConfig, scaler=None, *, norm: float = 1.0,
                 featurize: Callable | None = None):
        self.cfg = cfg
        self.norm = float(norm)
        self._feat = (
            fit_featurize(cfg, scaler, featurize)
            if (scaler is not None or featurize is not None)
            else None
        )

    def features(self, batch):
        if "A" in batch:
            return batch["A"], batch["Ap"]
        return self._feat(batch["Y"])

    def loss_fn(self, params, batch):
        A, Ap = self.features(batch)
        terms = M.nll_terms(self.cfg, params, A, Ap)
        w = batch.get("weights")
        total = jnp.sum(terms if w is None else w * terms)
        return total / self.norm, {}


def _pad_batch(batch: dict, multiple: int) -> tuple[dict, int, int]:
    """Pad batch rows to a multiple: zero weights, row-0 copies elsewhere
    (valid data — no NaN through the featurizer), the same padding rule as
    ``DistributedScoringEngine.score``. Returns (batch, n, n_pad)."""
    n = int(batch["weights"].shape[0])
    n_pad = -(-n // multiple) * multiple
    if n_pad == n:
        return batch, n, n_pad
    pad = n_pad - n
    out = {}
    for k, v in batch.items():
        v = np.asarray(v)
        if k == "weights":
            out[k] = np.concatenate([v, np.zeros(pad, v.dtype)])
        else:
            out[k] = np.concatenate(
                [v, np.broadcast_to(v[:1], (pad,) + v.shape[1:])]
            )
    return out, n, n_pad


def _replicated_specs(params):
    """Logical sharding specs that replicate every (tiny) parameter leaf."""
    return jax.tree.map(lambda p: (None,) * np.ndim(p), params)


def batch_plan(n: int, weights, chunk_size: int | None, microbatches: int | None):
    """Shared scaffolding of every full-batch density fit (MCTM and
    conditional): resolved per-example weights, their total (the constant
    objective normalizer), the chunk length, and the microbatch count
    (⌈n/chunk⌉ unless given). One implementation so the two fit entry points
    cannot drift on the streaming/normalization rules."""
    w = (
        np.ones(n, np.float32)
        if weights is None
        else np.asarray(weights, np.float32)
    )
    chunk = int(chunk_size) if chunk_size else n
    if microbatches is None:
        microbatches = max(1, -(-n // chunk))
    return w, float(w.sum()), chunk, microbatches


def fit_density_model(
    model,
    params0,
    batch: dict,
    *,
    optimizer: Optimizer,
    steps: int,
    mesh=None,
    microbatches: int = 1,
    checkpoint=None,
    ckpt_every: int = 0,
    resume: bool = False,
    log_every: int = 0,
    label: str = "fit",
):
    """The generic full-batch density-fit driver under every MCTM-family fit.

    ``model`` follows the trainer's ``loss_fn(params, batch)`` contract (the
    MCTM and conditional-MCTM adapters both do); ``batch`` must carry a
    ``"weights"`` row — rows are padded here to a (microbatches × shards)
    multiple with zero weight. With ``mesh`` the step is jitted through
    ``shard_train_step`` (batch row-sharded, params/optimizer state
    replicated); without, a plain donated jit. ``checkpoint`` is a
    ``CheckpointManager``; ``resume=True`` restarts from its latest step.

    Returns ``(params, losses, final_state)`` with params gathered to host
    and losses one float per executed step.
    """
    shards = 1 if mesh is None else int(np.prod(list(mesh.shape.values())))
    batch, _, _ = _pad_batch(batch, max(1, microbatches) * shards)
    step_pure = make_train_step(model, optimizer, microbatches=microbatches)
    state = init_train_state(params0, optimizer)
    state_sh = None
    if mesh is not None:
        batch_shapes = {
            k: jax.ShapeDtypeStruct(np.shape(v), np.asarray(v).dtype)
            for k, v in batch.items()
        }
        step_fn, state_sh, batch_sh = shard_train_step(
            step_pure,
            model,
            optimizer,
            mesh,
            params_shapes=params0,
            specs=_replicated_specs(params0),
            batch_shapes=batch_shapes,
        )
        batch = {
            k: jax.device_put(jnp.asarray(v), batch_sh[k]) for k, v in batch.items()
        }
        state = jax.device_put(state, state_sh)
    else:
        step_fn = jax.jit(step_pure, donate_argnums=(0,))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
    start = 0
    if resume:
        state, start = restore_train_state(checkpoint, state, shardings=state_sh)
    state, losses = train_loop(
        step_fn,
        state,
        lambda i: batch,
        steps,
        start=start,
        mgr=checkpoint,
        ckpt_every=ckpt_every,
        log_every=log_every,
        label=label,
    )
    params = jax.tree.map(lambda x: jnp.asarray(host_gather(x)), state.params)
    return params, np.asarray([float(x) for x in losses], np.float64), state


def fit_mctm_streaming(
    cfg: M.MCTMConfig,
    scaler,
    Y,
    weights=None,
    *,
    key: jax.Array | None = None,
    init: M.MCTMParams | None = None,
    steps: int = 1500,
    lr: float = 5e-2,
    optimizer: Optimizer | None = None,
    mesh=None,
    chunk_size: int | None = DEFAULT_CHUNK,
    microbatches: int | None = None,
    featurize: Callable | None = None,
    checkpoint=None,
    ckpt_every: int = 0,
    resume: bool = False,
    log_every: int = 0,
) -> M.FitResult:
    """Weighted maximum-likelihood MCTM fit — the engine behind
    ``mctm.fit_mctm`` (see the module doc for the streaming/sharding
    contract). ``weights`` are the coreset weights (None → unweighted
    full-data fit); inputs beyond ``chunk_size`` rows are featurized
    microbatch-by-microbatch inside the step, never as one (n, J, d) tensor.
    """
    Y = np.asarray(Y, np.float32)
    n = int(Y.shape[0])
    if n == 0:
        raise ValueError("cannot fit an empty dataset")
    if init is None:
        if key is None:
            key = jax.random.PRNGKey(0)
        init = M.init_params(key, cfg)
    w, total_w, chunk, microbatches = batch_plan(n, weights, chunk_size, microbatches)
    model = MCTMDensityModel(
        cfg, scaler, norm=total_w / microbatches, featurize=featurize
    )
    batch = {"Y": Y, "weights": w}
    if microbatches == 1 and featurize is None:
        # dense fast path (the scoring engine's single-chunk rule): featurize
        # exactly once outside the step instead of once per optimizer step
        A, Ap = fit_featurize(cfg, scaler)(jnp.asarray(Y))
        batch = {"A": np.asarray(A), "Ap": np.asarray(Ap), "weights": w}
    params, losses, _ = fit_density_model(
        model,
        init,
        batch,
        optimizer=optimizer or default_fit_optimizer(lr, steps),
        steps=steps,
        mesh=mesh,
        microbatches=microbatches,
        checkpoint=checkpoint,
        ckpt_every=ckpt_every,
        resume=resume,
        log_every=log_every,
        label="mctm-fit",
    )
    params = M.MCTMParams(*params)
    final = streamed_nll(
        cfg, scaler, params, Y,
        weights=None if weights is None else w,
        chunk=chunk, mesh=mesh, featurize=featurize,
    )
    return M.FitResult(params=params, losses=losses, final_nll=float(final))


# ---------------------------------------------------------------------------
# streamed full-data NLL evaluator
# ---------------------------------------------------------------------------


# evaluator closures keyed on (cfg, scaler bounds[, mesh/layout]): the driver
# evaluates several parameter sets over the same data layout, and an uncached
# closure would recompile the featurize→nll_terms body every call. Custom
# featurize callables are never cached (per-call closures; an id()-keyed
# entry could alias a GC'd closure's reused address).
_CHUNK_NLL_CACHE: dict = {}
_SHARDED_NLL_CACHE: dict = {}


def _chunk_nll_fn(feat, cfg):
    @jax.jit
    def chunk_nll(p, Yc, wc):
        A, Ap = feat(Yc)
        return jnp.sum(wc * M.nll_terms(cfg, p, A, Ap))

    return chunk_nll


def _make_sharded_nll_fn(feat, cfg, mesh, axes, chunk: int, cps: int):
    """One-psum sharded NLL sweep: each shard lax.scans its (cps, chunk, J)
    row slices through featurize → nll_terms, then the scalar totals psum —
    the evaluator analogue of the scoring engine's fused pass-1 collective."""
    axis_name = axes if len(axes) > 1 else axes[0]
    row_spec = axes if len(axes) > 1 else axes[0]

    def body(params, ys, wm):
        def step(carry, xs):
            yc, wc = xs
            A, Ap = feat(yc)
            return carry + jnp.sum(wc * M.nll_terms(cfg, params, A, Ap)), None

        total, _ = jax.lax.scan(
            step,
            jnp.zeros((), jnp.float32),
            (ys.reshape((cps, chunk) + ys.shape[1:]), wm.reshape(cps, chunk)),
        )
        return jax.lax.psum(total, axis_name)

    return jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), P(row_spec, None), P(row_spec)),
            out_specs=P(),
            check_vma=False,
        )
    )


def streamed_nll(
    cfg: M.MCTMConfig,
    scaler,
    params: M.MCTMParams,
    Y,
    weights=None,
    *,
    chunk: int | None = DEFAULT_CHUNK,
    mesh=None,
    axis="data",
    featurize: Callable | None = None,
    eta: float | None = None,
) -> float:
    """Total (weighted) NLL Σ w·nll(θ) streamed in O(chunk·J·d) memory.

    Single-host: a host chunk loop over the jitted featurize→nll_terms body.
    With ``mesh``: ONE psum'd shard_map sweep (chunks scanned inside the
    body, ``DistributedScoringEngine``-style; padding rows carry zero
    weight). ``eta`` overrides the Jacobian floor for strict evaluation
    (``eta=1e-9`` exposes log-term blow-ups a coreset failed to guard
    against — the convention of ``coreset.evaluate_coreset``).
    """
    cfg_eval = dataclasses.replace(cfg, eta=eta) if eta is not None else cfg
    feat = fit_featurize(cfg_eval, scaler, featurize)
    Y = np.asarray(Y, np.float32)
    n = int(Y.shape[0])
    w = (
        np.ones(n, np.float32)
        if weights is None
        else np.asarray(weights, np.float32)
    )
    if mesh is None:
        c = int(chunk) if chunk else n
        if featurize is not None:
            chunk_nll = _chunk_nll_fn(feat, cfg_eval)
        else:
            ck = (
                cfg_eval,
                None if scaler is None else np.asarray(scaler.low).tobytes(),
                None if scaler is None else np.asarray(scaler.high).tobytes(),
            )
            chunk_nll = _CHUNK_NLL_CACHE.get(ck)
            if chunk_nll is None:
                if len(_CHUNK_NLL_CACHE) > 64:
                    _CHUNK_NLL_CACHE.clear()
                chunk_nll = _chunk_nll_fn(feat, cfg_eval)
                _CHUNK_NLL_CACHE[ck] = chunk_nll
        total = 0.0
        for lo in range(0, n, c):
            hi = min(lo + c, n)
            total += float(chunk_nll(p=params, Yc=jnp.asarray(Y[lo:hi]),
                                     wc=jnp.asarray(w[lo:hi])))
        return total

    axes = _axis_tuple(axis)
    chunk_v, cps, n_pad = shard_layout(mesh, axes, n, chunk)
    pad = n_pad - n
    if pad:
        Y = np.concatenate([Y, np.broadcast_to(Y[:1], (pad,) + Y.shape[1:])])
        w = np.concatenate([w, np.zeros(pad, np.float32)])
    if featurize is not None:
        # custom featurize closures are per-call objects — an id()-keyed
        # cache could alias a GC'd closure's reused address; build fresh
        fn = _make_sharded_nll_fn(feat, cfg_eval, mesh, axes, chunk_v, cps)
    else:
        cache_key = (
            cfg_eval,
            None if scaler is None else np.asarray(scaler.low).tobytes(),
            None if scaler is None else np.asarray(scaler.high).tobytes(),
            mesh, axes, chunk_v, cps,
        )
        fn = _SHARDED_NLL_CACHE.get(cache_key)
        if fn is None:
            if len(_SHARDED_NLL_CACHE) > 64:
                _SHARDED_NLL_CACHE.clear()
            fn = _make_sharded_nll_fn(feat, cfg_eval, mesh, axes, chunk_v, cps)
            _SHARDED_NLL_CACHE[cache_key] = fn
    return float(host_gather(fn(params, jnp.asarray(Y), jnp.asarray(w))))


# ---------------------------------------------------------------------------
# (1±ε) validation helpers
# ---------------------------------------------------------------------------


def likelihood_ratio(nll_model: float, nll_ref: float) -> float:
    """NLL_ref-normalized likelihood ratio (≥ ~1, →1 better), computed as
    1 + (NLL_model − NLL_ref)/|NLL_ref|. For positive references this IS the
    raw ratio NLL_model/NLL_ref; for non-positive references (high-density
    data, where the raw ratio is meaningless) it equals the paper tables'
    shift normalization (shift by −2·NLL_ref) — and unlike the two-branch
    form it stays finite and correctly-signed for references near zero."""
    return float(1.0 + (nll_model - nll_ref) / max(abs(nll_ref), 1e-6))


def coreset_epsilon(
    cfg: M.MCTMConfig,
    scaler,
    Y,
    cs_Y,
    cs_weights,
    params_list,
    *,
    chunk: int | None = DEFAULT_CHUNK,
    mesh=None,
    axis="data",
    eta: float | None = None,
    full_nlls=None,
) -> float:
    """Measured coreset approximation parameter ε̂.

    The coreset property the paper proves is |NLL_C(θ) − NLL(θ)| ≤ ε·NLL(θ);
    this measures the realized ε at the parameters that matter (the coreset
    fit and the full fit): ε̂ = max_θ |Σ w·nll_C(θ) − NLL_full(θ)|/|NLL_full(θ)|,
    the full-data side streamed on the mesh, the (small) coreset side
    single-host. ``full_nlls``: optional per-θ precomputed full-data NLLs
    (aligned with ``params_list``, None entries computed here) — drivers that
    already ran the full sweep for the ratio pass them in instead of paying
    a second full-data pass per θ.
    """
    if full_nlls is None:
        full_nlls = [None] * len(params_list)
    eps = 0.0
    for p, full in zip(params_list, full_nlls):
        if full is None:
            full = streamed_nll(
                cfg, scaler, p, Y, chunk=chunk, mesh=mesh, axis=axis, eta=eta
            )
        cs = streamed_nll(
            cfg, scaler, p, cs_Y, weights=cs_weights, chunk=chunk, eta=eta
        )
        eps = max(eps, abs(cs - full) / max(abs(full), 1e-9))
    return float(eps)
