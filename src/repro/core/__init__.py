"""Paper core: MCTM models + coreset constructions.

Public API:
  - MCTMConfig / init_params / nll / fit_mctm / log_density / sample
  - fit_density_model / fit_mctm_streaming / streamed_nll / coreset_epsilon
    (the fit layer: streamed + SPMD-sharded weighted-NLL training behind one
    method= contract — full-batch adam, streaming-HVP lbfgs, sampled
    minibatch — and the (1±ε) evaluator; see repro.core.mctm_fit's
    module-doc method table for the contract)
  - build_coreset / evaluate_coreset (Algorithm 1 + baselines)
  - leverage scores (exact, sketched, ridge, root), hull ε-kernels
  - ScoringEngine + pass strategies (TwoPassExact / TwoPassSketched /
    OnePassSketched — see repro.core.scoring's module doc for the contract)
  - MergeReduceCoreset (streams), distributed_* (shard_map pods)
"""
from repro.core.bernstein import (
    DataScaler,
    bernstein_design,
    bernstein_deriv_design,
    monotone_theta,
)
from repro.core.coreset import (
    CORESET_METHODS,
    CoresetEvaluation,
    CoresetResult,
    build_coreset,
    coreset_scores,
    evaluate_coreset,
)
from repro.core.hull import epsilon_kernel_indices, greedy_hull_projection, hull_distance
from repro.core.leverage import (
    block_B_matrix,
    flatten_features,
    leverage_scores_gram,
    leverage_scores_qr,
    ridge_leverage_scores,
    root_leverage_scores,
    sketched_leverage,
)
from repro.core.mctm import (
    FitResult,
    MCTMConfig,
    MCTMParams,
    basis_features,
    fit_mctm,
    init_params,
    log_density,
    nll,
    nll_terms,
    sample,
)
from repro.core.mctm_fit import (
    FIT_METHODS,
    coreset_epsilon,
    fit_density_model,
    fit_mctm_streaming,
    likelihood_ratio,
    streamed_nll,
)
from repro.core.scoring import (
    OnePassSketched,
    PassStrategy,
    ScoringEngine,
    ScoringResult,
    TwoPassExact,
    TwoPassSketched,
    score_chunks,
)
from repro.core.sensitivity import sensitivity_sample
from repro.core.streaming import (
    DriftDetector,
    MergeReduceCoreset,
    StreamingCoresetMaintainer,
    WeightedSet,
    drift_window_nll,
)
