"""Convex-hull / ε-kernel approximation (Blum, Har-Peled, Raichel 2019).

The paper stabilizes the negative-log part f3 by force-including the extreme
points of {a'_ij} (paper Lemma 2.3 / Algorithm 2). Two primitives:

  * ``greedy_hull_projection`` — the paper's Algorithm 2: Frank-Wolfe style
    greedy projection of a query q onto conv(P), returning the approximate
    nearest hull point and the support (extremal) indices it touched.
  * ``epsilon_kernel_indices`` — selects k extremal points by directional
    queries argmax_i ⟨p_i, v⟩ over a spread of directions (random + PCA +
    Algorithm-2 support points). Directional extremal queries are matvecs →
    MXU-friendly, and distribute as per-shard argmax + global max.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "greedy_hull_projection",
    "epsilon_kernel_indices",
    "hull_directions",
    "hull_distance",
    "stable_first_unique",
]


@partial(jax.jit, static_argnames=("max_iter",))
def greedy_hull_projection(
    P: jax.Array, q: jax.Array, eps: float = 1e-2, max_iter: int = 64
):
    """Algorithm 2 of the paper (Blum et al. 2019 sparse hull approximation).

    Greedily walks from the closest point of P toward q, each step moving to
    the best point on the segment [t, p*] where p* is extremal in direction
    (q − t). Returns (t, support_indices, distances) with support_indices the
    sequence of extremal points touched (−1 padding).
    """
    # match q to P's dtype: a mixed-precision query (e.g. f64 q under
    # JAX_ENABLE_X64) would otherwise promote the scan carry mid-body
    q = jnp.asarray(q, P.dtype)
    d0 = jnp.sum(jnp.square(P - q), axis=1)
    i0 = jnp.argmin(d0)
    t0 = P[i0]

    def body(carry, _):
        t, _ = carry
        v = q - t
        scores = P @ v
        i_star = jnp.argmax(scores)
        p = P[i_star]
        seg = p - t
        denom = jnp.sum(jnp.square(seg))
        alpha = jnp.where(denom > 1e-30, jnp.dot(q - t, seg) / jnp.maximum(denom, 1e-30), 0.0)
        alpha = jnp.clip(alpha, 0.0, 1.0)
        t_new = t + alpha * seg
        # Stop moving once within eps (keep state fixed — lax.scan needs static length).
        dist = jnp.linalg.norm(q - t)
        t_new = jnp.where(dist < eps, t, t_new)
        i_rec = jnp.where(dist < eps, -1, i_star)
        return (t_new, i_rec), (i_rec, jnp.linalg.norm(q - t_new))

    (t, _), (support, dists) = jax.lax.scan(body, (t0, i0), None, length=max_iter)
    support = jnp.concatenate([jnp.asarray([i0]), support])
    return t, support, dists


def hull_distance(P: jax.Array, q: jax.Array, eps: float = 1e-3, max_iter: int = 128) -> float:
    """Approximate distance from q to conv(P) (for tests)."""
    t, _, _ = greedy_hull_projection(P, q, eps, max_iter)
    return float(jnp.linalg.norm(q - t))


def hull_directions(key: jax.Array, cov: np.ndarray, m: int) -> np.ndarray:
    """Direction net: m random unit directions + ±principal axes of ``cov``.

    ``cov`` is the (d, d) covariance of the point cloud — the only data
    statistic the net needs, which is what lets the chunked scoring engine
    build the identical net from streamed second moments.
    """
    d = cov.shape[0]
    g = np.array(jax.random.normal(key, (m, d), dtype=jnp.float32))
    g /= np.maximum(np.linalg.norm(g, axis=1, keepdims=True), 1e-12)
    # principal axes (d is small: basis dimension)
    _, V = np.linalg.eigh(cov)
    return np.concatenate([g, V.T, -V.T], axis=0)


def _spread_directions(key: jax.Array, P: np.ndarray, m: int) -> np.ndarray:
    """Random unit directions + principal axes of the centered point cloud."""
    Pc = P - P.mean(axis=0)
    cov = Pc.T @ Pc / max(P.shape[0], 1)
    return hull_directions(key, cov, m)


def stable_first_unique(cand: np.ndarray, k: int | None = None) -> np.ndarray:
    """First k distinct values of ``cand`` in order of first occurrence
    (all of them when ``k`` is None).

    Vectorized replacement for the quadratic ``if i not in seen`` scan: one
    ``np.unique`` for the distinct values, re-sorted by first-occurrence
    position.
    """
    uniq, first = np.unique(cand, return_index=True)
    order = np.argsort(first, kind="stable")
    out = uniq[order]
    return (out if k is None else out[:k]).astype(np.int64)


def epsilon_kernel_indices(
    P: jax.Array | np.ndarray,
    k: int,
    key: jax.Array,
    oversample: int = 4,
    dirs: np.ndarray | None = None,
) -> np.ndarray:
    """Select ≤ k extremal (hull) indices of P via directional queries.

    Matches the role of the η-kernel in Theorem 2.4: the selected set touches
    every direction's extreme within the resolution of the direction net. With
    `oversample·k` directions the dedup'd argmaxes cover the hull densely for
    the mild (low-d) data the paper targets. Pass ``dirs`` to reuse a
    precomputed net (e.g. the scoring engine's moment-derived one).
    """
    P_np = np.asarray(P, dtype=np.float32)
    n = P_np.shape[0]
    if n <= k:
        return np.arange(n)
    if dirs is None:
        dirs = _spread_directions(key, P_np, m=max(oversample * k, 8))
    scores = P_np @ dirs.T  # (n, m)
    cand = np.argmax(scores, axis=0)
    # also take per-direction minima (extreme in −v comes for free)
    cand = np.concatenate([cand, np.argmin(scores, axis=0)])
    return stable_first_unique(cand, k)
