"""Bernstein polynomial basis for MCTM marginal transformations.

The MCTM marginal transform is ``h̃_j(y) = a_j(y)ᵀ ϑ_j`` where ``a_j`` is the
degree-M Bernstein basis on a per-dimension interval [low_j, high_j]:

    b_{k,M}(t) = C(M,k) t^k (1-t)^{M-k},   t = (y - low)/(high - low)

``h̃`` is strictly increasing iff the coefficient vector ϑ is strictly
increasing, which we enforce with a cumulative-softplus reparameterization.

The basis and its derivative are the compute hot-spot of coreset scoring at
large n (the paper evaluates a, a' for all n·J points before sampling); a
fused Pallas kernel lives in ``repro.kernels.bernstein`` with this module's
``bernstein_design`` / ``bernstein_deriv_design`` as its jnp oracle.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "binomial_coefficients",
    "bernstein_design",
    "bernstein_deriv_design",
    "DataScaler",
    "monotone_theta",
    "monotone_theta_inverse",
]


def binomial_coefficients(degree: int) -> np.ndarray:
    """C(M, k) for k = 0..M, exact in float64 (degree is small, ≤ ~30)."""
    coeffs = np.ones(degree + 1, dtype=np.float64)
    for k in range(1, degree + 1):
        coeffs[k] = coeffs[k - 1] * (degree - k + 1) / k
    return coeffs


@partial(jax.jit, static_argnames=("degree",))
def bernstein_design(t: jax.Array, degree: int) -> jax.Array:
    """Bernstein basis matrix on normalized inputs.

    Args:
      t: any shape, values in [0, 1] (clipped inside).
      degree: polynomial degree M; output gets d = M+1 basis functions.

    Returns:
      shape ``t.shape + (M+1,)``; rows sum to 1 (partition of unity).
    """
    # dtype-typed endpoint constants: python floats would lower as weak
    # tensor<f64> scalars under JAX_ENABLE_X64 (flagged by the analysis gate)
    zero, one = t.dtype.type(0), t.dtype.type(1)
    t = jnp.clip(t, zero, one)[..., None]
    k = jnp.arange(degree + 1, dtype=t.dtype)
    coeff = jnp.asarray(binomial_coefficients(degree), dtype=t.dtype)
    # Direct powers are fine and exact-ish for the small degrees used by MCTMs.
    return coeff * jnp.power(t, k) * jnp.power(one - t, degree - k)


@partial(jax.jit, static_argnames=("degree",))
def bernstein_deriv_design(t: jax.Array, degree: int) -> jax.Array:
    """d a(t) / dt — derivative of every basis function w.r.t. normalized t.

    Uses d b_{k,M}/dt = M (b_{k-1,M-1} - b_{k,M-1}) with b_{-1}=b_{M}=0.
    Returns ``t.shape + (M+1,)``. Scale by 1/(high-low) for d/dy.
    """
    if degree == 0:
        return jnp.zeros(t.shape + (1,), dtype=t.dtype)
    lower = bernstein_design(t, degree - 1)  # (..., M)
    pad = jnp.zeros(lower.shape[:-1] + (1,), dtype=lower.dtype)
    left = jnp.concatenate([pad, lower], axis=-1)   # b_{k-1, M-1}
    right = jnp.concatenate([lower, pad], axis=-1)  # b_{k, M-1}
    return degree * (left - right)


@dataclasses.dataclass(frozen=True)
class DataScaler:
    """Per-dimension affine map of raw data onto [0, 1] with a safety margin.

    The same scaler MUST be shared between the full-data fit and every coreset
    fit (the paper fits the basis on the full-data range), so it is computed
    once and carried around explicitly.
    """

    low: np.ndarray   # (J,)
    high: np.ndarray  # (J,)

    @staticmethod
    def fit(Y: np.ndarray, margin: float = 0.05) -> "DataScaler":
        Y = np.asarray(Y)
        lo, hi = Y.min(axis=0), Y.max(axis=0)
        span = np.maximum(hi - lo, 1e-9)
        return DataScaler(low=lo - margin * span, high=hi + margin * span)

    def transform(self, Y: jax.Array) -> jax.Array:
        low = jnp.asarray(self.low, dtype=jnp.result_type(Y, jnp.float32))
        high = jnp.asarray(self.high, dtype=low.dtype)
        return (Y - low) / (high - low)

    @property
    def inv_span(self) -> np.ndarray:
        return 1.0 / (self.high - self.low)


def monotone_theta(theta_raw: jax.Array, min_slope: float = 1e-4) -> jax.Array:
    """Map unconstrained (..., d) coefficients to strictly increasing ones.

    ϑ_0 = raw_0; ϑ_k = ϑ_{k-1} + softplus(raw_k) + min_slope. Guarantees
    ⟨ϑ, a'(y)⟩ > 0 everywhere, i.e. a valid monotone transformation.
    """
    first = theta_raw[..., :1]
    steps = jax.nn.softplus(theta_raw[..., 1:]) + theta_raw.dtype.type(min_slope)
    return jnp.concatenate([first, first + jnp.cumsum(steps, axis=-1)], axis=-1)


def monotone_theta_inverse(theta: jax.Array, min_slope: float = 1e-4) -> jax.Array:
    """Inverse of ``monotone_theta`` (for warm-starting from valid ϑ)."""
    diffs = jnp.diff(theta, axis=-1) - theta.dtype.type(min_slope)
    diffs = jnp.clip(diffs, theta.dtype.type(1e-6), None)
    # softplus^{-1}(x) = log(expm1(x))
    raw_rest = jnp.log(jnp.expm1(diffs))
    return jnp.concatenate([theta[..., :1], raw_rest], axis=-1)
