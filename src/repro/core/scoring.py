"""Chunked two-pass scoring engine for Algorithm 1's pre-sampling phase.

The paper's construction must score *all n* points before it ever samples:
leverage scores u_i of the flattened basis matrix X̃ ∈ R^{n×Jd}, plus the
directional hull extremes of the derivative rows {a'_ij} ⊂ R^d that feed the
ε-kernel augmentation. The naive realization materializes the full (n, J, d)
basis tensor (twice — once for scores, once for the hull) and computes the
Gram in one dense shot, so peak memory grows linearly in n. This engine
replaces that with a streaming pipeline whose peak memory is O(chunk·J·d):

  Pass 1 — statistics. Stream row-chunks of Y through the fused Bernstein
    basis+derivative evaluation and accumulate three small sufficient
    statistics: the Gram G = X̃ᵀX̃ ∈ R^{Jd×Jd} (via the tiled Pallas
    ``gram_kernel`` when compiled on TPU, the XLA oracle elsewhere — see
    ``repro.kernels.gram.ops.gram_matrix``), and the first/second moments of
    the derivative rows P (Σp, Σppᵀ) from which the hull direction net's PCA
    axes are derived. With ``sketch_size > 0`` the Gram is replaced by the
    CountSketch Gram (SX)ᵀ(SX) (Woodruff 2014 Thm 2.13), still accumulated
    chunk-by-chunk. Everything kept across chunks is O((Jd)²).

  Between passes — tiny host-side algebra: one eigh of G gives the projection
    (V, w⁺) such that u_i = ‖X̃_i V‖²_{w⁺}; the direction net (random +
    ±principal axes, exactly ``hull.hull_directions``) is built from the
    accumulated P moments.

  Pass 2 — scores. Re-stream the same chunks to emit leverage scores
    u_i = Σ_m (X̃_i V)²_m · w⁺_m and, fused into the same sweep, the running
    per-direction max/min of ⟨p, v⟩ with first-occurrence argmax semantics —
    the chunked equivalent of ``hull.epsilon_kernel_indices``. No (n, Jd) or
    (n·J, m) array is ever materialized.

When the input fits in a single chunk the engine takes a dense fast path that
evaluates the basis exactly once and shares it between both "passes" (the
recompute-over-store tradeoff only pays off once n exceeds the chunk size).

Weighted inputs (Merge & Reduce streaming buckets) scale X̃ rows by √w —
leverage of the weighted matrix — while the hull operates on the raw
derivative rows, matching the batch construction.

The per-chunk math (``pass1_update``, ``leverage_chunk``,
``hull_chunk_extremes``) and the between-pass host algebra
(``projection_from_gram``, ``directions_from_moments``, ``finalize_scoring``)
are module-level functions so the sharded realization
(``repro.core.distributed_coreset.DistributedScoringEngine`` — the chunk loop
inside a shard_map body, pass-1 state psum'd once) reuses them verbatim; the
remaining follow-on (see ROADMAP) is a sketched pass 1 that avoids the second
data sweep entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hull import hull_directions, stable_first_unique
from repro.kernels.gram.ops import gram_matrix

__all__ = [
    "ScoringEngine",
    "ScoringResult",
    "score_chunks",
    "gram_projection",
    "pass1_update",
    "leverage_chunk",
    "hull_chunk_extremes",
    "projection_from_gram",
    "directions_from_moments",
    "finalize_scoring",
    "DEFAULT_CHUNK",
]

DEFAULT_CHUNK = 65_536

SCORE_METHODS = ("l2-only", "l2-hull", "ridge-lss", "root-l2")


def _spectrum_inverse(w, *, ridge_reg: float, rcond: float, xp):
    """Inverted eigenvalue weights shared by every projection variant.

    ``xp`` is the array module (np or jnp) so the jitted distributed path and
    the engine's f64 host path stay threshold-identical by construction.
    """
    if ridge_reg > 0.0:
        return 1.0 / (xp.maximum(w, 0.0) + ridge_reg)
    wmax = xp.max(xp.abs(w))
    return xp.where(w > rcond * wmax, 1.0 / xp.maximum(w, 1e-30), 0.0)


def gram_projection(
    G: jax.Array, *, ridge_reg: float = 0.0, rcond: float = 1e-6
) -> tuple[jax.Array, jax.Array]:
    """Factor G into (V, inv) with u_i = Σ_m (X_i V)²_m · inv_m.

    ``ridge_reg == 0`` reproduces ``leverage.leverage_from_gram``'s eigh
    pseudo-inverse (rank-deficient Bernstein Grams); ``ridge_reg > 0`` gives
    ridge leverage scores u_i(λ) = X_i (G + λI)⁻¹ X_iᵀ through the same
    eigenbasis (G and G + λI commute). ``rcond`` sits above the f32 noise
    floor so exactly-null modes are excluded regardless of how G was
    accumulated (see ``leverage_from_gram``).
    """
    w, V = jnp.linalg.eigh(G)
    return V, _spectrum_inverse(w, ridge_reg=ridge_reg, rcond=rcond, xp=jnp)


@dataclasses.dataclass
class ScoringResult:
    """Everything the sampling step of Algorithm 1 needs, for n points."""

    scores: np.ndarray             # (n,) sampling scores s_i (method-dependent)
    leverage: np.ndarray           # (n,) raw leverage-type scores u_i
    gram: np.ndarray               # (D, D) accumulated (possibly sketched) Gram
    hull_rows: np.ndarray | None   # ordered extremal row ids into the (n·r) P rows
    hull_points: np.ndarray | None  # unique point ids hit by hull_rows (sorted)
    n: int
    n_chunks: int
    rows_per_point: int = 1        # r: P rows per input point (row → point ÷ r)

    @property
    def hull_candidates(self) -> np.ndarray | None:
        """Alias for ``hull_rows`` (the ε-kernel candidate set)."""
        return self.hull_rows


# jitted featurize closures keyed on (cfg, scaler bounds): build_coreset /
# coreset_scores construct a fresh engine per call, and without this cache
# each engine would carry its own empty jit trace cache and recompile the
# fused basis evaluation every call
_MCTM_FEATURIZE_CACHE: dict = {}


def _mctm_featurize(cfg, scaler) -> Callable[[jax.Array], tuple[jax.Array, jax.Array]]:
    """Fused basis+derivative evaluation for one chunk of Y.

    Returns (X̃ chunk (c, J·d), P chunk (c·J, d)). Single jitted trace per
    distinct chunk length; the math is exactly ``mctm.basis_features``.
    """
    from repro.core import mctm as M

    cache_key = (
        cfg,
        np.asarray(scaler.low).tobytes(),
        np.asarray(scaler.high).tobytes(),
    )
    cached = _MCTM_FEATURIZE_CACHE.get(cache_key)
    if cached is not None:
        return cached

    @jax.jit
    def featurize(Yc: jax.Array) -> tuple[jax.Array, jax.Array]:
        A, Ap = M.basis_features(cfg, scaler, Yc)
        c = A.shape[0]
        return A.reshape(c, cfg.J * cfg.d), Ap.reshape(c * cfg.J, cfg.d)

    if len(_MCTM_FEATURIZE_CACHE) > 64:  # bound growth across many configs
        _MCTM_FEATURIZE_CACHE.clear()
    _MCTM_FEATURIZE_CACHE[cache_key] = featurize
    return featurize


# --------------------------------------------------------------------------
# per-chunk steps. The pure bodies (pass1_update, leverage_chunk,
# hull_chunk_extremes) are shared with the sharded engine, where they run
# inside shard_map scan bodies; the jitted _acc_* wrappers exist so all
# single-host engines share trace caches.
# --------------------------------------------------------------------------


def pass1_update(G, s1, s2, X, P, sw):
    """Pass-1 accumulation: Gram of √w-scaled rows + P first/second moments.

    Pure (traceable anywhere — jit, scan bodies, shard_map). ``P is None``
    skips the hull moments.
    """
    Xw = X * sw[:, None]
    G = G + gram_matrix(Xw)
    if P is not None:
        s1 = s1 + jnp.sum(P, axis=0)
        s2 = s2 + P.T @ P
    return G, s1, s2


def leverage_chunk(X, sw, V, inv):
    """u_i = Σ_m ((√w·X)_i V)²_m · inv_m for one chunk of rows. Pure."""
    Xw = X * sw[:, None]
    return jnp.sum(jnp.square(Xw @ V) * inv, axis=1)


def hull_chunk_extremes(P, dirs, mask=None):
    """Per-chunk directional extremes: (max, argmax, min, argmin) per direction.

    Laid out (m, c·r) so the reductions run along the contiguous last axis —
    axis-0 argmax over a (c·r, m) matrix is an order of magnitude slower on
    CPU (strided) and tiles badly on TPU (sublane reduction). ``mask`` (c·r,)
    excludes padding rows (sharded inputs padded to a shard multiple) by
    sending their scores to ∓inf. Pure.
    """
    S = dirs @ P.T  # (m, c·r) — chunk-local only, never (n·r, m)
    if mask is None:
        Smax = Smin = S
    else:
        Smax = jnp.where(mask[None, :], S, -jnp.inf)
        Smin = jnp.where(mask[None, :], S, jnp.inf)
    imax = jnp.argmax(Smax, axis=1)
    imin = jnp.argmin(Smin, axis=1)
    # gather the extreme values instead of separate max/min passes — argmax
    # and argmin are the only full sweeps over S
    vmax = jnp.take_along_axis(Smax, imax[:, None], axis=1)[:, 0]
    vmin = jnp.take_along_axis(Smin, imin[:, None], axis=1)[:, 0]
    return vmax, imax, vmin, imin


_acc_stats = jax.jit(pass1_update)
_leverage_chunk = jax.jit(leverage_chunk)
_hull_chunk = jax.jit(hull_chunk_extremes)


@jax.jit
def _acc_sketch(SX, s1, s2, X, P, sw, rows, signs):
    """Pass-1 CountSketch accumulation: SX += S_chunk · (√w·X) chunk."""
    Xw = X * sw[:, None]
    SX = SX.at[rows].add(signs[:, None] * Xw)
    if P is not None:
        s1 = s1 + jnp.sum(P, axis=0)
        s2 = s2 + P.T @ P
    return SX, s1, s2


# --------------------------------------------------------------------------
# between-pass host algebra — shared by the single-host and sharded engines
# --------------------------------------------------------------------------


def projection_from_gram(G, method: str, ridge_reg: float, rcond: float = 1e-6):
    """(V, inv) via float64 host eigh — same thresholds as ``gram_projection``
    but solver noise far below the f32 Gram's own accumulation error, so
    leverage is stable across chunk sizes (and across shard layouts).

    G is (Jd)², so the f64 eigh costs microseconds regardless of n.
    """
    G = np.asarray(G, np.float64)
    w, V = np.linalg.eigh(G)
    reg = ridge_reg if method == "ridge-lss" else 0.0
    inv = _spectrum_inverse(w, ridge_reg=reg, rcond=rcond, xp=np)
    return jnp.asarray(V, jnp.float32), jnp.asarray(inv, jnp.float32)


def directions_from_moments(
    hull_key, s1, s2, n_rows: int, hull_k: int, oversample: int = 4
) -> np.ndarray:
    """Direction net from accumulated P moments (cov = E[ppᵀ] − μμᵀ).

    ``n_rows`` is the number of REAL P rows the moments were accumulated over
    (padding rows must be masked to zero before accumulation).
    """
    s1 = np.asarray(s1, np.float64)
    s2 = np.asarray(s2, np.float64)
    mu = s1 / max(n_rows, 1)
    cov = s2 / max(n_rows, 1) - np.outer(mu, mu)
    m = max(oversample * hull_k, 8)
    return hull_directions(hull_key, cov, m).astype(np.float32)


def finalize_scoring(
    n: int, n_chunks: int, method: str, G, u, hull_rows, rows_per_point: int
) -> ScoringResult:
    """Assemble a ``ScoringResult`` from raw leverage + hull candidates."""
    u = np.asarray(u)
    if method == "root-l2":
        lev = np.sqrt(np.clip(u, 0.0, None))
    else:
        lev = u
    scores = lev + 1.0 / n
    hull_points = None
    if hull_rows is not None:
        hull_points = np.unique(hull_rows // rows_per_point)
    return ScoringResult(
        scores=scores,
        leverage=lev,
        gram=np.asarray(G),
        hull_rows=hull_rows,
        hull_points=hull_points,
        n=n,
        n_chunks=n_chunks,
        rows_per_point=rows_per_point,
    )


class ScoringEngine:
    """Drives the pre-sampling phase of Algorithm 1 with O(chunk) memory.

    Parameters
    ----------
    cfg, scaler: the MCTM model config and data scaler. The default featurizer
        is the fused Bernstein basis+derivative evaluation.
    featurize: optional override ``Y_chunk -> (X_chunk (c, D), P_chunk or
        None)`` for non-MCTM workloads (e.g. embedding features in the LM data
        pipeline; pass ``P_chunk = X_chunk`` to run hull selection on the
        feature rows themselves).
    chunk_size: rows of Y per chunk. Inputs with ``n <= chunk_size`` take the
        dense fast path (single basis evaluation). ``None``/0 → never chunk.
    rows_per_point: how many P rows each input point contributes (J for the
        MCTM derivative rows, 1 for generic features).
    """

    def __init__(
        self,
        cfg=None,
        scaler=None,
        *,
        featurize: Callable | None = None,
        chunk_size: int | None = DEFAULT_CHUNK,
        rows_per_point: int | None = None,
        hull_oversample: int = 4,
    ):
        if featurize is None:
            if cfg is None or scaler is None:
                raise ValueError("either (cfg, scaler) or featurize is required")
            featurize = _mctm_featurize(cfg, scaler)
            rows_per_point = cfg.J
        self.cfg = cfg
        self.scaler = scaler
        self.featurize = featurize
        self.chunk_size = int(chunk_size) if chunk_size else 0
        self.rows_per_point = int(rows_per_point or 1)
        self.hull_oversample = hull_oversample

    # ---------------------------------------------------------------- public

    def score(
        self,
        Y,
        *,
        method: str = "l2-hull",
        weights=None,
        key: jax.Array | None = None,
        sketch_size: int = 0,
        ridge_reg: float = 1.0,
        hull_k: int = 0,
        hull_key: jax.Array | None = None,
    ) -> ScoringResult:
        """Score all n points (and optionally select hull candidates).

        ``method`` follows ``coreset.CORESET_METHODS`` minus "uniform" (which
        needs no scoring pass). ``weights`` (n,) triggers the √w-scaled
        leverage of Merge & Reduce reductions. ``hull_k > 0`` sizes the
        direction net and returns ALL distinct ε-kernel candidate rows in
        first-occurrence order (requires ``hull_key``); truncation to k
        points happens at coreset assembly (``coreset.exact_hull_points``).
        """
        if method not in SCORE_METHODS:
            raise ValueError(f"unknown scoring method: {method}")
        Y = jnp.asarray(Y)
        n = int(Y.shape[0])
        if n == 0:
            raise ValueError("cannot score an empty dataset")
        if hull_k > 0 and hull_key is None:
            raise ValueError("hull_k > 0 requires hull_key")
        if sketch_size > 0 and key is None:
            raise ValueError("sketch_size > 0 requires key")
        sqrt_w = (
            jnp.sqrt(jnp.asarray(weights, jnp.float32)) if weights is not None else None
        )

        chunk = self.chunk_size if self.chunk_size > 0 else n
        if n <= chunk:
            out = self._score_dense(
                Y, sqrt_w, n, method, key, sketch_size, ridge_reg, hull_k, hull_key
            )
        else:
            out = self._score_chunked(
                Y, sqrt_w, n, chunk, method, key, sketch_size, ridge_reg, hull_k, hull_key
            )
        return out

    # --------------------------------------------------------------- helpers

    def _sketch_plan(self, key, n: int, sketch_size: int):
        """CountSketch rows/signs for all n rows — identical draws to
        ``leverage.sketched_leverage`` so the two paths are comparable."""
        k1, k2 = jax.random.split(key)
        rows = jax.random.randint(k1, (n,), 0, sketch_size)
        signs = jax.random.rademacher(k2, (n,), dtype=jnp.float32)
        return rows, signs

    def _finalize(self, n, n_chunks, method, G, u, hull_rows) -> ScoringResult:
        return finalize_scoring(
            n, n_chunks, method, G, u, hull_rows, self.rows_per_point
        )

    def _projection(self, G, method, ridge_reg, rcond=1e-6):
        """See ``projection_from_gram``."""
        return projection_from_gram(G, method, ridge_reg, rcond)

    def _directions(self, hull_key, s1, s2, n_rows: int, hull_k: int) -> np.ndarray:
        """Direction net from the accumulated P moments (cov = E[ppᵀ] − μμᵀ)."""
        return directions_from_moments(
            hull_key, s1, s2, n_rows, hull_k, self.hull_oversample
        )

    # ----------------------------------------------------------- dense path

    def _score_dense(
        self, Y, sqrt_w, n, method, key, sketch_size, ridge_reg, hull_k, hull_key
    ) -> ScoringResult:
        X, P = self.featurize(Y)
        if hull_k > 0 and P is None:
            raise ValueError("hull_k > 0 requires a featurize that returns P rows")
        if hull_k == 0:
            P = None  # no hull stage → don't pay for the P moment gram
        sw = sqrt_w if sqrt_w is not None else jnp.ones((n,), jnp.float32)
        zeros = self._zero_stats(X, P)
        if sketch_size > 0:
            rows, signs = self._sketch_plan(key, n, sketch_size)
            SX = jnp.zeros((sketch_size, X.shape[1]), jnp.float32)
            SX, s1, s2 = _acc_sketch(SX, zeros[1], zeros[2], X, P, sw, rows, signs)
            G = SX.T @ SX
        else:
            G, s1, s2 = _acc_stats(zeros[0], zeros[1], zeros[2], X, P, sw)
        V, inv = self._projection(G, method, ridge_reg)
        u = _leverage_chunk(X, sw, V, inv)
        hull_rows = None
        if hull_k > 0:
            dirs = jnp.asarray(
                self._directions(hull_key, s1, s2, int(P.shape[0]), hull_k)
            )
            bmax, imax, bmin, imin = _hull_chunk(P, dirs)
            cand = np.concatenate([np.asarray(imax), np.asarray(imin)])
            # keep EVERY distinct candidate row (first-occurrence order, ≤ 2m
            # of them): truncating to hull_k rows here would discard genuine
            # extremal points after the row → point dedup when r > 1
            hull_rows = stable_first_unique(cand)
        return self._finalize(n, 1, method, G, u, hull_rows)

    # --------------------------------------------------------- chunked path

    def _score_chunked(
        self, Y, sqrt_w, n, chunk, method, key, sketch_size, ridge_reg, hull_k, hull_key
    ) -> ScoringResult:
        featurize = self.featurize
        r = self.rows_per_point
        n_chunks = (n + chunk - 1) // chunk

        def chunk_iter():
            for lo in range(0, n, chunk):
                hi = min(lo + chunk, n)
                Xc, Pc = featurize(Y[lo:hi])
                if hull_k == 0:
                    Pc = None  # no hull stage → skip the P moment gram
                swc = (
                    sqrt_w[lo:hi]
                    if sqrt_w is not None
                    else jnp.ones((hi - lo,), jnp.float32)
                )
                yield lo, hi, Xc, Pc, swc

        # ---- pass 1: Gram (or sketch) + P moments, O((Jd)²) carried state
        if sketch_size > 0:
            rows_all, signs_all = self._sketch_plan(key, n, sketch_size)
        G = s1 = s2 = SX = None
        for lo, hi, Xc, Pc, swc in chunk_iter():
            if G is None and SX is None:
                if hull_k > 0 and Pc is None:
                    raise ValueError(
                        "hull_k > 0 requires a featurize that returns P rows"
                    )
                zG, zs1, zs2 = self._zero_stats(Xc, Pc)
                if sketch_size > 0:
                    SX = jnp.zeros((sketch_size, Xc.shape[1]), jnp.float32)
                else:
                    G = zG
                s1, s2 = zs1, zs2
            if sketch_size > 0:
                SX, s1, s2 = _acc_sketch(
                    SX, s1, s2, Xc, Pc, swc, rows_all[lo:hi], signs_all[lo:hi]
                )
            else:
                G, s1, s2 = _acc_stats(G, s1, s2, Xc, Pc, swc)
        if sketch_size > 0:
            G = SX.T @ SX

        # ---- between passes: (Jd)² algebra only
        V, inv = self._projection(G, method, ridge_reg)
        dirs = None
        if hull_k > 0:
            dirs = jnp.asarray(self._directions(hull_key, s1, s2, n * r, hull_k))
            m = int(dirs.shape[0])
            best_max = np.full(m, -np.inf, np.float32)
            best_min = np.full(m, np.inf, np.float32)
            best_imax = np.zeros(m, np.int64)
            best_imin = np.zeros(m, np.int64)

        # ---- pass 2: leverage emission + fused directional hull extremes
        u = np.empty(n, np.float32)
        for lo, hi, Xc, Pc, swc in chunk_iter():
            u[lo:hi] = np.asarray(_leverage_chunk(Xc, swc, V, inv))
            if dirs is not None:
                bmax, imax, bmin, imin = _hull_chunk(Pc, dirs)
                bmax, imax = np.asarray(bmax), np.asarray(imax) + lo * r
                bmin, imin = np.asarray(bmin), np.asarray(imin) + lo * r
                # strict comparison keeps the first-occurrence argmax semantics
                # of the dense np.argmax over the full score matrix
                upd = bmax > best_max
                best_max[upd], best_imax[upd] = bmax[upd], imax[upd]
                upd = bmin < best_min
                best_min[upd], best_imin[upd] = bmin[upd], imin[upd]

        hull_rows = None
        if dirs is not None:
            cand = np.concatenate([best_imax, best_imin])
            hull_rows = stable_first_unique(cand)  # all candidates — see dense path
        return self._finalize(n, n_chunks, method, G, u, hull_rows)

    @staticmethod
    def _zero_stats(X, P):
        D = X.shape[1]
        if P is None:
            return jnp.zeros((D, D), jnp.float32), None, None
        p = P.shape[1]
        return (
            jnp.zeros((D, D), jnp.float32),
            jnp.zeros((p,), jnp.float32),
            jnp.zeros((p, p), jnp.float32),
        )


def score_chunks(cfg, scaler, Y, **kwargs) -> ScoringResult:
    """Functional one-shot entry: ``ScoringEngine(cfg, scaler).score(Y, ...)``.

    ``chunk_size`` may be passed alongside the ``score`` kwargs.
    """
    chunk_size = kwargs.pop("chunk_size", DEFAULT_CHUNK)
    engine = ScoringEngine(cfg, scaler, chunk_size=chunk_size)
    return engine.score(Y, **kwargs)
