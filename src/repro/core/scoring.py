"""Pass-strategy scoring core for Algorithm 1's pre-sampling phase.

The paper's construction must score *all n* points before it ever samples:
leverage scores u_i of the flattened basis matrix X̃ ∈ R^{n×Jd}, plus the
directional hull extremes of the derivative rows {a'_ij} ⊂ R^d that feed the
ε-kernel augmentation. ``ScoringEngine`` streams row-chunks of Y through a
fused featurize and keeps peak memory at O(chunk·J·d) — but *how many times*
each row is streamed, and what small sufficient statistic is carried across
chunks, is owned by a pluggable **pass strategy**.

Pass-strategy contract (every strategy implements)
--------------------------------------------------
  state    — the cross-chunk carry, a jax pytree of O((Jd)²)-ish arrays
             (``init_state``). On the sharded engine the whole state tuple
             joins the ONE fused psum at the end of the shard-local scan, so
             anything a strategy carries must be sum-reducible across shards:
             ``TwoPassExact`` carries (G = X̃ᵀX̃, Σp, Σppᵀ),
             ``TwoPassSketched`` carries (SX = CountSketch(X̃), Σp, Σppᵀ),
             ``OnePassSketched`` carries just SX (its direction net is fixed
             upfront, so the moments would be dead weight).
  update   — per-chunk accumulation (``update(state, X, P, sw, plan_slice)``),
             pure and traceable (it runs inside jit / lax.scan / shard_map
             bodies). May additionally *emit* a per-row block: the one-pass
             strategy returns z = (√w·X)Ω, the sketch-projected rows leverage
             is later read off from.
  finalize — ``gram``/``result_gram``/``moments`` read the accumulated state:
             ``gram`` feeds the (tiny, host-side f64) eigh that produces the
             leverage projection (V, w⁺); ``moments`` feed the hull direction
             net. The chunk loop, hull running-extreme reduction, and the
             ``ScoringResult`` assembly live in the engine driver and are
             written exactly once for all strategies and both engines.

Strategies
----------
  ``TwoPassExact``   — pass 1 accumulates the exact Gram (plus hull moments),
      pass 2 re-streams the chunks to emit leverage and the fused directional
      hull extremes. ``gram_dtype="float64"`` accumulates the Gram host-side
      in f64 so degree-6 Bernstein bases no longer sit at the f32 rcond
      cutoff (the sharded engine instead casts inside the scan body, which
      requires x64 mode).
  ``TwoPassSketched`` — pass 1 accumulates the CountSketch Gram (SX)ᵀ(SX)
      (Woodruff 2014 Thm 2.13); pass 2 re-streams as above. Constant-factor
      leverage at O(nnz) pass-1 cost, but still two data sweeps.
  ``OnePassSketched`` — TRUE one-pass: the single sweep accumulates the row
      CountSketch SX, tracks the directional hull extremes against an
      upfront direction net, and emits the sketch-projected row blocks
      z_c = (√w·X_c)Ω. Leverage is finalized from z against the sketched
      Gram — u_i = z_i ((SXΩ)ᵀSXΩ)⁺ z_iᵀ — without ever touching a row
      twice, which is the shape insertion-only streams (Merge & Reduce
      blocks) and one-shot sharded I/O need. The saved sweep is bought with
      retention: the z blocks are O(n·q) device memory (q = Jd with
      ``proj_size=None``, where Ω = identity and the estimate reproduces the
      classic sketched leverage ‖X̃_i R⁻¹‖² exactly; ``proj_size=q < Jd``
      compresses retention at a rank-truncation cost). Callers who need
      O(chunk) peak memory more than they need the single sweep should ask
      for ``strategy="two-pass-sketched"`` instead. Because the direction
      net cannot see the data covariance before the sweep, its ±principal
      axes are replaced by the coordinate axes (an identity covariance prior
      through the same ``hull_directions``); the random directions are drawn
      identically to the two-pass net.

Strategy comparison (what each sweep costs; see docs/KERNELS.md for the
kernel dispatch contract behind ``fused_update``):

  strategy           sweeps  carry                 retention   chunk body
  TwoPassExact       2       (G, Σp, Σppᵀ)         O(chunk)    matmul + fused hull sweep 2
  TwoPassSketched    2       (SX, Σp, Σppᵀ)        O(chunk)    fused sweep (sketch+moments)
  OnePassSketched    1       SX                    O(n·q)      fused sweep (sketch+z+hull)

``fused_update`` is the strategy hook behind the single-residency sweep:
one call per chunk covering the sketch/Gram update, the optional emitted z
block, AND the block-local hull extremes (``repro.kernels.sweep`` — Pallas
kernel on TPU, fused-jnp oracle elsewhere). Strategies that don't fuse fall
back to ``update`` + a standalone hull reduction; the sketched strategies
override it, which is what makes the true one-pass sweep one dispatch per
chunk and strictly faster than two-pass (BENCH_scoring.json
``one_pass_vs_two_pass``, floor-gated ≥ 1.0 by scripts/bench_gate.py). The
fused op returns chunk-LOCAL extremes which the drivers fold at their own
row offsets, so engine state layouts — and sweep checkpoints — stay
byte-identical to the unfused formulation.

The per-chunk math (``pass1_update``, ``leverage_chunk``,
``hull_chunk_extremes``) and the between-pass host algebra
(``projection_from_gram``, ``directions_from_moments``, ``finalize_scoring``)
are module-level functions so the sharded realization
(``repro.core.distributed_coreset.DistributedScoringEngine`` — the same
strategies driven inside a shard_map body, state psum'd once) reuses them
verbatim.

When the input fits in a single chunk the engine featurizes exactly once and
shares the block between sweeps. Weighted inputs (Merge & Reduce streaming
buckets) scale X̃ rows by √w — leverage of the weighted matrix — while the
hull operates on the raw derivative rows, matching the batch construction.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hull import hull_directions, stable_first_unique
from repro.ft.config import get_ft_config, maybe_inject
from repro.kernels.extremes.ops import directional_extremes
from repro.kernels.gram.ops import gram_matrix
from repro.kernels.sweep.ops import fused_sweep_update

__all__ = [
    "ScoringEngine",
    "ScoringResult",
    "score_chunks",
    "gram_projection",
    "PassStrategy",
    "TwoPassExact",
    "TwoPassSketched",
    "OnePassSketched",
    "resolve_strategy",
    "sketch_plan",
    "upfront_directions",
    "RunningExtremes",
    "pass1_update",
    "leverage_chunk",
    "hull_chunk_extremes",
    "projection_from_gram",
    "directions_from_moments",
    "finalize_scoring",
    "DEFAULT_CHUNK",
]

DEFAULT_CHUNK = 65_536

SCORE_METHODS = ("l2-only", "l2-hull", "ridge-lss", "root-l2")
GRAM_DTYPES = ("float32", "float64")


def _spectrum_inverse(w, *, ridge_reg: float, rcond: float, xp):
    """Inverted eigenvalue weights shared by every projection variant.

    ``xp`` is the array module (np or jnp) so the jitted distributed path and
    the engine's f64 host path stay threshold-identical by construction.
    """
    if ridge_reg > 0.0:
        return 1.0 / (xp.maximum(w, 0.0) + ridge_reg)
    wmax = xp.max(xp.abs(w))
    return xp.where(w > rcond * wmax, 1.0 / xp.maximum(w, 1e-30), 0.0)


def gram_projection(
    G: jax.Array, *, ridge_reg: float = 0.0, rcond: float = 1e-6
) -> tuple[jax.Array, jax.Array]:
    """Factor G into (V, inv) with u_i = Σ_m (X_i V)²_m · inv_m.

    ``ridge_reg == 0`` reproduces ``leverage.leverage_from_gram``'s eigh
    pseudo-inverse (rank-deficient Bernstein Grams); ``ridge_reg > 0`` gives
    ridge leverage scores u_i(λ) = X_i (G + λI)⁻¹ X_iᵀ through the same
    eigenbasis (G and G + λI commute). ``rcond`` sits above the f32 noise
    floor so exactly-null modes are excluded regardless of how G was
    accumulated (see ``leverage_from_gram``).
    """
    w, V = jnp.linalg.eigh(G)
    return V, _spectrum_inverse(w, ridge_reg=ridge_reg, rcond=rcond, xp=jnp)


@dataclasses.dataclass
class ScoringResult:
    """Everything the sampling step of Algorithm 1 needs, for n points."""

    scores: np.ndarray             # (n,) sampling scores s_i (method-dependent)
    leverage: np.ndarray           # (n,) raw leverage-type scores u_i
    gram: np.ndarray               # (D, D) accumulated (possibly sketched) Gram
    hull_rows: np.ndarray | None   # ordered extremal row ids into the (n·r) P rows
    hull_points: np.ndarray | None  # unique point ids hit by hull_rows (sorted)
    n: int
    n_chunks: int
    rows_per_point: int = 1        # r: P rows per input point (row → point ÷ r)
    # accumulated P moments (s1, s2, n_rows) when the strategy tracked them —
    # the seed for the NEXT block's direction net in two-round streaming
    # (streaming.StreamingCoresetMaintainer); None otherwise
    moments: tuple | None = None

    @property
    def hull_candidates(self) -> np.ndarray | None:
        """Alias for ``hull_rows`` (the ε-kernel candidate set)."""
        return self.hull_rows


# jitted featurize closures keyed on (cfg, scaler bounds): build_coreset /
# coreset_scores construct a fresh engine per call, and without this cache
# each engine would carry its own empty jit trace cache and recompile the
# fused basis evaluation every call
_MCTM_FEATURIZE_CACHE: dict = {}


def _mctm_featurize(cfg, scaler) -> Callable[[jax.Array], tuple[jax.Array, jax.Array]]:
    """Fused basis+derivative evaluation for one chunk of Y.

    Returns (X̃ chunk (c, J·d), P chunk (c·J, d)). Single jitted trace per
    distinct chunk length; the math is exactly ``mctm.basis_features``.
    """
    from repro.core import mctm as M

    cache_key = (
        cfg,
        np.asarray(scaler.low).tobytes(),
        np.asarray(scaler.high).tobytes(),
    )
    cached = _MCTM_FEATURIZE_CACHE.get(cache_key)
    if cached is not None:
        return cached

    @jax.jit
    def featurize(Yc: jax.Array) -> tuple[jax.Array, jax.Array]:
        A, Ap = M.basis_features(cfg, scaler, Yc)
        c = A.shape[0]
        return A.reshape(c, cfg.J * cfg.d), Ap.reshape(c * cfg.J, cfg.d)

    if len(_MCTM_FEATURIZE_CACHE) > 64:  # bound growth across many configs
        _MCTM_FEATURIZE_CACHE.clear()
    _MCTM_FEATURIZE_CACHE[cache_key] = featurize
    return featurize


# --------------------------------------------------------------------------
# per-chunk steps. The pure bodies (pass1_update, leverage_chunk,
# hull_chunk_extremes) are shared with the sharded engine, where they run
# inside shard_map scan bodies; the jitted _acc_* wrappers exist so all
# single-host engines share trace caches.
# --------------------------------------------------------------------------


def pass1_update(G, s1, s2, X, P, sw, gram_dtype: str | None = None):
    """Pass-1 accumulation: Gram of √w-scaled rows + P first/second moments.

    Pure (traceable anywhere — jit, scan bodies, shard_map). ``P is None``
    skips the hull moments. ``gram_dtype="float64"`` casts the Gram update
    to f64 (requires an f64 carry and x64 mode; straight XᵀX — the Pallas
    gram kernel is f32-only); this is the sharded engine's f64 carry, the
    single-host ``TwoPassExact`` accumulates host-side instead.
    """
    Xw = X * sw[:, None]
    if gram_dtype == "float64":
        Xw64 = Xw.astype(jnp.float64)
        G = G + Xw64.T @ Xw64
    else:
        G = G + gram_matrix(Xw)
    if P is not None:
        s1 = s1 + jnp.sum(P, axis=0)
        s2 = s2 + P.T @ P
    return G, s1, s2


def leverage_chunk(X, sw, V, inv):
    """u_i = Σ_m ((√w·X)_i V)²_m · inv_m for one chunk of rows. Pure."""
    Xw = X * sw[:, None]
    return jnp.sum(jnp.square(Xw @ V) * inv, axis=1)


def hull_chunk_extremes(P, dirs, mask=None):
    """Per-chunk directional extremes: (max, argmax, min, argmin) per direction.

    Backend-dispatched like ``gram_matrix``: the fused Pallas running-extreme
    kernel on TPU (the (m, c·r) score block never leaves VMEM), the jnp
    oracle elsewhere (``kernels.extremes``). ``mask`` (c·r,) excludes padding
    rows (sharded inputs padded to a shard multiple) by sending their scores
    to ∓inf. Pure — both the two-pass and one-pass scan bodies (single-host
    and sharded) fold this into their running extremes.
    """
    return directional_extremes(P, dirs, mask)


def _moments_update(s1, s2, P):
    """Hull-moment half of ``pass1_update`` (the f64-Gram host path still
    accumulates moments on device in f32). Pure."""
    return s1 + jnp.sum(P, axis=0), s2 + P.T @ P


def _sketch_update(SX, s1, s2, X, P, sw, rows, signs):
    """CountSketch accumulation: SX += S_chunk · (√w·X) chunk. Pure."""
    Xw = X * sw[:, None]
    SX = SX.at[rows].add(signs[:, None] * Xw)
    if P is not None:
        s1, s2 = _moments_update(s1, s2, P)
    return SX, s1, s2


def _weighted_project(X, sw, omega):
    """z = (√w·X)Ω — the one-pass strategy's per-row emission (Ω=None → √w·X).
    Pure."""
    Xw = X * sw[:, None]
    return Xw if omega is None else Xw @ omega


def _z_leverage(z, V, inv):
    """Leverage read-off from stored (already √w-scaled) row blocks. Pure."""
    return jnp.sum(jnp.square(z @ V) * inv, axis=1)


_acc_stats = jax.jit(pass1_update, static_argnames=("gram_dtype",))
# the fused one-pass sweep step (kernels.sweep): CountSketch + moments +
# extremes + z in ONE dispatch — the single-host realization shares this
# trace cache, the sharded scan bodies trace the op inline
_fused_sweep = jax.jit(
    fused_sweep_update,
    static_argnames=("want_z", "block_rows", "backend", "interpret"),
)
_acc_moments = jax.jit(_moments_update)
_acc_sketch = jax.jit(_sketch_update)
_leverage_chunk = jax.jit(leverage_chunk)
_hull_chunk = jax.jit(hull_chunk_extremes)
_project_rows = jax.jit(_weighted_project)
_z_leverage_jit = jax.jit(_z_leverage)
_weighted_rows = jax.jit(lambda X, sw: X * sw[:, None])


def sketch_plan(key, n: int, sketch_size: int):
    """CountSketch rows/signs for all n rows — identical draws to
    ``leverage.sketched_leverage`` so the strategies and the standalone
    baseline are comparable row for row."""
    k1, k2 = jax.random.split(key)
    rows = jax.random.randint(k1, (n,), 0, sketch_size)
    signs = jax.random.rademacher(k2, (n,), dtype=jnp.float32)
    return rows, signs


# --------------------------------------------------------------------------
# between-pass host algebra — shared by the single-host and sharded engines
# --------------------------------------------------------------------------


def projection_from_gram(G, method: str, ridge_reg: float, rcond: float = 1e-6):
    """(V, inv) via float64 host eigh — same thresholds as ``gram_projection``
    but solver noise far below the f32 Gram's own accumulation error, so
    leverage is stable across chunk sizes (and across shard layouts).

    G is (Jd)², so the f64 eigh costs microseconds regardless of n.
    """
    G = np.asarray(G, np.float64)
    w, V = np.linalg.eigh(G)
    reg = ridge_reg if method == "ridge-lss" else 0.0
    inv = _spectrum_inverse(w, ridge_reg=reg, rcond=rcond, xp=np)
    return jnp.asarray(V, jnp.float32), jnp.asarray(inv, jnp.float32)


def directions_from_moments(
    hull_key, s1, s2, n_rows: int, hull_k: int, oversample: int = 4
) -> np.ndarray:
    """Direction net from accumulated P moments (cov = E[ppᵀ] − μμᵀ).

    ``n_rows`` is the number of REAL P rows the moments were accumulated over
    (padding rows must be masked to zero before accumulation).
    """
    s1 = np.asarray(s1, np.float64)
    s2 = np.asarray(s2, np.float64)
    mu = s1 / max(n_rows, 1)
    cov = s2 / max(n_rows, 1) - np.outer(mu, mu)
    m = max(oversample * hull_k, 8)
    return hull_directions(hull_key, cov, m).astype(np.float32)


def upfront_directions(
    hull_key, p: int, hull_k: int, oversample: int = 4
) -> np.ndarray:
    """Direction net for one-pass strategies — buildable BEFORE any data is
    seen. Same ``hull_directions`` construction and identical random draws as
    the two-pass net, but with an identity covariance prior, so the
    ±principal axes degenerate to the coordinate axes of the P rows.
    """
    m = max(oversample * hull_k, 8)
    return hull_directions(hull_key, np.eye(p), m).astype(np.float32)


class RunningExtremes:
    """Host-side running (max, argmax, min, argmin) per direction across
    chunks. Strict comparisons keep the first-occurrence (lowest-row)
    tie-break of a dense ``np.argmax`` over the full score matrix — the same
    reduction the sharded engine performs across shards via all_gather.
    """

    def __init__(self, m: int):
        self.best_max = np.full(m, -np.inf, np.float32)
        self.best_min = np.full(m, np.inf, np.float32)
        self.best_imax = np.zeros(m, np.int64)
        self.best_imin = np.zeros(m, np.int64)

    def update(self, vmax, imax, vmin, imin, offset: int) -> None:
        # widen the device int32 argmax ids BEFORE adding the chunk offset:
        # n·rows_per_point may exceed int32 on the single-host path
        vmax, imax = np.asarray(vmax), np.asarray(imax, np.int64) + offset
        vmin, imin = np.asarray(vmin), np.asarray(imin, np.int64) + offset
        upd = vmax > self.best_max
        self.best_max[upd], self.best_imax[upd] = vmax[upd], imax[upd]
        upd = vmin < self.best_min
        self.best_min[upd], self.best_imin[upd] = vmin[upd], imin[upd]

    def candidates(self) -> np.ndarray:
        """ALL distinct extremal row ids, first-occurrence order (≤ 2m):
        truncating to hull_k rows here would discard genuine extremal points
        after the row → point dedup when rows_per_point > 1."""
        cand = np.concatenate([self.best_imax, self.best_imin])
        return stable_first_unique(cand)

    def state(self) -> dict[str, np.ndarray]:
        """Checkpointable snapshot (f32/int64 arrays — exact roundtrip)."""
        return {
            "max": self.best_max.copy(),
            "imax": self.best_imax.copy(),
            "min": self.best_min.copy(),
            "imin": self.best_imin.copy(),
        }

    def load(self, s) -> None:
        self.best_max = np.asarray(s["max"], np.float32).copy()
        self.best_imax = np.asarray(s["imax"], np.int64).copy()
        self.best_min = np.asarray(s["min"], np.float32).copy()
        self.best_imin = np.asarray(s["imin"], np.int64).copy()


def finalize_scoring(
    n: int, n_chunks: int, method: str, G, u, hull_rows, rows_per_point: int,
    moments: tuple | None = None,
) -> ScoringResult:
    """Assemble a ``ScoringResult`` from raw leverage + hull candidates."""
    u = np.asarray(u)
    if method == "root-l2":
        lev = np.sqrt(np.clip(u, 0.0, None))
    else:
        lev = u
    scores = lev + 1.0 / n
    hull_points = None
    if hull_rows is not None:
        hull_points = np.unique(hull_rows // rows_per_point)
    return ScoringResult(
        scores=scores,
        leverage=lev,
        gram=np.asarray(G),
        hull_rows=hull_rows,
        hull_points=hull_points,
        n=n,
        n_chunks=n_chunks,
        rows_per_point=rows_per_point,
        moments=moments,
    )


# --------------------------------------------------------------------------
# pass strategies
# --------------------------------------------------------------------------


class PassStrategy:
    """Base contract — see the module doc. Subclasses set ``one_pass`` /
    ``needs_key`` and implement ``init_state`` / ``update`` / ``gram``;
    ``result_gram`` defaults to ``gram`` and ``moments`` to the (s1, s2)
    slots of the state tuple."""

    one_pass = False
    needs_key = False
    n_data_passes = 2

    def begin(self, n: int, D: int, key):
        """Per-call plan (sketch rows/signs, Ω). ``None`` when stateless."""
        return None

    def slice_plan(self, plan, lo: int, hi: int) -> tuple:
        """The per-chunk slice of the plan fed to ``update``."""
        return ()

    def moments(self, state):
        return state[1], state[2]

    def result_gram(self, state, plan=None):
        return self.gram(state, plan)

    def fused_update(self, state, X, P, sw, plan_slice=(), dirs=None):
        """Per-chunk accumulation fused with the directional-extremes block.

        Returns ``(state', z, ext)`` where ``ext`` is the chunk-LOCAL
        (vmax, imax, vmin, imin) against ``dirs`` (``None`` when ``dirs``
        is). The engine drivers call THIS — strategies whose sweep can fold
        the hull reduction into their accumulation (``OnePassSketched`` via
        ``kernels.sweep``) override it; the default composes ``update`` with
        the standalone extremes kernel, which is exactly the unfused
        behavior.
        """
        state, z = self.update(state, X, P, sw, plan_slice)
        ext = _hull_chunk(P, dirs) if dirs is not None else None
        return state, z, ext

    # init_state / update / gram: subclass responsibility


@dataclasses.dataclass(frozen=True)
class TwoPassExact(PassStrategy):
    """Exact Gram accumulation; re-streams for the leverage/extremes pass.

    ``gram_dtype="float64"`` accumulates G host-side in f64 (order-independent
    to ~1e-15, so chunk/shard layouts agree even when genuine degree-6
    eigenvalues sit at the f32 rcond cutoff). The moments stay f32 on device —
    the direction net only needs the covariance's coarse shape.
    """

    gram_dtype: str = "float32"

    def __post_init__(self):
        if self.gram_dtype not in GRAM_DTYPES:
            raise ValueError(f"gram_dtype must be one of {GRAM_DTYPES}")

    def init_state(self, D: int, p: int | None):
        if self.gram_dtype == "float64":
            G = np.zeros((D, D), np.float64)
        else:
            G = jnp.zeros((D, D), jnp.float32)
        if p is None:
            return (G, None, None)
        return (G, jnp.zeros((p,), jnp.float32), jnp.zeros((p, p), jnp.float32))

    def update(self, state, X, P, sw, plan_slice=()):
        G, s1, s2 = state
        if self.gram_dtype == "float64":
            Xw = np.asarray(_weighted_rows(X, sw), np.float64)
            G = G + Xw.T @ Xw
            if P is not None:
                s1, s2 = _acc_moments(s1, s2, P)
            return (G, s1, s2), None
        return _acc_stats(G, s1, s2, X, P, sw), None

    def gram(self, state, plan=None):
        return state[0]


@dataclasses.dataclass(frozen=True)
class _SketchedBase(PassStrategy):
    """Shared CountSketch plan/state for the sketched strategies.

    ``gram_dtype="float64"`` carries the CountSketch accumulator SX in f64 —
    the sketched analogue of the two-pass f64 Gram carry (same x64
    requirement: the accumulation runs on device, so a silent f32 downcast
    must be refused loudly). The streamed rows, moments and emitted z blocks
    stay f32; only the accumulator (and the Grams read off it) widen.
    """

    sketch_size: int = 0
    gram_dtype: str = "float32"

    needs_key = True

    def __post_init__(self):
        if self.sketch_size <= 0:
            raise ValueError("sketched strategies require sketch_size > 0")
        if self.gram_dtype not in GRAM_DTYPES:
            raise ValueError(f"gram_dtype must be one of {GRAM_DTYPES}")

    def _acc_dtype(self):
        if self.gram_dtype == "float64":
            if not jax.config.jax_enable_x64:
                raise ValueError(
                    "gram_dtype='float64' on a sketched strategy carries the "
                    "CountSketch accumulator in f64 on device and requires "
                    "x64 mode (JAX_ENABLE_X64=1 / jax.config.update"
                    "('jax_enable_x64', True))"
                )
            return jnp.float64
        return jnp.float32

    def begin(self, n: int, D: int, key):
        return sketch_plan(key, n, self.sketch_size)

    def slice_plan(self, plan, lo: int, hi: int) -> tuple:
        return (plan[0][lo:hi], plan[1][lo:hi])

    def init_state(self, D: int, p: int | None):
        SX = jnp.zeros((self.sketch_size, D), self._acc_dtype())
        if p is None:
            return (SX, None, None)
        return (SX, jnp.zeros((p,), jnp.float32), jnp.zeros((p, p), jnp.float32))

    def gram(self, state, plan=None):
        return state[0].T @ state[0]


@dataclasses.dataclass(frozen=True)
class TwoPassSketched(_SketchedBase):
    """CountSketch Gram in pass 1; still re-streams for pass 2 (the engine's
    pre-refactor ``sketch_size`` behavior, kept as an explicit strategy).
    Pass 1 runs through the fused sweep op (sketch + hull moments in one
    dispatch, ``want_z=False`` — nothing is retained)."""

    def update(self, state, X, P, sw, plan_slice=()):
        rows, signs = plan_slice
        moments = (state[1], state[2]) if P is not None else None
        SX, _, _, mom = _fused_sweep(
            state[0], X, P, sw, rows, signs, moments=moments, want_z=False
        )
        s1, s2 = mom if mom is not None else (state[1], state[2])
        return (SX, s1, s2), None


@dataclasses.dataclass(frozen=True)
class OnePassSketched(_SketchedBase):
    """True one-pass sketched scoring — see the module doc.

    ``proj_size=None`` stores the √w-scaled rows themselves (Ω = identity):
    leverage is then exactly the classic sketched estimate ‖X̃_i R⁻¹‖², at
    O(n·Jd) retained memory. ``proj_size=q < Jd`` right-projects the retained
    rows through a fixed Gaussian Ω (drawn from the same key), shrinking
    retention to O(n·q); leverage of XΩ equals leverage of X whenever q ≥
    rank(X) (rank-preserving right-multiplication), and degrades gracefully
    below.

    ``track_moments=True`` additionally accumulates the P hull moments
    (Σp, Σppᵀ) in the same fused dispatch (``kernels.sweep`` carries them for
    free next to the sketch). The moments cannot improve THIS sweep's net —
    it is fixed before the data is seen — but they surface on the
    ``ScoringResult`` so a streaming caller can seed the NEXT block's net via
    ``directions_from_moments`` + ``score(hull_dirs=...)``: the two-round
    streaming direction net that fixes the coordinate-axes weakness without
    re-streaming.
    """

    proj_size: int | None = None
    track_moments: bool = False

    one_pass = True
    n_data_passes = 1

    def begin(self, n: int, D: int, key):
        rows, signs = sketch_plan(key, n, self.sketch_size)
        omega = None
        if self.proj_size is not None and self.proj_size < D:
            ok = jax.random.fold_in(key, 0x0E60)
            omega = jax.random.normal(
                ok, (D, self.proj_size), jnp.float32
            ) / np.sqrt(self.proj_size)
        return (rows, signs, omega)

    def slice_plan(self, plan, lo: int, hi: int) -> tuple:
        return (plan[0][lo:hi], plan[1][lo:hi], plan[2])

    def init_state(self, D: int, p: int | None = None):
        # without track_moments there is no (p, p) moment gram: the one-pass
        # net is fixed upfront, so the moments would be dead weight on the
        # hot streaming path
        SX = jnp.zeros((self.sketch_size, D), self._acc_dtype())
        if self.track_moments and p is not None:
            return (SX, jnp.zeros((p,), jnp.float32), jnp.zeros((p, p), jnp.float32))
        return (SX, None, None)

    def update(self, state, X, P, sw, plan_slice=()):
        state, z, _ = self.fused_update(state, X, P, sw, plan_slice)
        return state, z

    def fused_update(self, state, X, P, sw, plan_slice=(), dirs=None):
        """The fused realization (kernels.sweep): CountSketch + z emission +
        hull extremes (+ optional moments) in ONE dispatch — single VMEM
        residency on TPU, one fused XLA call on CPU. ``ext`` carries
        chunk-local indices; the driver folds them with its row offset, so
        the carried state (and any sweep checkpoint written from it) is laid
        out exactly as the unfused path's."""
        rows, signs, omega = plan_slice
        moments = (
            (state[1], state[2])
            if state[1] is not None and P is not None
            else None
        )
        keep_P = dirs is not None or moments is not None
        SX, z, ext, mom = _fused_sweep(
            state[0], X, P if keep_P else None, sw, rows, signs,
            dirs=dirs, omega=omega, moments=moments,
        )
        s1, s2 = mom if mom is not None else (state[1], state[2])
        return (SX, s1, s2), z, ext

    def gram(self, state, plan=None):
        """Projection Gram — (SXΩ)ᵀ(SXΩ), the Gram of the retained z rows."""
        SX = state[0]
        if plan is not None and plan[2] is not None:
            SX = SX @ plan[2]
        return SX.T @ SX

    def result_gram(self, state, plan=None):
        """Reported Gram stays the full (D, D) sketched Gram."""
        return state[0].T @ state[0]


_STRATEGY_NAMES = ("two-pass", "two-pass-sketched", "one-pass")


def resolve_strategy(
    strategy, *, sketch_size: int = 0, gram_dtype: str = "float32"
) -> PassStrategy:
    """Resolve the ``strategy=`` argument of ``score``.

    ``None`` decides from ``sketch_size``: exact two-pass without a sketch,
    ONE-pass sketched with one — a deliberate default change from the
    pre-strategy engine (which re-streamed a second sweep even when
    sketching): a sketch caller has already accepted constant-factor scores,
    so the second data sweep buys nothing the retained z rows don't. Note
    the trade: one-pass retains O(n·proj_size) sketch-projected rows and
    draws its hull net from the upfront (identity-prior) directions — pass
    ``strategy="two-pass-sketched"`` to keep the old O(chunk)-memory,
    moment-net sketched behavior. Strings name the built-ins; instances
    pass through untouched.
    """
    if isinstance(strategy, PassStrategy):
        return strategy
    if strategy is None:
        if sketch_size > 0:
            return OnePassSketched(sketch_size, gram_dtype)
        return TwoPassExact(gram_dtype)
    if strategy == "two-pass":
        return TwoPassExact(gram_dtype)
    if strategy == "two-pass-sketched":
        return TwoPassSketched(sketch_size, gram_dtype)
    if strategy == "one-pass":
        return OnePassSketched(sketch_size, gram_dtype)
    raise ValueError(
        f"unknown pass strategy {strategy!r} (expected one of {_STRATEGY_NAMES} "
        "or a PassStrategy instance)"
    )


# --------------------------------------------------------------------------
# the engine — one driver for every strategy
# --------------------------------------------------------------------------


class _SweepCheckpoints:
    """Per-sweep ``CheckpointManager`` pair for resumable chunk scans.

    ``root`` is a directory (or anything with a ``directory`` attribute);
    sweep 1 and sweep 2 get separate subdirectories so their cursors cannot
    shadow each other. Cadence comes from the ``ft`` config.
    """

    def __init__(self, root):
        from repro.checkpoint import CheckpointManager

        if not isinstance(root, (str, os.PathLike)):
            root = getattr(root, "directory")
        self.every = max(int(get_ft_config().sweep_ckpt_every_chunks), 1)
        self.mgr1 = CheckpointManager(os.path.join(str(root), "sweep1"), keep=2)
        self.mgr2 = CheckpointManager(os.path.join(str(root), "sweep2"), keep=2)


def _restore_like(template, restored):
    """Rehydrate a restored host pytree to its template's array flavors
    (np leaves stay np — the f64 host Gram — jax leaves go back on device)."""
    return jax.tree.map(
        lambda t, v: np.asarray(v) if isinstance(t, np.ndarray) else jnp.asarray(v),
        template,
        restored,
    )


class ScoringEngine:
    """Drives the pre-sampling phase of Algorithm 1 with O(chunk) memory
    (two-pass strategies; the one-pass strategy additionally retains the
    O(n·proj_size) sketch-projected rows it reads leverage from — see the
    module doc).

    Parameters
    ----------
    cfg, scaler: the MCTM model config and data scaler. The default featurizer
        is the fused Bernstein basis+derivative evaluation.
    featurize: optional override ``Y_chunk -> (X_chunk (c, D), P_chunk or
        None)`` for non-MCTM workloads (e.g. embedding features in the LM data
        pipeline; pass ``P_chunk = X_chunk`` to run hull selection on the
        feature rows themselves).
    chunk_size: rows of Y per chunk. Inputs with ``n <= chunk_size`` take the
        dense fast path (single basis evaluation). ``None``/0 → never chunk.
    rows_per_point: how many P rows each input point contributes (J for the
        MCTM derivative rows, 1 for generic features).
    gram_dtype: default Gram accumulation dtype for auto-resolved
        ``TwoPassExact`` strategies ("float64" → host-side f64, see above).
    """

    def __init__(
        self,
        cfg=None,
        scaler=None,
        *,
        featurize: Callable | None = None,
        chunk_size: int | None = DEFAULT_CHUNK,
        rows_per_point: int | None = None,
        hull_oversample: int = 4,
        gram_dtype: str = "float32",
    ):
        if featurize is None:
            if cfg is None or scaler is None:
                raise ValueError("either (cfg, scaler) or featurize is required")
            featurize = _mctm_featurize(cfg, scaler)
            rows_per_point = cfg.J
        if gram_dtype not in GRAM_DTYPES:
            raise ValueError(f"gram_dtype must be one of {GRAM_DTYPES}")
        self.cfg = cfg
        self.scaler = scaler
        self.featurize = featurize
        self.chunk_size = int(chunk_size) if chunk_size else 0
        self.rows_per_point = int(rows_per_point or 1)
        self.hull_oversample = hull_oversample
        self.gram_dtype = gram_dtype

    # ---------------------------------------------------------------- public

    def score(
        self,
        Y,
        *,
        method: str = "l2-hull",
        weights=None,
        key: jax.Array | None = None,
        sketch_size: int = 0,
        ridge_reg: float = 1.0,
        hull_k: int = 0,
        hull_key: jax.Array | None = None,
        hull_dirs=None,
        strategy=None,
        gram_dtype: str | None = None,
        sweep_ckpt=None,
        resume: bool = False,
    ) -> ScoringResult:
        """Score all n points (and optionally select hull candidates).

        ``method`` follows ``coreset.CORESET_METHODS`` minus "uniform" (which
        needs no scoring pass). ``weights`` (n,) triggers the √w-scaled
        leverage of Merge & Reduce reductions. ``hull_k > 0`` sizes the
        direction net and returns ALL distinct ε-kernel candidate rows in
        first-occurrence order (requires ``hull_key``); truncation to k
        points happens at coreset assembly (``coreset.exact_hull_points``).
        ``hull_dirs`` (m, p) overrides the direction net entirely — the
        two-round streaming hook: a caller with moments from a PREVIOUS
        block (``ScoringResult.moments`` + ``directions_from_moments``)
        seeds this sweep's net instead of the one-pass identity prior (or
        this sweep's own moment net on two-pass strategies).
        ``strategy`` selects the pass strategy (name or instance — see
        ``resolve_strategy``); the default is decided by ``sketch_size``.

        ``sweep_ckpt`` (a directory path) makes the chunk-scan state a
        checkpointable pytree saved every ``ft`` config
        ``sweep_ckpt_every_chunks`` chunks: strategy carry, running extremes,
        retained z rows / emitted leverage, and the chunk cursor. With
        ``resume=True`` a crashed sweep restarts from its cursor instead of
        row 0, and the result is bit-identical to the uninterrupted sweep
        (the carry is f32/f64/int64 arrays — exact save/restore roundtrip —
        and chunk accumulation order is preserved).
        """
        if method not in SCORE_METHODS:
            raise ValueError(f"unknown scoring method: {method}")
        Y = jnp.asarray(Y)
        n = int(Y.shape[0])
        if n == 0:
            raise ValueError("cannot score an empty dataset")
        if hull_k > 0 and hull_key is None:
            raise ValueError("hull_k > 0 requires hull_key")
        strat = resolve_strategy(
            strategy,
            sketch_size=sketch_size,
            gram_dtype=gram_dtype or self.gram_dtype,
        )
        if strat.needs_key and key is None:
            raise ValueError("sketch_size > 0 requires key")
        sqrt_w = (
            jnp.sqrt(jnp.asarray(weights, jnp.float32)) if weights is not None else None
        )
        if hull_dirs is not None and hull_k <= 0:
            raise ValueError("hull_dirs requires hull_k > 0")
        chunk = self.chunk_size if self.chunk_size > 0 else n
        return self._drive(
            strat, key, Y, sqrt_w, n, chunk, method, ridge_reg, hull_k, hull_key,
            hull_dirs=hull_dirs, sweep_ckpt=sweep_ckpt, resume=resume,
        )

    # --------------------------------------------------------------- helpers

    def _projection(self, G, method, ridge_reg, rcond=1e-6):
        """See ``projection_from_gram``."""
        return projection_from_gram(G, method, ridge_reg, rcond)

    def _directions(self, hull_key, s1, s2, n_rows: int, hull_k: int) -> np.ndarray:
        """Direction net from the accumulated P moments (cov = E[ppᵀ] − μμᵀ)."""
        return directions_from_moments(
            hull_key, s1, s2, n_rows, hull_k, self.hull_oversample
        )

    # ---------------------------------------------------------------- driver

    def _drive(
        self, strat, key, Y, sqrt_w, n, chunk, method, ridge_reg, hull_k, hull_key,
        hull_dirs=None, sweep_ckpt=None, resume=False,
    ) -> ScoringResult:
        """The shared chunk loop — ONE implementation for every strategy.

        Sweep 1 streams every chunk through ``strat.update`` (plus, for
        one-pass strategies, the fused hull running-extreme tracking against
        the upfront direction net). Two-pass strategies then re-stream the
        same chunks for leverage emission + extremes against the moment-
        derived net; one-pass strategies read leverage off the retained z
        blocks instead. Dense inputs (one chunk) featurize exactly once and
        share the block between sweeps.

        ``sweep_ckpt`` turns each sweep's carry into a checkpointable pytree
        (fixed-shape — restore validates shapes) saved every N chunks with a
        chunk cursor; ``resume`` skips the chunks the cursor covers. The
        between-sweep algebra (V, inv, direction net) is recomputed
        deterministically from the restored carry, so a resumed run is
        bit-identical to an uninterrupted one. Only this checkpointed path
        pays an extra shape-discovery featurize of chunk 0; the plain path
        is byte-for-byte the pre-existing loop (featurize call counts
        unchanged).
        """
        featurize = self.featurize
        r = self.rows_per_point
        want_hull = hull_k > 0
        # track_moments keeps P flowing even without a hull stage (the
        # moments seed a FUTURE sweep's net, not this one's)
        want_P = want_hull or getattr(strat, "track_moments", False)
        n_chunks = -(-n // chunk)
        ranges = [(lo, min(lo + chunk, n)) for lo in range(0, n, chunk)]

        def _prep(lo, hi):
            Xc, Pc = featurize(Y[lo:hi])
            if want_hull and Pc is None:
                raise ValueError("hull_k > 0 requires a featurize that returns P rows")
            if not want_P:
                Pc = None  # no hull stage → don't pay for the P moment gram
            swc = (
                sqrt_w[lo:hi]
                if sqrt_w is not None
                else jnp.ones((hi - lo,), jnp.float32)
            )
            return lo, hi, Xc, Pc, swc

        if n_chunks == 1:
            # dense fast path: featurize once, share the block between sweeps
            cached: list = []

            def get_chunk(lo, hi):
                if not cached:
                    cached.append(_prep(lo, hi))
                return cached[0]

        else:
            get_chunk = _prep

        # ---- sweep 1: strategy accumulation (the only data sweep for
        # one-pass strategies), O((Jd)²)-ish carried state
        state = plan = None
        z_blocks: list = []
        z_buf = None
        ext = dirs1 = None
        ck = _SweepCheckpoints(sweep_ckpt) if sweep_ckpt is not None else None
        done1 = 0
        if ck is not None:
            # fixed-shape checkpoint payloads need (D, p) before the loop:
            # probe-featurize chunk 0 for shapes (cached on the dense path)
            _, _, Xc0, Pc0, _ = get_chunk(*ranges[0])
            D = int(Xc0.shape[1])
            p = int(Pc0.shape[1]) if Pc0 is not None else None
            plan = strat.begin(n, D, key)
            state = strat.init_state(D, p)
            if strat.one_pass:
                width = D
                if plan is not None and plan[2] is not None:
                    width = int(plan[2].shape[1])
                z_buf = np.zeros((n, width), np.float32)
                if want_hull:
                    dirs1 = jnp.asarray(
                        hull_dirs
                        if hull_dirs is not None
                        else upfront_directions(
                            hull_key, p, hull_k, self.hull_oversample
                        )
                    )
                    ext = RunningExtremes(int(dirs1.shape[0]))

            def payload1():
                out = {"chunks": np.asarray(done1, np.int64), "state": state}
                if z_buf is not None:
                    out["z"] = z_buf
                if ext is not None:
                    out["ext"] = ext.state()
                return out

            if resume and ck.mgr1.latest_step() is not None:
                got = ck.mgr1.restore(jax.tree.map(np.asarray, payload1()))
                done1 = int(got["chunks"])
                state = _restore_like(state, got["state"])
                if z_buf is not None:
                    z_buf = np.asarray(got["z"], np.float32)
                if ext is not None:
                    ext.load(got["ext"])

        for ci, (lo, hi) in enumerate(ranges):
            if ci < done1:
                continue
            lo, hi, Xc, Pc, swc = get_chunk(lo, hi)
            if state is None:
                D = int(Xc.shape[1])
                p = int(Pc.shape[1]) if Pc is not None else None
                plan = strat.begin(n, D, key)
                state = strat.init_state(D, p)
                if strat.one_pass and want_hull:
                    dirs1 = jnp.asarray(
                        hull_dirs
                        if hull_dirs is not None
                        else upfront_directions(
                            hull_key, p, hull_k, self.hull_oversample
                        )
                    )
                    ext = RunningExtremes(int(dirs1.shape[0]))
            state, z, extb = strat.fused_update(
                state, Xc, Pc, swc, strat.slice_plan(plan, lo, hi), dirs=dirs1
            )
            if z is not None:
                if z_buf is not None:
                    z_buf[lo:hi] = np.asarray(z)
                else:
                    z_blocks.append(z)
            if ext is not None:
                ext.update(*extb, offset=lo * r)
            if ck is not None and ((ci + 1) % ck.every == 0 or ci + 1 == n_chunks):
                done1 = ci + 1
                ck.mgr1.save(ci + 1, payload1())
            maybe_inject("scoring", ci + 1)

        # ---- between sweeps: (Jd)²-scale host algebra only
        V, inv = self._projection(strat.gram(state, plan), method, ridge_reg)

        hull_rows = None
        if strat.one_pass:
            if z_buf is not None:
                u = np.empty(n, np.float32)
                for lo, hi in ranges:  # chunk-sized device transfers
                    u[lo:hi] = np.asarray(
                        _z_leverage_jit(jnp.asarray(z_buf[lo:hi]), V, inv)
                    )
            else:
                u = np.concatenate(
                    [np.asarray(_z_leverage_jit(z, V, inv)) for z in z_blocks]
                )
            if ext is not None:
                hull_rows = ext.candidates()
        else:
            # ---- sweep 2: leverage emission + fused directional hull extremes
            if want_hull:
                if hull_dirs is not None:
                    dirs = jnp.asarray(hull_dirs)
                else:
                    s1, s2 = strat.moments(state)
                    dirs = jnp.asarray(
                        self._directions(hull_key, s1, s2, n * r, hull_k)
                    )
                ext = RunningExtremes(int(dirs.shape[0]))
            u = np.zeros(n, np.float32)
            done2 = 0
            if ck is not None:

                def payload2():
                    out = {"chunks": np.asarray(done2, np.int64), "u": u}
                    if ext is not None:
                        out["ext"] = ext.state()
                    return out

                if resume and ck.mgr2.latest_step() is not None:
                    got = ck.mgr2.restore(jax.tree.map(np.asarray, payload2()))
                    done2 = int(got["chunks"])
                    u = np.asarray(got["u"], np.float32)
                    if ext is not None:
                        ext.load(got["ext"])
            for ci, (lo, hi) in enumerate(ranges):
                if ci < done2:
                    continue
                lo, hi, Xc, Pc, swc = get_chunk(lo, hi)
                u[lo:hi] = np.asarray(_leverage_chunk(Xc, swc, V, inv))
                if ext is not None:
                    ext.update(*_hull_chunk(Pc, dirs), offset=lo * r)
                if ck is not None and ((ci + 1) % ck.every == 0 or ci + 1 == n_chunks):
                    done2 = ci + 1
                    ck.mgr2.save(ci + 1, payload2())
                maybe_inject("scoring", n_chunks + ci + 1)
            if ext is not None:
                hull_rows = ext.candidates()

        moments = None
        if getattr(strat, "track_moments", False) and state[1] is not None:
            moments = (np.asarray(state[1]), np.asarray(state[2]), n * r)
        return finalize_scoring(
            n, n_chunks, method, strat.result_gram(state, plan), u, hull_rows, r,
            moments=moments,
        )


def score_chunks(cfg, scaler, Y, **kwargs) -> ScoringResult:
    """Functional one-shot entry: ``ScoringEngine(cfg, scaler).score(Y, ...)``.

    ``chunk_size`` may be passed alongside the ``score`` kwargs.
    """
    chunk_size = kwargs.pop("chunk_size", DEFAULT_CHUNK)
    engine = ScoringEngine(cfg, scaler, chunk_size=chunk_size)
    return engine.score(Y, **kwargs)
