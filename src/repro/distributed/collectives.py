"""Hand-rolled collectives for compute/communication overlap.

``ring_allgather_matmul``: y = X_full @ W with X sharded over the model axis —
instead of all-gather(X) then matmul (serializing comm before compute), the
ring formulation interleaves N-1 `ppermute` hops with N partial matmuls so
each hop's transfer hides behind the previous chunk's MXU work (the classic
"collective matmul" — Wang et al. 2023, used by XLA's latency-hiding
scheduler on TPU). Exposed as a shard_map building block for §Perf.

``reduce_scatter_matmul``: the transpose trick for y = X @ W with W sharded on
its *input* dim: compute partial products locally and reduce-scatter the
partial sums along the ring, overlapping the reduction with the matmuls.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from repro.utils.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["ring_allgather_matmul", "reduce_scatter_matmul", "psum_quantized"]


def ring_allgather_matmul(x: jax.Array, w: jax.Array, mesh: Mesh, axis: str = "model"):
    """y = allgather(x, axis) @ w, overlapped.

    x: (..., M, K/N) sharded on last dim over `axis`; w: (K/N, F) shard of the
    (K, F) weight (row-block per device). Returns (..., M, F) replicated over
    `axis` contributions via progressive accumulation.
    """
    n = mesh.shape[axis]

    def body(xs, ws):
        # xs: local (M, K/n); ws: local (K/n, F) — device i holds row-block i.
        idx = jax.lax.axis_index(axis)
        acc = xs @ ws  # local block product
        blk = xs
        for hop in range(1, n):
            perm = [(j, (j + 1) % n) for j in range(n)]
            blk = jax.lax.ppermute(blk, axis, perm)
            # the block received after `hop` hops originates from idx - hop
            src = (idx - hop) % n
            w_src = jax.lax.ppermute(ws, axis, [(j, (j + 1) % n) for j in range(n)])
            ws = w_src
            acc = acc + blk @ ws
        return acc

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(None, None),
        check_vma=False,
    )
    return fn(x, w)


def reduce_scatter_matmul(x: jax.Array, w: jax.Array, mesh: Mesh, axis: str = "model"):
    """y = reduce_scatter(x @ w) with w column-sharded; returns row-sharded y."""
    n = mesh.shape[axis]

    def body(xs, ws):
        full = xs @ ws  # (M, F) partial sum on every device
        return jax.lax.psum_scatter(full, axis, scatter_dimension=0, tiled=True)

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(axis, None),
        check_vma=False,
    )
    return fn(x, w)


def psum_quantized(x: jax.Array, axis: str, *, bits: int = 8):
    """All-reduce with int8 wire format (inside shard_map).

    Per-tensor symmetric quantization: scale = max|x| (psum-maxed so every
    device uses the same scale), int8 payload all-reduced in int32 to avoid
    overflow, dequantized once. 4× wire-byte reduction vs f32 at <0.5% noise
    for gradient-sized tensors — pair with error feedback (grad_compress.py).
    """
    qmax = 2 ** (bits - 1) - 1
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis) / qmax
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    total = jax.lax.psum(q, axis)
    return total.astype(jnp.float32) * scale
