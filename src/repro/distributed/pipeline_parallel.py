"""GPipe-style pipeline parallelism over a 'stage' mesh axis (shard_map).

Layers are stacked (L, ...) and split into `n_stages` contiguous groups; the
stage axis holds one group per device row. The forward executes the classic
pipeline schedule: at tick t, stage s processes microbatch t−s and passes
activations to stage s+1 via ``ppermute`` — n_micro + n_stages − 1 ticks,
bubble fraction (S−1)/(M+S−1). Works under jit/grad (the schedule is a
lax.fori-style Python loop over static tick count, all ops batched).

This composes with the existing axes: mesh ("stage", "data", "model") gives
PP × DP × TP. Used by the PP dry-run demo (launch/dryrun_pp.py) and unit
tests; the production 16×16 mesh itself stays DP×TP as assigned.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from repro.utils.compat import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["pipeline_forward", "split_stages"]


def split_stages(layer_params, n_stages: int):
    """(L, ...) stacked layer params → (n_stages, L/n_stages, ...)."""

    def reshape(x):
        L = x.shape[0]
        assert L % n_stages == 0, f"L={L} not divisible by {n_stages} stages"
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    return jax.tree.map(reshape, layer_params)


def pipeline_forward(
    x_micro: jax.Array,
    stage_params,
    layer_fn: Callable,
    mesh: Mesh,
    *,
    axis: str = "stage",
):
    """Run microbatches through pipeline stages.

    x_micro: (n_micro, mb, S, D) microbatched activations (replicated over
    `axis`; each stage consumes/produces via the rotating buffer).
    stage_params: pytree with leading (n_stages, L_per_stage, ...) — sharded
    over `axis` on dim 0.
    layer_fn: (layer_params_slice, x) → x, applied L_per_stage times (scan).

    Returns (n_micro, mb, S, D) outputs (gathered on the last stage and
    broadcast). Pure-JAX GPipe: at each tick every stage runs its scan on its
    current microbatch then ppermutes the result forward.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def stage_fn(xs, params):
        # xs: (n_micro, mb, S, D) full microbatch queue (same on all stages)
        # params: (1, L_per, ...) this stage's layer stack
        params = jax.tree.map(lambda p: p[0], params)
        sid = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros(mb_shape, xs.dtype)  # activation in flight
        outputs = jnp.zeros_like(xs)

        def run_stage(x):
            def body(h, lp):
                return layer_fn(lp, h), None

            h, _ = jax.lax.scan(body, x, params)
            return h

        def tick(t, carry):
            buf, outputs = carry
            # stage 0 ingests microbatch t (if in range)
            feed = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False
            )
            x_in = jnp.where(sid == 0, feed, buf)
            y = run_stage(x_in)
            # last stage emits microbatch t − (S−1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            is_valid = (t - (n_stages - 1) >= 0) & (sid == n_stages - 1)
            outputs = jax.lax.cond(
                is_valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
                lambda o: o,
                outputs,
            )
            # pass activations forward along the ring
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outputs

        buf, outputs = jax.lax.fori_loop(0, ticks, tick, (buf, outputs))
        # broadcast the last stage's outputs to every stage (psum of one-hot)
        has = (sid == n_stages - 1).astype(outputs.dtype)
        outputs = jax.lax.psum(outputs * has, axis)
        return outputs

    in_specs = (P(), P(axis))
    fn = shard_map(
        stage_fn, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False
    )
    return fn(x_micro, stage_params)
