from repro.distributed.sharding import (
    ShardingRules,
    batch_specs,
    default_rules,
    replicated,
    resolve_spec,
    resolve_tree,
)

__all__ = [
    "ShardingRules",
    "batch_specs",
    "default_rules",
    "replicated",
    "resolve_spec",
    "resolve_tree",
]
