"""Logical-axis sharding rules → concrete PartitionSpecs.

Params and caches carry *logical* axis names ('embed', 'heads', 'kv', 'mlp',
'vocab', 'expert', 'lru', 'batch', 'layer', None). A :class:`ShardingRules`
maps logical names to mesh axes; :func:`resolve_spec` drops any assignment
whose dimension is not divisible by the mesh axis size (e.g. MQA's kv=1 head
can't shard over model=16 → replicated), so every arch gets a *valid* spec on
every mesh without per-arch special-casing.

Default strategy (single pod, mesh ('data','model')):
  batch → 'data' | heads/kv/mlp/vocab/expert/lru → 'model' | embed → 'data'
  (FSDP: parameters ZeRO-3-sharded over the data axis, all-gathered by XLA)
Multi-pod mesh ('pod','data','model'): batch → ('pod','data'); parameters
stay sharded within a pod and replicated across pods (pure DP on 'pod').
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.utils.tree import is_spec_leaf as _is_spec

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: dict

    def get(self, name):
        return self.rules.get(name)


def default_rules(mesh: Mesh, *, fsdp: bool = True) -> ShardingRules:
    multi_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules(
        {
            "batch": batch_axes,
            "embed": ("data",) if fsdp else None,
            "heads": ("model",),
            "kv": ("model",),
            "mlp": ("model",),
            "vocab": ("model",),
            "expert": ("model",),
            "lru": ("model",),
            "seq_kv": ("model",),  # only emitted by decode_seq_shard caches
            "state": None,
            "layer": None,
            None: None,
        }
    )


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve_spec(
    logical: tuple, shape: tuple[int, ...], mesh: Mesh, rules: ShardingRules
) -> PartitionSpec:
    """Logical names → PartitionSpec, dropping non-divisible assignments."""
    out = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        axes = rules.get(name)
        if axes is None:
            out.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if a in mesh.axis_names and a not in used)
        if not axes or dim % _axis_size(mesh, axes) != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    return PartitionSpec(*out)


def resolve_tree(
    specs: PyTree, shapes: PyTree, mesh: Mesh, rules: ShardingRules
) -> PyTree:
    """Map (logical-spec tree, array/ShapeDtypeStruct tree) → NamedSharding tree."""

    def one(spec, arr):
        ps = resolve_spec(tuple(spec), tuple(arr.shape), mesh, rules)
        return NamedSharding(mesh, ps)

    return jax.tree.map(one, specs, shapes, is_leaf=_is_spec)


def batch_specs(batch_shapes: dict, mesh: Mesh, rules: ShardingRules) -> dict:
    """Input batch shardings: leading dim = batch, rest replicated."""
    out = {}
    for k, v in batch_shapes.items():
        nd = len(v.shape)
        logical = ("batch",) + (None,) * (nd - 1) if nd else ()
        out[k] = NamedSharding(mesh, resolve_spec(logical, v.shape, mesh, rules))
    return out


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


# ---------------------------------------------------------------------------
# Activation-sharding context: lets model code hint GSPMD with logical names
# without holding a mesh reference. Disabled (identity) unless a launcher
# calls ``set_activation_axes`` — tests and host-scale runs are unaffected.
# ---------------------------------------------------------------------------

_ACT: dict = {"enabled": False, "batch": ("data",), "model": ("model",)}


def set_activation_axes(*, batch=("data",), model=("model",), enabled=True):
    _ACT.update(batch=tuple(batch), model=tuple(model), enabled=enabled)


def activation_axes_enabled() -> bool:
    return _ACT["enabled"]


def act_spec(*names) -> PartitionSpec:
    """names ∈ {'batch', 'model', None} → PartitionSpec under current axes."""
    out = []
    for n in names:
        if n is None:
            out.append(None)
        else:
            axes = _ACT[n]
            out.append(axes if len(axes) > 1 else axes[0])
    return PartitionSpec(*out)


def constrain(x, *names):
    """with_sharding_constraint by logical names (no-op when disabled)."""
    if not _ACT["enabled"]:
        return x
    return jax.lax.with_sharding_constraint(x, act_spec(*names))
