"""Gradient compression with error feedback (distributed-optimization trick).

``compressed_dp_gradients``: explicit-DP gradient averaging where each
all-reduce ships int8 (or top-k sparsified) payloads; the quantization
residual is carried in an error-feedback buffer so the *accumulated* update
is unbiased (Karimireddy et al. 2019). Used by the shard_map DP trainer
variant and benchmarked in §Perf for the collective-bound cell.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from repro.utils.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.collectives import psum_quantized

PyTree = Any

__all__ = ["init_error_state", "compress_and_average", "topk_sparsify"]


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def topk_sparsify(g: jax.Array, frac: float = 0.01) -> jax.Array:
    """Keep the top `frac` fraction of entries by magnitude (rest zeroed)."""
    flat = g.reshape(-1)
    k = max(int(flat.shape[0] * frac), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress_and_average(
    grads: PyTree,
    error: PyTree,
    mesh: Mesh,
    axis: str = "data",
    *,
    bits: int = 8,
) -> tuple[PyTree, PyTree]:
    """(avg_grads, new_error): int8 all-reduce with error feedback.

    grads are data-parallel replicas (same shape per device, different
    values); returns the averaged gradient and the updated residual buffer.
    Must be called inside a shard_map over `axis`, or use the convenience
    wrapper below for replicated inputs.
    """
    n = mesh.shape[axis]

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        avg = psum_quantized(corrected, axis, bits=bits) / n
        # error = what we intended to send minus what the wire carried
        qmax = 2 ** (bits - 1) - 1
        scale = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis) / qmax
        scale = jnp.maximum(scale, 1e-12)
        sent = jnp.clip(jnp.round(corrected / scale), -qmax, qmax) * scale
        return avg, corrected - sent

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    avg = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return avg, new_err
