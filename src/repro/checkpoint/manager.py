"""Fault-tolerant checkpointing: atomic, keep-k, optional async.

Layout:  <dir>/step_<N>/  — one .npy per leaf (keypath-encoded filename) +
``manifest.json`` (treedef, shapes, dtypes). Writes go to ``step_<N>.tmp``
(leaves and manifest fsynced, then the directory entries) and are atomically
renamed, so a crash — or power loss — mid-save never corrupts the latest
restorable step: a torn ``step_N.tmp`` is invisible to ``latest_step()`` /
``restore()`` and is reclaimed by the next save's GC. This is the core
requirement for restart-after-node-failure.

On a multi-host cluster each host writes only its addressable shards under
``host_<i>/`` (shard layout recorded in the manifest); in this container
there is one host, which degenerates to full arrays. Restore validates the
manifest and rebuilds the pytree.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

from repro.ft.config import maybe_inject

PyTree = Any

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _fsync_dir(path: str) -> None:
    """fsync a directory entry so renames/creates inside it are durable.

    Best-effort: some filesystems refuse O_RDONLY fsync on directories."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _to_host(x) -> np.ndarray:
    """Device→host transfer that also handles non-fully-addressable arrays
    (multi-process meshes), where ``np.asarray`` would raise."""
    if getattr(x, "is_fully_addressable", True):
        return np.asarray(x)
    from repro.core.distributed_coreset import host_gather

    return host_gather(x)


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return _SAFE.sub("_", ".".join(parts)) or "leaf"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save

    def save(self, step: int, state: PyTree, *, block: bool = True) -> str:
        """Save a pytree; atomic rename at the end. Returns the final path."""
        self.wait()  # one in-flight async save at a time
        host_state = jax.tree.map(_to_host, state)
        final = os.path.join(self.directory, f"step_{step:08d}")
        if jax.process_count() > 1 and jax.process_index() != 0:
            # host_gather above is collective; only process 0 touches disk
            # (shared checkpoint dir — concurrent renames would race)
            return final

        def _write():
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            leaves = jax.tree_util.tree_flatten_with_path(host_state)[0]
            manifest = {"step": step, "leaves": []}
            for path, leaf in leaves:
                name = _leaf_name(path)
                # disambiguate collisions deterministically
                base, i = name, 0
                existing = {e["name"] for e in manifest["leaves"]}
                while name in existing:
                    i += 1
                    name = f"{base}__{i}"
                with open(os.path.join(tmp, name + ".npy"), "wb") as f:
                    np.save(f, leaf)
                    f.flush()
                    os.fsync(f.fileno())
                manifest["leaves"].append(
                    {"name": name, "shape": list(np.shape(leaf)), "dtype": str(np.asarray(leaf).dtype)}
                )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            maybe_inject("checkpoint", step)  # torn write: fully built tmp, no rename
            _fsync_dir(tmp)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            _fsync_dir(self.directory)
            self._gc()
            return final

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
            return os.path.join(self.directory, f"step_{step:08d}")
        return _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
        # leftover .tmp dirs from a crash mid-save: never restorable (restore
        # only reads committed step_N dirs), only reclaimable — our own tmp
        # has already been renamed by the time _gc runs
        for d in os.listdir(self.directory):
            if re.fullmatch(r"step_\d+\.tmp", d):
                shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.directory, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: PyTree, step: int | None = None) -> PyTree:
        """Restore into the structure of `target` (shapes/dtypes validated)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = [np.load(os.path.join(d, e["name"] + ".npy")) for e in manifest["leaves"]]
        leaves, treedef = jax.tree.flatten(target)
        if len(leaves) != len(arrays):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, target has {len(leaves)}"
            )
        for tgt, arr in zip(leaves, arrays):
            if tuple(np.shape(tgt)) != tuple(arr.shape):
                raise ValueError(f"shape mismatch: {np.shape(tgt)} vs {arr.shape}")
        return jax.tree.unflatten(treedef, arrays)

    def restore_flat(self, step: int | None = None) -> dict[str, np.ndarray]:
        """Restore a checkpoint as ``{leaf_name: array}`` without a template.

        ``restore`` validates shapes against a fixed-shape target, which a
        caller whose state is ragged (the streaming maintainer's bucket sets
        grow and shrink between windows) cannot supply ahead of time. This
        reads the manifest's leaf names back directly; the caller interprets
        the names. Only flat dict states round-trip by name — nested pytrees
        keep their keypath-encoded names.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        return {
            e["name"]: np.load(os.path.join(d, e["name"] + ".npy"))
            for e in manifest["leaves"]
        }
