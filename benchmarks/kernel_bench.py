"""Kernel-path micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (not
representative of TPU wall time), so the timed numbers here are the XLA-CPU
oracle paths — used to sanity-track the compute shapes. Kernel↔oracle
numerical agreement is asserted in tests/test_kernels.py; TPU timings come
from the roofline model (§Roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.bernstein import bernstein_design, bernstein_deriv_design
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gram.ref import gram_ref
from repro.kernels.ssd.ref import ssd_ref


def run():
    rng = np.random.default_rng(0)

    # bernstein basis path at coreset-scoring scale
    t = jnp.asarray(rng.random(200_000), jnp.float32)
    f = jax.jit(lambda t: (bernstein_design(t, 6), bernstein_deriv_design(t, 6)))
    f(t)  # compile
    us = time_call(f, t)
    emit("kernel/bernstein_ref/n200k_d7", us, f"{200_000 * 14 / (us / 1e6) / 1e9:.2f} Gelem/s")

    # gram at leverage scale
    X = jnp.asarray(rng.standard_normal((100_000, 70)), jnp.float32)
    g = jax.jit(gram_ref)
    g(X)
    us = time_call(g, X)
    emit("kernel/gram_ref/100kx70", us, f"{2 * 100_000 * 70 * 70 / (us / 1e6) / 1e9:.1f} GFLOP/s")

    # attention at test scale
    q = jnp.asarray(rng.standard_normal((8, 512, 64)), jnp.bfloat16)
    a = jax.jit(lambda q: attention_ref(q, q, q))
    a(q)
    us = time_call(a, q)
    emit("kernel/attention_ref/8x512x64", us, "oracle path")

    # ssd at test scale
    BH, T, P, N = 16, 512, 64, 32
    x = jnp.asarray(rng.standard_normal((BH, T, P)), jnp.float32)
    dt = jnp.asarray(rng.random((BH, T, 1)) * 0.5 + 0.01, jnp.float32)
    A = jnp.asarray(-rng.random((BH, 1)) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((BH, T, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((BH, T, N)), jnp.float32)
    s = jax.jit(ssd_ref)
    s(x, dt, A, Bm, Cm)
    us = time_call(s, x, dt, A, Bm, Cm)
    emit("kernel/ssd_ref/16x512", us, "oracle sequential scan")


def main():
    run()


if __name__ == "__main__":
    main()
